//! In-tree stand-in for the vendored `xla` (PJRT) bindings.
//!
//! The real serving path loads AOT HLO artifacts through PJRT. That
//! closure is not vendorable in this build (the crate's only external
//! dependency is `anyhow`), so this module keeps the exact API surface
//! the [`crate::runtime`] layer consumes while *gating* execution:
//!
//! * [`Literal`] is a real host-side container (shape + f32/i32 data) —
//!   constructing, reshaping and reading literals all work.
//! * [`HloModuleProto::from_text_file`] performs a lightweight sanity
//!   probe of HLO text (the file must exist and contain `HloModule`).
//! * [`PjRtClient::cpu`] returns an error: without the real bindings no
//!   artifact can be compiled or executed. `Runtime::load` therefore
//!   fails cleanly and every artifact-dependent test/bench/example skips
//!   with a notice, while the pure-rust attention substrate (and the
//!   coordinator's CPU-substrate serving path) keep working.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/src/lib.rs` (point `xla` at the extern crate instead).

use std::fmt;

/// Error type mirroring the binding crate's; converts into
/// [`anyhow::Error`] at the `runtime` boundary via `std::error::Error`.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

type XlaResult<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> XlaResult<T> {
    Err(XlaError(format!(
        "{what} is unavailable in this build (in-tree stub; the real PJRT \
         bindings are not vendored — see README.md §Runtime)"
    )))
}

/// Element types crossing the AOT boundary (subset the manifest allows,
/// plus the common extras so matches stay non-exhaustive-friendly).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

/// Typed payload of a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Host values storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    fn element_type() -> ElementType;
    fn wrap(v: Vec<Self>) -> LiteralData;
    fn read(d: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }

    fn read(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            LiteralData::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }

    fn read(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            LiteralData::F32(_) => None,
        }
    }
}

/// Dims + element type of an array-shaped literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side XLA literal: array (shape + data) or tuple of literals.
#[derive(Debug, Clone)]
pub enum Literal {
    Array { dims: Vec<i64>, data: LiteralData },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal::Array { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Reshape (element count must be preserved).
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        match self {
            Literal::Array { data, dims: old } => {
                let count: i64 = old.iter().product();
                let want: i64 = dims.iter().product();
                if count != want {
                    return Err(XlaError(format!(
                        "reshape {old:?} -> {dims:?}: element count {count} != {want}"
                    )));
                }
                Ok(Literal::Array { dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => Err(XlaError("cannot reshape a tuple literal".into())),
        }
    }

    pub fn array_shape(&self) -> XlaResult<ArrayShape> {
        match self {
            Literal::Array { dims, data } => Ok(ArrayShape {
                dims: dims.clone(),
                ty: match data {
                    LiteralData::F32(_) => ElementType::F32,
                    LiteralData::I32(_) => ElementType::S32,
                },
            }),
            Literal::Tuple(_) => Err(XlaError("tuple literal has no array shape".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::read(data)
                .ok_or_else(|| XlaError("literal element type mismatch".into())),
            Literal::Tuple(_) => Err(XlaError("tuple literal has no flat data".into())),
        }
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        match self {
            Literal::Tuple(items) => Ok(items.clone()),
            Literal::Array { .. } => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

/// Parsed (here: sanity-probed) HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Probe an HLO text artifact: the file must be readable UTF-8 and
    /// declare an `HloModule`. Full parsing needs the real bindings.
    pub fn from_text_file(path: &str) -> XlaResult<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(XlaError(format!("{path}: no HloModule declaration found")));
        }
        Ok(HloModuleProto { text })
    }
}

/// Opaque computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// Device buffer handle returned by an execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable handle. Never constructible through the stub
/// (compilation errors out), but the type keeps `runtime` compiling.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        unavailable("artifact execution")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub build: there is no PJRT runtime to bind.
    pub fn cpu() -> XlaResult<PjRtClient> {
        unavailable("the PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        unavailable("XLA compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());

        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.array_shape().unwrap().ty(), ElementType::S32);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
    }

    #[test]
    fn reshape_validates_element_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_decomposes() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let items = t.to_tuple().unwrap();
        assert_eq!(items.len(), 2);
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn client_is_gated() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
    }

    #[test]
    fn hlo_probe_requires_module_text() {
        let dir = std::env::temp_dir();
        let good = dir.join("fm_stub_good.hlo.txt");
        let bad = dir.join("fm_stub_bad.hlo.txt");
        std::fs::write(&good, "HloModule m\nENTRY e { ROOT c = f32[] constant(0) }").unwrap();
        std::fs::write(&bad, "not hlo at all").unwrap();
        assert!(HloModuleProto::from_text_file(good.to_str().unwrap()).is_ok());
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
