//! Monte-Carlo simulation of the Appendix-A generative model.
//!
//! Per trial: n blocks of B keys. Dot products q·k are drawn directly as
//! Gaussians with variance σ² = 1/d (normalized vectors in high
//! dimension): noise keys mean μ_noise, the signal key mean μ_signal,
//! and m−1 clustered keys mean μ_cluster inside the signal block. The
//! router score of a block is the mean of its keys' dot products
//! (centroid linearity); retrieval succeeds when the signal block ranks
//! in the top-k.
//!
//! Validates Eq. 1–3 / the Φ(−SNR) failure law, and generates the
//! RULER-shaped retrieval curves at paper-scale block counts.

use crate::attention::testutil::Rng;
use crate::snr::theory;

#[derive(Debug, Clone, Copy)]
pub struct McConfig {
    pub d: usize,
    pub block: usize,
    pub n_blocks: usize,
    pub topk: usize,
    /// E[q·k*] − E[q·k_noise]
    pub delta_mu: f64,
    /// number of clustered signal tokens in the target block (≥1)
    pub m: usize,
    /// E[q·k_cluster] − E[q·k_noise] for the m−1 clustered tokens
    pub cluster_gain: f64,
    pub trials: usize,
    pub seed: u64,
}

impl Default for McConfig {
    fn default() -> Self {
        Self {
            d: 64,
            block: 128,
            n_blocks: 64,
            topk: 8,
            delta_mu: 1.0,
            m: 1,
            cluster_gain: 0.0,
            trials: 2000,
            seed: 0x5eed,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct McResult {
    /// empirical P(signal block in top-k)
    pub success_rate: f64,
    /// empirical P(one given noise block outranks signal block)
    pub pairwise_fail: f64,
    /// closed-form prediction for the same quantities
    pub predicted_success: f64,
    pub predicted_pairwise_fail: f64,
    pub snr: f64,
}

/// Run the simulation.
pub fn simulate_retrieval(cfg: McConfig) -> McResult {
    assert!(cfg.m >= 1 && cfg.m <= cfg.block);
    let mut rng = Rng::new(cfg.seed);
    let sigma = 1.0 / (cfg.d as f64).sqrt();
    let inv_b = 1.0 / cfg.block as f64;

    let mut successes = 0usize;
    let mut pair_fails = 0usize;
    let mut pair_total = 0usize;

    for _ in 0..cfg.trials {
        // noise block scores: mean of B iid N(0, sigma^2) => N(0, sigma^2/B)
        let block_sigma = sigma * inv_b.sqrt();
        let mut noise_scores = Vec::with_capacity(cfg.n_blocks - 1);
        for _ in 0..cfg.n_blocks - 1 {
            noise_scores.push(rng.normal() * block_sigma);
        }
        // signal block: 1 signal key + (m-1) cluster keys + (B-m) noise keys
        let mut sum = cfg.delta_mu + rng.normal() * sigma; // signal key
        for _ in 0..cfg.m - 1 {
            sum += cfg.cluster_gain + rng.normal() * sigma;
        }
        for _ in 0..cfg.block - cfg.m {
            sum += rng.normal() * sigma;
        }
        let signal_score = sum * inv_b;

        let beaten = noise_scores.iter().filter(|&&s| s > signal_score).count();
        if beaten < cfg.topk {
            successes += 1;
        }
        pair_fails += beaten;
        pair_total += noise_scores.len();
    }

    let dmu_eff = theory::delta_mu_eff(cfg.delta_mu, cfg.m, cfg.cluster_gain, 0.0);
    let snr = theory::snr(dmu_eff, cfg.d, cfg.block);
    McResult {
        success_rate: successes as f64 / cfg.trials as f64,
        pairwise_fail: pair_fails as f64 / pair_total as f64,
        predicted_success: theory::topk_success_prob(snr, cfg.n_blocks, cfg.topk),
        predicted_pairwise_fail: theory::p_fail(snr),
        snr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ci_halfwidth(p: f64, n: usize) -> f64 {
        // ~4σ binomial half-width
        4.0 * (p * (1.0 - p) / n as f64).sqrt() + 0.01
    }

    #[test]
    fn pairwise_failure_matches_phi_minus_snr() {
        for (d, b) in [(64, 64), (64, 256), (128, 128)] {
            let cfg = McConfig { d, block: b, trials: 4000, ..Default::default() };
            let r = simulate_retrieval(cfg);
            let tol = ci_halfwidth(r.predicted_pairwise_fail, cfg.trials * (cfg.n_blocks - 1));
            assert!(
                (r.pairwise_fail - r.predicted_pairwise_fail).abs() < tol,
                "d={d} B={b}: mc={} theory={} tol={tol}",
                r.pairwise_fail,
                r.predicted_pairwise_fail
            );
        }
    }

    #[test]
    fn topk_success_matches_theory() {
        let cfg = McConfig { trials: 3000, ..Default::default() };
        let r = simulate_retrieval(cfg);
        let tol = ci_halfwidth(r.predicted_success, cfg.trials);
        assert!(
            (r.success_rate - r.predicted_success).abs() < tol,
            "mc={} theory={}",
            r.success_rate,
            r.predicted_success
        );
    }

    #[test]
    fn smaller_blocks_retrieve_better() {
        // the paper's headline: B 512 -> 128 at fixed kB improves retrieval
        let base = McConfig { delta_mu: 0.6, trials: 3000, ..Default::default() };
        let r512 = simulate_retrieval(McConfig { block: 512, topk: 2, n_blocks: 16, ..base });
        let r128 = simulate_retrieval(McConfig { block: 128, topk: 8, n_blocks: 64, ..base });
        assert!(
            r128.success_rate > r512.success_rate + 0.05,
            "B=128: {} vs B=512: {}",
            r128.success_rate,
            r512.success_rate
        );
    }

    #[test]
    fn clustering_helps() {
        let base = McConfig { delta_mu: 0.4, trials: 3000, n_blocks: 128, ..Default::default() };
        let plain = simulate_retrieval(base);
        let clustered = simulate_retrieval(McConfig { m: 4, cluster_gain: 0.3, ..base });
        assert!(clustered.success_rate > plain.success_rate, "{} vs {}",
            clustered.success_rate, plain.success_rate);
        assert!(clustered.snr > plain.snr);
    }
}
