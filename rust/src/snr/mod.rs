//! The paper's statistical model of MoBA block selection (§3, App. A).
//!
//! * [`theory`] — closed forms: SNR = Δμ_eff · √(d / 2B), failure
//!   probability p = Φ(−SNR), top-k retrieval probability among n blocks.
//! * [`montecarlo`] — direct simulation of the Appendix-A generative
//!   model, used to validate the closed forms (Eq. 1–3) and to extend
//!   the RULER-style retrieval predictions to paper-scale block counts
//!   (64K-token-equivalent) that the CPU testbed cannot train at.
//! * [`autotune`] — the model applied: per-KV-head `(block, topk)`
//!   selection (or dense fallback) against a recall target, emitting a
//!   loadable `RoutePlan` (the `flash-moba autotune` CLI).

pub mod autotune;
pub mod montecarlo;
pub mod theory;

pub use autotune::{autotune, AutotuneConfig, AutotuneOutcome, HeadReport};
pub use montecarlo::{simulate_retrieval, McConfig, McResult};
pub use theory::{
    delta_mu_eff, normal_cdf, normal_icdf, p_fail, snr, topk_success_prob,
};
