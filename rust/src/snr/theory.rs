//! Closed-form SNR model (paper Eq. 1–3 and Appendix A).
//!
//! Score difference between the signal block and a noise block:
//!   E[D]   = Δμ_eff / B
//!   Var(D) = 2σ² / B          with σ² = 1/d for normalized vectors
//!   SNR    = Δμ_eff · √(d / 2B)
//!   p_fail = Φ(−SNR)           (one noise block outranking the signal)

/// Effective signal separation (App. A.2):
/// Δμ_eff = Δμ + (m−1)(μ_cluster − μ_noise).
pub fn delta_mu_eff(delta_mu: f64, m: usize, mu_cluster: f64, mu_noise: f64) -> f64 {
    delta_mu + (m.saturating_sub(1)) as f64 * (mu_cluster - mu_noise)
}

/// SNR = Δμ_eff · √(d / 2B)  (Eq. 3).
pub fn snr(delta_mu_eff: f64, d: usize, block: usize) -> f64 {
    delta_mu_eff * (d as f64 / (2.0 * block as f64)).sqrt()
}

/// Probability a single noise block outranks the signal block (Eq. 12).
pub fn p_fail(snr_value: f64) -> f64 {
    normal_cdf(-snr_value)
}

/// P(signal block ranks in the top-k among `n_blocks` candidates).
///
/// Outranking events are *correlated* through the shared signal score, so
/// a plain Binomial(n−1, p_fail) underestimates success. Conditioning on
/// the standardized signal score z (noise blocks are then independent):
///
///   P(success) = ∫ φ(z) · BinomCDF(k−1; n−1, Φ(−(√2·SNR + z))) dz
///
/// using μ_s/σ_b = √2·SNR (Var(D) = 2σ_b² in the paper's Eq. 2).
/// Evaluated by trapezoid quadrature over z ∈ [−8, 8].
pub fn topk_success_prob(snr_value: f64, n_blocks: usize, k: usize) -> f64 {
    if n_blocks <= k {
        return 1.0;
    }
    let n = n_blocks - 1;
    let steps = 241usize;
    let (lo, hi) = (-8.0f64, 8.0f64);
    let h = (hi - lo) / (steps - 1) as f64;
    let mut total = 0.0f64;
    for i in 0..steps {
        let z = lo + i as f64 * h;
        let phi = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
        let p = normal_cdf(-(std::f64::consts::SQRT_2 * snr_value + z));
        let mut cdf = 0.0f64;
        for x in 0..k.min(n + 1) {
            cdf += binom_pmf(n, x, p);
        }
        let w = if i == 0 || i == steps - 1 { 0.5 } else { 1.0 };
        total += w * phi * cdf.min(1.0);
    }
    (total * h).clamp(0.0, 1.0)
}

fn binom_pmf(n: usize, x: usize, p: f64) -> f64 {
    if p <= 0.0 {
        return if x == 0 { 1.0 } else { 0.0 };
    }
    if p >= 1.0 {
        return if x == n { 1.0 } else { 0.0 };
    }
    let logc = ln_choose(n, x);
    (logc + x as f64 * p.ln() + (n - x) as f64 * (1.0 - p).ln()).exp()
}

fn ln_choose(n: usize, x: usize) -> f64 {
    ln_factorial(n) - ln_factorial(x) - ln_factorial(n - x)
}

fn ln_factorial(n: usize) -> f64 {
    // Stirling for large n, exact for small
    if n < 32 {
        (2..=n).map(|i| (i as f64).ln()).sum()
    } else {
        let nf = n as f64;
        nf * nf.ln() - nf + 0.5 * (2.0 * std::f64::consts::PI * nf).ln() + 1.0 / (12.0 * nf)
    }
}

/// Standard normal CDF Φ (Abramowitz–Stegun 7.1.26-based erf, |ε| < 1.5e-7).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Inverse standard normal CDF (Acklam's rational approximation).
///
/// Edge behavior follows the mathematical limits instead of panicking:
/// `p <= 0` maps to `-inf`, `p >= 1` to `+inf`, and NaN propagates —
/// callers probing degenerate targets (e.g. the autotuner at
/// `target_recall = 1.0`) get a comparable sentinel, not an abort.
pub fn normal_icdf(p: f64) -> f64 {
    if p.is_nan() {
        return f64::NAN;
    }
    if p <= 0.0 {
        return f64::NEG_INFINITY;
    }
    if p >= 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        return (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0);
    }
    if p > 1.0 - plow {
        return -normal_icdf(1.0 - p);
    }
    let q = p - 0.5;
    let r = q * q;
    (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
        / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snr_scales_sqrt_d_over_b() {
        // halving B improves SNR by sqrt(2) (paper §3.3 point 1)
        let s1 = snr(1.0, 64, 128);
        let s2 = snr(1.0, 64, 64);
        assert!((s2 / s1 - std::f64::consts::SQRT_2).abs() < 1e-12);
        // doubling d same effect
        let s3 = snr(1.0, 128, 128);
        assert!((s3 / s1 - std::f64::consts::SQRT_2).abs() < 1e-12);
    }

    #[test]
    fn clustering_multiplies_signal() {
        // m related tokens raise delta_mu_eff linearly (§3.3 point 2)
        let base = delta_mu_eff(0.5, 1, 0.3, 0.0);
        assert_eq!(base, 0.5);
        let clustered = delta_mu_eff(0.5, 4, 0.3, 0.0);
        assert!((clustered - (0.5 + 3.0 * 0.3)).abs() < 1e-12);
    }

    #[test]
    fn cdf_basics() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!(normal_cdf(-8.0) < 1e-14);
        assert!((p_fail(0.0) - 0.5).abs() < 1e-7); // erf approx, not exact
        assert!(p_fail(3.0) < 0.0014);
    }

    #[test]
    fn icdf_inverts_cdf() {
        for p in [0.001, 0.01, 0.1, 0.5, 0.9, 0.99, 0.999] {
            let x = normal_icdf(p);
            assert!((normal_cdf(x) - p).abs() < 1e-4, "p={p}");
        }
    }

    #[test]
    fn icdf_edge_cases_saturate_instead_of_panicking() {
        assert_eq!(normal_icdf(0.0), f64::NEG_INFINITY);
        assert_eq!(normal_icdf(-0.5), f64::NEG_INFINITY);
        assert_eq!(normal_icdf(1.0), f64::INFINITY);
        assert_eq!(normal_icdf(1.5), f64::INFINITY);
        assert!(normal_icdf(f64::NAN).is_nan());
        // interior values are untouched by the clamping
        assert!((normal_icdf(0.5)).abs() < 1e-9);
        // and the saturation is consistent with the CDF limits
        assert_eq!(normal_cdf(f64::NEG_INFINITY), 0.0);
        assert_eq!(normal_cdf(f64::INFINITY), 1.0);
    }

    #[test]
    fn topk_success_monotone_in_snr_and_k() {
        let a = topk_success_prob(1.0, 64, 2);
        let b = topk_success_prob(2.0, 64, 2);
        assert!(b > a);
        let c = topk_success_prob(1.0, 64, 8);
        assert!(c > a);
        // trivially successful when every block fits in top-k
        assert_eq!(topk_success_prob(0.0, 4, 8), 1.0);
    }

    #[test]
    fn paper_reliability_criterion() {
        // "for reliable top-k retrieval we need p < k/n, i.e.
        //  SNR > Phi^{-1}(1 - k/n)" — check the two formulations agree.
        let (n, k) = (64usize, 8usize);
        let thresh = normal_icdf(1.0 - k as f64 / n as f64);
        // just above the threshold, success probability should be decent
        let p_ok = topk_success_prob(thresh + 1.0, n, k);
        let p_bad = topk_success_prob(thresh - 1.5, n, k);
        assert!(p_ok > 0.85, "p_ok={p_ok}");
        assert!(p_bad < 0.4, "p_bad={p_bad}");
        // and the heuristic threshold itself sits in the transition zone
        let p_at = topk_success_prob(thresh, n, k);
        assert!(p_at > 0.2 && p_at < 0.95, "p_at={p_at}");
    }

    #[test]
    fn binom_pmf_sums_to_one() {
        let total: f64 = (0..=20).map(|x| binom_pmf(20, x, 0.3)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
