//! Offline SNR-driven routing autotuner: pick each KV head's
//! `(block, topk)` — or a dense fallback — from the closed-form
//! retrieval model, and emit a [`RoutePlan`] the serving coordinator
//! can load.
//!
//! The paper's Eq. 3 (SNR = Δμ_eff · √(d/2B)) plus the conditioned
//! top-k success integral ([`topk_success_prob`]) turn a head's signal
//! separation Δμ_eff into a predicted retrieval recall for any
//! `(block, topk)` geometry. The tuner searches a candidate grid per
//! head for the *cheapest* geometry (lowest attended density) whose
//! predicted recall clears `target_recall`; heads whose signal is too
//! weak for every candidate degrade to [`HeadPlan::dense`] — routing a
//! head the model says will mis-retrieve is worse than paying the
//! dense cost.
//!
//! Everything here is deterministic closed-form arithmetic: the same
//! config always produces the same plan (no RNG, no timing), so the
//! emitted JSON is reproducible and diffable in CI.

use crate::attention::plan::{HeadPlan, RoutePlan};
use crate::snr::theory::{snr, topk_success_prob};
use crate::util::json::Json;

/// Search space and targets for one autotune run.
#[derive(Debug, Clone)]
pub struct AutotuneConfig {
    /// head dimension
    pub d: usize,
    /// sequence length the plan is tuned for
    pub n: usize,
    /// KV heads to plan (query heads in a GQA group share the plan)
    pub h_kv: usize,
    /// minimum acceptable predicted top-k retrieval probability
    pub target_recall: f64,
    /// maximum attended fraction of the sequence for a routed head;
    /// geometries denser than this are never picked over dense
    pub max_density: f64,
    /// candidate block sizes
    pub blocks: Vec<usize>,
    /// candidate top-k values
    pub topks: Vec<usize>,
    /// per-head effective signal separation Δμ_eff (measured offline);
    /// empty = the deterministic synthetic spread of
    /// [`AutotuneConfig::synthetic_delta_mu`]
    pub head_delta_mu: Vec<f64>,
    /// runtime margin-fallback threshold stamped into the plan
    /// (`-inf` disables the probe)
    pub fallback_margin: f64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        Self {
            d: 64,
            n: 2048,
            h_kv: 4,
            target_recall: 0.95,
            max_density: 0.5,
            blocks: vec![16, 32, 64, 128],
            topks: vec![1, 2, 4, 8, 16],
            head_delta_mu: Vec::new(),
            fallback_margin: f64::NEG_INFINITY,
        }
    }
}

impl AutotuneConfig {
    /// The deterministic Δμ_eff spread used when no per-head
    /// measurements are supplied: heads fan out linearly from strong
    /// separation down to nearly none, so a default run exercises the
    /// whole decision range (small-block routing → large top-k →
    /// dense fallback).
    pub fn synthetic_delta_mu(&self) -> Vec<f64> {
        let h = self.h_kv.max(1);
        (0..h)
            .map(|i| {
                if h == 1 {
                    1.0
                } else {
                    // head 0: 1.6 (easily routed) ... head h-1: 0.02
                    1.6 - (1.6 - 0.02) * i as f64 / (h - 1) as f64
                }
            })
            .collect()
    }

    /// Per-head Δμ_eff this run tunes against (supplied or synthetic).
    pub fn effective_delta_mu(&self) -> Vec<f64> {
        if self.head_delta_mu.is_empty() {
            self.synthetic_delta_mu()
        } else {
            assert_eq!(
                self.head_delta_mu.len(),
                self.h_kv,
                "need one delta_mu per KV head"
            );
            self.head_delta_mu.clone()
        }
    }
}

/// One head's tuning decision plus the model quantities behind it.
#[derive(Debug, Clone, Copy)]
pub struct HeadReport {
    pub head: usize,
    /// the Δμ_eff this head was tuned against
    pub delta_mu: f64,
    /// chosen geometry (mode [`HeadMode::Dense`] when no candidate met
    /// the recall target)
    ///
    /// [`HeadMode::Dense`]: crate::attention::plan::HeadMode::Dense
    pub plan: HeadPlan,
    /// Eq.-3 SNR at the chosen block (0 for dense heads)
    pub snr: f64,
    /// predicted top-k retrieval probability (1 for dense heads)
    pub recall: f64,
    /// attended fraction of the sequence (1 for dense heads)
    pub density: f64,
}

/// An autotune run's full result: the loadable plan plus per-head
/// diagnostics.
#[derive(Debug, Clone)]
pub struct AutotuneOutcome {
    pub plan: RoutePlan,
    pub rows: Vec<HeadReport>,
}

impl AutotuneOutcome {
    /// Per-head diagnostic rows as JSON (the `BENCH_`-style report
    /// blob; the plan itself serializes via [`RoutePlan::to_json`]).
    pub fn report_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("head", Json::from(r.head)),
                        ("delta_mu", Json::from(r.delta_mu)),
                        ("block", Json::from(r.plan.block)),
                        ("topk", Json::from(r.plan.topk)),
                        ("dense", Json::from(r.plan.is_dense())),
                        ("snr", Json::from(r.snr)),
                        ("recall", Json::from(r.recall)),
                        ("density", Json::from(r.density)),
                    ])
                })
                .collect(),
        )
    }
}

/// Attended fraction of an `n`-token sequence for a routed head:
/// `topk` selected blocks plus the always-attended own block.
fn routed_density(n: usize, block: usize, topk: usize) -> f64 {
    (((topk + 1) * block) as f64 / n.max(1) as f64).min(1.0)
}

/// Tune one head: cheapest `(block, topk)` meeting the recall target,
/// else dense. Ties in density break deterministically toward the
/// earlier candidate in the config's `blocks` × `topks` order.
fn tune_head(cfg: &AutotuneConfig, head: usize, delta_mu: f64) -> HeadReport {
    let mut best: Option<HeadReport> = None;
    for &block in &cfg.blocks {
        if block == 0 || block > cfg.n {
            continue;
        }
        let n_blocks = cfg.n / block;
        let s = snr(delta_mu, cfg.d, block);
        for &topk in &cfg.topks {
            if topk == 0 {
                continue;
            }
            let density = routed_density(cfg.n, block, topk);
            if density > cfg.max_density {
                continue;
            }
            let recall = topk_success_prob(s, n_blocks, topk);
            if recall < cfg.target_recall {
                continue;
            }
            let cand = HeadReport {
                head,
                delta_mu,
                plan: HeadPlan::routed(block, topk),
                snr: s,
                recall,
                density,
            };
            let better = match &best {
                Some(b) => density < b.density,
                None => true,
            };
            if better {
                best = Some(cand);
            }
        }
    }
    best.unwrap_or_else(|| {
        // no candidate retrieves reliably enough: dense fallback, with
        // the largest candidate block sizing the decode cache's
        // centroid accounting (fewest centroid rows)
        let block = cfg.blocks.iter().copied().max().unwrap_or(64).min(cfg.n.max(1));
        HeadReport {
            head,
            delta_mu,
            plan: HeadPlan::dense(block),
            snr: 0.0,
            recall: 1.0,
            density: 1.0,
        }
    })
}

/// Run the tuner over every KV head.
pub fn autotune(cfg: &AutotuneConfig) -> AutotuneOutcome {
    assert!(cfg.h_kv >= 1, "autotune needs h_kv >= 1");
    assert!(!cfg.blocks.is_empty(), "autotune needs candidate blocks");
    let mus = cfg.effective_delta_mu();
    let rows: Vec<HeadReport> =
        mus.iter().enumerate().map(|(i, &mu)| tune_head(cfg, i, mu)).collect();
    let plan = RoutePlan {
        heads: rows.iter().map(|r| r.plan).collect(),
        fallback_margin: cfg.fallback_margin as f32,
        kv_dtype: None,
    };
    debug_assert!(plan.validate(cfg.n).is_ok());
    AutotuneOutcome { plan, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::plan::HeadMode;

    #[test]
    fn strong_heads_route_weak_heads_go_dense() {
        let cfg = AutotuneConfig {
            h_kv: 2,
            head_delta_mu: vec![1.5, 0.001],
            ..AutotuneConfig::default()
        };
        let out = autotune(&cfg);
        assert_eq!(out.plan.h_kv(), 2);
        assert_eq!(out.rows[0].plan.mode, HeadMode::Routed);
        assert!(out.rows[0].recall >= cfg.target_recall);
        assert!(out.rows[0].density <= cfg.max_density);
        // ~zero separation cannot clear a 0.95 recall target at any
        // candidate geometry under the density cap
        assert_eq!(out.rows[1].plan.mode, HeadMode::Dense);
        assert!(out.plan.validate(cfg.n).is_ok());
    }

    #[test]
    fn stronger_signal_never_costs_more_density() {
        let base = AutotuneConfig::default();
        let mut last = f64::INFINITY;
        for mu in [0.4, 0.8, 1.6] {
            let cfg = AutotuneConfig {
                h_kv: 1,
                head_delta_mu: vec![mu],
                ..base.clone()
            };
            let d = autotune(&cfg).rows[0].density;
            assert!(d <= last, "mu={mu}: density {d} > {last}");
            last = d;
        }
    }

    #[test]
    fn synthetic_spread_is_deterministic_and_mixed() {
        let cfg = AutotuneConfig { h_kv: 6, ..AutotuneConfig::default() };
        let a = autotune(&cfg);
        let b = autotune(&cfg);
        assert_eq!(a.plan, b.plan);
        // the default spread spans routed strong heads and a dense tail
        assert_eq!(a.rows[0].plan.mode, HeadMode::Routed);
        assert_eq!(a.rows[5].plan.mode, HeadMode::Dense);
        assert_eq!(a.plan.is_uniform(), None);
    }

    #[test]
    fn emitted_plan_json_is_loadable() {
        let cfg = AutotuneConfig { h_kv: 3, fallback_margin: 0.25, ..AutotuneConfig::default() };
        let out = autotune(&cfg);
        let text = out.plan.to_json().to_string_pretty();
        let back = RoutePlan::parse(&text).unwrap();
        assert_eq!(back, out.plan);
        assert!(back.fallback_enabled());
        let report = out.report_json();
        assert_eq!(report.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn density_cap_is_respected_by_routed_choices() {
        let cfg = AutotuneConfig {
            h_kv: 1,
            head_delta_mu: vec![0.5],
            max_density: 0.25,
            ..AutotuneConfig::default()
        };
        let out = autotune(&cfg);
        if out.rows[0].plan.mode == HeadMode::Routed {
            assert!(out.rows[0].density <= 0.25);
        }
    }
}
