//! flash-moba CLI — the L3 entrypoint.
//!
//! ```text
//! flash-moba info                      # manifest / artifact inventory
//! flash-moba train --variant tiny-moba32 --steps 200
//! flash-moba eval  --variant tiny-moba32 [--ckpt path.bin]
//! flash-moba bench table1|...|table6|fig2|fig3|fig4|snr|ablate-tiles|all [--quick] [--steps N]
//! flash-moba autotune [--quick] [--out plan.json]   # SNR-driven per-head route plan
//! flash-moba serve-demo [--requests N] # coordinator demo over PJRT kernels
//! ```

use std::path::{Path, PathBuf};
use std::time::Instant;

use flash_moba::bench_harness::{
    chaos_soak, decode as decode_bench, decode_batch as decode_batch_bench, figures, kvdtype,
    report, serve_soak, smallblock, snr_harness, tables,
};
use flash_moba::config::AppConfig;
use flash_moba::util::json::Json;
use flash_moba::coordinator::{AttnKind, AttnRequest, Coordinator};
use flash_moba::data::corpus::{Corpus, CorpusConfig};
use flash_moba::eval::Evaluator;
use flash_moba::runtime::Runtime;
use flash_moba::train::Trainer;
use flash_moba::util::cli::Args;
use flash_moba::Result;

const USAGE: &str = "\
flash-moba — FlashMoBA: optimized Mixture of Block Attention (rust+JAX+Pallas reproduction)

USAGE:
  flash-moba <command> [options]

COMMANDS:
  info                         manifest / artifact inventory
  train                        train one variant (--variant, --steps)
  eval                         evaluate a variant (--variant, --ckpt)
  bench <target>               regenerate a paper table/figure:
                               table1..table6, fig2, fig3, fig4, snr,
                               parity, parity-gqa, parity-mixed, decode,
                               decode-batch, serve-soak, chaos-soak,
                               smallblock, kvdtype, ablate-tiles, all
                               (--quick, --steps N)
                               (smallblock sweeps block 16/32/64 at
                               fixed N, flash_moba vs dense, through
                               the zero-allocation forward_into path;
                               its B=32 speedup is floor-gated in CI)
                               (decode-batch sweeps one batched
                               forward_decode_batch launch over
                               B ∈ {1,4,16,64} sessions vs the
                               sequential loop; its B=16-vs-B=1
                               aggregate speedup is floor-gated in CI)
                               (serve-soak soaks the paged-KV serving
                               path: fork-shared session families on an
                               unbounded pool vs a tight page budget;
                               CI floors the fork prefix_hit_rate and
                               the pressured leg's bitwise parity_ok)
                               (chaos-soak replays identical traffic
                               with and without an active fault plan —
                               injected kernel panics, page denials,
                               corrupted inputs, wave stalls — at
                               MOBA_THREADS 1 and 4; CI floors
                               chaos_parity_ok, the bitwise parity of
                               every non-faulted session, and
                               no_worker_deaths)
                               (kvdtype sweeps routed decode with the
                               KV cache stored at f32/f16/bf16/i8 on
                               identical inputs; its f16-vs-f32
                               per-token speedup is floor-gated in CI)
                               (parity/parity-gqa/decode/decode-batch/
                               serve-soak/chaos-soak/fig3/fig4/snr/
                               ablate-tiles
                               need no
                               artifacts: they run the CPU substrate
                               through the
                               AttentionBackend registry; every target
                               writes a machine-readable
                               BENCH_<target>.json under the results
                               dir. parity-gqa re-runs the parity table
                               at a grouped-query head layout, h=8 over
                               h_kv=2; parity-mixed runs that layout
                               under a mixed per-KV-head RoutePlan —
                               one routed head, one dense head — and
                               gates the plan path's bitwise parity
                               against a per-head reference splice)
  autotune                     pick each KV head's (block, topk) — or a
                               dense fallback — from the closed-form
                               SNR retrieval model and write a route
                               plan JSON the coordinator loads via
                               serve.route_plan (--quick shrinks the
                               candidate grid; --out sets the plan
                               path, default results/route_plan.json)
  bench-check                  gate BENCH_*.json metrics against the
                               committed floors (--floor
                               ci/bench_floor.json, --results DIR);
                               exits non-zero below any floor
  serve-demo                   run the serving coordinator demo (--requests N)

GLOBAL OPTIONS:
  --config path.json           partial config override
  --artifacts DIR              artifacts directory (default: artifacts)

ENVIRONMENT:
  MOBA_THREADS                 worker threads for the attention substrate
                               (default: all cores; outputs are
                               bit-identical at any setting)
  MOBA_KV_DTYPE                KV-cache storage dtype for decode sessions
                               (f32|f16|bf16|i8; default f32). Overrides
                               serve.kv_dtype; a plan file's kv_dtype
                               wins over both. Routing stays f32, so the
                               selected blocks are dtype-independent
  MOBA_SIMD                    instruction set for the attention
                               microkernels (auto|scalar|avx2|neon;
                               default auto). Every choice is
                               bit-identical — scalar is the reference
                               the dispatched ISAs are tested against
  MOBA_FAULTS                  deterministic fault injection for the
                               serving coordinator, seed:spec — e.g.
                               7:kernel_panic@3,alloc_deny=0.25 keys
                               session 3's launches to panic and
                               denies a quarter of page admissions.
                               Points: kernel_panic, alloc_deny,
                               wave_stall, corrupt_input; @k1|k2 keys
                               exact ids, =rate hashes. Overrides
                               serve.fault_plan; unset = disabled
                               (zero-cost, bit-identical serving)
";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), &["quick"]);
    let Some(cmd) = args.pos(0).map(|s| s.to_string()) else {
        print!("{USAGE}");
        return Ok(());
    };

    let mut cfg = AppConfig::load(args.get("config").map(Path::new))?;
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    if let Some(s) = args.get_usize("steps") {
        cfg.train.steps = s;
    }

    match cmd.as_str() {
        "info" => info(&cfg),
        "train" => train(&cfg, args.get("variant").unwrap_or("tiny-moba32")),
        "eval" => eval(
            &cfg,
            args.get("variant").unwrap_or("tiny-moba32"),
            args.get("ckpt").map(PathBuf::from),
        ),
        "bench" => {
            let target = args.pos(1).unwrap_or("all").to_string();
            bench(&cfg, &target, args.has("quick"))
        }
        "bench-check" => bench_check(
            Path::new(args.get("floor").unwrap_or("ci/bench_floor.json")),
            args.get("results").map(Path::new).unwrap_or(&cfg.results_dir),
        ),
        "autotune" => autotune_cmd(&cfg, args.has("quick"), args.get("out").map(PathBuf::from)),
        "serve-demo" => serve_demo(&cfg, args.get_usize("requests").unwrap_or(32)),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn info(cfg: &AppConfig) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", rt.artifacts_dir().display());
    println!("\nvariants:");
    for (name, v) in &rt.manifest().variants {
        println!(
            "  {name:<24} {:>9} params  attn={:<6} B={} k={} kconv={} seq={} evals={:?}",
            v.param_count, v.attn, v.moba_block, v.moba_topk, v.kconv, v.seq_len, v.eval_seqs
        );
    }
    println!("\nartifacts: {}", rt.manifest().artifacts.len());
    for (name, a) in &rt.manifest().artifacts {
        println!("  {name:<28} {} inputs, {} outputs", a.inputs.len(), a.outputs.len());
    }
    Ok(())
}

fn train(cfg: &AppConfig, variant: &str) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let spec = rt.manifest().variant(variant)?;
    let corpus = Corpus::new(CorpusConfig { vocab: spec.vocab_size, ..Default::default() });
    let mut tr = Trainer::new(&rt, variant)?;
    println!(
        "training {variant}: {} params, {} steps, batch {} x seq {}",
        tr.spec().param_count,
        cfg.train.steps,
        tr.spec().train_batch,
        tr.spec().seq_len
    );
    let final_loss = tr.run(&corpus, &cfg.train, |log| {
        println!(
            "step {:>4}  loss {:.4}  lr {:.2e}  {:.2}s/step",
            log.step, log.loss, log.lr, log.step_time_s
        );
    })?;
    tr.checkpoint(&cfg.results_dir.join("ckpt"), &format!("s{}", cfg.train.steps))?;
    println!("final loss: {final_loss:.4}");
    Ok(())
}

fn eval(cfg: &AppConfig, variant: &str, ckpt: Option<PathBuf>) -> Result<()> {
    let rt = Runtime::load(&cfg.artifacts_dir)?;
    let spec = rt.manifest().variant(variant)?.clone();
    let params = match ckpt {
        Some(p) => Trainer::load_checkpoint(&rt, variant, &p)?,
        None => rt.load_init_params(variant)?,
    };
    let corpus = Corpus::new(CorpusConfig { vocab: spec.vocab_size, ..Default::default() });
    let mut ev = Evaluator::new(&rt, variant, params)?;
    let lens: Vec<usize> =
        cfg.eval.niah_lens.iter().cloned().filter(|l| spec.eval_seqs.contains(l)).collect();
    let rep = ev.full_report(
        &corpus,
        &lens,
        cfg.eval.niah_samples,
        cfg.eval.task_len,
        cfg.eval.task_samples,
        cfg.eval.ppl_batches,
    )?;
    println!("\n== eval {variant} ==");
    println!("ppl: {:.2}", rep.wiki_ppl.unwrap_or(f64::NAN));
    for ((task, len), acc) in &rep.niah {
        println!("{task}@{len}: {acc:.0}%");
    }
    for (task, sc) in &rep.tasks {
        println!("{task}: {sc:.1}");
    }
    println!("NIAH avg {:.1}, task avg {:.1}", rep.niah_avg(), rep.task_avg());
    Ok(())
}

/// The bench config a target actually runs with: `parity-gqa` and
/// `parity-mixed` pin the grouped-query head layout (h=8 over h_kv=2),
/// everything else uses the configured (default single-head) layout.
/// Also what lands in the emitted BENCH_<target>.json `config` object.
fn effective_bench(cfg: &AppConfig, target: &str) -> flash_moba::config::BenchParams {
    let mut b = cfg.bench.clone();
    if target == "parity-gqa" || target == "parity-mixed" {
        b.heads = 8;
        b.kv_heads = 2;
    }
    b
}

fn bench(cfg: &AppConfig, target: &str, quick: bool) -> Result<()> {
    let needs_runtime = matches!(
        target,
        "table1" | "table2" | "table3" | "table4" | "table5" | "table6" | "fig2" | "all"
    );
    let rt = if needs_runtime { Some(Runtime::load(&cfg.artifacts_dir)?) } else { None };
    // each target returns the headline metrics for its BENCH_<target>.json
    let run_one = |cfg: &AppConfig, target: &str| -> Result<Vec<(String, f64)>> {
        let none = |r: Result<()>| r.map(|_| Vec::new());
        match target {
            "table1" => none(tables::run_table_lm(cfg, rt.as_ref().unwrap(), "tiny")),
            "table2" => none(tables::run_table_lm(cfg, rt.as_ref().unwrap(), "small")),
            "table3" => none(tables::run_table_niah(cfg, rt.as_ref().unwrap(), "tiny")),
            "table4" => none(tables::run_table_niah(cfg, rt.as_ref().unwrap(), "small")),
            "table5" => none(tables::run_table_longbench(cfg, rt.as_ref().unwrap(), "tiny")),
            "table6" => none(tables::run_table_longbench(cfg, rt.as_ref().unwrap(), "small")),
            "fig2" => none(tables::run_fig2(cfg, rt.as_ref().unwrap())),
            "fig3" => {
                let rows = figures::run_fig3(cfg, quick)?;
                let headline = figures::print_fig3(cfg, &rows)?;
                let (multicore, threads) = figures::measure_multicore_speedup(cfg, quick);
                println!(
                    "multi-core: flash_moba forward {multicore:.2}x vs serial ({threads} threads)\n"
                );
                Ok(vec![
                    ("headline_speedup_vs_dense".into(), headline),
                    ("multicore_speedup".into(), multicore),
                ])
            }
            "fig4" => none(figures::run_fig4(cfg, if quick { 4096 } else { 16384 })),
            "snr" => none(snr_harness::run_snr(cfg, if quick { 1000 } else { 4000 })),
            "parity" => tables::run_table_parity(cfg, quick, "parity")
                .map(|s| vec![("speedup_vs_dense".into(), s)]),
            "parity-gqa" => {
                // the multi-head floor config: 8 query heads grouped
                // over 2 KV heads through the same parity table
                let mut gqa = cfg.clone();
                gqa.bench = effective_bench(cfg, "parity-gqa");
                tables::run_table_parity(&gqa, quick, "parity-gqa")
                    .map(|s| vec![("speedup_vs_dense".into(), s)])
            }
            "parity-mixed" => {
                // two distinct per-KV-head plans through one launch:
                // the plan-path bitwise-parity gate
                let mut mixed = cfg.clone();
                mixed.bench = effective_bench(cfg, "parity-mixed");
                tables::run_table_parity_mixed(&mixed, quick)
                    .map(|p| vec![("parity_ok".into(), p)])
            }
            "decode" => decode_bench::run_decode(cfg, quick)
                .map(|s| vec![("speedup_vs_dense".into(), s)]),
            // batched cross-session decode: aggregate tok/s at
            // B ∈ {1,4,16,64}; floors the B=16-vs-B=1 speedup
            "decode-batch" => decode_batch_bench::run_decode_batch(cfg, quick),
            // paged serving soak: fork sharing + page pressure; floors
            // prefix_hit_rate and the pressured leg's bitwise parity
            "serve-soak" => serve_soak::run_serve_soak(cfg, quick),
            // chaos parity: identical traffic with/without an active
            // fault plan; floors chaos_parity_ok and no_worker_deaths
            "chaos-soak" => chaos_soak::run_chaos_soak(cfg, quick),
            "smallblock" => smallblock::run_smallblock(cfg, quick),
            // quantized-KV decode sweep: f16/bf16/i8 vs the f32 cache;
            // floors the f16-vs-f32 per-token speedup
            "kvdtype" => kvdtype::run_kvdtype(cfg, quick),
            "ablate-tiles" => {
                none(figures::run_tile_ablation(cfg, if quick { 2048 } else { 8192 }))
            }
            other => Err(anyhow::anyhow!("unknown bench target {other}")),
        }
    };
    let run_and_emit = |cfg: &AppConfig, t: &str| -> Result<()> {
        let t0 = Instant::now();
        let metrics = run_one(cfg, t)?;
        report::save_bench_summary(
            &cfg.results_dir,
            t,
            t0.elapsed().as_secs_f64(),
            quick,
            &effective_bench(cfg, t),
            &metrics,
        )
    };
    if target == "all" {
        for t in [
            "parity", "parity-gqa", "parity-mixed", "decode", "decode-batch", "serve-soak",
            "chaos-soak", "smallblock", "kvdtype", "snr", "fig3", "fig4", "ablate-tiles", "table1",
            "table3", "table5", "fig2", "table2", "table4", "table6",
        ] {
            println!("\n######## bench {t} ########");
            run_and_emit(cfg, t)?;
        }
        Ok(())
    } else {
        run_and_emit(cfg, target)
    }
}

/// `bench-check`: compare every metric named in the committed floor
/// file against the matching `BENCH_<target>.json` in the results dir.
/// A missing file, a missing metric or a value below its floor fails
/// the run — this is the CI perf gate.
fn bench_check(floor_path: &Path, results_dir: &Path) -> Result<()> {
    let floors = Json::parse(
        &std::fs::read_to_string(floor_path)
            .map_err(|e| anyhow::anyhow!("reading floor file {floor_path:?}: {e}"))?,
    )?;
    let targets = floors
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("floor file must be an object of targets"))?;
    let mut failures: Vec<String> = Vec::new();
    for (target, metrics) in targets {
        let path = results_dir.join(format!("BENCH_{target}.json"));
        let blob = match std::fs::read_to_string(&path) {
            Ok(text) => Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?,
            Err(e) => {
                failures.push(format!("{target}: missing {} ({e})", path.display()));
                continue;
            }
        };
        let got = blob.get("metrics");
        let Some(floor_metrics) = metrics.as_obj() else {
            failures.push(format!(
                "{target}: floor entry must be an object of metric -> floor pairs"
            ));
            continue;
        };
        for (metric, floor) in floor_metrics {
            let Some(floor) = floor.as_f64() else {
                failures.push(format!("{target}.{metric}: floor is not a number"));
                continue;
            };
            match got.and_then(|m| m.get(metric)).and_then(|v| v.as_f64()) {
                Some(v) if v >= floor => {
                    println!("[bench-check] OK   {target}.{metric} = {v:.3} (floor {floor:.3})");
                }
                Some(v) => {
                    failures.push(format!("{target}.{metric} = {v:.3} below floor {floor:.3}"));
                }
                None => {
                    failures.push(format!("{target}.{metric} missing from {}", path.display()));
                }
            }
        }
    }
    if failures.is_empty() {
        println!("[bench-check] all floors hold");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("[bench-check] FAIL {f}");
        }
        Err(anyhow::anyhow!("{} bench floor violation(s)", failures.len()))
    }
}

/// `autotune`: run the SNR-driven per-head planner and write the
/// resulting route plan JSON (plus a per-head diagnostic report next to
/// it). The emitted plan is re-parsed before reporting success, so a
/// plan this command wrote is always loadable by
/// `serve.route_plan` — the CI smoke step relies on that.
fn autotune_cmd(cfg: &AppConfig, quick: bool, out: Option<PathBuf>) -> Result<()> {
    let mut tune = cfg.autotune.to_config();
    if quick {
        // small grid, short sequence: seconds, same code path
        tune.n = tune.n.min(512);
        tune.blocks.retain(|&b| b <= 64);
        tune.topks.retain(|&k| k <= 8);
    }
    let outcome = flash_moba::snr::autotune(&tune);
    println!(
        "autotune: d={} n={} h_kv={} target_recall={} max_density={}",
        tune.d, tune.n, tune.h_kv, tune.target_recall, tune.max_density
    );
    for r in &outcome.rows {
        if r.plan.is_dense() {
            println!(
                "  head {:>2}  dmu={:.3}  -> dense (B={}; no candidate met the recall target)",
                r.head, r.delta_mu, r.plan.block
            );
        } else {
            println!(
                "  head {:>2}  dmu={:.3}  -> B={:<4} k={:<3} snr={:.2} recall={:.4} density={:.3}",
                r.head, r.delta_mu, r.plan.block, r.plan.topk, r.snr, r.recall, r.density
            );
        }
    }
    let path = out.unwrap_or_else(|| cfg.results_dir.join("route_plan.json"));
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let text = outcome.plan.to_json().to_string_pretty();
    std::fs::write(&path, &text)?;
    // self-check: the written plan must round-trip through the same
    // parser the coordinator uses at startup
    flash_moba::attention::plan::RoutePlan::parse(&text)
        .map_err(|e| anyhow::anyhow!("emitted plan failed to re-parse: {e}"))?;
    let report_path = path.with_extension("report.json");
    std::fs::write(&report_path, outcome.report_json().to_string_pretty())?;
    println!("plan:   {}", path.display());
    println!("report: {}", report_path.display());
    Ok(())
}

fn serve_demo(cfg: &AppConfig, requests: usize) -> Result<()> {
    let coord = Coordinator::start(cfg.artifacts_dir.clone(), cfg.serve.clone())?;
    let t0 = std::time::Instant::now();
    let mut tickets = Vec::new();
    for i in 0..requests {
        let n = if i % 3 == 0 { 512 } else { 1024 };
        let d = 64;
        let mut rng = flash_moba::attention::testutil::Rng::new(i as u64 + 1);
        let req = AttnRequest {
            id: i as u64,
            kind: if i % 4 == 0 { AttnKind::Dense } else { AttnKind::Moba },
            h: 1,
            h_kv: 1,
            n,
            d,
            q: rng.normal_vec(n * d),
            k: rng.normal_vec(n * d),
            v: rng.normal_vec(n * d),
            plan: None,
            deadline: None,
        };
        tickets.push(coord.submit_async(req)?);
    }
    let mut ok = 0usize;
    for t in tickets {
        let resp = t.wait()?;
        assert!(!resp.o.is_empty());
        ok += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} requests in {elapsed:.2}s ({:.1} req/s)",
        ok as f64 / elapsed
    );
    println!("metrics: {}", coord.metrics().summary());
    coord.shutdown();
    Ok(())
}
