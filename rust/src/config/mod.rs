//! JSON-backed configuration system for the CLI and examples.
//!
//! Everything has a sensible default so `flash-moba <cmd>` works with no
//! config file; `--config path.json` overrides fields selectively (every
//! table and field is optional).

use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::util::json::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct AppConfig {
    /// where `make artifacts` put the HLO + manifest
    pub artifacts_dir: PathBuf,
    /// where harnesses write json/csv results
    pub results_dir: PathBuf,
    pub train: TrainParams,
    pub eval: EvalParams,
    pub serve: ServeParams,
    pub bench: BenchParams,
    pub autotune: AutotuneParams,
}

impl Default for AppConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            results_dir: PathBuf::from("results"),
            train: TrainParams::default(),
            eval: EvalParams::default(),
            serve: ServeParams::default(),
            bench: BenchParams::default(),
            autotune: AutotuneParams::default(),
        }
    }
}

/// Paper §5.1 optimizer recipe (AdamW betas/wd live in the artifact; the
/// schedule is driven from rust).
#[derive(Debug, Clone)]
pub struct TrainParams {
    pub steps: usize,
    pub peak_lr: f64,
    pub warmup: usize,
    /// cosine floor as a fraction of peak
    pub floor_frac: f64,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainParams {
    fn default() -> Self {
        Self { steps: 300, peak_lr: 6e-4, warmup: 20, floor_frac: 0.1, log_every: 10, seed: 42 }
    }
}

#[derive(Debug, Clone)]
pub struct EvalParams {
    pub niah_samples: usize,
    pub task_samples: usize,
    pub ppl_batches: usize,
    pub niah_lens: Vec<usize>,
    pub task_len: usize,
}

impl Default for EvalParams {
    fn default() -> Self {
        Self {
            niah_samples: 25,
            task_samples: 10,
            ppl_batches: 8,
            niah_lens: vec![1024, 2048, 4096],
            task_len: 1024,
        }
    }
}

#[derive(Debug, Clone)]
pub struct ServeParams {
    /// max requests packed into one batch (the PJRT path additionally
    /// caps packing at the compiled kernels' head capacity)
    pub max_batch: usize,
    /// flush deadline for a partially filled batch
    pub max_wait_ms: u64,
    pub queue_capacity: usize,
    /// MoBA routing geometry used when requests are served on the CPU
    /// attention substrate (no PJRT artifacts available); mirrors the
    /// serving kernels' B=128, k=8
    pub moba_block: usize,
    pub moba_topk: usize,
    /// query heads of the serving model (the router's advertised head
    /// layout; decode sessions default to it). Plumbed from the runtime
    /// manifest via [`ServeParams::with_variant`]; mirrors the compiled
    /// kernels' H=4.
    pub n_heads: usize,
    /// KV heads of the serving model (GQA: `n_heads % n_kv_heads == 0`)
    pub n_kv_heads: usize,
    /// path to a per-head routing plan JSON file (the `flash-moba
    /// autotune` output) applied to MoBA requests and decode sessions
    /// on the CPU substrate; `None` serves the uniform
    /// `moba_block`/`moba_topk` geometry
    pub route_plan: Option<String>,
    /// runtime dense-fallback threshold on the observed routing score
    /// margin, applied to plans that don't carry their own; `-inf`
    /// (the default) disables the probe
    pub fallback_margin: f64,
    /// tokens per KV-cache page on the CPU substrate's paged decode
    /// path. A *floor* request, not an exact size: the worker derives
    /// the minimum page able to hold the largest serving block (plan
    /// heads and `moba_block`) and takes the max of the two, so a
    /// too-small configured value can never produce an invalid pool.
    /// 0 (the default) = fully derived
    pub page_tokens: usize,
    /// soft page budget for the shared KV pool: the continuous-batching
    /// admission rule defers or preempts once live pages would exceed
    /// it (in-flight steps still complete — the budget gates admission,
    /// allocation never fails). 0 (the default) = unbounded, which also
    /// disables swap logging and preemption entirely
    pub max_pages: usize,
    /// KV-cache storage dtype for decode sessions on the CPU substrate:
    /// `"f32"` (default), `"f16"`, `"bf16"`, or `"i8"`. Quantization is
    /// storage-only — routing centroids stay f32, so block selection is
    /// identical across dtypes. Overridden by the `MOBA_KV_DTYPE` env
    /// var and by a plan file's `kv_dtype`; an unrecognized string
    /// falls back to f32
    pub kv_dtype: String,
    /// fault-injection plan spec (`"seed:spec"`, the
    /// [`crate::util::faults::FaultPlan`] grammar) armed for this
    /// coordinator; `None` (the default) disables injection entirely.
    /// The `MOBA_FAULTS` env var overrides this field. An unparseable
    /// spec fails coordinator startup loudly
    pub fault_plan: Option<String>,
    /// graceful degradation under pool saturation: when the page pool
    /// is at budget and eviction cannot free anything, `true` admits
    /// *new* sessions with their KV dtype degraded to i8 (quarter
    /// footprint; outputs change, so it is opt-in), `false` (the
    /// default) rejects them with a typed `PoolSaturated` error.
    /// Either way: never a panic
    pub degrade_under_pressure: bool,
    /// bounded deterministic retries after a transient admission
    /// denial (pool pressure or an injected `alloc_deny` fault) before
    /// the work parks FIFO; each retry is counted in
    /// `Metrics::retries`
    pub admit_retries: usize,
}

impl Default for ServeParams {
    fn default() -> Self {
        Self {
            max_batch: 4,
            max_wait_ms: 5,
            queue_capacity: 1024,
            moba_block: 128,
            moba_topk: 8,
            n_heads: 4,
            n_kv_heads: 4,
            route_plan: None,
            fallback_margin: f64::NEG_INFINITY,
            page_tokens: 0,
            max_pages: 0,
            kv_dtype: "f32".into(),
            fault_plan: None,
            degrade_under_pressure: false,
            admit_retries: 3,
        }
    }
}

impl ServeParams {
    /// Adopt a manifest variant's attention geometry: head layout
    /// (`n_heads` / `n_kv_heads` — the fields `runtime/manifest.rs`
    /// parses) and MoBA routing config. This is the plumbing the
    /// serving router reads its head layout from.
    pub fn with_variant(mut self, v: &crate::runtime::VariantSpec) -> Self {
        self.n_heads = v.n_heads.max(1);
        self.n_kv_heads = v.n_kv_heads.max(1);
        self.moba_block = v.moba_block.max(1);
        self.moba_topk = v.moba_topk;
        self
    }
}

/// Search space and targets for the `flash-moba autotune` command
/// (mirrors [`crate::snr::AutotuneConfig`]; see [`AutotuneParams::to_config`]).
#[derive(Debug, Clone)]
pub struct AutotuneParams {
    pub d: usize,
    pub n: usize,
    pub h_kv: usize,
    pub target_recall: f64,
    pub max_density: f64,
    pub blocks: Vec<usize>,
    pub topks: Vec<usize>,
    /// per-head Δμ_eff measurements; empty = deterministic synthetic spread
    pub head_delta_mu: Vec<f64>,
    /// fallback threshold stamped into the emitted plan (-inf disables)
    pub fallback_margin: f64,
}

impl Default for AutotuneParams {
    fn default() -> Self {
        let c = crate::snr::AutotuneConfig::default();
        Self {
            d: c.d,
            n: c.n,
            h_kv: c.h_kv,
            target_recall: c.target_recall,
            max_density: c.max_density,
            blocks: c.blocks,
            topks: c.topks,
            head_delta_mu: Vec::new(),
            fallback_margin: f64::NEG_INFINITY,
        }
    }
}

impl AutotuneParams {
    pub fn to_config(&self) -> crate::snr::AutotuneConfig {
        crate::snr::AutotuneConfig {
            d: self.d,
            n: self.n,
            h_kv: self.h_kv,
            target_recall: self.target_recall,
            max_density: self.max_density,
            blocks: self.blocks.clone(),
            topks: self.topks.clone(),
            head_delta_mu: self.head_delta_mu.clone(),
            fallback_margin: self.fallback_margin,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchParams {
    /// sequence lengths for the Figure-3 sweep
    pub fig3_lens: Vec<usize>,
    /// repetitions per point
    pub reps: usize,
    /// block size / top-k for the efficiency figures (paper: 128 / 8)
    pub block: usize,
    pub topk: usize,
    pub head_dim: usize,
    /// head layout for the substrate sweeps (1/1 = the single-head
    /// figures; the `parity-gqa` bench target overrides to a GQA config)
    pub heads: usize,
    pub kv_heads: usize,
}

impl Default for BenchParams {
    fn default() -> Self {
        Self {
            fig3_lens: vec![2048, 4096, 8192, 16384, 32768],
            reps: 3,
            block: 128,
            topk: 8,
            head_dim: 64,
            heads: 1,
            kv_heads: 1,
        }
    }
}

fn ov_usize(j: &Json, key: &str, dst: &mut usize) {
    if let Some(x) = j.get(key).and_then(|x| x.as_usize()) {
        *dst = x;
    }
}

fn ov_f64(j: &Json, key: &str, dst: &mut f64) {
    if let Some(x) = j.get(key).and_then(|x| x.as_f64()) {
        *dst = x;
    }
}

fn ov_usize_vec(j: &Json, key: &str, dst: &mut Vec<usize>) {
    if let Some(arr) = j.get(key).and_then(|x| x.as_arr()) {
        let parsed: Option<Vec<usize>> = arr.iter().map(|x| x.as_usize()).collect();
        if let Some(v) = parsed {
            *dst = v;
        }
    }
}

fn ov_f64_vec(j: &Json, key: &str, dst: &mut Vec<f64>) {
    if let Some(arr) = j.get(key).and_then(|x| x.as_arr()) {
        let parsed: Option<Vec<f64>> = arr.iter().map(|x| x.as_f64()).collect();
        if let Some(v) = parsed {
            *dst = v;
        }
    }
}

impl AppConfig {
    /// Apply a partial JSON override onto the defaults.
    pub fn apply(&mut self, j: &Json) {
        if let Some(s) = j.get("artifacts_dir").and_then(|x| x.as_str()) {
            self.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = j.get("results_dir").and_then(|x| x.as_str()) {
            self.results_dir = PathBuf::from(s);
        }
        if let Some(t) = j.get("train") {
            ov_usize(t, "steps", &mut self.train.steps);
            ov_f64(t, "peak_lr", &mut self.train.peak_lr);
            ov_usize(t, "warmup", &mut self.train.warmup);
            ov_f64(t, "floor_frac", &mut self.train.floor_frac);
            ov_usize(t, "log_every", &mut self.train.log_every);
            if let Some(x) = t.get("seed").and_then(|x| x.as_f64()) {
                self.train.seed = x as u64;
            }
        }
        if let Some(e) = j.get("eval") {
            ov_usize(e, "niah_samples", &mut self.eval.niah_samples);
            ov_usize(e, "task_samples", &mut self.eval.task_samples);
            ov_usize(e, "ppl_batches", &mut self.eval.ppl_batches);
            ov_usize_vec(e, "niah_lens", &mut self.eval.niah_lens);
            ov_usize(e, "task_len", &mut self.eval.task_len);
        }
        if let Some(s) = j.get("serve") {
            ov_usize(s, "max_batch", &mut self.serve.max_batch);
            if let Some(x) = s.get("max_wait_ms").and_then(|x| x.as_f64()) {
                self.serve.max_wait_ms = x as u64;
            }
            ov_usize(s, "queue_capacity", &mut self.serve.queue_capacity);
            ov_usize(s, "moba_block", &mut self.serve.moba_block);
            ov_usize(s, "moba_topk", &mut self.serve.moba_topk);
            ov_usize(s, "n_heads", &mut self.serve.n_heads);
            ov_usize(s, "n_kv_heads", &mut self.serve.n_kv_heads);
            if let Some(p) = s.get("route_plan").and_then(|x| x.as_str()) {
                self.serve.route_plan = Some(p.to_string());
            }
            ov_f64(s, "fallback_margin", &mut self.serve.fallback_margin);
            ov_usize(s, "page_tokens", &mut self.serve.page_tokens);
            ov_usize(s, "max_pages", &mut self.serve.max_pages);
            if let Some(x) = s.get("kv_dtype").and_then(|x| x.as_str()) {
                self.serve.kv_dtype = x.to_string();
            }
            if let Some(x) = s.get("fault_plan").and_then(|x| x.as_str()) {
                self.serve.fault_plan = Some(x.to_string());
            }
            if let Some(x) = s.get("degrade_under_pressure").and_then(|x| x.as_bool()) {
                self.serve.degrade_under_pressure = x;
            }
            ov_usize(s, "admit_retries", &mut self.serve.admit_retries);
        }
        if let Some(a) = j.get("autotune") {
            ov_usize(a, "d", &mut self.autotune.d);
            ov_usize(a, "n", &mut self.autotune.n);
            ov_usize(a, "h_kv", &mut self.autotune.h_kv);
            ov_f64(a, "target_recall", &mut self.autotune.target_recall);
            ov_f64(a, "max_density", &mut self.autotune.max_density);
            ov_usize_vec(a, "blocks", &mut self.autotune.blocks);
            ov_usize_vec(a, "topks", &mut self.autotune.topks);
            ov_f64_vec(a, "head_delta_mu", &mut self.autotune.head_delta_mu);
            ov_f64(a, "fallback_margin", &mut self.autotune.fallback_margin);
        }
        if let Some(b) = j.get("bench") {
            ov_usize_vec(b, "fig3_lens", &mut self.bench.fig3_lens);
            ov_usize(b, "reps", &mut self.bench.reps);
            ov_usize(b, "block", &mut self.bench.block);
            ov_usize(b, "topk", &mut self.bench.topk);
            ov_usize(b, "head_dim", &mut self.bench.head_dim);
            ov_usize(b, "heads", &mut self.bench.heads);
            ov_usize(b, "kv_heads", &mut self.bench.kv_heads);
        }
        // a zero head count is a config mistake, not a geometry: clamp
        // once here so every bench target and the serving router see
        // the same valid layout (non-multiple h/h_kv combinations are
        // still rejected downstream with a real error)
        self.bench.heads = self.bench.heads.max(1);
        self.bench.kv_heads = self.bench.kv_heads.max(1);
        self.serve.n_heads = self.serve.n_heads.max(1);
        self.serve.n_kv_heads = self.serve.n_kv_heads.max(1);
    }

    pub fn load(path: Option<&Path>) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(p) = path {
            let text =
                std::fs::read_to_string(p).with_context(|| format!("reading {p:?}"))?;
            let j = Json::parse(&text).context("parsing config JSON")?;
            cfg.apply(&j);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_sane() {
        let c = AppConfig::default();
        assert!(c.train.steps > 0);
        assert!(c.serve.max_batch >= 1);
        assert!(!c.bench.fig3_lens.is_empty());
    }

    #[test]
    fn partial_json_overrides_only_named_fields() {
        let j = Json::parse(
            r#"{"train": {"steps": 7}, "serve": {"max_batch": 2}, "results_dir": "/tmp/r"}"#,
        )
        .unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.train.steps, 7);
        assert_eq!(c.serve.max_batch, 2);
        assert_eq!(c.results_dir, PathBuf::from("/tmp/r"));
        // untouched fields keep defaults
        assert_eq!(c.train.warmup, TrainParams::default().warmup);
        assert_eq!(c.eval.ppl_batches, EvalParams::default().ppl_batches);
    }

    #[test]
    fn vec_override() {
        let j = Json::parse(r#"{"bench": {"fig3_lens": [128, 256]}}"#).unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.bench.fig3_lens, vec![128, 256]);
    }

    #[test]
    fn head_layout_overrides() {
        let j = Json::parse(
            r#"{"serve": {"n_heads": 8, "n_kv_heads": 2}, "bench": {"heads": 4, "kv_heads": 2}}"#,
        )
        .unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!((c.serve.n_heads, c.serve.n_kv_heads), (8, 2));
        assert_eq!((c.bench.heads, c.bench.kv_heads), (4, 2));
        // defaults are single-head benches, H=4 serving (the kernels' H)
        let d = AppConfig::default();
        assert_eq!((d.bench.heads, d.bench.kv_heads), (1, 1));
        assert_eq!((d.serve.n_heads, d.serve.n_kv_heads), (4, 4));
        // zeros in the config are clamped once at apply time, so every
        // consumer (fig3, parity, router) sees the same valid layout
        let z = Json::parse(r#"{"serve": {"n_heads": 0}, "bench": {"heads": 0, "kv_heads": 0}}"#)
            .unwrap();
        let mut c = AppConfig::default();
        c.apply(&z);
        assert_eq!((c.bench.heads, c.bench.kv_heads), (1, 1));
        assert_eq!(c.serve.n_heads, 1);
    }

    #[test]
    fn route_plan_and_autotune_overrides() {
        let j = Json::parse(
            r#"{"serve": {"route_plan": "plans/p.json", "fallback_margin": 0.1},
                "autotune": {"h_kv": 8, "target_recall": 0.9, "blocks": [32, 64],
                             "head_delta_mu": [1.5, 0.2]}}"#,
        )
        .unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.serve.route_plan.as_deref(), Some("plans/p.json"));
        assert!((c.serve.fallback_margin - 0.1).abs() < 1e-12);
        assert_eq!(c.autotune.h_kv, 8);
        assert_eq!(c.autotune.blocks, vec![32, 64]);
        assert_eq!(c.autotune.head_delta_mu, vec![1.5, 0.2]);
        // untouched: plan off, probe disabled, defaults preserved
        let d = AppConfig::default();
        assert!(d.serve.route_plan.is_none());
        assert_eq!(d.serve.fallback_margin, f64::NEG_INFINITY);
        assert_eq!(d.autotune.topks, crate::snr::AutotuneConfig::default().topks);
        // the conversion round-trips onto the tuner's config
        let cfg = c.autotune.to_config();
        assert_eq!(cfg.h_kv, 8);
        assert_eq!(cfg.head_delta_mu, vec![1.5, 0.2]);
    }

    #[test]
    fn paging_overrides() {
        // defaults: derived page size, unbounded pool (no preemption)
        let d = AppConfig::default();
        assert_eq!((d.serve.page_tokens, d.serve.max_pages), (0, 0));
        let j = Json::parse(r#"{"serve": {"page_tokens": 256, "max_pages": 1024}}"#).unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.serve.page_tokens, 256);
        assert_eq!(c.serve.max_pages, 1024);
    }

    #[test]
    fn kv_dtype_override() {
        // default stores f32; a serve-table string overrides it
        let d = AppConfig::default();
        assert_eq!(d.serve.kv_dtype, "f32");
        let j = Json::parse(r#"{"serve": {"kv_dtype": "f16"}}"#).unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.serve.kv_dtype, "f16");
        // the string is validated at session creation, not here: apply
        // stores whatever was configured and the router falls back to
        // f32 on an unparseable value
        let j = Json::parse(r#"{"serve": {"kv_dtype": "f8"}}"#).unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.serve.kv_dtype, "f8");
    }

    /// Fault-tolerance knobs: off by default (no plan armed, no
    /// degraded admission, 3 bounded retries), each overridable from
    /// the serve table. The fault spec itself is validated at
    /// coordinator startup, not here — apply stores the string.
    #[test]
    fn fault_tolerance_overrides() {
        let d = AppConfig::default();
        assert_eq!(d.serve.fault_plan, None);
        assert!(!d.serve.degrade_under_pressure);
        assert_eq!(d.serve.admit_retries, 3);
        let j = Json::parse(
            r#"{"serve": {"fault_plan": "42:kernel_panic=0.1",
                          "degrade_under_pressure": true,
                          "admit_retries": 5}}"#,
        )
        .unwrap();
        let mut c = AppConfig::default();
        c.apply(&j);
        assert_eq!(c.serve.fault_plan.as_deref(), Some("42:kernel_panic=0.1"));
        assert!(c.serve.degrade_under_pressure);
        assert_eq!(c.serve.admit_retries, 5);
    }

    #[test]
    fn missing_file_errors() {
        assert!(AppConfig::load(Some(Path::new("/nonexistent/cfg.json"))).is_err());
    }
}
