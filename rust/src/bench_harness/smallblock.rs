//! `bench smallblock` — the small-block sweep the paper's core tension
//! is about (§4: theory wants *small* blocks, hardware punishes them
//! without a fused kernel). Fixed N, block ∈ {16, 32, 64}, flash_moba
//! vs the dense FA-2 analogue, measured through the zero-allocation
//! `forward_into` serving path. Emits `BENCH_smallblock.json`; the CI
//! perf job holds the block=32 flash-vs-dense speedup against its
//! committed floor in `ci/bench_floor.json` — the regression gate for
//! the register-blocked microkernels and the workspace-reuse runtime.

use std::time::Instant;

use crate::attention::backend::{AttentionBackend, BackendRegistry};
use crate::attention::testutil::qkv_packed;
use crate::attention::AttnShape;
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

use super::report::{self, Table};

/// Best-of-reps wall time of one backend through `forward_into` with a
/// reused output buffer (the steady-state serving path — after the
/// warmup call the measured loop is allocation-free on a serial pool).
fn best_of(
    backend: &dyn AttentionBackend,
    ctx: &ExecCtx,
    shape: &AttnShape,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    reps: usize,
) -> f64 {
    let mut o = Vec::new();
    backend.forward_into(ctx, shape, q, k, v, &mut o); // warmup
    (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            backend.forward_into(ctx, shape, q, k, v, &mut o);
            t0.elapsed().as_secs_f64()
        })
        .fold(f64::INFINITY, f64::min)
}

/// The `bench smallblock` target. Returns the headline metrics for
/// `BENCH_smallblock.json` — the floor-gated block=32 speedup plus the
/// per-block speedups for context.
pub fn run_smallblock(cfg: &AppConfig, quick: bool) -> Result<Vec<(String, f64)>> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let dense = registry.get("dense").expect("dense registered");
    let flash = registry.get("flash_moba").expect("flash_moba registered");

    let n = if quick { 4096 } else { 8192 };
    let d = cfg.bench.head_dim;
    let topk = cfg.bench.topk.max(1);
    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    let reps = if quick { 2 } else { 3 };
    let blocks = [16usize, 32, 64];

    let mut t = Table::new(
        &format!(
            "bench smallblock — flash_moba vs dense across block sizes  \
             [N={n}, k={topk}, d={d}, h={h}/{h_kv}, {} threads]",
            ctx.threads()
        ),
        &["block", "density", "dense ms", "flash_moba ms", "speedup"],
    );
    let mut rows = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for &block in &blocks {
        let shape = AttnShape::new(h, h_kv, n, d, block, topk);
        let (q, k, v) = qkv_packed(0x5B10C + block as u64, h, h_kv, n, d);
        // dense ignores the routing geometry but is re-timed per block
        // so both sides see identical cache state
        let dense_s = best_of(dense, ctx, &shape, &q, &k, &v, reps);
        let flash_s = best_of(flash, ctx, &shape, &q, &k, &v, reps);
        let speedup = dense_s / flash_s.max(1e-12);
        t.row(vec![
            block.to_string(),
            format!("{:.3}", shape.density()),
            report::ms(dense_s),
            report::ms(flash_s),
            format!("{speedup:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("block", Json::from(block)),
            ("n", Json::from(n)),
            ("density", Json::from(shape.density())),
            ("dense_s", Json::from(dense_s)),
            ("flash_moba_s", Json::from(flash_s)),
            ("speedup_vs_dense", Json::from(speedup)),
        ]));
        metrics.push((format!("speedup_vs_dense_b{block}"), speedup));
    }
    t.print();
    println!(
        "small-block story: FlashMoBA keeps its dense speedup as B shrinks — the regime \
         the paper's fused kernel (and this runtime's microkernels) exist for\n"
    );
    report::save_json(
        &cfg.results_dir,
        "smallblock",
        &Json::obj(vec![("rows", Json::arr(rows))]),
    )?;
    Ok(metrics)
}
