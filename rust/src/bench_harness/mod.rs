//! Regeneration harness for every table and figure in the paper's
//! evaluation (§5.2, §5.3) — see README.md §Benchmarks for the
//! experiment index.
//!
//! * [`tables`] — Tables 1–6 (+ Figure 2): train the scaled variants on
//!   the synthetic corpus via the AOT train-step artifacts, then run the
//!   evaluators. Checkpoints are cached in `results/ckpt` so Tables 1,
//!   3, 5 (and 2, 4, 6) share one training run per variant.
//! * [`figures`] — Figures 3–4 + headline speedups: run the CPU
//!   attention substrate (dense FA-2 analogue vs original MoBA vs
//!   FlashMoBA) across sequence lengths, with stage decomposition and
//!   workspace-memory accounting (analytic beyond the timeable range,
//!   with the paper's OOM point reproduced as a workspace budget).
//! * [`decode`] — incremental-decode throughput: per-token latency of
//!   every backend's `forward_decode` at steady-state context lengths,
//!   plus a decode↔prefill parity table.
//! * [`decode_batch`] — batched cross-session decode: aggregate
//!   tokens/s of one `forward_decode_batch` launch over B sessions vs
//!   the sequential per-session loop, B ∈ {1, 4, 16, 64}; CI floors
//!   the B=16-vs-B=1 aggregate speedup.
//! * [`kvdtype`] — quantized-KV decode sweep: routed flash_moba decode
//!   with the cache stored at f32/f16/bf16/i8, identical inputs and
//!   (asserted) identical routed blocks; CI floors the f16-vs-f32
//!   per-token speedup — the fused in-tile dequant regression gate.
//! * [`serve_soak`] — paged-KV serving soak: fork-heavy session
//!   families through the coordinator, unbounded pool vs a tight page
//!   budget; CI floors the fork `prefix_hit_rate` and the bitwise
//!   `parity_ok` of the pressured leg.
//! * [`chaos_soak`] — the chaos-parity gate: identical traffic with
//!   and without an active fault plan (injected kernel panics, page
//!   denials, corrupted inputs, wave stalls); CI floors
//!   `chaos_parity_ok` (non-faulted sessions bitwise identical) and
//!   `no_worker_deaths` (the worker survives and keeps serving).
//! * [`smallblock`] — flash_moba vs dense across block ∈ {16, 32, 64}
//!   at fixed N (the paper's small-block regime), through the
//!   zero-allocation `forward_into` path; CI floors the B=32 speedup.
//! * [`snr_harness`] — Eq. 1–3 validation: closed form vs Monte-Carlo,
//!   plus paper-scale retrieval curves (the Tables 3–4 shape at 64K).
//! * [`report`] — aligned-table printing + JSON result persistence.

pub mod chaos_soak;
pub mod decode;
pub mod decode_batch;
pub mod figures;
pub mod kvdtype;
pub mod report;
pub mod serve_soak;
pub mod smallblock;
pub mod snr_harness;
pub mod tables;
