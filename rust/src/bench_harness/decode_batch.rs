//! `bench decode-batch` — batched cross-session decode throughput:
//! aggregate tokens/s of ONE `forward_decode_batch` launch over B
//! steady-state sessions, against the same B sessions stepped by B
//! sequential `forward_decode` calls.
//!
//! Single-row decode is pure memory-bound work — one launch per token
//! cannot saturate cores no matter how good the microkernels are. The
//! batched launch partitions whole sessions across the pool, so
//! aggregate throughput grows with B until the cores are covered while
//! every session's output stays bit-identical to the sequential loop
//! (asserted here on every measurement, and pinned by the property
//! suite). CI floors the B=16-vs-B=1 aggregate speedup.

use std::time::Instant;

#[allow(unused_imports)]
use crate::attention::backend::AttentionBackend;
use crate::attention::backend::BackendRegistry;
use crate::attention::decode::DecodeSession;
use crate::attention::testutil::Rng;
use crate::attention::{packed_rows, AttnShape};
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

use super::report::{self, Table};

/// One (backend, batch size) decode-batch measurement.
#[derive(Debug, Clone)]
pub struct DecodeBatchPoint {
    pub backend: String,
    pub batch: usize,
    pub context_n: usize,
    /// aggregate tokens/s of the batched launch
    pub batched_tok_s: f64,
    /// aggregate tokens/s of the sequential per-session loop
    pub sequential_tok_s: f64,
}

/// Build `b` independent sessions at steady state (context `shape.n`,
/// untimed prefill via per-token appends) plus one packed batch query
/// (the concatenation of each session's `(h, d)` step row).
fn build_sessions(shape: &AttnShape, b: usize, seed: u64) -> (Vec<DecodeSession>, Vec<f32>) {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let mut sessions = Vec::with_capacity(b);
    let mut q = Vec::with_capacity(b * h * d);
    for i in 0..b {
        let mut rng = Rng::new(seed.wrapping_add(1 + i as u64));
        let ks = rng.normal_vec(h_kv * n * d);
        let vs = rng.normal_vec(h_kv * n * d);
        let mut sess = DecodeSession::new(h, h_kv, d, block, topk);
        for t in 0..n {
            sess.append(&packed_rows(&ks, h_kv, n, d, t), &packed_rows(&vs, h_kv, n, d, t));
        }
        q.extend_from_slice(&rng.normal_vec(h * d));
        sessions.push(sess);
    }
    (sessions, q)
}

/// Measure one backend at one batch size: aggregate tokens/s of the
/// batched launch and of the sequential per-session loop, over `steps`
/// steady-state steps (no appends while timing — every step sees the
/// identical cache). Asserts the batched output is `to_bits`-identical
/// to the sequential loop's before timing.
pub fn measure_decode_batch(
    ctx: &ExecCtx,
    backend: &dyn AttentionBackend,
    shape: &AttnShape,
    b: usize,
    steps: usize,
    seed: u64,
) -> (f64, f64) {
    let (h, d) = (shape.h, shape.d);
    let (mut batched, q) = build_sessions(shape, b, seed);
    let mut sequential = batched.clone();

    // correctness guard: one batched step == the sequential loop, bitwise
    let mut o = Vec::new();
    backend.forward_decode_batch_into(ctx, &mut batched, &q, &mut o);
    let mut row = Vec::new();
    for (i, sess) in sequential.iter_mut().enumerate() {
        backend.forward_decode_into(ctx, sess, &q[i * h * d..(i + 1) * h * d], &mut row);
        let win = &o[i * h * d..(i + 1) * h * d];
        assert!(
            row.iter().zip(win).all(|(a, z)| a.to_bits() == z.to_bits()),
            "batched decode differs from sequential (backend={} b={b} session={i})",
            backend.name()
        );
    }

    let t0 = Instant::now();
    for _ in 0..steps {
        backend.forward_decode_batch_into(ctx, &mut batched, &q, &mut o);
    }
    let batched_tok_s = (b * steps) as f64 / t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    for _ in 0..steps {
        for (i, sess) in sequential.iter_mut().enumerate() {
            backend.forward_decode_into(ctx, sess, &q[i * h * d..(i + 1) * h * d], &mut row);
        }
    }
    let sequential_tok_s = (b * steps) as f64 / t1.elapsed().as_secs_f64();
    (batched_tok_s, sequential_tok_s)
}

/// The `bench decode-batch` target: sweep B ∈ {1, 4, 16, 64} (quick:
/// up to 16) per backend. Returns the CI floor metrics:
/// `agg_speedup_b16` — the best backend's aggregate-throughput ratio of
/// the batched launch at B=16 over B=1 — and `monotonic_b1_to_b16`
/// (1.0 when that backend's aggregate throughput rises monotonically
/// B=1 → 4 → 16).
pub fn run_decode_batch(cfg: &AppConfig, quick: bool) -> Result<Vec<(String, f64)>> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let d = cfg.bench.head_dim;
    let block = cfg.bench.block;
    let topk = cfg.bench.topk;
    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    let n = if quick { 1024 } else { 4096 };
    let steps = if quick { 16 } else { 64 };
    let batches: &[usize] = if quick { &[1, 4, 16] } else { &[1, 4, 16, 64] };
    let shape = AttnShape::new(h, h_kv, n, d, block, topk);

    let mut t = Table::new(
        &format!(
            "bench decode-batch — aggregate decode throughput vs batch size  \
             [N={n}, B={block}, k={topk}, d={d}, h={h}/{h_kv}, threads={}]",
            ctx.threads()
        ),
        &["backend", "batch", "batched tok/s", "sequential tok/s", "batched/seq"],
    );
    let mut blob = Vec::new();
    let mut agg_speedup_b16: f64 = 0.0;
    let mut monotonic = 0.0;
    for backend in registry.iter() {
        let mut per_b: Vec<(usize, f64)> = Vec::new();
        for &b in batches {
            let (bat, seq) =
                measure_decode_batch(ctx, backend, &shape, b, steps, 0xBA7C4 + b as u64);
            per_b.push((b, bat));
            t.row(vec![
                backend.name().to_string(),
                b.to_string(),
                format!("{bat:.0}"),
                format!("{seq:.0}"),
                format!("{:.2}", bat / seq),
            ]);
            blob.push(Json::obj(vec![
                ("backend", Json::from(backend.name())),
                ("batch", Json::from(b)),
                ("context_n", Json::from(n)),
                ("batched_tok_s", Json::from(bat)),
                ("sequential_tok_s", Json::from(seq)),
            ]));
        }
        let tok = |b: usize| per_b.iter().find(|&&(x, _)| x == b).map(|&(_, s)| s);
        if let (Some(s1), Some(s4), Some(s16)) = (tok(1), tok(4), tok(16)) {
            let speedup = s16 / s1;
            if speedup > agg_speedup_b16 {
                agg_speedup_b16 = speedup;
                monotonic = if s1 <= s4 && s4 <= s16 { 1.0 } else { 0.0 };
            }
        }
    }
    t.print();
    println!(
        "headline: one batched launch at B=16 serves {agg_speedup_b16:.1}x the aggregate \
         decode throughput of B=1 (best backend, {} threads)\n",
        ctx.threads()
    );
    report::save_json(
        &cfg.results_dir,
        "decode-batch",
        &Json::obj(vec![
            ("rows", Json::arr(blob)),
            ("agg_speedup_b16", Json::from(agg_speedup_b16)),
            ("monotonic_b1_to_b16", Json::from(monotonic)),
        ]),
    )?;
    Ok(vec![
        ("agg_speedup_b16".to_string(), agg_speedup_b16),
        ("monotonic_b1_to_b16".to_string(), monotonic),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_agrees_with_sequential_and_counts_tokens() {
        let registry = BackendRegistry::with_defaults();
        let shape = AttnShape::single(96, 16, 16, 2);
        for backend in registry.iter() {
            // the bitwise batched==sequential guard inside measure is
            // the actual assertion; throughputs just need to be finite
            let (bat, seq) =
                measure_decode_batch(ExecCtx::global(), backend, &shape, 3, 2, 42);
            assert!(bat > 0.0 && bat.is_finite(), "{}", backend.name());
            assert!(seq > 0.0 && seq.is_finite(), "{}", backend.name());
        }
    }

    #[test]
    fn build_sessions_are_independent_and_steady() {
        let shape = AttnShape::single(64, 16, 16, 1);
        let (sessions, q) = build_sessions(&shape, 4, 7);
        assert_eq!(sessions.len(), 4);
        assert_eq!(q.len(), 4 * shape.h * shape.d);
        for s in &sessions {
            assert_eq!(s.len(), 64);
        }
        // different seeds per session: the packed queries differ
        assert!(q[..shape.d] != q[shape.d..2 * shape.d]);
    }
}
