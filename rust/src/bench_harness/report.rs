//! Result formatting: aligned console tables (paper-row style) + JSON
//! persistence under `results/`.

use std::path::Path;

use crate::util::json::Json;
use crate::Result;

/// Simple aligned table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn ms(x: f64) -> String {
    format!("{:.1}", x * 1e3)
}

pub fn mb(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / 1e6)
}

/// Write a JSON result blob under `dir/name.json`.
pub fn save_json(dir: &Path, name: &str, value: &Json) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string_pretty())?;
    println!("[results] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "ppl"]);
        t.row(vec!["dense".into(), "19.6".into()]);
        t.row(vec!["moba-128".into(), "19.7".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("dense"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn row_width_enforced() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f1(1.25), "1.2");
        assert_eq!(f2(1.257), "1.26");
        assert_eq!(ms(0.0123), "12.3");
        assert_eq!(mb(2_500_000), "2.5");
    }
}
