//! Result formatting: aligned console tables (paper-row style) + JSON
//! persistence under `results/`, including the machine-readable
//! `BENCH_<target>.json` summaries the CI perf job consumes.

use std::path::Path;

use crate::config::BenchParams;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

/// Simple aligned table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("== {} ==\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn ms(x: f64) -> String {
    format!("{:.1}", x * 1e3)
}

pub fn mb(bytes: u64) -> String {
    format!("{:.1}", bytes as f64 / 1e6)
}

/// Write a JSON result blob under `dir/name.json`.
pub fn save_json(dir: &Path, name: &str, value: &Json) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_string_pretty())?;
    println!("[results] wrote {}", path.display());
    Ok(())
}

/// Build the machine-readable `BENCH_<target>.json` blob (see README.md
/// §Performance for the schema):
///
/// ```json
/// {
///   "target": "parity", "quick": true, "threads": 4, "wall_s": 1.2,
///   "config": {"block": 128, "topk": 8, "head_dim": 64, "heads": 1, "kv_heads": 1},
///   "metrics": {"speedup_vs_dense": 2.1}
/// }
/// ```
pub fn bench_summary(
    target: &str,
    wall_s: f64,
    quick: bool,
    bench: &BenchParams,
    metrics: &[(String, f64)],
) -> Json {
    Json::obj(vec![
        ("target", Json::from(target)),
        ("quick", Json::from(quick)),
        ("threads", Json::from(ExecCtx::global().threads())),
        ("wall_s", Json::from(wall_s)),
        (
            "config",
            Json::obj(vec![
                ("block", Json::from(bench.block)),
                ("topk", Json::from(bench.topk)),
                ("head_dim", Json::from(bench.head_dim)),
                ("heads", Json::from(bench.heads)),
                ("kv_heads", Json::from(bench.kv_heads)),
            ]),
        ),
        (
            "metrics",
            Json::Obj(metrics.iter().map(|(k, v)| (k.clone(), Json::from(*v))).collect()),
        ),
    ])
}

/// Write `BENCH_<target>.json` under `dir` (the artifact the CI
/// perf-smoke job uploads and `flash-moba bench-check` gates on).
pub fn save_bench_summary(
    dir: &Path,
    target: &str,
    wall_s: f64,
    quick: bool,
    bench: &BenchParams,
    metrics: &[(String, f64)],
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("BENCH_{target}.json"));
    std::fs::write(&path, bench_summary(target, wall_s, quick, bench, metrics).to_string_pretty())?;
    println!("[bench] wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["model", "ppl"]);
        t.row(vec!["dense".into(), "19.6".into()]);
        t.row(vec!["moba-128".into(), "19.7".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("dense"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn row_width_enforced() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f1(1.25), "1.2");
        assert_eq!(f2(1.257), "1.26");
        assert_eq!(ms(0.0123), "12.3");
        assert_eq!(mb(2_500_000), "2.5");
    }

    /// The BENCH_* schema the CI floor check parses: target, threads,
    /// config and a flat numeric metrics object.
    #[test]
    fn bench_summary_schema() {
        let bench = BenchParams::default();
        let metrics = vec![("speedup_vs_dense".to_string(), 2.5)];
        let blob = bench_summary("parity", 1.25, true, &bench, &metrics);
        let parsed = Json::parse(&blob.to_string_pretty()).unwrap();
        assert_eq!(parsed.req("target").unwrap().as_str(), Some("parity"));
        assert_eq!(parsed.req("quick").unwrap().as_bool(), Some(true));
        assert!(parsed.req("threads").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(parsed.req("wall_s").unwrap().as_f64(), Some(1.25));
        let cfg = parsed.req("config").unwrap();
        assert_eq!(cfg.req("block").unwrap().as_usize(), Some(bench.block));
        assert_eq!(cfg.req("heads").unwrap().as_usize(), Some(bench.heads));
        assert_eq!(cfg.req("kv_heads").unwrap().as_usize(), Some(bench.kv_heads));
        let m = parsed.req("metrics").unwrap();
        assert_eq!(m.req("speedup_vs_dense").unwrap().as_f64(), Some(2.5));
    }
}
