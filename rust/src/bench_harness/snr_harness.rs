//! SNR-model validation harness (paper §3, Appendix A) and the
//! paper-scale retrieval predictions backing Tables 3–4's shape.


use crate::config::AppConfig;
use crate::util::json::Json;
use crate::snr::{simulate_retrieval, theory, McConfig};
use crate::Result;

use super::report::{self, Table};

/// Theory-vs-Monte-Carlo across (d, B) + the two design principles.
pub fn run_snr(cfg: &AppConfig, trials: usize) -> Result<()> {
    // ---- Eq.3 validation sweep: SNR ∝ sqrt(d/B)
    let mut t = Table::new(
        "SNR model — theory vs Monte-Carlo (Δμ=1, n=64 blocks, k=8)",
        &["d", "B", "SNR", "p_fail (theory)", "p_fail (MC)", "top-k ok (theory)", "top-k ok (MC)"],
    );
    let mut points = Vec::new();
    for &d in &[32usize, 64, 128] {
        for &b in &[64usize, 128, 256, 512] {
            let mc = simulate_retrieval(McConfig {
                d,
                block: b,
                trials,
                ..Default::default()
            });
            t.row(vec![
                d.to_string(),
                b.to_string(),
                report::f2(mc.snr),
                format!("{:.4}", mc.predicted_pairwise_fail),
                format!("{:.4}", mc.pairwise_fail),
                format!("{:.3}", mc.predicted_success),
                format!("{:.3}", mc.success_rate),
            ]);
            points.push(Json::obj(vec![
                ("d", Json::from(d)),
                ("B", Json::from(b)),
                ("snr", Json::from(mc.snr)),
                ("p_fail_theory", Json::from(mc.predicted_pairwise_fail)),
                ("p_fail_mc", Json::from(mc.pairwise_fail)),
                ("topk_theory", Json::from(mc.predicted_success)),
                ("topk_mc", Json::from(mc.success_rate)),
            ]));
        }
    }
    t.print();

    // ---- clustering multiplier (§3.3 principle 2 / kconv mechanism)
    let mut t2 = Table::new(
        "Clustering boost — m related tokens in the block (Δμ=0.5, B=128)",
        &["m", "μ_cluster gain", "SNR", "top-k ok (MC)"],
    );
    let mut cluster_points = Vec::new();
    for &(m, gain) in &[(1usize, 0.0f64), (2, 0.3), (4, 0.3), (8, 0.3), (4, 0.5)] {
        let mc = simulate_retrieval(McConfig {
            delta_mu: 0.5,
            m,
            cluster_gain: gain,
            trials,
            ..Default::default()
        });
        t2.row(vec![
            m.to_string(),
            format!("{gain}"),
            report::f2(mc.snr),
            format!("{:.3}", mc.success_rate),
        ]);
        cluster_points.push(Json::obj(vec![
            ("m", Json::from(m)),
            ("gain", Json::from(gain)),
            ("snr", Json::from(mc.snr)),
            ("mc", Json::from(mc.success_rate)),
        ]));
    }
    t2.print();

    // ---- paper-scale retrieval curves (Tables 3-4 shape at 8K..64K)
    // paper configs at N tokens: B in {512,256,128}, k in {2,4,8}
    let mut t3 = Table::new(
        "Predicted retrieval vs context (paper configs, Δμ_eff=1.4, d=64)",
        &["N tokens", "MoBA-512 k=2", "MoBA-256 k=4", "MoBA-128 k=8"],
    );
    let mut curve_points = Vec::new();
    for &n_tokens in &[4096usize, 8192, 16384, 32768, 65536] {
        let mut row = vec![n_tokens.to_string()];
        for &(b, k) in &[(512usize, 2usize), (256, 4), (128, 8)] {
            let mc = simulate_retrieval(McConfig {
                d: 64,
                block: b,
                n_blocks: (n_tokens / b).max(2),
                topk: k,
                delta_mu: 1.4,
                trials,
                ..Default::default()
            });
            row.push(format!("{:.0}%", 100.0 * mc.success_rate));
            curve_points.push(Json::obj(vec![
                ("n_tokens", Json::from(n_tokens)),
                ("B", Json::from(b)),
                ("k", Json::from(k)),
                ("success", Json::from(mc.success_rate)),
            ]));
        }
        t3.row(row);
    }
    t3.print();
    println!("shape check vs paper Table 3: smaller B holds accuracy to much longer contexts\n");

    report::save_json(
        &cfg.results_dir,
        "snr",
        &Json::obj(vec![
            ("eq3_sweep", Json::arr(points)),
            ("clustering", Json::arr(cluster_points)),
            ("paper_scale_retrieval", Json::arr(curve_points)),
            (
                "reliability_criterion_example",
                Json::obj(vec![
                    ("n_blocks", Json::from(512usize)),
                    ("k", Json::from(8usize)),
                    ("required_snr", Json::from(theory::normal_icdf(1.0 - 8.0 / 512.0))),
                ]),
            ),
        ]),
    )
}
