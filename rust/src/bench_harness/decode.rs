//! `bench decode` — incremental-decode throughput on the CPU attention
//! substrate: per-token latency of every registered backend's
//! `forward_decode` at steady-state context lengths, plus a
//! decode↔prefill parity check on small shapes.
//!
//! The story mirrors Figure 3 for serving: dense decode reads the whole
//! cache (O(N·d) per token), routed MoBA decode reads (k+1)·B rows
//! (O(k·B·d)) — so the speedup grows linearly in N while the routing
//! cost stays at one centroid dot per complete block.

use std::time::Instant;

#[allow(unused_imports)]
use crate::attention::backend::AttentionBackend;
use crate::attention::backend::BackendRegistry;
use crate::attention::decode::DecodeSession;
use crate::attention::testutil::Rng;
use crate::attention::{packed_rows, AttnShape};
use crate::config::AppConfig;
use crate::eval::decode_eval;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

use super::report::{self, Table};

/// One (backend, context length) decode measurement.
#[derive(Debug, Clone)]
pub struct DecodePoint {
    pub backend: String,
    pub context_n: usize,
    pub per_token_s: f64,
    /// blocks attended per step (incl. the own block)
    pub routed_blocks: usize,
    /// K/V bytes gathered from the cache per step
    pub gathered_bytes: u64,
}

/// Time `steps` decode queries against a fixed context of length
/// `shape.n`, with `shape`'s head layout (one packed step covers every
/// query head). The session is pre-filled by appending `n` tokens
/// (untimed), then each timed step routes + attends without appending,
/// so every backend sees the identical steady-state cache.
pub fn measure_decode(
    ctx: &ExecCtx,
    registry: &BackendRegistry,
    shape: &AttnShape,
    steps: usize,
    seed: u64,
) -> Vec<DecodePoint> {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let mut rng = Rng::new(seed);
    let ks = rng.normal_vec(h_kv * n * d);
    let vs = rng.normal_vec(h_kv * n * d);
    let qs = rng.normal_vec(steps * h * d);
    let mut points = Vec::new();
    for backend in registry.iter() {
        let mut sess = DecodeSession::new(h, h_kv, d, block, topk);
        for t in 0..n {
            sess.append(&packed_rows(&ks, h_kv, n, d, t), &packed_rows(&vs, h_kv, n, d, t));
        }
        let t0 = Instant::now();
        for s in 0..steps {
            let o = backend.forward_decode(ctx, &mut sess, &qs[s * h * d..(s + 1) * h * d]);
            debug_assert_eq!(o.len(), h * d);
        }
        let per_token_s = t0.elapsed().as_secs_f64() / steps as f64;
        points.push(DecodePoint {
            backend: backend.name().to_string(),
            context_n: n,
            per_token_s,
            routed_blocks: sess.last_routed_blocks(),
            gathered_bytes: sess.last_gathered_bytes(),
        });
    }
    points
}

/// The `bench decode` target: parity table + per-token latency sweep.
/// Returns the headline routed-vs-dense per-token speedup (the CI perf
/// job's floor metric).
pub fn run_decode(cfg: &AppConfig, quick: bool) -> Result<f64> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();

    // 1) decode↔prefill parity on small shapes (every backend),
    //    single-head, MHA and GQA layouts
    let shapes = vec![
        AttnShape::single(128, 16, 16, 2),
        AttnShape::single(96, 8, 16, 6), // fully routed
        AttnShape::single(256, 8, 32, 3),
        AttnShape::new(4, 2, 96, 8, 16, 2), // GQA
    ];
    let parity = decode_eval(ctx, &registry, &shapes, 0xDEC0);
    let mut pt = Table::new(
        "Decode parity — token-by-token forward_decode vs prefill forward",
        &["backend", "H", "Hkv", "N", "B", "k", "max|Δ| vs prefill", "us/token"],
    );
    for r in &parity {
        assert!(
            r.max_dev_vs_prefill < 1e-4,
            "decode parity violated: {} dev {:.2e} at N={} h={}",
            r.backend,
            r.max_dev_vs_prefill,
            r.n,
            r.h
        );
        pt.row(vec![
            r.backend.clone(),
            r.h.to_string(),
            r.h_kv.to_string(),
            r.n.to_string(),
            r.block.to_string(),
            r.topk.to_string(),
            format!("{:.1e}", r.max_dev_vs_prefill),
            format!("{:.1}", r.per_token_s * 1e6),
        ]);
    }
    pt.print();

    // 2) steady-state per-token latency vs context length
    let d = cfg.bench.head_dim;
    let block = cfg.bench.block;
    let topk = cfg.bench.topk;
    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    let lens: Vec<usize> = if quick { vec![1024, 4096] } else { vec![1024, 4096, 16384] };
    let steps = if quick { 32 } else { 128 };
    let mut t = Table::new(
        &format!(
            "bench decode — per-token latency vs context  [B={block}, k={topk}, d={d}, h={h}/{h_kv}]"
        ),
        &["backend", "context N", "us/token", "blocks/step", "gathered KB/step"],
    );
    let mut blob = Vec::new();
    let mut headline: f64 = 0.0;
    for &n in &lens {
        let shape = AttnShape::new(h, h_kv, n, d, block, topk);
        let points = measure_decode(ctx, &registry, &shape, steps, 0xDEC0DE + n as u64);
        let dense_s = points
            .iter()
            .find(|p| p.backend == "dense")
            .map(|p| p.per_token_s);
        for p in &points {
            t.row(vec![
                p.backend.clone(),
                p.context_n.to_string(),
                format!("{:.1}", p.per_token_s * 1e6),
                p.routed_blocks.to_string(),
                format!("{:.1}", p.gathered_bytes as f64 / 1e3),
            ]);
            blob.push(Json::obj(vec![
                ("backend", Json::from(p.backend.as_str())),
                ("h", Json::from(h)),
                ("h_kv", Json::from(h_kv)),
                ("context_n", Json::from(p.context_n)),
                ("per_token_s", Json::from(p.per_token_s)),
                ("routed_blocks", Json::from(p.routed_blocks)),
                ("gathered_bytes", Json::from(p.gathered_bytes)),
            ]));
            if p.backend == "flash_moba" {
                if let Some(ds) = dense_s {
                    headline = headline.max(ds / p.per_token_s);
                }
            }
        }
    }
    t.print();
    println!(
        "headline: routed decode up to {headline:.1}x faster per token than dense \
         decode at these contexts\n"
    );
    report::save_json(
        &cfg.results_dir,
        "decode",
        &Json::obj(vec![
            ("rows", Json::arr(blob)),
            ("headline_speedup_vs_dense", Json::from(headline)),
        ]),
    )?;
    Ok(headline)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_covers_all_backends_and_sparse_gathers_less() {
        let registry = BackendRegistry::with_defaults();
        // 8 blocks, k=1: routed decode touches 2 blocks vs dense's 8
        let shape = AttnShape::single(256, 8, 32, 1);
        let points = measure_decode(ExecCtx::global(), &registry, &shape, 4, 9);
        assert_eq!(points.len(), registry.len());
        let dense = points.iter().find(|p| p.backend == "dense").unwrap();
        let flash = points.iter().find(|p| p.backend == "flash_moba").unwrap();
        assert_eq!(dense.routed_blocks, 8);
        assert_eq!(flash.routed_blocks, 2);
        assert!(flash.gathered_bytes < dense.gathered_bytes);
        assert!(dense.per_token_s > 0.0 && flash.per_token_s > 0.0);
    }

    #[test]
    fn gqa_measure_sums_blocks_over_query_heads() {
        let registry = BackendRegistry::with_defaults();
        let shape = AttnShape::new(4, 2, 256, 8, 32, 1);
        let points = measure_decode(ExecCtx::global(), &registry, &shape, 2, 10);
        let dense = points.iter().find(|p| p.backend == "dense").unwrap();
        let flash = points.iter().find(|p| p.backend == "flash_moba").unwrap();
        // per query head: dense reads 8 blocks, routed reads 2
        assert_eq!(dense.routed_blocks, 4 * 8);
        assert_eq!(flash.routed_blocks, 4 * 2);
        assert!(flash.gathered_bytes < dense.gathered_bytes);
    }
}
