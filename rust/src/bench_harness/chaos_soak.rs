//! `bench chaos-soak` — the chaos-parity gate: replay identical
//! decode traffic through the coordinator twice, once fault-free and
//! once under an active [`FaultPlan`](crate::util::faults::FaultPlan)
//! that panics one session's kernel launches, denies another's page
//! admissions, corrupts a third's inputs and stalls every wave — then
//! hard-fail unless
//!
//! * every **non-faulted** session's outputs are `to_bits`-identical
//!   to the fault-free run (crash isolation must be invisible to the
//!   math: innocent wave siblings are re-executed solo after a caught
//!   panic, and the batched-vs-solo bitwise contract makes that
//!   re-execution exact);
//! * every **faulted** session terminates loudly with the right typed
//!   [`ServeError`] sequence (`KernelPanic` once, `SessionPoisoned`
//!   ever after; `InvalidInput` for corrupted inputs) — never a hang,
//!   never a silently dropped step (`served_n` is audited per step);
//! * the worker thread survives: a liveness probe session created
//!   *after* the chaos must serve, and an expired-deadline step must
//!   shed with `DeadlineExceeded`.
//!
//! The whole pair runs at `MOBA_THREADS` ∈ {1, 4}; the fault-free
//! leg's outputs must also match bitwise *across* thread counts (the
//! repo-wide determinism contract). CI floors `chaos_parity_ok` and
//! `no_worker_deaths` at 1.0.

use std::sync::atomic::Ordering::Relaxed;
use std::time::{Duration, Instant};

use crate::attention::testutil::Rng;
use crate::config::{AppConfig, ServeParams};
use crate::coordinator::{AttnKind, Coordinator, ServeError};
use crate::util::json::Json;
use crate::Result;

use super::report::{self, Table};

/// Session ids cursed by the fault plan. Session ids are assigned
/// 1..=sessions in creation order (asserted at runtime); the plan
/// keys these exact ids, so the roles are deterministic:
/// * `PANIC_SID` — every kernel launch touching it panics (injected);
///   its first step must come back `KernelPanic`, the rest
///   `SessionPoisoned`, and its wave siblings must be unharmed.
/// * `DENY_SID` — every page admission is transiently denied; its
///   steps are delayed through the retry/park/pace machinery but must
///   serve **bitwise identically** (it counts toward parity).
/// * `CORRUPT_SID` — one K element of each step is NaN'd before
///   validation; every step must be rejected `InvalidInput`.
const PANIC_SID: u64 = 2;
const DENY_SID: u64 = 3;
const CORRUPT_SID: u64 = 5;

/// Chaos soak geometry: `families` fork groups of `1 + forks_per`
/// sessions (forks included so quarantine interacts with CoW pages),
/// each prefilled `n0` tokens then decoded `steps` tokens.
#[derive(Debug, Clone, Copy)]
pub struct ChaosSpec {
    pub families: usize,
    pub forks_per: usize,
    pub n0: usize,
    pub steps: usize,
    pub h: usize,
    pub h_kv: usize,
    pub d: usize,
    pub block: usize,
    pub topk: usize,
}

impl ChaosSpec {
    pub fn quick(d: usize) -> Self {
        Self { families: 2, forks_per: 2, n0: 32, steps: 12, h: 2, h_kv: 1, d, block: 16, topk: 2 }
    }

    pub fn full(d: usize) -> Self {
        Self { families: 2, forks_per: 2, n0: 128, steps: 32, h: 2, h_kv: 1, d, block: 32, topk: 2 }
    }

    fn sessions(&self) -> usize {
        self.families * (1 + self.forks_per)
    }

    /// One session's worst-case page footprint, used to size a
    /// generous (never saturated) page budget — chaos parity is about
    /// injected denials, not real pressure (serve-soak covers that).
    fn footprint(&self) -> usize {
        self.h_kv * (self.n0 + self.steps).div_ceil(self.block)
    }

    fn fault_spec(&self) -> String {
        format!(
            "7:kernel_panic@{PANIC_SID},alloc_deny@{DENY_SID},corrupt_input@{CORRUPT_SID},wave_stall=1.0"
        )
    }
}

/// One decode step's outcome: the packed output row, or the error the
/// coordinator answered with (expected and audited for cursed sids).
type StepRes = std::result::Result<Vec<f32>, anyhow::Error>;

/// One leg's fault-machinery counters plus the liveness verdict.
#[derive(Debug, Default)]
pub struct LegReport {
    pub panics_caught: u64,
    pub sessions_poisoned: u64,
    pub retries: u64,
    pub deadline_sheds: u64,
    pub rejected: u64,
    pub probe_err: Option<String>,
}

/// Deterministic traffic, generated once and replayed on every leg.
struct Traffic {
    prompts: Vec<(Vec<f32>, Vec<f32>)>,
    rows: Vec<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
}

fn build_traffic(spec: &ChaosSpec, seed: u64) -> Traffic {
    let mut rng = Rng::new(seed);
    let prompts = (0..spec.families)
        .map(|_| {
            (rng.normal_vec(spec.h_kv * spec.n0 * spec.d), rng.normal_vec(spec.h_kv * spec.n0 * spec.d))
        })
        .collect();
    let rows = (0..spec.sessions())
        .map(|_| {
            (0..spec.steps)
                .map(|_| {
                    (
                        rng.normal_vec(spec.h * spec.d),
                        rng.normal_vec(spec.h_kv * spec.d),
                        rng.normal_vec(spec.h_kv * spec.d),
                    )
                })
                .collect()
        })
        .collect();
    Traffic { prompts, rows }
}

/// Run one leg: prefill + fork the families, interleave `steps`
/// decode rounds across every session (errors collected, not
/// propagated — cursed sessions are *supposed* to fail), then probe
/// liveness and deadline shedding on a fresh session. Every `Ok`
/// step's `served_n` is audited against the session's own count of
/// served steps, so a silently dropped or reordered step fails the
/// leg even before the bitwise comparison.
fn run_chaos_leg(
    spec: &ChaosSpec,
    traffic: &Traffic,
    fault_spec: Option<&str>,
) -> Result<(Vec<Vec<StepRes>>, LegReport)> {
    let params = ServeParams {
        max_batch: 8,
        max_wait_ms: 1,
        queue_capacity: 4096,
        moba_block: spec.block,
        moba_topk: spec.topk,
        // generous: ~4x the whole working set, so every denial the
        // chaos leg sees is injected, never real pressure
        max_pages: 4 * spec.sessions() * spec.footprint(),
        fault_plan: fault_spec.map(str::to_string),
        ..Default::default()
    };
    let coord = Coordinator::start("/nonexistent/flash-moba-artifacts", params)?;

    let mut sids = Vec::with_capacity(spec.sessions());
    for (k0, v0) in &traffic.prompts {
        let parent = coord.session_create(AttnKind::Moba, spec.h, spec.h_kv, spec.d)?;
        coord.session_prefill(parent, spec.n0, k0.clone(), v0.clone())?;
        sids.push(parent);
        for _ in 0..spec.forks_per {
            sids.push(coord.session_fork(parent)?);
        }
    }
    // the fault plan keys concrete session ids — if numbering ever
    // changes, miss loudly here rather than "pass" by injecting nothing
    let expect: Vec<u64> = (1..=spec.sessions() as u64).collect();
    if sids != expect {
        return Err(anyhow::anyhow!(
            "session ids {sids:?} != {expect:?}: the fault plan's keyed sids would miss"
        ));
    }

    let mut outs: Vec<Vec<StepRes>> = (0..sids.len()).map(|_| Vec::new()).collect();
    for t in 0..spec.steps {
        let tickets: Vec<_> = sids
            .iter()
            .enumerate()
            .map(|(i, &sid)| {
                let (q, k, v) = &traffic.rows[i][t];
                coord.decode_async(sid, q.clone(), k.clone(), v.clone())
            })
            .collect::<Result<_>>()?;
        for (i, ticket) in tickets.into_iter().enumerate() {
            let res = ticket.wait();
            if let Ok(resp) = &res {
                let expect_n = spec.n0 + outs[i].iter().filter(|r| r.is_ok()).count() + 1;
                if resp.served_n != expect_n {
                    return Err(anyhow::anyhow!(
                        "session {} step {t}: served_n {} != {expect_n} — a step was \
                         silently dropped or reordered",
                        sids[i],
                        resp.served_n
                    ));
                }
            }
            outs[i].push(res.map(|r| r.o));
        }
    }

    // liveness + deadline probes on a *fresh* session: a worker that
    // died (or wedged) during the chaos cannot answer any of this
    let probe = (|| -> Result<()> {
        let sid = coord.session_create(AttnKind::Moba, spec.h, spec.h_kv, spec.d)?;
        let (k0, v0) = &traffic.prompts[0];
        coord.session_prefill(sid, spec.n0, k0.clone(), v0.clone())?;
        let (q, k, v) = &traffic.rows[0][0];
        let resp = coord.decode_async(sid, q.clone(), k.clone(), v.clone())?.wait()?;
        if !resp.o.iter().all(|x| x.is_finite()) {
            return Err(anyhow::anyhow!("liveness probe produced non-finite output"));
        }
        // a dead-on-arrival deadline must shed loudly and typed,
        // leaving the session's cache untouched
        let (q, k, v) = &traffic.rows[0][1];
        let dl = Instant::now() - Duration::from_millis(1);
        let shed = coord
            .decode_deadline_async(sid, q.clone(), k.clone(), v.clone(), Some(dl))?
            .wait();
        match shed {
            Err(e)
                if matches!(ServeError::of(&e), Some(ServeError::DeadlineExceeded { .. })) => {}
            Ok(_) => return Err(anyhow::anyhow!("expired-deadline step served instead of shedding")),
            Err(e) => {
                return Err(anyhow::anyhow!("expired-deadline step: wrong error class: {e:#}"))
            }
        }
        coord.session_free(sid)?;
        Ok(())
    })();

    let m = coord.metrics();
    let rep = LegReport {
        panics_caught: m.panics_caught.load(Relaxed),
        sessions_poisoned: m.sessions_poisoned.load(Relaxed),
        retries: m.retries.load(Relaxed),
        deadline_sheds: m.deadline_sheds.load(Relaxed),
        rejected: m.rejected.load(Relaxed),
        probe_err: probe.err().map(|e| format!("{e:#}")),
    };
    // freeing works for live AND quarantined sessions (for the
    // poisoned sid this clears the quarantine record)
    for sid in sids {
        coord.session_free(sid)?;
    }
    coord.shutdown();
    Ok((outs, rep))
}

fn is_err<F: Fn(&ServeError) -> bool>(r: &StepRes, f: F) -> bool {
    matches!(r, Err(e) if ServeError::of(e).is_some_and(|se| f(se)))
}

/// Audit one chaos leg against its fault-free twin. Returns an error
/// describing the first violated clause; parity (clause 1) is also
/// what the `chaos_parity_ok` floor pins.
fn check_pair(
    spec: &ChaosSpec,
    free: &[Vec<StepRes>],
    chaos: &[Vec<StepRes>],
    free_rep: &LegReport,
    chaos_rep: &LegReport,
) -> Result<()> {
    // 1 — non-faulted sessions (the alloc-denied one included: its
    // steps are delayed, never dropped) must match bitwise
    for i in 0..spec.sessions() {
        let sid = i as u64 + 1;
        if sid == PANIC_SID || sid == CORRUPT_SID {
            continue;
        }
        for t in 0..spec.steps {
            match (&free[i][t], &chaos[i][t]) {
                (Ok(a), Ok(b))
                    if a.len() == b.len()
                        && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()) => {}
                (Ok(_), Ok(_)) => {
                    return Err(anyhow::anyhow!(
                        "chaos parity broken: session {sid} step {t} served different bits \
                         under the fault plan"
                    ))
                }
                (a, b) => {
                    return Err(anyhow::anyhow!(
                        "non-faulted session {sid} step {t}: free={} chaos={} (both must serve)",
                        if a.is_ok() { "ok" } else { "err" },
                        if b.is_ok() { "ok" } else { "err" }
                    ))
                }
            }
        }
    }
    // 2 — the panicked session: one typed KernelPanic blaming exactly
    // it, then SessionPoisoned for every later step
    let p = &chaos[(PANIC_SID - 1) as usize];
    if !is_err(&p[0], |se| {
        matches!(se, ServeError::KernelPanic { session: Some(s), .. } if *s == PANIC_SID)
    }) {
        return Err(anyhow::anyhow!(
            "session {PANIC_SID} step 0: expected KernelPanic{{session: {PANIC_SID}}}, got {:?}",
            p[0].as_ref().map(|_| "ok")
        ));
    }
    if let Some(t) = (1..spec.steps).find(|&t| {
        !is_err(&p[t], |se| {
            matches!(se, ServeError::SessionPoisoned { session } if *session == PANIC_SID)
        })
    }) {
        return Err(anyhow::anyhow!(
            "session {PANIC_SID} step {t}: expected SessionPoisoned after the quarantine"
        ));
    }
    // 3 — the corrupted session: every step rejected with the typed
    // input-validation error (caught by the finite check, not the kernel)
    let c = &chaos[(CORRUPT_SID - 1) as usize];
    if let Some(t) =
        (0..spec.steps).find(|&t| !is_err(&c[t], |se| matches!(se, ServeError::InvalidInput { .. })))
    {
        return Err(anyhow::anyhow!(
            "session {CORRUPT_SID} step {t}: expected InvalidInput for the corrupted step"
        ));
    }
    // 4 — the fault machinery actually ran (batched panic + solo
    // re-run are two caught panics minimum), and exactly one session
    // was quarantined
    if chaos_rep.panics_caught < 2 || chaos_rep.sessions_poisoned != 1 || chaos_rep.retries < 1 {
        return Err(anyhow::anyhow!(
            "chaos leg counters off: panics_caught={} (want >= 2), sessions_poisoned={} \
             (want 1), retries={} (want >= 1)",
            chaos_rep.panics_caught,
            chaos_rep.sessions_poisoned,
            chaos_rep.retries
        ));
    }
    if chaos_rep.deadline_sheds < 1 || free_rep.deadline_sheds < 1 {
        return Err(anyhow::anyhow!("the expired-deadline probe never shed"));
    }
    // 5 — a disabled plan is a perfect no-op
    if free_rep.panics_caught != 0 || free_rep.sessions_poisoned != 0 || free_rep.retries != 0 {
        return Err(anyhow::anyhow!(
            "fault-free leg touched the fault machinery: panics={} poisoned={} retries={}",
            free_rep.panics_caught,
            free_rep.sessions_poisoned,
            free_rep.retries
        ));
    }
    Ok(())
}

/// Both legs at one thread count.
fn run_pair(
    spec: &ChaosSpec,
    traffic: &Traffic,
) -> Result<(Vec<Vec<StepRes>>, Vec<Vec<StepRes>>, LegReport, LegReport)> {
    let (free, free_rep) = run_chaos_leg(spec, traffic, None)?;
    let (chaos, chaos_rep) = run_chaos_leg(spec, traffic, Some(&spec.fault_spec()))?;
    Ok((free, chaos, free_rep, chaos_rep))
}

/// The full soak: the leg pair at `MOBA_THREADS` ∈ {1, 4}, every
/// clause audited, plus the cross-thread bitwise check on the
/// fault-free leg. Returns `(parity, no_deaths, last chaos report)`.
pub fn run_chaos_soak_inner(spec: &ChaosSpec, seed: u64) -> Result<(f64, f64, Vec<(usize, LegReport, LegReport)>)> {
    let traffic = build_traffic(spec, seed);
    let mut reports = Vec::new();
    let mut reference: Option<Vec<Vec<Vec<f32>>>> = None;
    for threads in [1usize, 4] {
        std::env::set_var("MOBA_THREADS", threads.to_string());
        let (free, chaos, free_rep, chaos_rep) = run_pair(spec, &traffic)?;
        for rep in [&free_rep, &chaos_rep] {
            if let Some(e) = &rep.probe_err {
                return Err(anyhow::anyhow!(
                    "worker liveness probe failed at {threads} threads: {e}"
                ));
            }
        }
        check_pair(spec, &free, &chaos, &free_rep, &chaos_rep)
            .map_err(|e| anyhow::anyhow!("at MOBA_THREADS={threads}: {e}"))?;
        // the fault-free leg is all-Ok (checked above for every
        // non-cursed sid; cursed sids are only cursed under the plan)
        let bits: Vec<Vec<Vec<f32>>> = free
            .into_iter()
            .map(|sess| sess.into_iter().map(|r| r.unwrap_or_default()).collect())
            .collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => {
                let same = r.iter().zip(&bits).all(|(a, b)| {
                    a.iter().zip(b).all(|(x, y)| {
                        x.len() == y.len()
                            && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
                    })
                });
                if !same {
                    return Err(anyhow::anyhow!(
                        "fault-free outputs differ across thread counts — the determinism \
                         contract broke before chaos even entered"
                    ));
                }
            }
        }
        reports.push((threads, free_rep, chaos_rep));
    }
    Ok((1.0, 1.0, reports))
}

/// The `bench chaos-soak` target. CI floors `chaos_parity_ok` and
/// `no_worker_deaths` at 1.0; any violated clause errors the run
/// outright (which fails CI the same way).
pub fn run_chaos_soak(cfg: &AppConfig, quick: bool) -> Result<Vec<(String, f64)>> {
    let d = cfg.bench.head_dim;
    let spec = if quick { ChaosSpec::quick(d) } else { ChaosSpec::full(d) };

    // the legs own their fault plans via ServeParams; an ambient
    // MOBA_FAULTS would override *both* legs and sabotage the parity
    // baseline, so park it (and the thread override) for the duration
    let saved_faults = std::env::var("MOBA_FAULTS").ok();
    let saved_threads = std::env::var("MOBA_THREADS").ok();
    std::env::remove_var("MOBA_FAULTS");
    let result = run_chaos_soak_inner(&spec, 0xC4A5);
    match saved_threads {
        Some(v) => std::env::set_var("MOBA_THREADS", v),
        None => std::env::remove_var("MOBA_THREADS"),
    }
    if let Some(v) = saved_faults {
        std::env::set_var("MOBA_FAULTS", v);
    }
    let (parity_ok, no_deaths, reports) = result?;

    let mut t = Table::new(
        &format!(
            "bench chaos-soak — crash isolation under an active fault plan  \
             [{} sessions, n0={}, steps={}, cursed: panic@{PANIC_SID} deny@{DENY_SID} \
             corrupt@{CORRUPT_SID}]",
            spec.sessions(),
            spec.n0,
            spec.steps
        ),
        &["threads", "leg", "panics", "poisoned", "retries", "sheds", "rejected"],
    );
    for (threads, free_rep, chaos_rep) in &reports {
        for (name, r) in [("fault-free", free_rep), ("chaos", chaos_rep)] {
            t.row(vec![
                threads.to_string(),
                name.to_string(),
                r.panics_caught.to_string(),
                r.sessions_poisoned.to_string(),
                r.retries.to_string(),
                r.deadline_sheds.to_string(),
                r.rejected.to_string(),
            ]);
        }
    }
    t.print();
    let last = &reports[reports.len() - 1].2;
    println!(
        "headline: {} injected kernel panics caught, {} session quarantined, {} admission \
         retries — every non-faulted session bitwise identical to the fault-free run \
         (chaos_parity_ok={parity_ok})\n",
        last.panics_caught, last.sessions_poisoned, last.retries
    );
    report::save_json(
        &cfg.results_dir,
        "chaos-soak",
        &Json::obj(vec![
            ("chaos_parity_ok", Json::from(parity_ok)),
            ("no_worker_deaths", Json::from(no_deaths)),
            ("panics_caught", Json::from(last.panics_caught as f64)),
            ("sessions_poisoned", Json::from(last.sessions_poisoned as f64)),
            ("retries", Json::from(last.retries as f64)),
            ("deadline_sheds", Json::from(last.deadline_sheds as f64)),
        ]),
    )?;
    Ok(vec![
        ("chaos_parity_ok".to_string(), parity_ok),
        ("no_worker_deaths".to_string(), no_deaths),
    ])
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    /// A miniature chaos pair at the ambient thread count (no env
    /// mutation — sibling test threads also read MOBA_THREADS).
    #[test]
    fn mini_chaos_pair_holds_parity_and_quarantines() {
        // an ambient MOBA_FAULTS (CI's chaos leg) overrides both legs'
        // configured plans — the fault-free baseline would not be
        // fault-free. The full bench parks the variable; a parallel
        // unit test cannot safely mutate the process environment, so
        // it steps aside instead.
        if std::env::var("MOBA_FAULTS").is_ok() {
            return;
        }
        let spec = ChaosSpec {
            families: 2,
            forks_per: 2,
            n0: 16,
            steps: 6,
            h: 2,
            h_kv: 1,
            d: 8,
            block: 8,
            topk: 2,
        };
        let traffic = build_traffic(&spec, 0x3A0);
        let (free, chaos, free_rep, chaos_rep) = run_pair(&spec, &traffic).unwrap();
        assert!(free_rep.probe_err.is_none(), "{:?}", free_rep.probe_err);
        assert!(chaos_rep.probe_err.is_none(), "{:?}", chaos_rep.probe_err);
        check_pair(&spec, &free, &chaos, &free_rep, &chaos_rep).unwrap();
    }
}
