//! `bench kvdtype` — quantized-KV decode throughput: per-token latency
//! of routed flash_moba decode with the cache stored at each
//! [`KvDtype`], against the f32 baseline on identical inputs.
//!
//! Decode at long context is gather-bound: every step reads (k+1)·B
//! K/V rows out of the cache and does O(d) work per row, so halving the
//! stored bytes (f16/bf16) — or quartering them (i8) — moves the
//! bottleneck directly. Dequantization happens inside the register
//! tiles of the fused kernels (no materialized f32 copy), and routing
//! centroids stay f32, so the routed block set is identical across
//! dtypes — the sweep asserts that, plus a quantization-error bound on
//! the outputs. Emits `BENCH_kvdtype.json`; CI floors
//! `speedup_f16_vs_f32` — the regression gate for the fused dequant
//! microkernels (a naive expand-to-f32-then-attend implementation
//! fails it, because it adds traffic instead of removing any).

use std::time::Instant;

use crate::attention::backend::{AttentionBackend, BackendRegistry};
use crate::attention::decode::DecodeSession;
use crate::attention::testutil::Rng;
use crate::attention::{packed_rows, AttnShape, KvDtype};
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

use super::report::{self, Table};

/// One dtype's decode measurement at a fixed context.
struct DtypePoint {
    dtype: KvDtype,
    per_token_s: f64,
    /// K/V bytes gathered from the cache per step
    gathered_bytes: u64,
    /// blocks attended per step (must match the f32 leg — routing is
    /// dtype-independent)
    routed_blocks: usize,
    /// max over steps of max|o − o_f32| / max|o_f32|
    max_rel_err: f64,
}

/// Acceptable output deviation vs the f32 cache, per storage dtype.
/// f16 keeps 11 significand bits (≲1e-3 per element; headroom for
/// softmax amplification), bf16 keeps 8, i8 rides a per-row scale.
fn rel_err_bound(dtype: KvDtype) -> f64 {
    match dtype {
        KvDtype::F32 => 0.0,
        KvDtype::F16 => 2e-2,
        KvDtype::Bf16 => 1e-1,
        KvDtype::I8 => 2e-1,
    }
}

/// Time `steps` routed decode queries against an `n`-token context
/// stored at each dtype. Every leg appends the *same* f32 token rows
/// (quantization happens inside the cache) and routes the same
/// queries, so the only variable is the storage width.
fn measure_dtypes(
    ctx: &ExecCtx,
    backend: &dyn AttentionBackend,
    shape: &AttnShape,
    steps: usize,
    seed: u64,
) -> Vec<DtypePoint> {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let mut rng = Rng::new(seed);
    let ks = rng.normal_vec(h_kv * n * d);
    let vs = rng.normal_vec(h_kv * n * d);
    let qs = rng.normal_vec(steps * h * d);
    // the f32 leg runs first and supplies the error baseline for the
    // quantized legs
    let mut baseline: Vec<Vec<f32>> = Vec::new();
    let mut points = Vec::new();
    for dtype in KvDtype::ALL {
        let mut sess = DecodeSession::new(h, h_kv, d, block, topk).with_dtype(dtype);
        for t in 0..n {
            sess.append(&packed_rows(&ks, h_kv, n, d, t), &packed_rows(&vs, h_kv, n, d, t));
        }
        // untimed warmup step so every leg measures steady state
        backend.forward_decode(ctx, &mut sess, &qs[..h * d]);
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(steps);
        let t0 = Instant::now();
        for s in 0..steps {
            outs.push(backend.forward_decode(ctx, &mut sess, &qs[s * h * d..(s + 1) * h * d]));
        }
        let per_token_s = t0.elapsed().as_secs_f64() / steps as f64;
        let max_rel_err = if dtype == KvDtype::F32 {
            baseline = outs.clone();
            0.0
        } else {
            outs.iter()
                .zip(&baseline)
                .map(|(o, b)| {
                    let scale = b.iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1e-6);
                    o.iter()
                        .zip(b)
                        .map(|(x, y)| ((x - y).abs() / scale) as f64)
                        .fold(0.0, f64::max)
                })
                .fold(0.0, f64::max)
        };
        points.push(DtypePoint {
            dtype,
            per_token_s,
            gathered_bytes: sess.last_gathered_bytes(),
            routed_blocks: sess.last_routed_blocks(),
            max_rel_err,
        });
    }
    points
}

/// The `bench kvdtype` target: decode-latency sweep over KV storage
/// dtypes at a gather-bound context. Returns the headline metrics for
/// `BENCH_kvdtype.json` — the floor-gated `speedup_f16_vs_f32` plus
/// the other dtypes' speedups and a `quant_ok` validity bit (1.0 when
/// every dtype kept the f32 routed-block count and stayed inside its
/// error bound).
pub fn run_kvdtype(cfg: &AppConfig, quick: bool) -> Result<Vec<(String, f64)>> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let flash = registry.get("flash_moba").expect("flash_moba registered");

    let n = if quick { 8192 } else { 16384 };
    let steps = if quick { 32 } else { 128 };
    let d = cfg.bench.head_dim;
    let block = cfg.bench.block.max(1);
    let topk = cfg.bench.topk.max(1);
    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    let shape = AttnShape::new(h, h_kv, n, d, block, topk);

    let mut t = Table::new(
        &format!(
            "bench kvdtype — routed decode per-token latency vs KV storage dtype  \
             [N={n}, B={block}, k={topk}, d={d}, h={h}/{h_kv}, {} threads]",
            ctx.threads()
        ),
        &["kv dtype", "us/token", "speedup vs f32", "gathered KB/step", "max rel err"],
    );
    let points = measure_dtypes(ctx, flash, &shape, steps, 0xD71FE);
    let f32_point = &points[0];
    assert_eq!(f32_point.dtype, KvDtype::F32);
    let f32_s = f32_point.per_token_s;
    let f32_blocks = f32_point.routed_blocks;

    let mut rows = Vec::new();
    let mut metrics: Vec<(String, f64)> = Vec::new();
    let mut quant_ok = true;
    for p in &points {
        let speedup = f32_s / p.per_token_s.max(1e-12);
        quant_ok &=
            p.routed_blocks == f32_blocks && p.max_rel_err <= rel_err_bound(p.dtype);
        t.row(vec![
            p.dtype.as_str().to_string(),
            format!("{:.1}", p.per_token_s * 1e6),
            format!("{speedup:.2}x"),
            format!("{:.1}", p.gathered_bytes as f64 / 1e3),
            format!("{:.1e}", p.max_rel_err),
        ]);
        rows.push(Json::obj(vec![
            ("kv_dtype", Json::from(p.dtype.as_str())),
            ("context_n", Json::from(n)),
            ("per_token_s", Json::from(p.per_token_s)),
            ("speedup_vs_f32", Json::from(speedup)),
            ("gathered_bytes", Json::from(p.gathered_bytes)),
            ("routed_blocks", Json::from(p.routed_blocks)),
            ("max_rel_err", Json::from(p.max_rel_err)),
        ]));
        if p.dtype != KvDtype::F32 {
            metrics.push((format!("speedup_{}_vs_f32", p.dtype.as_str()), speedup));
        }
    }
    metrics.push(("quant_ok".into(), if quant_ok { 1.0 } else { 0.0 }));
    t.print();
    println!(
        "memory-traffic story: routed decode is gather-bound, so halving the stored \
         K/V bytes (f16) buys per-token latency directly — with routing (f32 \
         centroids) picking the identical block set at every dtype\n"
    );
    report::save_json(
        &cfg.results_dir,
        "kvdtype",
        &Json::obj(vec![("rows", Json::arr(rows))]),
    )?;
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The sweep covers every dtype, keeps the routed block set, stays
    /// inside each dtype's error bound, and gathers strictly fewer
    /// bytes per step at every narrower storage width.
    #[test]
    fn dtype_sweep_preserves_routing_and_bounds_error() {
        let registry = BackendRegistry::with_defaults();
        let flash = registry.get("flash_moba").unwrap();
        let shape = AttnShape::single(256, 16, 32, 2);
        let points = measure_dtypes(ExecCtx::global(), flash, &shape, 4, 7);
        assert_eq!(points.len(), KvDtype::ALL.len());
        let f32_p = &points[0];
        assert_eq!(f32_p.dtype, KvDtype::F32);
        for p in &points[1..] {
            assert_eq!(p.routed_blocks, f32_p.routed_blocks, "{:?}", p.dtype);
            assert!(
                p.max_rel_err <= rel_err_bound(p.dtype),
                "{:?}: rel err {:.2e}",
                p.dtype,
                p.max_rel_err
            );
            let expect = f32_p.gathered_bytes / 4 * p.dtype.elem_bytes() as u64;
            assert_eq!(p.gathered_bytes, expect, "{:?}", p.dtype);
        }
    }
}
