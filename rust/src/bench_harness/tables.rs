//! Tables 1–6 + Figure 2: train the scaled §5.1 variants from scratch
//! through the AOT train-step artifacts, then evaluate. One training run
//! per variant is cached as a checkpoint and shared by all tables.
//!
//! Scale mapping (README.md §Architecture): `tiny-*` == the paper's 340M family,
//! `small-*` == the 1B family; `tiny-moba128/64/32` == paper
//! MoBA-512/256/128 (same candidate-block counts and k ladder at the
//! testbed's 1024-token training context).

use std::path::PathBuf;
use std::time::Instant;

use crate::attention::backend::{self, AttentionBackend, BackendRegistry, ParityTolerance};
use crate::attention::testutil::qkv_packed;
use crate::attention::AttnShape;
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::longbench;
use crate::data::niah::NiahVariant;
use crate::eval::{substrate_eval, Evaluator};
use crate::runtime::{ParamStore, Runtime};
use crate::train::Trainer;
use crate::Result;

use super::report::{self, Table};

/// Display order of variants per scale (paper table row order).
pub fn variants_of(scale: &str) -> Vec<&'static str> {
    match scale {
        "tiny" => vec![
            "tiny-dense",
            "tiny-moba128",
            "tiny-moba64",
            "tiny-moba32",
            "tiny-moba32-kconv3",
            "tiny-moba32-kconv5",
        ],
        "small" => vec![
            "small-dense",
            "small-moba32",
            "small-moba32-kconv3",
            "small-moba32-kconv5",
        ],
        other => panic!("unknown scale {other}"),
    }
}

/// Paper-row label for a variant.
pub fn paper_label(variant: &str) -> String {
    match variant {
        "tiny-dense" | "small-dense" => "Dense".into(),
        "tiny-moba128" => "MoBA-512*".into(),
        "tiny-moba64" => "MoBA-256*".into(),
        "tiny-moba32" | "small-moba32" => "MoBA-128*".into(),
        "tiny-moba32-kconv3" | "small-moba32-kconv3" => "+ kconv3".into(),
        "tiny-moba32-kconv5" | "small-moba32-kconv5" => "+ kconv5".into(),
        other => other.into(),
    }
}

fn ckpt_path(cfg: &AppConfig, variant: &str, steps: usize) -> PathBuf {
    cfg.results_dir.join("ckpt").join(format!("{variant}_s{steps}.bin"))
}

/// Train (or load a cached checkpoint of) a variant for `steps` steps.
pub fn ensure_trained(
    cfg: &AppConfig,
    runtime: &Runtime,
    corpus: &Corpus,
    variant: &str,
) -> Result<ParamStore> {
    let steps = cfg.train.steps;
    let path = ckpt_path(cfg, variant, steps);
    if path.exists() {
        println!("[train] {variant}: using cached checkpoint {}", path.display());
        return Trainer::load_checkpoint(runtime, variant, &path);
    }
    println!("[train] {variant}: training {steps} steps...");
    let mut tr = Trainer::new(runtime, variant)?;
    let mut tcfg = cfg.train.clone();
    tcfg.steps = steps;
    tr.run(corpus, &tcfg, |log| {
        println!(
            "[train] {variant} step {:>4}  loss {:.4}  lr {:.2e}  ({:.2}s/step)",
            log.step, log.loss, log.lr, log.step_time_s
        );
    })?;
    std::fs::create_dir_all(path.parent().unwrap())?;
    let ps = tr.params()?;
    std::fs::write(&path, ps.to_bytes()?)?;
    // persist the loss curve alongside
    tr.checkpoint(&cfg.results_dir.join("ckpt"), &format!("s{steps}"))?;
    Ok(ps)
}

fn corpus_for(runtime: &Runtime, variant: &str) -> Result<Corpus> {
    let spec = runtime.manifest().variant(variant)?;
    Ok(Corpus::new(CorpusConfig { vocab: spec.vocab_size, ..Default::default() }))
}

/// 8 probe tasks standing in for the paper's 8 zero-shot suites.
const LM_PROBES: [&str; 8] = [
    "qasper", "mfield", "hotpotqa", "2wikimqa", "musique", "triviaqa", "lcc", "repobench",
];

/// Tables 1 (scale=tiny) and 2 (scale=small): LM quality.
pub fn run_table_lm(cfg: &AppConfig, runtime: &Runtime, scale: &str) -> Result<()> {
    let table_no = if scale == "tiny" { 1 } else { 2 };
    let mut header = vec!["Model", "ppl↓"];
    header.extend(LM_PROBES);
    header.push("Avg acc↑");
    let mut t = Table::new(
        &format!("Table {table_no} — LM quality ({scale} scale, synthetic corpus)"),
        &header,
    );
    let mut blob = Vec::new();
    for variant in variants_of(scale) {
        let corpus = corpus_for(runtime, variant)?;
        let params = ensure_trained(cfg, runtime, &corpus, variant)?;
        let mut ev = Evaluator::new(runtime, variant, params)?;
        let ppl = ev.perplexity(&corpus, cfg.eval.ppl_batches)?;
        let train_seq = ev.spec().seq_len;
        let mut row = vec![paper_label(variant), report::f1(ppl)];
        let mut accs = Vec::new();
        for task in LM_PROBES {
            let acc = ev.task_score(task, train_seq, cfg.eval.task_samples)?;
            row.push(report::f1(acc));
            accs.push(acc);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        row.push(report::f1(avg));
        t.row(row);
        blob.push(Json::obj(vec![
            ("variant", Json::from(variant)),
            ("ppl", Json::from(ppl)),
            ("probe_acc", Json::arr(accs.iter().map(|&a| Json::from(a)).collect())),
            ("avg", Json::from(avg)),
        ]));
    }
    t.print();
    report::save_json(
        &cfg.results_dir,
        &format!("table{table_no}"),
        &Json::obj(vec![("rows", Json::arr(blob))]),
    )
}

/// Tables 3 (tiny) and 4 (small): S-NIAH retrieval sweeps.
pub fn run_table_niah(cfg: &AppConfig, runtime: &Runtime, scale: &str) -> Result<()> {
    let table_no = if scale == "tiny" { 3 } else { 4 };
    let lens = &cfg.eval.niah_lens;
    let mut header: Vec<String> = vec!["Model".into()];
    for v in NiahVariant::all() {
        for &l in lens {
            header.push(format!("{}@{}", v.label().trim_start_matches("S-NIAH-"), l));
        }
    }
    header.push("Avg".into());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Table {table_no} — S-NIAH retrieval ({scale} scale, trained at 1024)"),
        &hrefs,
    );
    let mut blob = Vec::new();
    for variant in variants_of(scale) {
        let corpus = corpus_for(runtime, variant)?;
        let params = ensure_trained(cfg, runtime, &corpus, variant)?;
        let mut ev = Evaluator::new(runtime, variant, params)?;
        let mut row = vec![paper_label(variant)];
        let mut cells = Vec::new();
        let mut accs: Vec<f64> = Vec::new();
        for v in NiahVariant::all() {
            for &l in lens {
                let acc = ev.niah_accuracy(v, l, cfg.eval.niah_samples)?;
                row.push(format!("{acc:.0}"));
                accs.push(acc);
                cells.push(Json::obj(vec![
                    ("task", Json::from(v.label())),
                    ("len", Json::from(l)),
                    ("acc", Json::from(acc)),
                ]));
            }
        }
        let avg: f64 = accs.iter().sum::<f64>() / accs.len() as f64;
        row.push(report::f1(avg));
        t.row(row);
        blob.push(Json::obj(vec![
            ("variant", Json::from(variant)),
            ("cells", Json::arr(cells)),
            ("avg", Json::from(avg)),
        ]));
    }
    t.print();
    report::save_json(
        &cfg.results_dir,
        &format!("table{table_no}"),
        &Json::obj(vec![("rows", Json::arr(blob))]),
    )
}

/// Tables 5 (tiny) and 6 (small): LongBench-proxy suite.
pub fn run_table_longbench(cfg: &AppConfig, runtime: &Runtime, scale: &str) -> Result<()> {
    let table_no = if scale == "tiny" { 5 } else { 6 };
    let mut header = vec!["Model"];
    header.extend(longbench::TASKS);
    header.push("Avg");
    let mut t = Table::new(
        &format!("Table {table_no} — LongBench-proxy ({scale} scale, ctx {})", cfg.eval.task_len),
        &header,
    );
    let mut blob = Vec::new();
    for variant in variants_of(scale) {
        let corpus = corpus_for(runtime, variant)?;
        let params = ensure_trained(cfg, runtime, &corpus, variant)?;
        let mut ev = Evaluator::new(runtime, variant, params)?;
        let mut row = vec![paper_label(variant)];
        let mut scores = Vec::new();
        for task in longbench::TASKS {
            let sc = ev.task_score(task, cfg.eval.task_len, cfg.eval.task_samples)?;
            row.push(report::f1(sc));
            scores.push(sc);
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        row.push(report::f1(avg));
        t.row(row);
        blob.push(Json::obj(vec![
            ("variant", Json::from(variant)),
            ("scores", Json::arr(scores.iter().map(|&x| Json::from(x)).collect())),
            ("avg", Json::from(avg)),
        ]));
    }
    t.print();
    report::save_json(
        &cfg.results_dir,
        &format!("table{table_no}"),
        &Json::obj(vec![("rows", Json::arr(blob))]),
    )
}

/// Backend parity table: every registered `AttentionBackend` across
/// the verification shape grid — deviation vs the dense oracle,
/// workspace and latency — after *asserting* grid parity through the
/// shared harness, plus a flash-vs-dense speed probe at a
/// Figure-3-scale shape. Runs without artifacts. Returns the probe's
/// speedup (the CI perf job's floor metric).
///
/// The head layout comes from `cfg.bench.heads` / `cfg.bench.kv_heads`
/// (1/1 = the single-head `parity` target; the `parity-gqa` target
/// sets a GQA layout and re-runs the whole table through it).
/// `results_name` is the bench target invoking the run — the rows blob
/// is persisted as `<results>/<results_name>.json`, matching the
/// target's `BENCH_<results_name>.json` summary regardless of the
/// configured head layout.
pub fn run_table_parity(cfg: &AppConfig, quick: bool, results_name: &str) -> Result<f64> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    backend::check_grid_parity(&registry, &ParityTolerance::default())
        .map_err(|e| anyhow::anyhow!("backend parity violated: {e}"))?;

    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    // the grid is re-run for measurement: the assertion harness above
    // keeps pairwise outputs, the table wants timings/workspace — the
    // duplicated forward work is milliseconds at these shapes. With a
    // multi-head bench config the whole grid is mapped onto that head
    // layout (the grid's own single-head rows already ran in the
    // assertion above).
    let shapes: Vec<AttnShape> = if h == 1 && h_kv == 1 {
        backend::parity_grid()
    } else {
        backend::parity_grid().into_iter().map(|s| s.with_heads(h, h_kv)).collect()
    };
    let rows = substrate_eval(ctx, &registry, &shapes, 0xA11CE);
    let mut t = Table::new(
        "Backend parity — registered backends vs the dense oracle (shape grid)",
        &["backend", "H", "Hkv", "N", "B", "k", "density", "max|Δ| vs dense", "ws MB", "fwd ms"],
    );
    let mut blob = Vec::new();
    for r in &rows {
        t.row(vec![
            r.backend.clone(),
            r.h.to_string(),
            r.h_kv.to_string(),
            r.n.to_string(),
            r.block.to_string(),
            r.topk.to_string(),
            format!("{:.2}", r.density),
            format!("{:.1e}", r.max_dev_vs_dense),
            report::mb(r.workspace_bytes),
            report::ms(r.fwd_s),
        ]);
        blob.push(Json::obj(vec![
            ("backend", Json::from(r.backend.as_str())),
            ("h", Json::from(r.h)),
            ("h_kv", Json::from(r.h_kv)),
            ("n", Json::from(r.n)),
            ("block", Json::from(r.block)),
            ("topk", Json::from(r.topk)),
            ("density", Json::from(r.density)),
            ("max_dev_vs_dense", Json::from(r.max_dev_vs_dense as f64)),
            ("fwd_s", Json::from(r.fwd_s)),
            ("workspace_bytes", Json::from(r.workspace_bytes)),
        ]));
    }
    t.print();
    println!(
        "parity OK: {} backends agree with the dense reference (full routing) and each other\n",
        registry.len()
    );

    // speed probe: flash_moba vs dense at one fig3-scale geometry (the
    // grid shapes are too small to separate the backends from noise).
    // This number feeds the hard CI floor, so both backends get a
    // warmup pass and the best of several reps — one scheduling hiccup
    // on a shared runner must not flip the gate.
    let n = if quick { 8192 } else { 16384 };
    let probe = AttnShape::new(h, h_kv, n, cfg.bench.head_dim, cfg.bench.block, cfg.bench.topk);
    let (q, k, v) = qkv_packed(0xBEEF, probe.h, probe.h_kv, probe.n, probe.d);
    let dense = registry.get("dense").expect("dense registered");
    let flash = registry.get("flash_moba").expect("flash_moba registered");
    let best_of = |b: &dyn AttentionBackend| -> f64 {
        b.forward(ctx, &probe, &q, &k, &v); // warmup (page faults, caches)
        let reps = if quick { 2 } else { 3 };
        (0..reps)
            .map(|_| {
                let t0 = Instant::now();
                b.forward(ctx, &probe, &q, &k, &v);
                t0.elapsed().as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min)
    };
    let dense_s = best_of(dense);
    let flash_s = best_of(flash);
    let speedup = dense_s / flash_s.max(1e-12);
    println!(
        "speed probe at N={n} [B={}, k={}, h={}/{}, {} threads]: dense {:.1} ms, \
         flash_moba {:.1} ms -> {speedup:.2}x\n",
        probe.block,
        probe.topk,
        probe.h,
        probe.h_kv,
        ctx.threads(),
        dense_s * 1e3,
        flash_s * 1e3
    );

    report::save_json(
        &cfg.results_dir,
        results_name,
        &Json::obj(vec![
            ("rows", Json::arr(blob)),
            (
                "speed_probe",
                Json::obj(vec![
                    ("n", Json::from(probe.n)),
                    ("h", Json::from(probe.h)),
                    ("h_kv", Json::from(probe.h_kv)),
                    ("threads", Json::from(ctx.threads())),
                    ("dense_s", Json::from(dense_s)),
                    ("flash_moba_s", Json::from(flash_s)),
                    ("speedup_vs_dense", Json::from(speedup)),
                ]),
            ),
        ]),
    )?;
    Ok(speedup)
}

/// `parity-mixed`: the per-head plan path's bitwise-parity gate. Builds
/// a mixed [`RoutePlan`] over the configured GQA layout — even KV heads
/// routed at a small block, odd KV heads planned dense — runs it
/// through every registered backend's `forward_plan`, and compares each
/// output `to_bits`-exactly against a per-head reference splice (each
/// KV head's group run as its own `(group, 1)` launch at that head's
/// effective geometry). Also asserts the uniform-plan fast path is
/// bitwise identical to the plain `forward_into` path. Returns 1.0 iff
/// every comparison matched (the CI floor metric), 0.0 otherwise.
pub fn run_table_parity_mixed(cfg: &AppConfig, quick: bool) -> Result<f64> {
    use crate::attention::plan::{HeadPlan, RoutePlan};

    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let (h, h_kv) = (cfg.bench.heads.max(1), cfg.bench.kv_heads.max(1));
    let group = h / h_kv.max(1);
    anyhow::ensure!(group >= 1 && h == group * h_kv, "parity-mixed needs h a multiple of h_kv");
    let n = if quick { 1024 } else { 2048 };
    let d = cfg.bench.head_dim;
    let heads: Vec<HeadPlan> = (0..h_kv)
        .map(|i| if i % 2 == 0 { HeadPlan::routed(32, 4) } else { HeadPlan::dense(64) })
        .collect();
    let plan = RoutePlan { heads, fallback_margin: f32::NEG_INFINITY, kv_dtype: None };
    let uniform = RoutePlan::uniform(h_kv, cfg.bench.block, cfg.bench.topk.max(1));
    let shape = AttnShape::new(h, h_kv, n, d, cfg.bench.block, cfg.bench.topk.max(1));
    let (q, k, v) = qkv_packed(0xD15C0, h, h_kv, n, d);

    // per-head reference splice: each KV head's group as its own
    // (group, 1) launch at that head's effective geometry — exactly the
    // decomposition forward_plan promises to equal bit for bit
    let splice = |b: &dyn AttentionBackend| -> Vec<f32> {
        let mut full = vec![0.0f32; h * n * d];
        for kvh in 0..h_kv {
            let hp = *plan.head(kvh);
            let qs = &q[kvh * group * n * d..(kvh + 1) * group * n * d];
            let ks = &k[kvh * n * d..(kvh + 1) * n * d];
            let vs = &v[kvh * n * d..(kvh + 1) * n * d];
            let sub = AttnShape::new(group, 1, n, d, hp.block, hp.topk);
            let run = if hp.is_dense() {
                // a planned-dense head runs fully routed (== dense
                // causal through this backend)
                AttnShape { topk: sub.max_candidates().max(1), ..sub }
            } else {
                sub
            };
            let (sub_o, _) = b.forward(ctx, &run, qs, ks, vs);
            full[kvh * group * n * d..(kvh + 1) * group * n * d].copy_from_slice(&sub_o);
        }
        full
    };
    let bitwise = |a: &[f32], b: &[f32]| -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    };

    let mut t = Table::new(
        "Plan-path parity — mixed per-KV-head (block, topk) vs per-head reference splice",
        &["backend", "H", "Hkv", "N", "mixed == splice", "uniform == static", "plan fwd ms"],
    );
    let mut blob = Vec::new();
    let mut all_ok = true;
    for b in registry.iter() {
        let reference = splice(b);
        let t0 = Instant::now();
        let (mixed_o, _) = b.forward_plan(ctx, &shape, &plan, &q, &k, &v);
        let plan_s = t0.elapsed().as_secs_f64();
        let mixed_ok = bitwise(&mixed_o, &reference);
        // the uniform fast path must be the static path, bit for bit
        let (uni_o, _) = b.forward_plan(ctx, &shape, &uniform, &q, &k, &v);
        let mut static_o = Vec::new();
        b.forward_into(ctx, &shape, &q, &k, &v, &mut static_o);
        let uniform_ok = bitwise(&uni_o, &static_o);
        all_ok &= mixed_ok && uniform_ok;
        t.row(vec![
            b.name().to_string(),
            h.to_string(),
            h_kv.to_string(),
            n.to_string(),
            mixed_ok.to_string(),
            uniform_ok.to_string(),
            report::ms(plan_s),
        ]);
        blob.push(Json::obj(vec![
            ("backend", Json::from(b.name())),
            ("mixed_matches_splice", Json::from(mixed_ok)),
            ("uniform_matches_static", Json::from(uniform_ok)),
            ("plan_fwd_s", Json::from(plan_s)),
        ]));
    }
    t.print();
    let parity_ok = if all_ok { 1.0 } else { 0.0 };
    println!(
        "plan-path parity {} at h={h}/h_kv={h_kv}, N={n} ({} threads)\n",
        if all_ok { "OK" } else { "VIOLATED" },
        ctx.threads()
    );

    report::save_json(
        &cfg.results_dir,
        "parity-mixed",
        &Json::obj(vec![
            ("rows", Json::arr(blob)),
            ("n", Json::from(n)),
            ("h", Json::from(h)),
            ("h_kv", Json::from(h_kv)),
            ("threads", Json::from(ctx.threads())),
            ("parity_ok", Json::from(parity_ok)),
        ]),
    )?;
    Ok(parity_ok)
}

/// Figure 2: block-size ablation summary (ppl + NIAH avg vs B), derived
/// from fresh evals of the tiny block-size ladder.
pub fn run_fig2(cfg: &AppConfig, runtime: &Runtime) -> Result<()> {
    let ladder = [("tiny-moba128", 128usize), ("tiny-moba64", 64), ("tiny-moba32", 32)];
    let mut t = Table::new(
        "Figure 2 — smaller blocks improve ppl and retrieval (tiny scale)",
        &["B", "paper-B equiv", "ppl↓", "NIAH avg↑"],
    );
    let mut blob = Vec::new();
    for (variant, b) in ladder {
        let corpus = corpus_for(runtime, variant)?;
        let params = ensure_trained(cfg, runtime, &corpus, variant)?;
        let mut ev = Evaluator::new(runtime, variant, params)?;
        let ppl = ev.perplexity(&corpus, cfg.eval.ppl_batches)?;
        let mut accs = Vec::new();
        for v in NiahVariant::all() {
            for &l in &cfg.eval.niah_lens {
                accs.push(ev.niah_accuracy(v, l, cfg.eval.niah_samples)?);
            }
        }
        let niah = accs.iter().sum::<f64>() / accs.len() as f64;
        t.row(vec![
            b.to_string(),
            (b * 4).to_string(),
            report::f1(ppl),
            report::f1(niah),
        ]);
        blob.push(Json::obj(vec![
            ("B", Json::from(b)),
            ("ppl", Json::from(ppl)),
            ("niah_avg", Json::from(niah)),
        ]));
    }
    t.print();
    report::save_json(&cfg.results_dir, "fig2", &Json::obj(vec![("points", Json::arr(blob))]))
}
