//! Figures 3–4 + §5.3 headline numbers, on the CPU attention substrate.
//!
//! Every forward measurement dispatches through the
//! [`AttentionBackend`] registry, so a newly registered backend shows up
//! in the sweeps and breakdowns without touching this file; what stays
//! per-implementation here is measurement *policy*, not dispatch: the
//! backward timings (not part of the trait), the analytic workspace
//! curves, and the single-core timing caps in [`fwd_cap`]/[`bwd_cap`]
//! (unknown backends get no cap and no backward point).
//!
//! Figure 3 (latency & memory vs N): dense FA-2 analogue vs original
//! MoBA vs FlashMoBA, forward + backward + top-k decomposition. Points
//! too slow to time on one core are skipped per-impl (the paper skips
//! original-MoBA points past its OOM the same way); memory curves are
//! exact workspace accounting and extend analytically to paper-scale N
//! with the OOM budget marker.
//!
//! Figure 4 (stage breakdown): the original's five stages vs
//! FlashMoBA's two at the largest timed N.

use std::time::Instant;

#[allow(unused_imports)]
use crate::attention::backend::AttentionBackend;
use crate::attention::backend::BackendRegistry;
use crate::attention::backward::{flash_moba_backward, naive_backward};
use crate::attention::flash_moba::{flash_moba_forward, flash_moba_forward_ctx, FlashMobaConfig};
use crate::attention::moba_naive::moba_naive_forward;
use crate::attention::stats::{ws_bytes, StageStats};
use crate::attention::testutil::{qkv_packed, Rng};
use crate::attention::AttnShape;
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::util::pool::ExecCtx;
use crate::Result;

use super::report::{self, Table};

/// Measured timings for one (backend, N) point; `None` = skipped (too
/// slow on this testbed / past the OOM budget — rendered as `--`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Point {
    pub fwd_s: Option<f64>,
    pub bwd_s: Option<f64>,
    pub topk_s: Option<f64>,
    pub workspace: u64,
    pub oom: bool,
}

/// Analytic workspace of the original pipeline (bytes): score tensor +
/// gathered copies + partial outputs (the Figure-3 memory story),
/// per query head (score/gather/partials/local/merge) and per KV head
/// (centroids).
pub fn naive_workspace_bytes(shape: AttnShape) -> u64 {
    let AttnShape { h, h_kv, n, d, topk, .. } = shape;
    let cb = shape.complete_blocks();
    let routed = n * topk; // upper bound on routed pairs per head
    ws_bytes(&[
        h * n * cb,          // score tensor
        h_kv * cb * d,       // centroids
        h * routed * d,      // gathered queries
        h * routed * d,      // partial outputs
        h * routed,          // partial lse
        h * (n * d + n),     // local outputs + lse
        2 * h * n,           // merge workspace
    ])
}

/// Analytic workspace of FlashMoBA (bytes).
pub fn flash_workspace_bytes(shape: AttnShape, cfg: FlashMobaConfig) -> u64 {
    let AttnShape { h, h_kv, n, d, topk, .. } = shape;
    let cb = shape.complete_blocks();
    ws_bytes(&[
        h_kv * cb * d,               // centroids
        cfg.topk_tile + 2 * topk,    // topk running state
        h * (n * topk + 2 * cb),     // varlen layouts (one per head)
        h * (2 * n + n * d),         // m, l, acc accumulators
        cfg.tile_r * d,              // gathered tile
        cfg.tile_r * cfg.tile_c,     // score tile
    ])
}

/// Analytic workspace of the dense FA-2 analogue (bytes).
pub fn dense_workspace_bytes(d: usize, br: usize, bc: usize) -> u64 {
    ws_bytes(&[br * bc, br * d, 2 * br])
}

fn analytic_workspace(name: &str, shape: AttnShape) -> u64 {
    match name {
        "dense" => dense_workspace_bytes(shape.d, 64, 64),
        "moba_naive" => naive_workspace_bytes(shape),
        "flash_moba" => flash_workspace_bytes(shape, FlashMobaConfig::default()),
        _ => 0, // unknown backend: filled from measured stats
    }
}

/// Largest N we time a backend's forward at on one core.
fn fwd_cap(name: &str, quick: bool) -> usize {
    match name {
        "dense" => if quick { 4096 } else { 16384 },
        "moba_naive" => if quick { 8192 } else { 32768 },
        _ => usize::MAX,
    }
}

/// Largest N we time a backend's backward at.
fn bwd_cap(name: &str, quick: bool) -> usize {
    match name {
        "dense" => if quick { 2048 } else { 8192 },
        "moba_naive" => if quick { 8192 } else { 32768 },
        "flash_moba" => usize::MAX,
        _ => 0, // backward is not part of the trait; unknown backends skip
    }
}

/// Sum of the routing-overhead stages a backend reports (the "top-k"
/// decomposition column; labels cover both pipelines).
fn topk_seconds(st: &StageStats) -> f64 {
    ["gating", "reindex", "flash_topk"]
        .iter()
        .copied()
        .filter_map(|label| st.get(label))
        .map(|d| d.as_secs_f64())
        .sum()
}

/// One backward timing, per implementation (Algorithm 5 for FlashMoBA,
/// the materializing baseline otherwise).
fn backward_seconds(
    name: &str,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    shape: AttnShape,
) -> Option<f64> {
    debug_assert_eq!(shape.h, 1, "backward timing is per head");
    match name {
        "dense" => {
            // dense backward == naive_backward with full routing
            let full_shape =
                AttnShape::single(shape.n, shape.d, shape.block, shape.n_blocks());
            let full_idx = full_routing(shape);
            Some(time_reps(1, || {
                naive_backward(q, k, v, dout, full_shape, &full_idx);
            }))
        }
        "moba_naive" => {
            let (_, idx, _) = moba_naive_forward(q, k, v, shape);
            Some(time_reps(1, || {
                naive_backward(q, k, v, dout, shape, &idx);
            }))
        }
        "flash_moba" => {
            let out = flash_moba_forward(q, k, v, shape, FlashMobaConfig::default());
            Some(time_reps(1, || {
                flash_moba_backward(q, k, v, &out.o, &out.lse, dout, shape, &out.layouts[0]);
            }))
        }
        _ => None,
    }
}

/// One Figure-3 sweep row: every registered backend's measurements at N.
pub struct Fig3Row {
    pub n: usize,
    /// (backend name, point) in registry order
    pub points: Vec<(String, Point)>,
}

impl Fig3Row {
    pub fn point(&self, name: &str) -> Option<&Point> {
        self.points.iter().find(|(n, _)| n == name).map(|(_, p)| p)
    }
}

pub fn run_fig3(cfg: &AppConfig, quick: bool) -> Result<Vec<Fig3Row>> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let b = cfg.bench.block;
    let k = cfg.bench.topk;
    let d = cfg.bench.head_dim;
    let (h, h_kv) = (cfg.bench.heads, cfg.bench.kv_heads);
    let reps = if quick { 1 } else { cfg.bench.reps };
    let budget_bytes: u64 = 2 << 30; // 2 GiB workspace budget = "80GB H100" analogue

    let mut rows = Vec::new();
    for &n in &cfg.bench.fig3_lens {
        let shape = AttnShape::new(h, h_kv, n, d, b, k);
        let (q, kk, v) = qkv_packed(1000 + n as u64, h, h_kv, n, d);
        let mut rng = Rng::new(7 + n as u64);
        let dout = rng.normal_vec(n * d);

        let mut points = Vec::new();
        for backend in registry.iter() {
            let name = backend.name();
            let mut p = Point { workspace: analytic_workspace(name, shape), ..Default::default() };
            // any backend whose known workspace exceeds the budget is
            // marked OOM and skipped — in practice only the original
            // pipeline's materialized score matrix hits the cliff
            p.oom = p.workspace > budget_bytes;

            if !p.oom && backend.supports(&shape) && n <= fwd_cap(name, quick) {
                let mut topk_s = 0.0;
                let mut measured_ws = 0u64;
                p.fwd_s = Some(time_reps(reps, || {
                    let (_, st) = backend.forward(ctx, &shape, &q, &kk, &v);
                    topk_s += topk_seconds(&st);
                    measured_ws = st.workspace_bytes;
                }));
                if topk_s > 0.0 {
                    p.topk_s = Some(topk_s / reps as f64);
                }
                if p.workspace == 0 {
                    p.workspace = measured_ws;
                }
            }
            // backward is timed per head; only the single-head sweep
            // reports it (multi-head backward is h independent repeats)
            if !p.oom && h == 1 && backend.supports(&shape) && n <= bwd_cap(name, quick) {
                p.bwd_s = backward_seconds(name, &q, &kk, &v, &dout, shape);
            }
            points.push((name.to_string(), p));
        }
        rows.push(Fig3Row { n, points });
    }
    Ok(rows)
}

fn full_routing(shape: AttnShape) -> Vec<i32> {
    // every strictly-past block routed (dense as a MoBA special case);
    // single-head, like the backward timings that consume it
    let nb = shape.n_blocks();
    let mut idx = vec![-1i32; shape.n * nb];
    for t in 0..shape.n {
        let own = t / shape.block;
        for j in 0..own {
            idx[t * nb + j] = j as i32;
        }
    }
    idx
}

fn time_reps(reps: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn opt_ms(x: Option<f64>) -> String {
    x.map(report::ms).unwrap_or_else(|| "--".into())
}

/// Print Figure 3 and persist JSON. Returns the headline speedup
/// (FlashMoBA vs dense at the largest common timed N).
pub fn print_fig3(cfg: &AppConfig, rows: &[Fig3Row]) -> Result<f64> {
    let names: Vec<String> = rows
        .first()
        .map(|r| r.points.iter().map(|(n, _)| n.clone()).collect())
        .unwrap_or_default();
    let mut header: Vec<String> = vec!["N".into()];
    for name in &names {
        header.push(format!("{name}.topk"));
        header.push(format!("{name}.fwd"));
        header.push(format!("{name}.bwd"));
        header.push(format!("{name}.ws"));
    }
    header.push("note".into());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "Figure 3 — latency (ms) & workspace (MB) vs N  [B={}, k={}, h={}/{}]",
            cfg.bench.block, cfg.bench.topk, cfg.bench.heads, cfg.bench.kv_heads
        ),
        &hrefs,
    );
    let mut headline: f64 = 0.0;
    for r in rows {
        let mut cells = vec![r.n.to_string()];
        let mut notes: Vec<String> = Vec::new();
        for name in &names {
            let p = r.point(name).copied().unwrap_or_default();
            if p.oom {
                notes.push(format!("{name} OOM"));
            }
            cells.push(opt_ms(p.topk_s));
            cells.push(opt_ms(p.fwd_s));
            cells.push(opt_ms(p.bwd_s));
            cells.push(report::mb(p.workspace));
        }
        cells.push(notes.join(", "));
        t.row(cells);
        if let (Some(dp), Some(fp)) = (r.point("dense"), r.point("flash_moba")) {
            if let (Some(dfwd), Some(ffwd)) = (dp.fwd_s, fp.fwd_s) {
                headline = headline.max(dfwd / ffwd);
            }
        }
    }
    t.print();
    println!("headline: FlashMoBA up to {headline:.1}x faster than dense (paper: 14.7x at 512K on H100)\n");

    let blob = Json::obj(vec![
        (
            "rows",
            Json::arr(
                rows.iter()
                    .map(|r| {
                        let mut pairs: Vec<(&str, Json)> = vec![("n", Json::from(r.n))];
                        for (name, p) in &r.points {
                            pairs.push((name.as_str(), point_json(p)));
                        }
                        Json::obj(pairs)
                    })
                    .collect(),
            ),
        ),
        ("headline_speedup_vs_dense", Json::from(headline)),
    ]);
    report::save_json(&cfg.results_dir, "fig3", &blob)?;
    Ok(headline)
}

fn point_json(p: &Point) -> Json {
    Json::obj(vec![
        ("fwd_s", Json::from(p.fwd_s)),
        ("bwd_s", Json::from(p.bwd_s)),
        ("topk_s", Json::from(p.topk_s)),
        ("workspace_bytes", Json::from(p.workspace)),
        ("oom", Json::from(p.oom)),
    ])
}

/// Figure 4: per-stage forward breakdown of every registered backend at
/// one N (five stages for the original, two for FlashMoBA, one for the
/// dense FA-2 analogue).
pub fn run_fig4(cfg: &AppConfig, n: usize) -> Result<()> {
    let ctx = ExecCtx::global();
    let registry = BackendRegistry::with_defaults();
    let shape = AttnShape::new(
        cfg.bench.heads,
        cfg.bench.kv_heads,
        n,
        cfg.bench.head_dim,
        cfg.bench.block,
        cfg.bench.topk,
    );
    let (q, k, v) = qkv_packed(4444, shape.h, shape.h_kv, n, cfg.bench.head_dim);

    let mut t = Table::new(
        &format!("Figure 4 — forward timing breakdown at N={n}  [{} threads]", ctx.threads()),
        &["backend", "stage", "ms", "% of backend total"],
    );
    let mut all_stats: Vec<(String, StageStats)> = Vec::new();
    for backend in registry.iter() {
        if !backend.supports(&shape) {
            continue;
        }
        let (_, st) = backend.forward(ctx, &shape, &q, &k, &v);
        let total = st.total().as_secs_f64().max(1e-12);
        for rec in st.stages() {
            let s = rec.wall.as_secs_f64();
            t.row(vec![
                backend.name().into(),
                rec.name.to_string(),
                report::ms(s),
                format!("{:.0}%", 100.0 * s / total),
            ]);
        }
        all_stats.push((backend.name().to_string(), st));
    }
    t.print();

    let mut overhead_frac = 0.0f64;
    if let Some((_, st)) = all_stats.iter().find(|(name, _)| name == "moba_naive") {
        if let (Some(g), Some(r), Some(m)) = (st.get("gating"), st.get("reindex"), st.get("merge")) {
            overhead_frac = (g + r + m).as_secs_f64() / st.total().as_secs_f64().max(1e-12);
            println!(
                "original MoBA overhead stages (gating+reindex+merge): {:.0}% of runtime (paper: >70%)",
                100.0 * overhead_frac
            );
        }
    }
    let totals: Vec<String> = all_stats
        .iter()
        .map(|(name, st)| format!("{name} {:.1} ms", st.total().as_secs_f64() * 1e3))
        .collect();
    println!("totals: {}\n", totals.join(" | "));

    let stage_arr = |st: &StageStats| {
        Json::arr(
            st.stages()
                .iter()
                .map(|rec| {
                    Json::obj(vec![
                        ("stage", Json::from(rec.name)),
                        ("s", Json::from(rec.wall.as_secs_f64())),
                        ("threads", Json::from(rec.threads)),
                    ])
                })
                .collect(),
        )
    };
    let blob = Json::obj(vec![
        ("n", Json::from(n)),
        (
            "backends",
            Json::obj(
                all_stats
                    .iter()
                    .map(|(name, st)| (name.as_str(), stage_arr(st)))
                    .collect(),
            ),
        ),
        ("original_overhead_fraction", Json::from(overhead_frac)),
    ]);
    report::save_json(&cfg.results_dir, "fig4", &blob)
}

/// Multi-core calibration: the FlashMoBA forward at one Figure-3 shape,
/// serial context vs the process pool. Returns (serial_wall /
/// parallel_wall, pool thread count) — the `multicore_speedup` metric
/// the CI perf job holds against its committed floor. The two runs are
/// bit-identical by the pool's determinism contract; only wall time may
/// differ.
pub fn measure_multicore_speedup(cfg: &AppConfig, quick: bool) -> (f64, usize) {
    let n = if quick { 8192 } else { 16384 };
    let shape = AttnShape::new(
        cfg.bench.heads,
        cfg.bench.kv_heads,
        n,
        cfg.bench.head_dim,
        cfg.bench.block,
        cfg.bench.topk,
    );
    let (q, k, v) = qkv_packed(777, shape.h, shape.h_kv, n, cfg.bench.head_dim);
    let fm = FlashMobaConfig::default();
    let serial = ExecCtx::serial();
    let pooled = ExecCtx::global();
    // warm caches so the first timed run isn't paying page faults
    flash_moba_forward_ctx(&serial, &q, &k, &v, shape, fm);
    flash_moba_forward_ctx(pooled, &q, &k, &v, shape, fm);
    let reps = if quick { 2 } else { 3 };
    let t_serial = time_reps(reps, || {
        flash_moba_forward_ctx(&serial, &q, &k, &v, shape, fm);
    });
    let t_pooled = time_reps(reps, || {
        flash_moba_forward_ctx(pooled, &q, &k, &v, shape, fm);
    });
    (t_serial / t_pooled, pooled.threads())
}

/// Ablation: FlashMoBA physical tile sizes (the §C.2 tuning trade-off).
/// Stays implementation-specific: it sweeps FlashMoBA's own config knob.
pub fn run_tile_ablation(cfg: &AppConfig, n: usize) -> Result<()> {
    let shape = AttnShape::new(
        cfg.bench.heads,
        cfg.bench.kv_heads,
        n,
        cfg.bench.head_dim,
        cfg.bench.block,
        cfg.bench.topk,
    );
    let (q, k, v) = qkv_packed(555, shape.h, shape.h_kv, n, cfg.bench.head_dim);
    let mut t = Table::new(
        &format!("Ablation — physical tile sizes at N={n}"),
        &["tile_r", "tile_c", "fwd ms", "ws MB"],
    );
    let mut results = Vec::new();
    for tile_r in [16, 32, 64, 128] {
        for tile_c in [16, 32, 64, 128] {
            let fm = FlashMobaConfig { tile_r, tile_c, topk_tile: 64 };
            let t0 = Instant::now();
            let out = flash_moba_forward(&q, &k, &v, shape, fm);
            let el = t0.elapsed().as_secs_f64();
            t.row(vec![
                tile_r.to_string(),
                tile_c.to_string(),
                report::ms(el),
                report::mb(out.stats.workspace_bytes),
            ]);
            results.push(Json::obj(vec![
                ("tile_r", Json::from(tile_r as usize)),
                ("tile_c", Json::from(tile_c as usize)),
                ("fwd_s", Json::from(el)),
            ]));
        }
    }
    t.print();
    report::save_json(
        &cfg.results_dir,
        "ablate_tiles",
        &Json::obj(vec![("n", Json::from(n)), ("points", Json::arr(results))]),
    )
}
