//! Figures 3–4 + §5.3 headline numbers, on the CPU attention substrate.
//!
//! Figure 3 (latency & memory vs N): dense FA-2 analogue vs original
//! MoBA vs FlashMoBA, forward + backward + top-k decomposition. Points
//! too slow to time on one core are skipped per-impl (the paper skips
//! original-MoBA points past its OOM the same way); memory curves are
//! exact workspace accounting and extend analytically to paper-scale N
//! with the OOM budget marker.
//!
//! Figure 4 (stage breakdown): the original's five stages vs
//! FlashMoBA's two at the largest timed N.

use std::time::Instant;


use crate::attention::backward::{flash_moba_backward, naive_backward};
use crate::attention::dense::flash_attention;
use crate::attention::flash_moba::{flash_moba_forward, FlashMobaConfig};
use crate::attention::moba_naive::moba_naive_forward;
use crate::attention::stats::ws_bytes;
use crate::attention::testutil::{qkv, Rng};
use crate::attention::MobaShape;
use crate::config::AppConfig;
use crate::util::json::Json;
use crate::Result;

use super::report::{self, Table};

/// Measured timings for one (impl, N) point; `None` = skipped (too slow
/// on this testbed / past the OOM budget — rendered as `--`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Point {
    pub fwd_s: Option<f64>,
    pub bwd_s: Option<f64>,
    pub topk_s: Option<f64>,
    pub workspace: u64,
    pub oom: bool,
}

/// Analytic workspace of the original pipeline (bytes): score matrix +
/// gathered copies + partial outputs (the Figure-3 memory story).
pub fn naive_workspace_bytes(shape: MobaShape) -> u64 {
    let MobaShape { n, d, topk, .. } = shape;
    let nb = shape.n_blocks();
    let routed = n * topk; // upper bound on routed pairs
    ws_bytes(&[
        n * nb,          // score matrix
        nb * d,          // centroids
        routed * d,      // gathered queries
        routed * d,      // partial outputs
        routed,          // partial lse
        n * d + n,       // local outputs + lse
        2 * n,           // merge workspace
    ])
}

/// Analytic workspace of FlashMoBA (bytes).
pub fn flash_workspace_bytes(shape: MobaShape, cfg: FlashMobaConfig) -> u64 {
    let MobaShape { n, d, topk, .. } = shape;
    let nb = shape.n_blocks();
    ws_bytes(&[
        nb * d,                      // centroids
        cfg.topk_tile + 2 * topk,    // topk running state
        n * topk + 2 * nb,           // varlen layout
        2 * n + n * d,               // m, l, acc accumulators
        cfg.tile_r * d,              // gathered tile
        cfg.tile_r * cfg.tile_c,     // score tile
    ])
}

/// Analytic workspace of the dense FA-2 analogue (bytes).
pub fn dense_workspace_bytes(d: usize, br: usize, bc: usize) -> u64 {
    ws_bytes(&[br * bc, br * d, 2 * br])
}

/// One Figure-3 sweep. `budget_bytes` reproduces the OOM cliff.
pub struct Fig3Row {
    pub n: usize,
    pub dense: Point,
    pub naive: Point,
    pub flash: Point,
}

pub fn run_fig3(cfg: &AppConfig, quick: bool) -> Result<Vec<Fig3Row>> {
    let b = cfg.bench.block;
    let k = cfg.bench.topk;
    let d = cfg.bench.head_dim;
    let reps = if quick { 1 } else { cfg.bench.reps };
    let budget_bytes: u64 = 2 << 30; // 2 GiB workspace budget = "80GB H100" analogue
    // single-core time budgets (seconds) per measured point
    let (dense_fwd_cap, dense_bwd_cap, naive_cap) =
        if quick { (4096, 2048, 8192) } else { (16384, 8192, 32768) };

    let mut rows = Vec::new();
    for &n in &cfg.bench.fig3_lens {
        let shape = MobaShape::new(n, d, b, k);
        let (q, kk, v) = qkv(1000 + n as u64, n, d);
        let mut rng = Rng::new(7 + n as u64);

        // ---------------- dense (FA-2 analogue)
        let mut dense = Point { workspace: dense_workspace_bytes(d, 64, 64), ..Default::default() };
        if n <= dense_fwd_cap {
            dense.fwd_s = Some(time_reps(reps, || {
                flash_attention(&q, &kk, &v, n, d, 64, 64);
            }));
        }
        if n <= dense_bwd_cap {
            // dense backward == naive_backward with full routing
            let full_idx = full_routing(shape);
            let dout = rng.normal_vec(n * d);
            let full_shape = MobaShape::new(n, d, b, shape.n_blocks());
            dense.bwd_s = Some(time_reps(1, || {
                naive_backward(&q, &kk, &v, &dout, full_shape, &full_idx);
            }));
        }

        // ---------------- original MoBA
        let naive_ws = naive_workspace_bytes(shape);
        let mut naive = Point { workspace: naive_ws, oom: naive_ws > budget_bytes, ..Default::default() };
        if !naive.oom && n <= naive_cap {
            let mut topk_s = 0.0;
            naive.fwd_s = Some(time_reps(reps, || {
                let (_, _, st) = moba_naive_forward(&q, &kk, &v, shape);
                topk_s += st.get("gating").unwrap().as_secs_f64()
                    + st.get("reindex").unwrap().as_secs_f64();
            }));
            naive.topk_s = Some(topk_s / reps as f64);
            let dout = rng.normal_vec(n * d);
            let (_, idx, _) = moba_naive_forward(&q, &kk, &v, shape);
            naive.bwd_s = Some(time_reps(1, || {
                naive_backward(&q, &kk, &v, &dout, shape, &idx);
            }));
        }

        // ---------------- FlashMoBA
        let fm_cfg = FlashMobaConfig::default();
        let mut flash = Point { workspace: flash_workspace_bytes(shape, fm_cfg), ..Default::default() };
        let mut topk_s = 0.0;
        flash.fwd_s = Some(time_reps(reps, || {
            let out = flash_moba_forward(&q, &kk, &v, shape, fm_cfg);
            topk_s += out.stats.get("flash_topk").unwrap().as_secs_f64();
        }));
        flash.topk_s = Some(topk_s / reps as f64);
        let out = flash_moba_forward(&q, &kk, &v, shape, fm_cfg);
        let dout = rng.normal_vec(n * d);
        flash.bwd_s = Some(time_reps(1, || {
            flash_moba_backward(&q, &kk, &v, &out.o, &out.lse, &dout, shape, &out.layout);
        }));

        rows.push(Fig3Row { n, dense, naive, flash });
    }
    Ok(rows)
}

fn full_routing(shape: MobaShape) -> Vec<i32> {
    // every strictly-past block routed (dense as a MoBA special case)
    let nb = shape.n_blocks();
    let mut idx = vec![-1i32; shape.n * nb];
    for t in 0..shape.n {
        let own = t / shape.block;
        for j in 0..own {
            idx[t * nb + j] = j as i32;
        }
    }
    idx
}

fn time_reps(reps: usize, mut f: impl FnMut()) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn opt_ms(x: Option<f64>) -> String {
    x.map(|v| report::ms(v)).unwrap_or_else(|| "--".into())
}

/// Print Figure 3 and persist JSON. Returns the headline speedup
/// (FlashMoBA vs dense at the largest common timed N).
pub fn print_fig3(cfg: &AppConfig, rows: &[Fig3Row]) -> Result<f64> {
    let mut t = Table::new(
        "Figure 3 — latency (ms) & workspace (MB) vs N  [B=128-analogue, k=8]",
        &[
            "N", "dense.fwd", "dense.bwd", "moba.topk", "moba.fwd", "moba.bwd", "moba.ws",
            "flash.topk", "flash.fwd", "flash.bwd", "flash.ws", "note",
        ],
    );
    let mut headline: f64 = 0.0;
    for r in rows {
        let note = if r.naive.oom { "moba OOM" } else { "" };
        t.row(vec![
            r.n.to_string(),
            opt_ms(r.dense.fwd_s),
            opt_ms(r.dense.bwd_s),
            opt_ms(r.naive.topk_s),
            opt_ms(r.naive.fwd_s),
            opt_ms(r.naive.bwd_s),
            report::mb(r.naive.workspace),
            opt_ms(r.flash.topk_s),
            opt_ms(r.flash.fwd_s),
            opt_ms(r.flash.bwd_s),
            report::mb(r.flash.workspace),
            note.into(),
        ]);
        if let (Some(dfwd), Some(ffwd)) = (r.dense.fwd_s, r.flash.fwd_s) {
            headline = headline.max(dfwd / ffwd);
        }
    }
    t.print();
    println!("headline: FlashMoBA up to {headline:.1}x faster than dense (paper: 14.7x at 512K on H100)\n");

    let blob = Json::obj(vec![
        (
            "rows",
            Json::arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("n", Json::from(r.n)),
                            ("dense", point_json(&r.dense)),
                            ("moba_naive", point_json(&r.naive)),
                            ("flash_moba", point_json(&r.flash)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("headline_speedup_vs_dense", Json::from(headline)),
    ]);
    report::save_json(&cfg.results_dir, "fig3", &blob)?;
    Ok(headline)
}

fn point_json(p: &Point) -> Json {
    Json::obj(vec![
        ("fwd_s", Json::from(p.fwd_s)),
        ("bwd_s", Json::from(p.bwd_s)),
        ("topk_s", Json::from(p.topk_s)),
        ("workspace_bytes", Json::from(p.workspace)),
        ("oom", Json::from(p.oom)),
    ])
}

/// Figure 4: five-stage vs two-stage forward breakdown at one N.
pub fn run_fig4(cfg: &AppConfig, n: usize) -> Result<()> {
    let shape = MobaShape::new(n, cfg.bench.head_dim, cfg.bench.block, cfg.bench.topk);
    let (q, k, v) = qkv(4444, n, cfg.bench.head_dim);

    let (_, _, st_naive) = moba_naive_forward(&q, &k, &v, shape);
    let out = flash_moba_forward(&q, &k, &v, shape, FlashMobaConfig::default());
    let (_, _, dense_ws) = flash_attention(&q, &k, &v, n, cfg.bench.head_dim, 64, 64);
    let t0 = Instant::now();
    flash_attention(&q, &k, &v, n, cfg.bench.head_dim, 64, 64);
    let dense_t = t0.elapsed().as_secs_f64();

    let mut t = Table::new(
        &format!("Figure 4 — forward timing breakdown at N={n}"),
        &["impl", "stage", "ms", "% of impl total"],
    );
    let naive_total = st_naive.total().as_secs_f64();
    for (name, dur) in st_naive.stages() {
        let s = dur.as_secs_f64();
        t.row(vec![
            "MoBA (original)".into(),
            name.clone(),
            report::ms(s),
            format!("{:.0}%", 100.0 * s / naive_total),
        ]);
    }
    let flash_total = out.stats.total().as_secs_f64();
    for (name, dur) in out.stats.stages() {
        let s = dur.as_secs_f64();
        t.row(vec![
            "FlashMoBA".into(),
            name.clone(),
            report::ms(s),
            format!("{:.0}%", 100.0 * s / flash_total),
        ]);
    }
    t.row(vec!["FlashAttention-2".into(), "fwd".into(), report::ms(dense_t), "100%".into()]);
    t.print();

    let overhead_frac = (st_naive.get("gating").unwrap()
        + st_naive.get("reindex").unwrap()
        + st_naive.get("merge").unwrap())
    .as_secs_f64()
        / naive_total;
    println!(
        "original MoBA overhead stages (gating+reindex+merge): {:.0}% of runtime (paper: >70%)",
        100.0 * overhead_frac
    );
    println!(
        "FlashMoBA total {:.1} ms vs dense {:.1} ms vs original {:.1} ms\n",
        flash_total * 1e3,
        dense_t * 1e3,
        naive_total * 1e3
    );

    let stage_arr = |stages: &[(String, std::time::Duration)]| {
        Json::arr(
            stages
                .iter()
                .map(|(s, d)| {
                    Json::obj(vec![
                        ("stage", Json::from(s.as_str())),
                        ("s", Json::from(d.as_secs_f64())),
                    ])
                })
                .collect(),
        )
    };
    let blob = Json::obj(vec![
        ("n", Json::from(n)),
        ("moba_original_stages", stage_arr(st_naive.stages())),
        ("flash_moba_stages", stage_arr(out.stats.stages())),
        ("dense_fwd_s", Json::from(dense_t)),
        ("dense_ws_bytes", Json::from(dense_ws)),
        ("original_overhead_fraction", Json::from(overhead_frac)),
    ]);
    report::save_json(&cfg.results_dir, "fig4", &blob)
}

/// Ablation: FlashMoBA physical tile sizes (the §C.2 tuning trade-off).
pub fn run_tile_ablation(cfg: &AppConfig, n: usize) -> Result<()> {
    let shape = MobaShape::new(n, cfg.bench.head_dim, cfg.bench.block, cfg.bench.topk);
    let (q, k, v) = qkv(555, n, cfg.bench.head_dim);
    let mut t = Table::new(
        &format!("Ablation — physical tile sizes at N={n}"),
        &["tile_r", "tile_c", "fwd ms", "ws MB"],
    );
    let mut results = Vec::new();
    for tile_r in [16, 32, 64, 128] {
        for tile_c in [16, 32, 64, 128] {
            let fm = FlashMobaConfig { tile_r, tile_c, topk_tile: 64 };
            let t0 = Instant::now();
            let out = flash_moba_forward(&q, &k, &v, shape, fm);
            let el = t0.elapsed().as_secs_f64();
            t.row(vec![
                tile_r.to_string(),
                tile_c.to_string(),
                report::ms(el),
                report::mb(out.stats.workspace_bytes),
            ]);
            results.push(Json::obj(vec![
                ("tile_r", Json::from(tile_r as usize)),
                ("tile_c", Json::from(tile_c as usize)),
                ("fwd_s", Json::from(el)),
            ]));
        }
    }
    t.print();
    report::save_json(
        &cfg.results_dir,
        "ablate_tiles",
        &Json::obj(vec![("n", Json::from(n)), ("points", Json::arr(results))]),
    )
}
