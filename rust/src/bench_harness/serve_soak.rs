//! `bench serve-soak` — paged-KV serving soak: fork-heavy session
//! families decoding through the coordinator, once on an unbounded
//! page pool and once under a deliberately tight page budget.
//!
//! The soak builds `families` sessions sharing one prefilled prompt
//! each (parent + copy-on-write forks), then interleaves decode steps
//! across every session so the continuous-batching scheduler sees
//! mixed traffic. Two CI-floored headline metrics come out:
//!
//! * `prefix_hit_rate` — from the unbounded leg: the fraction of
//!   page-table entries satisfied by sharing a fork parent's pages
//!   instead of allocating (the paged allocator's reason to exist);
//! * `parity_ok` — 1.0 when the pressured leg's every served output is
//!   `to_bits`-identical to the unbounded leg's. Preemption, swap-log
//!   replay and deferred admission must be invisible to the math.
//!
//! The pressured leg's budget is sized from the session footprint so
//! the working set cannot be resident at once — preemption round trips
//! are guaranteed, and the run fails if none happened.

use crate::attention::testutil::Rng;
use crate::config::{AppConfig, ServeParams};
use crate::coordinator::{AttnKind, Coordinator};
use crate::util::json::Json;
use crate::Result;

use super::report::{self, Table};

/// Soak geometry: `families` fork groups of `1 + forks_per` sessions,
/// each prefilled with `n0` shared tokens then decoded `steps` tokens.
#[derive(Debug, Clone, Copy)]
pub struct SoakSpec {
    pub families: usize,
    pub forks_per: usize,
    pub n0: usize,
    pub steps: usize,
    pub h: usize,
    pub h_kv: usize,
    pub d: usize,
    pub block: usize,
    pub topk: usize,
}

impl SoakSpec {
    pub fn quick(d: usize) -> Self {
        Self { families: 2, forks_per: 3, n0: 64, steps: 16, h: 2, h_kv: 1, d, block: 32, topk: 2 }
    }

    pub fn full(d: usize) -> Self {
        Self { families: 4, forks_per: 7, n0: 256, steps: 64, h: 2, h_kv: 1, d, block: 32, topk: 2 }
    }

    fn sessions(&self) -> usize {
        self.families * (1 + self.forks_per)
    }

    /// One session's worst-case page footprint (prefix + all decoded
    /// tokens, per KV head) — the unit the pressured budget is sized in.
    fn footprint(&self) -> usize {
        self.h_kv * (self.n0 + self.steps).div_ceil(self.block)
    }
}

/// One leg's counters, read off the coordinator metrics after a gauge
/// barrier (pool gauges sync at the end of each worker turn).
#[derive(Debug, Clone, Copy, Default)]
pub struct LegStats {
    pub prefix_hit_rate: f64,
    pub pages_allocated: u64,
    pub pages_live: u64,
    pub preemptions: u64,
    pub restores: u64,
    pub deferred: u64,
    pub rejected: u64,
}

/// Deterministic soak traffic, generated once and replayed identically
/// on both legs: per-family prompts and per-(session, step) rows.
struct Traffic {
    prompts: Vec<(Vec<f32>, Vec<f32>)>,
    rows: Vec<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>>,
}

fn build_traffic(spec: &SoakSpec, seed: u64) -> Traffic {
    let mut rng = Rng::new(seed);
    let prompts = (0..spec.families)
        .map(|_| {
            (rng.normal_vec(spec.h_kv * spec.n0 * spec.d), rng.normal_vec(spec.h_kv * spec.n0 * spec.d))
        })
        .collect();
    let rows = (0..spec.sessions())
        .map(|_| {
            (0..spec.steps)
                .map(|_| {
                    (
                        rng.normal_vec(spec.h * spec.d),
                        rng.normal_vec(spec.h_kv * spec.d),
                        rng.normal_vec(spec.h_kv * spec.d),
                    )
                })
                .collect()
        })
        .collect();
    Traffic { prompts, rows }
}

/// Run one leg: all families prefilled and forked, then `steps` rounds
/// of one interleaved decode step per session (async within a round, so
/// steps batch across sessions). Returns every served output in
/// (session, step) order plus the leg's paging counters.
/// `max_pages == 0` = unbounded pool.
pub fn run_leg(spec: &SoakSpec, traffic: &Traffic, max_pages: usize) -> Result<(Vec<Vec<f32>>, LegStats)> {
    let params = ServeParams {
        max_batch: 8,
        max_wait_ms: 1,
        queue_capacity: 4096,
        moba_block: spec.block,
        moba_topk: spec.topk,
        max_pages,
        ..Default::default()
    };
    // a dir that never holds artifacts: the CPU-substrate serving path
    let coord = Coordinator::start("/nonexistent/flash-moba-artifacts", params)?;

    let mut sids = Vec::with_capacity(spec.sessions());
    for (k0, v0) in &traffic.prompts {
        let parent = coord.session_create(AttnKind::Moba, spec.h, spec.h_kv, spec.d)?;
        coord.session_prefill(parent, spec.n0, k0.clone(), v0.clone())?;
        sids.push(parent);
        for _ in 0..spec.forks_per {
            sids.push(coord.session_fork(parent)?);
        }
    }

    let mut outs: Vec<Vec<f32>> = vec![Vec::new(); sids.len()];
    for t in 0..spec.steps {
        let tickets: Vec<_> = sids
            .iter()
            .enumerate()
            .map(|(i, &sid)| {
                let (q, k, v) = &traffic.rows[i][t];
                coord.decode_async(sid, q.clone(), k.clone(), v.clone())
            })
            .collect::<Result<_>>()?;
        for (i, ticket) in tickets.into_iter().enumerate() {
            let resp = ticket.wait()?;
            if resp.served_n != spec.n0 + t + 1 {
                return Err(anyhow::anyhow!(
                    "session {i} step {t}: served_n {} != {} — a step was lost or reordered",
                    resp.served_n,
                    spec.n0 + t + 1
                ));
            }
            outs[i].extend_from_slice(&resp.o);
        }
    }

    // gauge barrier: pool gauges mirror into the metrics at the end of
    // each worker turn, so one more blocking round trip guarantees the
    // soak turns above are all synced
    let barrier = coord.session_create(AttnKind::Moba, spec.h, spec.h_kv, spec.d)?;
    let m = coord.metrics();
    let stats = LegStats {
        prefix_hit_rate: m.prefix_hit_rate(),
        pages_allocated: m.pages_allocated.load(std::sync::atomic::Ordering::Relaxed),
        pages_live: m.pages_live.load(std::sync::atomic::Ordering::Relaxed),
        preemptions: m.preemptions.load(std::sync::atomic::Ordering::Relaxed),
        restores: m.restores.load(std::sync::atomic::Ordering::Relaxed),
        deferred: m.admits_deferred.load(std::sync::atomic::Ordering::Relaxed),
        rejected: m.rejected.load(std::sync::atomic::Ordering::Relaxed),
    };
    coord.session_free(barrier)?;
    for sid in sids {
        coord.session_free(sid)?;
    }
    coord.shutdown();
    Ok((outs, stats))
}

/// Both legs over the same traffic: returns
/// `(prefix_hit_rate, parity_ok, unbounded stats, pressured stats)`.
/// The pressured budget is `3 × footprint` — enough for any single
/// session's restore, far below the working set.
pub fn run_soak(spec: &SoakSpec, seed: u64) -> Result<(f64, f64, LegStats, LegStats)> {
    let traffic = build_traffic(spec, seed);
    let (free_outs, free_stats) = run_leg(spec, &traffic, 0)?;
    let budget = 3 * spec.footprint();
    let (tight_outs, tight_stats) = run_leg(spec, &traffic, budget)?;
    let parity = free_outs
        .iter()
        .zip(&tight_outs)
        .all(|(a, b)| a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()));
    Ok((free_stats.prefix_hit_rate, if parity { 1.0 } else { 0.0 }, free_stats, tight_stats))
}

/// The `bench serve-soak` target. CI floors `prefix_hit_rate` (the
/// unbounded leg's fork sharing) and `parity_ok` (pressured == unbounded
/// bitwise); the run also hard-fails if the pressured leg never
/// preempted or dropped any parked work.
pub fn run_serve_soak(cfg: &AppConfig, quick: bool) -> Result<Vec<(String, f64)>> {
    let d = cfg.bench.head_dim;
    let spec = if quick { SoakSpec::quick(d) } else { SoakSpec::full(d) };
    let (hit_rate, parity_ok, free, tight) = run_soak(&spec, 0x50AC)?;

    if tight.preemptions == 0 || tight.restores == 0 {
        return Err(anyhow::anyhow!(
            "the pressured leg (budget {} pages) never exercised preemption \
             (preempt={} restore={}): the soak proves nothing",
            3 * spec.footprint(),
            tight.preemptions,
            tight.restores
        ));
    }
    if tight.rejected != 0 {
        return Err(anyhow::anyhow!(
            "the pressured leg dropped {} parked work items — the budget must \
             defer, never lose",
            tight.rejected
        ));
    }

    let mut t = Table::new(
        &format!(
            "bench serve-soak — paged serving under fork sharing + page pressure  \
             [{} sessions = {}×(1+{}), n0={}, steps={}, B={}, d={}]",
            spec.sessions(),
            spec.families,
            spec.forks_per,
            spec.n0,
            spec.steps,
            spec.block,
            spec.d
        ),
        &["leg", "pages alloc", "pages live", "prefix_hit", "preempt", "restore", "deferred"],
    );
    for (name, s) in [("unbounded", &free), ("pressured", &tight)] {
        t.row(vec![
            name.to_string(),
            s.pages_allocated.to_string(),
            s.pages_live.to_string(),
            format!("{:.2}", s.prefix_hit_rate),
            s.preemptions.to_string(),
            s.restores.to_string(),
            s.deferred.to_string(),
        ]);
    }
    t.print();
    println!(
        "headline: fork sharing satisfied {:.0}% of page-table entries without \
         allocating; {} preemption round trips served bit-identically (parity_ok={parity_ok})\n",
        hit_rate * 100.0,
        tight.restores
    );
    report::save_json(
        &cfg.results_dir,
        "serve-soak",
        &Json::obj(vec![
            ("prefix_hit_rate", Json::from(hit_rate)),
            ("parity_ok", Json::from(parity_ok)),
            ("pages_allocated_unbounded", Json::from(free.pages_allocated as f64)),
            ("pages_allocated_pressured", Json::from(tight.pages_allocated as f64)),
            ("preemptions", Json::from(tight.preemptions as f64)),
            ("restores", Json::from(tight.restores as f64)),
            ("admits_deferred", Json::from(tight.deferred as f64)),
            ("budget_pages", Json::from(3 * spec.footprint())),
        ]),
    )?;
    Ok(vec![("prefix_hit_rate".to_string(), hit_rate), ("parity_ok".to_string(), parity_ok)])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature soak end-to-end: sharing must register, the
    /// pressured leg must preempt, and parity must hold bitwise.
    #[test]
    fn mini_soak_holds_parity_under_pressure() {
        let spec = SoakSpec {
            families: 2,
            forks_per: 1,
            n0: 16,
            steps: 6,
            h: 2,
            h_kv: 1,
            d: 8,
            block: 8,
            topk: 2,
        };
        let (hit_rate, parity_ok, free, tight) = run_soak(&spec, 0x77).unwrap();
        assert_eq!(parity_ok, 1.0, "pressured leg diverged from the unbounded pool");
        assert!(hit_rate > 0.0, "forks never shared a prefix page");
        assert_eq!(free.preemptions, 0, "an unbounded pool must never preempt");
        assert!(tight.preemptions > 0, "the tight budget never preempted");
        assert_eq!(tight.rejected, 0, "parked work was dropped");
        // pressure respects the budget gauge
        assert!(tight.pages_live <= (3 * spec.footprint()) as u64);
    }
}
