//! `artifacts/manifest.json` schema — the contract between `aot.py` (L2)
//! and this runtime (L3). Every artifact's I/O signature plus every model
//! variant's configuration and parameter table. Parsed with the in-tree
//! JSON parser ([`crate::util::json`]).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context};

use crate::util::json::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: u32,
    pub variants: BTreeMap<String, VariantSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

/// One lowered HLO graph.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.req("name")?.as_str().ok_or_else(|| anyhow!("name"))?.to_string(),
            shape: usize_vec(j.req("shape")?)?,
            dtype: j.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype"))?.to_string(),
        })
    }
}

/// One trained model variant (paper §5.1 configuration, scaled).
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_dim: usize,
    pub seq_len: usize,
    pub window: usize,
    /// "dense" | "moba" (even-layer global attention type)
    pub attn: String,
    pub moba_block: usize,
    pub moba_topk: usize,
    pub kconv: usize,
    pub use_pallas: bool,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub init_file: String,
    pub train_batch: usize,
    pub eval_seqs: Vec<usize>,
    pub train_step: Option<String>,
    /// eval seq len -> fwd artifact name
    pub fwd: BTreeMap<usize, String>,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

fn usize_vec(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array"))?
        .iter()
        .map(|x| x.as_usize().ok_or_else(|| anyhow!("expected number")))
        .collect()
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?.as_usize().ok_or_else(|| anyhow!("field {key} not a number"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.req(key)?.as_str().ok_or_else(|| anyhow!("field {key} not a string"))?.to_string())
}

impl VariantSpec {
    fn from_json(name: &str, j: &Json) -> Result<Self> {
        let params = j
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not array"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec { name: get_str(p, "name")?, shape: usize_vec(p.req("shape")?)? })
            })
            .collect::<Result<Vec<_>>>()?;
        let fwd = j
            .req("fwd")?
            .as_obj()
            .ok_or_else(|| anyhow!("fwd not object"))?
            .iter()
            .map(|(k, v)| {
                Ok((
                    k.parse::<usize>().context("fwd key")?,
                    v.as_str().ok_or_else(|| anyhow!("fwd value"))?.to_string(),
                ))
            })
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Self {
            name: name.to_string(),
            vocab_size: get_usize(j, "vocab_size")?,
            d_model: get_usize(j, "d_model")?,
            n_layers: get_usize(j, "n_layers")?,
            n_heads: get_usize(j, "n_heads")?,
            n_kv_heads: get_usize(j, "n_kv_heads")?,
            head_dim: get_usize(j, "head_dim")?,
            ffn_dim: get_usize(j, "ffn_dim")?,
            seq_len: get_usize(j, "seq_len")?,
            window: get_usize(j, "window")?,
            attn: get_str(j, "attn")?,
            moba_block: get_usize(j, "moba_block")?,
            moba_topk: get_usize(j, "moba_topk")?,
            kconv: get_usize(j, "kconv")?,
            use_pallas: j.get("use_pallas").and_then(|x| x.as_bool()).unwrap_or(false),
            param_count: get_usize(j, "param_count")?,
            params,
            init_file: get_str(j, "init_file")?,
            train_batch: get_usize(j, "train_batch")?,
            eval_seqs: usize_vec(j.req("eval_seqs")?)?,
            train_step: j
                .get("train_step")
                .and_then(|x| x.as_str())
                .map(|s| s.to_string()),
            fwd,
        })
    }
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let mut variants = BTreeMap::new();
        for (name, v) in j.req("variants")?.as_obj().ok_or_else(|| anyhow!("variants"))? {
            variants.insert(
                name.clone(),
                VariantSpec::from_json(name, v).with_context(|| format!("variant {name}"))?,
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().ok_or_else(|| anyhow!("artifacts"))? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec { file: get_str(a, "file")?, inputs, outputs },
            );
        }
        Ok(Manifest {
            version: j.req("version")?.as_usize().unwrap_or(0) as u32,
            variants,
            artifacts,
        })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("variant {name:?} not in manifest"))
    }
}

impl VariantSpec {
    /// Total f32 count across all parameter tensors (== init.bin length / 4).
    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Fwd artifact for an eval sequence length.
    pub fn fwd_artifact(&self, seq: usize) -> Result<&str> {
        self.fwd
            .get(&seq)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("variant {} has no fwd artifact at seq {}", self.name, seq))
    }

    /// Minimal spec for unit tests elsewhere in the crate.
    #[doc(hidden)]
    pub fn test_stub(name: &str, params: Vec<(&str, Vec<usize>)>) -> Self {
        let params: Vec<ParamSpec> = params
            .into_iter()
            .map(|(n, shape)| ParamSpec { name: n.to_string(), shape })
            .collect();
        Self {
            name: name.to_string(),
            vocab_size: 512,
            d_model: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 64,
            ffn_dim: 256,
            seq_len: 128,
            window: 32,
            attn: "moba".into(),
            moba_block: 32,
            moba_topk: 2,
            kconv: 0,
            use_pallas: false,
            param_count: params.iter().map(|p| p.numel()).sum(),
            params,
            init_file: "x.bin".into(),
            train_batch: 1,
            eval_seqs: vec![128],
            train_step: None,
            fwd: BTreeMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "variants": {
        "tiny-dense": {
          "name": "tiny-dense", "vocab_size": 512, "d_model": 128,
          "n_layers": 4, "n_heads": 2, "n_kv_heads": 2, "head_dim": 64,
          "ffn_dim": 384, "seq_len": 1024, "window": 128, "attn": "dense",
          "moba_block": 32, "moba_topk": 8, "kconv": 0, "rope_theta": 10000.0,
          "use_pallas": false, "param_count": 10,
          "params": [{"name": "embed", "shape": [5, 2]}],
          "init_file": "tiny-dense_init.bin", "train_batch": 4,
          "eval_seqs": [1024], "train_step": "tiny-dense_train_step",
          "fwd": {"1024": "tiny-dense_fwd_n1024"}
        }
      },
      "artifacts": {
        "tiny-dense_fwd_n1024": {
          "file": "tiny-dense_fwd_n1024.hlo.txt",
          "inputs": [{"name": "tokens", "shape": [1, 1024], "dtype": "int32"}],
          "outputs": [{"name": "logits", "shape": [1, 1024, 512], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.version, 1);
        let v = m.variant("tiny-dense").unwrap();
        assert_eq!(v.total_param_elems(), 10);
        assert_eq!(v.fwd_artifact(1024).unwrap(), "tiny-dense_fwd_n1024");
        assert!(v.fwd_artifact(2048).is_err());
        assert_eq!(v.train_step.as_deref(), Some("tiny-dense_train_step"));
        assert!(m.variant("nope").is_err());
        assert!(m.artifact("nope").is_err());
        let a = m.artifact("tiny-dense_fwd_n1024").unwrap();
        assert_eq!(a.inputs[0].numel(), 1024);
        assert_eq!(a.outputs[0].dtype, "float32");
    }

    #[test]
    fn null_train_step_is_none() {
        let text = SAMPLE.replace("\"tiny-dense_train_step\"", "null");
        let m = Manifest::parse(&text).unwrap();
        assert!(m.variant("tiny-dense").unwrap().train_step.is_none());
    }

    #[test]
    fn unknown_extra_fields_ignored() {
        // rope_theta is present in the sample but not in the struct
        assert!(Manifest::parse(SAMPLE).is_ok());
    }

    #[test]
    fn test_stub_consistency() {
        let s = VariantSpec::test_stub("t", vec![("a", vec![2, 2]), ("b", vec![3])]);
        assert_eq!(s.total_param_elems(), 7);
        assert_eq!(s.params.len(), 2);
    }
}
