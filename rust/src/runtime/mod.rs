//! PJRT runtime: load AOT HLO-text artifacts produced by `aot.py` and
//! execute them on the CPU PJRT client from the request path.
//!
//! Pattern: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Artifacts are lowered with
//! `return_tuple=True`, so every execution returns a single tuple
//! literal that we decompose into output tensors.
//!
//! This build links the in-tree [`crate::xla`] stub, which gates client
//! creation: [`Runtime::load`] returns an error, artifact-dependent
//! tests and examples skip with a notice, and the coordinator falls
//! back to the pure-rust attention substrate (see `coordinator::server`).

mod manifest;
mod params;
mod tensor;

pub use manifest::{ArtifactSpec, Manifest, ParamSpec, TensorSpec, VariantSpec};
pub use params::ParamStore;
pub use tensor::{DType, Tensor, TensorData};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context};

use crate::xla;
use crate::Result;

/// A compiled artifact plus its manifest signature.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
    /// cumulative host<->device + execute wall time, for perf accounting
    stats: Mutex<ExecStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total: Duration,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }

    /// Validate inputs against the manifest signature.
    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, artifact expects {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "{}: input #{i} ({}) shape {:?} != expected {:?}",
                    self.name,
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != DType::parse(&s.dtype)? {
                bail!("{}: input #{i} ({}) dtype mismatch", self.name, s.name);
            }
        }
        Ok(())
    }

    /// Execute with host tensors; returns host tensors (tuple decomposed).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let t0 = Instant::now();
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let bufs = self.exe.execute::<xla::Literal>(&lits)?;
        let tuple = bufs[0][0].to_literal_sync()?;
        let outs = tuple
            .to_tuple()?
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        let mut st = self.stats.lock().unwrap();
        st.calls += 1;
        st.total += t0.elapsed();
        Ok(outs)
    }
}

/// Artifact registry: manifest + lazily compiled executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client and read `manifest.json` from `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, dir, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("XLA compile of {name}"))?;
        let exec = Arc::new(Executable {
            name: name.to_string(),
            exe,
            spec,
            stats: Mutex::new(ExecStats::default()),
        });
        tracing_compile(name, t0.elapsed());
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Load a variant's initial parameters from its `init.bin`.
    pub fn load_init_params(&self, variant: &str) -> Result<ParamStore> {
        let v = self.manifest.variant(variant)?;
        ParamStore::from_init_bin(v, &self.dir.join(&v.init_file))
    }
}

fn tracing_compile(name: &str, took: Duration) {
    if std::env::var_os("FLASH_MOBA_QUIET").is_none() {
        eprintln!("[runtime] compiled {name} in {:.2}s", took.as_secs_f64());
    }
}
