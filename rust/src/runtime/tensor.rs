//! Host-side tensors and conversion to/from PJRT [`xla::Literal`]s.
//!
//! Only the dtypes crossing the AOT boundary are supported: `f32`
//! (parameters, activations, scalars) and `i32` (token ids).

use anyhow::{anyhow, bail};

use crate::xla;
use crate::Result;

/// Dtype of a boundary tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A dense host tensor in row-major layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("data len {} != shape {:?} product {}", data.len(), shape, n);
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("data len {} != shape {:?} product {}", data.len(), shape, n);
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn zeros_f32(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn scalar(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("not a scalar: shape {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
            TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Tensor::f32(lit.to_vec::<f32>()?, &dims),
            xla::ElementType::S32 => Tensor::i32(lit.to_vec::<i32>()?, &dims),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_enforced() {
        assert!(Tensor::f32(vec![1.0; 5], &[2, 3]).is_err());
        assert!(Tensor::f32(vec![1.0; 6], &[2, 3]).is_ok());
    }

    #[test]
    fn dtype_accessors() {
        let t = Tensor::i32(vec![1, 2], &[2]).unwrap();
        assert_eq!(t.dtype(), DType::I32);
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.scalar().unwrap(), 3.5);
        assert!(t.shape().is_empty());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("bfloat16").is_err());
    }
}
