//! Parameter store: the flat, manifest-ordered list of model tensors that
//! crosses the AOT boundary (`<variant>_init.bin` and checkpoints).

use std::path::Path;

use anyhow::{bail, Context};

use super::{Tensor, VariantSpec};
use crate::Result;

/// All parameters of one model variant, in manifest order.
#[derive(Debug, Clone)]
pub struct ParamStore {
    names: Vec<String>,
    tensors: Vec<Tensor>,
}

impl ParamStore {
    /// Read `<variant>_init.bin`: raw little-endian f32, concatenated in
    /// manifest parameter order.
    pub fn from_init_bin(spec: &VariantSpec, path: &Path) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        let expect = spec.total_param_elems() * 4;
        if bytes.len() != expect {
            bail!(
                "{}: init.bin is {} bytes, manifest says {} ({} f32)",
                spec.name,
                bytes.len(),
                expect,
                spec.total_param_elems()
            );
        }
        let mut tensors = Vec::with_capacity(spec.params.len());
        let mut names = Vec::with_capacity(spec.params.len());
        let mut off = 0usize;
        for p in &spec.params {
            let n = p.numel();
            let data: Vec<f32> = bytes[off..off + n * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.push(Tensor::f32(data, &p.shape)?);
            names.push(p.name.clone());
            off += n * 4;
        }
        Ok(Self { names, tensors })
    }

    /// Build from tensors already in manifest order (e.g. train-step outputs).
    pub fn from_tensors(spec: &VariantSpec, tensors: Vec<Tensor>) -> Result<Self> {
        if tensors.len() != spec.params.len() {
            bail!(
                "{}: got {} tensors, manifest lists {} params",
                spec.name,
                tensors.len(),
                spec.params.len()
            );
        }
        for (t, p) in tensors.iter().zip(&spec.params) {
            if t.shape() != p.shape.as_slice() {
                bail!("param {}: shape {:?} != manifest {:?}", p.name, t.shape(), p.shape);
            }
        }
        Ok(Self { names: spec.params.iter().map(|p| p.name.clone()).collect(), tensors })
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn into_tensors(self) -> Vec<Tensor> {
        self.tensors
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.names.iter().position(|n| n == name).map(|i| &self.tensors[i])
    }

    /// Zero-filled clone (optimizer moment init).
    pub fn zeros_like(&self) -> Self {
        Self {
            names: self.names.clone(),
            tensors: self.tensors.iter().map(|t| Tensor::zeros_f32(t.shape())).collect(),
        }
    }

    /// Serialize to the same raw format as init.bin (checkpointing).
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        let mut out = Vec::new();
        for t in &self.tensors {
            for v in t.as_f32()? {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpec;

    fn spec() -> VariantSpec {
        VariantSpec::test_stub("t", vec![("a", vec![2, 2]), ("b", vec![2])])
    }

    #[test]
    fn init_bin_roundtrip() {
        let s = spec();
        let vals: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let dir = std::env::temp_dir().join("fm_params_test.bin");
        std::fs::write(&dir, &bytes).unwrap();
        let ps = ParamStore::from_init_bin(&s, &dir).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.get("a").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ps.get("b").unwrap().as_f32().unwrap(), &[5.0, 6.0]);
        assert_eq!(ps.to_bytes().unwrap(), bytes);
    }

    #[test]
    fn init_bin_size_mismatch_rejected() {
        let s = spec();
        let dir = std::env::temp_dir().join("fm_params_bad.bin");
        std::fs::write(&dir, [0u8; 8]).unwrap();
        assert!(ParamStore::from_init_bin(&s, &dir).is_err());
    }

    #[test]
    fn from_tensors_validates_shapes() {
        let s = spec();
        let ok = vec![
            Tensor::f32(vec![0.0; 4], &[2, 2]).unwrap(),
            Tensor::f32(vec![0.0; 2], &[2]).unwrap(),
        ];
        assert!(ParamStore::from_tensors(&s, ok).is_ok());
        let bad = vec![
            Tensor::f32(vec![0.0; 4], &[4]).unwrap(),
            Tensor::f32(vec![0.0; 2], &[2]).unwrap(),
        ];
        assert!(ParamStore::from_tensors(&s, bad).is_err());
        let _ = ParamSpec { name: "x".into(), shape: vec![1] }.numel();
    }

    #[test]
    fn zeros_like_preserves_shapes() {
        let s = spec();
        let ps = ParamStore::from_tensors(
            &s,
            vec![
                Tensor::f32(vec![1.0; 4], &[2, 2]).unwrap(),
                Tensor::f32(vec![1.0; 2], &[2]).unwrap(),
            ],
        )
        .unwrap();
        let z = ps.zeros_like();
        assert_eq!(z.tensors()[0].as_f32().unwrap(), &[0.0; 4]);
        assert_eq!(z.tensors()[0].shape(), &[2, 2]);
    }
}
