//! The *original* MoBA pipeline (Lu et al., 2025) re-implemented
//! faithfully, overheads included — the baseline of Figures 3–4.
//!
//! Five stages (§5.3 "Breakdown Analysis"):
//!   1. `gating`   — centroids (once per KV head) + full H×N×n score
//!                   tensor + top-k per query head
//!   2. `reindex`  — global reindexing: gather routed queries into
//!                   per-(head, block) contiguous buffers
//!   3. `routed`   — attention of gathered queries against their blocks,
//!                   materializing *partial* outputs + logsumexps
//!   4. `local`    — separate causal attention on each query's own block
//!   5. `merge`    — logsumexp-weighted combination of all partials
//!
//! Stages 1, 2 and 5 dominate at small block sizes — exactly the
//! overhead FlashMoBA eliminates.
//!
//! Tensors are packed: q/o `(h, n, d)`, k/v `(h_kv, n, d)` (GQA).
//! A ragged final block is supported: tail queries attend their partial
//! own block causally and route among the complete strictly-past blocks
//! only (the tail is never a routing candidate).
//!
//! Multi-core adaptation: gating, local and merge partition flattened
//! `(head, query-row)` units, the routed stage flattened
//! `(head, key-block)` units. Every work unit runs the unchanged serial
//! arithmetic — for merge, each query still combines its local partial
//! first and its routed partials in ascending block order — so outputs
//! are bit-identical to the serial path at any thread count, and
//! `h = h_kv = 1` reproduces the single-head pipeline bit-for-bit.
//!
//! Like every kernel behind the backend trait, this pipeline only ever
//! sees uniform `(block, topk)` launches: mixed per-head route plans
//! are decomposed upstream (`attention::backend`) into one sub-launch
//! per KV head, so no plan awareness lives here.
//!
//! Also hosts [`moba_reference`], the slow token-mask oracle used by
//! every test.

use super::centroid::centroids_packed;
use super::dense::NEG_INF;
use super::gemm::{accum_rows, qk_row};
use super::simd::axpy;
use super::stats::{ws_bytes, StageStats};
use super::topk::naive_topk_packed;
use super::varlen::{build_varlen_heads, VarlenLayout};
use super::AttnShape;
use crate::util::pool::ExecCtx;

/// Token-mask oracle: O(N²) masked softmax per query head, f64
/// accumulation. Given a packed routing table `(h, n, k)` (-1 padded),
/// head `qh`'s token t attends token u of KV head `qh / group` iff
/// u <= t and (block(u) routed for (qh, t) or block(u) == block(t)).
/// Handles ragged n (the tail block is its own queries' own block).
pub fn moba_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    indices: &[i32],
) -> (Vec<f32>, Vec<f32>) {
    let AttnShape { h, n, d, block, topk, .. } = shape;
    assert_eq!(indices.len(), h * n * topk);
    let group = shape.group();
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; h * n * d];
    let mut lse = vec![0.0f32; h * n];
    for qh in 0..h {
        let kvh = qh / group;
        let kh = &k[kvh * n * d..(kvh + 1) * n * d];
        let vh = &v[kvh * n * d..(kvh + 1) * n * d];
        for t in 0..n {
            let own = t / block;
            let routed = &indices[(qh * n + t) * topk..(qh * n + t + 1) * topk];
            let qt = &q[(qh * n + t) * d..(qh * n + t + 1) * d];
            let mut s = vec![f64::NEG_INFINITY; t + 1];
            for (u, su) in s.iter_mut().enumerate() {
                let ub = u / block;
                let ok = ub == own || routed.contains(&(ub as i32));
                if !ok {
                    continue;
                }
                let ku = &kh[u * d..(u + 1) * d];
                let mut dot = 0.0f64;
                for c in 0..d {
                    dot += qt[c] as f64 * ku[c] as f64;
                }
                *su = dot * scale;
            }
            let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut z = 0.0f64;
            let ot = &mut o[(qh * n + t) * d..(qh * n + t + 1) * d];
            let mut acc = vec![0.0f64; d];
            for (u, &su) in s.iter().enumerate() {
                if su == f64::NEG_INFINITY {
                    continue;
                }
                let p = (su - m).exp();
                z += p;
                let vu = &vh[u * d..(u + 1) * d];
                for c in 0..d {
                    acc[c] += p * vu[c] as f64;
                }
            }
            for c in 0..d {
                ot[c] = (acc[c] / z) as f32;
            }
            lse[qh * n + t] = (m + z.ln()) as f32;
        }
    }
    (o, lse)
}

/// Full original pipeline on the process-wide shared pool. Returns
/// (packed (h, n, d) output, (h, n, topk) routing indices, stats).
pub fn moba_naive_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
) -> (Vec<f32>, Vec<i32>, StageStats) {
    moba_naive_forward_ctx(ExecCtx::global(), q, k, v, shape)
}

/// [`moba_naive_forward`] on an explicit execution context.
pub fn moba_naive_forward_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
) -> (Vec<f32>, Vec<i32>, StageStats) {
    let AttnShape { h, h_kv, n, d, block, topk } = shape;
    assert_eq!(q.len(), shape.q_elems());
    assert_eq!(k.len(), shape.kv_elems());
    assert_eq!(v.len(), shape.kv_elems());
    let cb = shape.complete_blocks(); // routing candidate universe
    let group = shape.group();
    let scale = 1.0 / (d as f32).sqrt();
    let mut st = StageStats::for_heads(ctx, h);

    // ---- stage 1: gating (full score tensor!) --------------------------
    let (indices, gate_ws) = st.time("gating", || {
        let c = centroids_packed(ctx, k, h_kv, n, d, block);
        naive_topk_packed(ctx, q, &c, &shape)
    });
    st.add_workspace(gate_ws + ws_bytes(&[h_kv * cb * d]));

    // ---- stage 2: global reindex (gather q copies per head × block) ----
    let layouts: Vec<VarlenLayout> =
        st.time("reindex", || build_varlen_heads(&indices, h, n, topk, cb));
    let gathered: Vec<Vec<f32>> = st.time("reindex", || {
        (0..h * cb)
            .map(|u| {
                let (qh, j) = (u / cb, u % cb);
                let qs = layouts[qh].queries_of(j);
                let mut g = Vec::with_capacity(qs.len() * d);
                for &t in qs {
                    let row = qh * n + t as usize;
                    g.extend_from_slice(&q[row * d..(row + 1) * d]);
                }
                g
            })
            .collect()
    });
    // per-head base offset of the global partial buffers
    let mut pbase = vec![0usize; h + 1];
    for qh in 0..h {
        pbase[qh + 1] = pbase[qh] + layouts[qh].total();
    }
    let total_all = pbase[h];
    st.add_workspace(ws_bytes(&[total_all * d + total_all + 2 * h * cb]));

    // ---- stage 3: routed attention (partial outputs materialized) ------
    // partials grouped by (head, block): head qh's block j owns global
    // partial rows pbase[qh] + offsets[j] .. + counts[j]
    let mut partial_o = Vec::with_capacity(total_all * d);
    let mut partial_l = Vec::with_capacity(total_all);
    st.time("routed", || {
        let parts = ctx.pool().map_ranges(h * cb, |units| {
            let mut po: Vec<f32> = Vec::new();
            let mut pl: Vec<f32> = Vec::new();
            let mut s = vec![0.0f32; block];
            for u in units {
                let (qh, j) = (u / cb, u % cb);
                let kvh = qh / group;
                let qs = layouts[qh].queries_of(j);
                let g = &gathered[u];
                let kb = &k[(kvh * n + j * block) * d..(kvh * n + (j + 1) * block) * d];
                let vb = &v[(kvh * n + j * block) * d..(kvh * n + (j + 1) * block) * d];
                for (row, _t) in qs.iter().enumerate() {
                    let qt = &g[row * d..(row + 1) * d];
                    // register-blocked block scoring (bit-identical to
                    // the per-row dot), then the same softmax order
                    qk_row(qt, kb, d, block, scale, &mut s);
                    let mut m = NEG_INF;
                    for &x in s.iter() {
                        if x > m {
                            m = x;
                        }
                    }
                    let mut z = 0.0f32;
                    for x in s.iter_mut() {
                        *x = (*x - m).exp();
                        z += *x;
                    }
                    let p0 = po.len();
                    po.resize(p0 + d, 0.0);
                    let prow = &mut po[p0..p0 + d];
                    accum_rows(prow, &s, vb);
                    for c in prow.iter_mut() {
                        *c /= z;
                    }
                    pl.push(m + z.ln());
                }
            }
            (po, pl)
        });
        for (po, pl) in parts {
            partial_o.extend_from_slice(&po);
            partial_l.extend_from_slice(&pl);
        }
    });
    st.add_workspace(ws_bytes(&[partial_o.len(), partial_l.len()]));

    // ---- stage 4: local (own block, causal; tail block may be partial) --
    let mut local_o = Vec::with_capacity(h * n * d);
    let mut local_l = Vec::with_capacity(h * n);
    st.time("local", || {
        let parts = ctx.pool().map_ranges(h * n, |rows| {
            let mut lo_o = vec![0.0f32; rows.len() * d];
            let mut lo_l = vec![0.0f32; rows.len()];
            let mut s: Vec<f32> = Vec::with_capacity(block);
            for (tt, u) in rows.enumerate() {
                let (qh, t) = (u / n, u % n);
                let kvh = qh / group;
                let own = t / block;
                let base = own * block;
                let qt = &q[u * d..(u + 1) * d];
                let upto = t - base; // inclusive offset in own block
                s.clear();
                s.resize(upto + 1, 0.0);
                let krows = &k[(kvh * n + base) * d..(kvh * n + base + upto + 1) * d];
                qk_row(qt, krows, d, upto + 1, scale, &mut s);
                let mut m = NEG_INF;
                for &x in s.iter() {
                    if x > m {
                        m = x;
                    }
                }
                let mut z = 0.0f32;
                for x in s.iter_mut() {
                    *x = (*x - m).exp();
                    z += *x;
                }
                let ot = &mut lo_o[tt * d..(tt + 1) * d];
                accum_rows(ot, &s, &v[(kvh * n + base) * d..(kvh * n + base + upto + 1) * d]);
                for c in ot.iter_mut() {
                    *c /= z;
                }
                lo_l[tt] = m + z.ln();
            }
            (lo_o, lo_l)
        });
        for (lo_o, lo_l) in parts {
            local_o.extend_from_slice(&lo_o);
            local_l.extend_from_slice(&lo_l);
        }
    });
    st.add_workspace(ws_bytes(&[local_o.len(), local_l.len()]));

    // ---- stage 5: merge --------------------------------------------------
    // per query: max over (local, routed partials in ascending block
    // order), then the weighted combination in the same order — the
    // serial accumulation order, partitioned by flattened (head, row)
    // ranges (each flattened range splits at head boundaries so every
    // row merges against its own head's layout)
    let mut o = Vec::with_capacity(h * n * d);
    st.time("merge", || {
        let parts = ctx.pool().map_ranges(h * n, |rows| {
            let mut og_all: Vec<f32> = Vec::with_capacity(rows.len() * d);
            let mut start = rows.start;
            while start < rows.end {
                let qh = start / n;
                let head_end = ((qh + 1) * n).min(rows.end);
                // per-head row window [lo, hi) in head-local coordinates
                let (lo, hi) = (start % n, start % n + (head_end - start));
                let layout = &layouts[qh];
                let base = pbase[qh];
                let count = hi - lo;
                // this range's routed sub-slice of every block's query
                // list (computed once; the max pass and the accumulate
                // pass both walk the same (a, b) windows)
                let windows: Vec<(usize, usize)> = (0..cb)
                    .map(|j| {
                        let qs = layout.queries_of(j);
                        let a = qs.partition_point(|&t| (t as usize) < lo);
                        let b = qs.partition_point(|&t| (t as usize) < hi);
                        (a, b)
                    })
                    .collect();
                // global max per query over partials
                let mut m: Vec<f32> = local_l[qh * n + lo..qh * n + hi].to_vec();
                for (j, &(a, b)) in windows.iter().enumerate() {
                    let qs = layout.queries_of(j);
                    for (off, &t) in qs[a..b].iter().enumerate() {
                        let p = base + layout.offsets[j] as usize + a + off;
                        let ti = t as usize - lo;
                        if partial_l[p] > m[ti] {
                            m[ti] = partial_l[p];
                        }
                    }
                }
                let mut z = vec![0.0f32; count];
                let mut og = vec![0.0f32; count * d];
                for (tt, t) in (lo..hi).enumerate() {
                    let row = qh * n + t;
                    let w = (local_l[row] - m[tt]).exp();
                    z[tt] += w;
                    axpy(&mut og[tt * d..(tt + 1) * d], w, &local_o[row * d..(row + 1) * d]);
                }
                for (j, &(a, b)) in windows.iter().enumerate() {
                    let qs = layout.queries_of(j);
                    for (off, &t) in qs[a..b].iter().enumerate() {
                        let p = base + layout.offsets[j] as usize + a + off;
                        let ti = t as usize - lo;
                        let w = (partial_l[p] - m[ti]).exp();
                        z[ti] += w;
                        axpy(
                            &mut og[ti * d..(ti + 1) * d],
                            w,
                            &partial_o[p * d..(p + 1) * d],
                        );
                    }
                }
                for ti in 0..count {
                    for c in 0..d {
                        og[ti * d + c] /= z[ti];
                    }
                }
                og_all.extend_from_slice(&og);
                start = head_end;
            }
            og_all
        });
        for og in parts {
            o.extend_from_slice(&og);
        }
    });
    st.add_workspace(ws_bytes(&[2 * h * n]));

    (o, indices, st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::{naive_attention, naive_attention_packed};
    use crate::attention::testutil::{max_abs_diff, qkv, qkv_packed};

    #[test]
    fn naive_pipeline_matches_reference() {
        for (n, d, b, k) in [(128, 16, 16, 2), (256, 8, 32, 3), (64, 4, 16, 1)] {
            let shape = AttnShape::single(n, d, b, k);
            let (q, kk, v) = qkv(21, n, d);
            let (o, idx, _st) = moba_naive_forward(&q, &kk, &v, shape);
            let (oref, _) = moba_reference(&q, &kk, &v, shape, &idx);
            assert!(max_abs_diff(&o, &oref) < 3e-5, "n={n} b={b} k={k}");
        }
    }

    #[test]
    fn multi_head_gqa_matches_reference() {
        for (h, h_kv, n) in [(2, 2, 128), (4, 2, 96), (4, 1, 64)] {
            let shape = AttnShape::new(h, h_kv, n, 8, 16, 2);
            let (q, kk, v) = qkv_packed(26, h, h_kv, n, 8);
            let (o, idx, st) = moba_naive_forward(&q, &kk, &v, shape);
            assert_eq!(o.len(), shape.q_elems());
            assert_eq!(idx.len(), h * n * shape.topk);
            assert_eq!(st.heads(), h);
            let (oref, _) = moba_reference(&q, &kk, &v, shape, &idx);
            assert!(max_abs_diff(&o, &oref) < 3e-5, "h={h} h_kv={h_kv}");
        }
    }

    #[test]
    fn ragged_tail_matches_reference() {
        // n = 100 over B = 16: 6 complete blocks + a 4-token tail that
        // is always-attended and never routed
        let shape = AttnShape::new(2, 1, 100, 8, 16, 2);
        let (q, kk, v) = qkv_packed(27, 2, 1, 100, 8);
        let (o, idx, _) = moba_naive_forward(&q, &kk, &v, shape);
        // no tail-block index can appear in the routing table
        assert!(idx.iter().all(|&j| j < shape.complete_blocks() as i32));
        let (oref, _) = moba_reference(&q, &kk, &v, shape, &idx);
        assert!(max_abs_diff(&o, &oref) < 3e-5);
    }

    #[test]
    fn all_blocks_routed_equals_dense() {
        let (n, d, b) = (128, 8, 16);
        let shape = AttnShape::single(n, d, b, n / b); // k = nb: everything routed
        let (q, kk, v) = qkv(22, n, d);
        let (o, _, _) = moba_naive_forward(&q, &kk, &v, shape);
        let (oref, _) = naive_attention(&q, &kk, &v, n, d);
        assert!(max_abs_diff(&o, &oref) < 3e-5);
    }

    #[test]
    fn ragged_fully_routed_equals_dense() {
        // topk >= complete blocks: tail and complete queries attend
        // everything causal, so the pipeline must equal dense attention
        let shape = AttnShape::new(2, 2, 72, 8, 16, 4); // cb = 4, tail = 8
        let (q, kk, v) = qkv_packed(28, 2, 2, 72, 8);
        let (o, _, _) = moba_naive_forward(&q, &kk, &v, shape);
        let (oref, _) = naive_attention_packed(&q, &kk, &v, 2, 2, 72, 8);
        assert!(max_abs_diff(&o, &oref) < 3e-5);
    }

    /// Partitioning the five stages across workers must not change a
    /// single bit of the output or the routing table — single- and
    /// multi-head.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        for shape in [
            AttnShape::single(5 * 16, 8, 16, 2), // 5 blocks: uneven splits
            AttnShape::new(4, 2, 5 * 16, 8, 16, 2),
            AttnShape::new(2, 1, 90, 8, 16, 2), // ragged tail
        ] {
            let (q, kk, v) = qkv_packed(25, shape.h, shape.h_kv, shape.n, shape.d);
            let (o1, i1, _) = moba_naive_forward_ctx(&ExecCtx::serial(), &q, &kk, &v, shape);
            for threads in [2, 3, 4, 11] {
                let ctx = ExecCtx::with_threads(threads);
                let (o2, i2, st) = moba_naive_forward_ctx(&ctx, &q, &kk, &v, shape);
                assert_eq!(o1, o2, "o differs at threads={threads} {shape:?}");
                assert_eq!(i1, i2, "indices differ at threads={threads} {shape:?}");
                assert_eq!(st.threads(), threads);
            }
        }
    }

    #[test]
    fn stage_labels_complete() {
        let shape = AttnShape::single(64, 4, 16, 1);
        let (q, kk, v) = qkv(23, 64, 4);
        let (_, _, st) = moba_naive_forward(&q, &kk, &v, shape);
        for label in ["gating", "reindex", "routed", "local", "merge"] {
            assert!(st.get(label).is_some(), "missing stage {label}");
        }
        assert!(st.workspace_bytes > 0);
    }

    #[test]
    fn reference_first_token_is_v0() {
        let shape = AttnShape::single(32, 4, 8, 1);
        let (q, kk, v) = qkv(24, 32, 4);
        let idx = vec![-1i32; 32];
        let (o, _) = moba_reference(&q, &kk, &v, shape, &idx);
        assert!(max_abs_diff(&o[..4], &v[..4]) < 1e-6);
    }
}
