//! The *original* MoBA pipeline (Lu et al., 2025) re-implemented
//! faithfully, overheads included — the baseline of Figures 3–4.
//!
//! Five stages (§5.3 "Breakdown Analysis"):
//!   1. `gating`   — centroids + full N×n score matrix + top-k
//!   2. `reindex`  — global reindexing: gather routed queries into
//!                   per-block contiguous buffers
//!   3. `routed`   — attention of gathered queries against their blocks,
//!                   materializing *partial* outputs + logsumexps
//!   4. `local`    — separate causal attention on each query's own block
//!   5. `merge`    — logsumexp-weighted combination of all partials
//!
//! Stages 1, 2 and 5 dominate at small block sizes — exactly the
//! overhead FlashMoBA eliminates.
//!
//! Also hosts [`moba_reference`], the slow token-mask oracle used by
//! every test.

use super::centroid::centroids;
use super::simd::{axpy, dot};
use super::dense::NEG_INF;
use super::stats::{ws_bytes, StageStats};
use super::topk::naive_topk;
use super::varlen::build_varlen;
use super::MobaShape;

/// Token-mask oracle: O(N²) masked softmax, f64 accumulation.
/// Given a routing table (n, k) (-1 padded), token t attends token u iff
/// u <= t and (block(u) routed for t or block(u) == block(t)).
pub fn moba_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
    indices: &[i32],
) -> (Vec<f32>, Vec<f32>) {
    let MobaShape { n, d, block, topk } = shape;
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; n * d];
    let mut lse = vec![0.0f32; n];
    for t in 0..n {
        let own = t / block;
        let routed = &indices[t * topk..(t + 1) * topk];
        let qt = &q[t * d..(t + 1) * d];
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            let ub = u / block;
            let ok = ub == own || routed.contains(&(ub as i32));
            if !ok {
                continue;
            }
            let ku = &k[u * d..(u + 1) * d];
            let mut dot = 0.0f64;
            for c in 0..d {
                dot += qt[c] as f64 * ku[c] as f64;
            }
            *su = dot * scale;
        }
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0f64;
        let ot = &mut o[t * d..(t + 1) * d];
        let mut acc = vec![0.0f64; d];
        for (u, &su) in s.iter().enumerate() {
            if su == f64::NEG_INFINITY {
                continue;
            }
            let p = (su - m).exp();
            z += p;
            let vu = &v[u * d..(u + 1) * d];
            for c in 0..d {
                acc[c] += p * vu[c] as f64;
            }
        }
        for c in 0..d {
            ot[c] = (acc[c] / z) as f32;
        }
        lse[t] = (m + z.ln()) as f32;
    }
    (o, lse)
}

/// Full original pipeline. Returns (o, routing indices, stats).
pub fn moba_naive_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
) -> (Vec<f32>, Vec<i32>, StageStats) {
    let MobaShape { n, d, block, topk } = shape;
    let nb = shape.n_blocks();
    let scale = 1.0 / (d as f32).sqrt();
    let mut st = StageStats::new();

    // ---- stage 1: gating (full score matrix!) --------------------------
    let (indices, gate_ws) = st.time("gating", || {
        let c = centroids(k, n, d, block);
        naive_topk(q, &c, n, d, block, topk)
    });
    st.add_workspace(gate_ws + ws_bytes(&[nb * d]));

    // ---- stage 2: global reindex (gather q copies per block) -----------
    let layout = st.time("reindex", || build_varlen(&indices, n, topk, nb));
    let gathered: Vec<Vec<f32>> = st.time("reindex", || {
        (0..nb)
            .map(|j| {
                let qs = layout.queries_of(j);
                let mut g = Vec::with_capacity(qs.len() * d);
                for &t in qs {
                    g.extend_from_slice(&q[t as usize * d..(t as usize + 1) * d]);
                }
                g
            })
            .collect()
    });
    st.add_workspace(ws_bytes(&[layout.total() * d + layout.total() + 2 * nb]));

    // ---- stage 3: routed attention (partial outputs materialized) ------
    // partials[p] = (query id, partial out, partial lse)
    let mut partial_o = vec![0.0f32; layout.total() * d];
    let mut partial_l = vec![0.0f32; layout.total()];
    st.time("routed", || {
        let mut p_idx = 0usize;
        for j in 0..nb {
            let qs = layout.queries_of(j);
            let g = &gathered[j];
            let kb = &k[j * block * d..(j + 1) * block * d];
            let vb = &v[j * block * d..(j + 1) * block * d];
            for (row, _t) in qs.iter().enumerate() {
                let qt = &g[row * d..(row + 1) * d];
                let mut s = vec![0.0f32; block];
                let mut m = NEG_INF;
                for (u, su) in s.iter_mut().enumerate() {
                    *su = dot(qt, &kb[u * d..(u + 1) * d]) * scale;
                    if *su > m {
                        m = *su;
                    }
                }
                let mut z = 0.0f32;
                let po = &mut partial_o[p_idx * d..(p_idx + 1) * d];
                for (u, su) in s.iter().enumerate() {
                    let p = (su - m).exp();
                    z += p;
                    axpy(po, p, &vb[u * d..(u + 1) * d]);
                }
                for c in po.iter_mut() {
                    *c /= z;
                }
                partial_l[p_idx] = m + z.ln();
                p_idx += 1;
            }
        }
    });
    st.add_workspace(ws_bytes(&[partial_o.len(), partial_l.len()]));

    // ---- stage 4: local (own block, causal) -----------------------------
    let mut local_o = vec![0.0f32; n * d];
    let mut local_l = vec![0.0f32; n];
    st.time("local", || {
        for t in 0..n {
            let own = t / block;
            let base = own * block;
            let qt = &q[t * d..(t + 1) * d];
            let mut m = NEG_INF;
            let upto = t - base; // inclusive offset in own block
            let mut s = vec![0.0f32; upto + 1];
            for (u, su) in s.iter_mut().enumerate() {
                *su = dot(qt, &k[(base + u) * d..(base + u + 1) * d]) * scale;
                if *su > m {
                    m = *su;
                }
            }
            let mut z = 0.0f32;
            let ot = &mut local_o[t * d..(t + 1) * d];
            for (u, su) in s.iter().enumerate() {
                let p = (su - m).exp();
                z += p;
                axpy(ot, p, &v[(base + u) * d..(base + u + 1) * d]);
            }
            for c in ot.iter_mut() {
                *c /= z;
            }
            local_l[t] = m + z.ln();
        }
    });
    st.add_workspace(ws_bytes(&[local_o.len(), local_l.len()]));

    // ---- stage 5: merge --------------------------------------------------
    let mut o = vec![0.0f32; n * d];
    st.time("merge", || {
        // global max per query over partials
        let mut m = local_l.clone();
        let mut p_idx = 0usize;
        for j in 0..nb {
            for &t in layout.queries_of(j) {
                let t = t as usize;
                if partial_l[p_idx] > m[t] {
                    m[t] = partial_l[p_idx];
                }
                p_idx += 1;
            }
        }
        let mut z = vec![0.0f32; n];
        for t in 0..n {
            let w = (local_l[t] - m[t]).exp();
            z[t] += w;
            axpy(&mut o[t * d..(t + 1) * d], w, &local_o[t * d..(t + 1) * d]);
        }
        p_idx = 0;
        for j in 0..nb {
            for &t in layout.queries_of(j) {
                let t = t as usize;
                let w = (partial_l[p_idx] - m[t]).exp();
                z[t] += w;
                axpy(&mut o[t * d..(t + 1) * d], w, &partial_o[p_idx * d..(p_idx + 1) * d]);
                p_idx += 1;
            }
        }
        for t in 0..n {
            for c in 0..d {
                o[t * d + c] /= z[t];
            }
        }
    });
    st.add_workspace(ws_bytes(&[2 * n]));

    (o, indices, st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::testutil::{max_abs_diff, qkv};

    #[test]
    fn naive_pipeline_matches_reference() {
        for (n, d, b, k) in [(128, 16, 16, 2), (256, 8, 32, 3), (64, 4, 16, 1)] {
            let shape = MobaShape::new(n, d, b, k);
            let (q, kk, v) = qkv(21, n, d);
            let (o, idx, _st) = moba_naive_forward(&q, &kk, &v, shape);
            let (oref, _) = moba_reference(&q, &kk, &v, shape, &idx);
            assert!(max_abs_diff(&o, &oref) < 3e-5, "n={n} b={b} k={k}");
        }
    }

    #[test]
    fn all_blocks_routed_equals_dense() {
        let (n, d, b) = (128, 8, 16);
        let shape = MobaShape::new(n, d, b, n / b); // k = nb: everything routed
        let (q, kk, v) = qkv(22, n, d);
        let (o, _, _) = moba_naive_forward(&q, &kk, &v, shape);
        let (oref, _) = naive_attention(&q, &kk, &v, n, d);
        assert!(max_abs_diff(&o, &oref) < 3e-5);
    }

    #[test]
    fn stage_labels_complete() {
        let shape = MobaShape::new(64, 4, 16, 1);
        let (q, kk, v) = qkv(23, 64, 4);
        let (_, _, st) = moba_naive_forward(&q, &kk, &v, shape);
        for label in ["gating", "reindex", "routed", "local", "merge"] {
            assert!(st.get(label).is_some(), "missing stage {label}");
        }
        assert!(st.workspace_bytes > 0);
    }

    #[test]
    fn reference_first_token_is_v0() {
        let shape = MobaShape::new(32, 4, 8, 1);
        let (q, kk, v) = qkv(24, 32, 4);
        let idx = vec![-1i32; 32];
        let (o, _) = moba_reference(&q, &kk, &v, shape, &idx);
        assert!(max_abs_diff(&o[..4], &v[..4]) < 1e-6);
    }
}
