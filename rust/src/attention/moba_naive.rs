//! The *original* MoBA pipeline (Lu et al., 2025) re-implemented
//! faithfully, overheads included — the baseline of Figures 3–4.
//!
//! Five stages (§5.3 "Breakdown Analysis"):
//!   1. `gating`   — centroids + full N×n score matrix + top-k
//!   2. `reindex`  — global reindexing: gather routed queries into
//!                   per-block contiguous buffers
//!   3. `routed`   — attention of gathered queries against their blocks,
//!                   materializing *partial* outputs + logsumexps
//!   4. `local`    — separate causal attention on each query's own block
//!   5. `merge`    — logsumexp-weighted combination of all partials
//!
//! Stages 1, 2 and 5 dominate at small block sizes — exactly the
//! overhead FlashMoBA eliminates.
//!
//! Multi-core adaptation: gating, local and merge partition query rows,
//! the routed stage partitions key blocks (each block owns a contiguous
//! slice of the partial buffers). Every work unit runs the unchanged
//! serial arithmetic — for merge, each query still combines its local
//! partial first and its routed partials in ascending block order — so
//! outputs are bit-identical to the serial path at any thread count.
//!
//! Also hosts [`moba_reference`], the slow token-mask oracle used by
//! every test.

use super::centroid::centroids_ctx;
use super::simd::{axpy, dot};
use super::dense::NEG_INF;
use super::stats::{ws_bytes, StageStats};
use super::topk::naive_topk_ctx;
use super::varlen::build_varlen;
use super::MobaShape;
use crate::util::pool::ExecCtx;

/// Token-mask oracle: O(N²) masked softmax, f64 accumulation.
/// Given a routing table (n, k) (-1 padded), token t attends token u iff
/// u <= t and (block(u) routed for t or block(u) == block(t)).
pub fn moba_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
    indices: &[i32],
) -> (Vec<f32>, Vec<f32>) {
    let MobaShape { n, d, block, topk } = shape;
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; n * d];
    let mut lse = vec![0.0f32; n];
    for t in 0..n {
        let own = t / block;
        let routed = &indices[t * topk..(t + 1) * topk];
        let qt = &q[t * d..(t + 1) * d];
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            let ub = u / block;
            let ok = ub == own || routed.contains(&(ub as i32));
            if !ok {
                continue;
            }
            let ku = &k[u * d..(u + 1) * d];
            let mut dot = 0.0f64;
            for c in 0..d {
                dot += qt[c] as f64 * ku[c] as f64;
            }
            *su = dot * scale;
        }
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut z = 0.0f64;
        let ot = &mut o[t * d..(t + 1) * d];
        let mut acc = vec![0.0f64; d];
        for (u, &su) in s.iter().enumerate() {
            if su == f64::NEG_INFINITY {
                continue;
            }
            let p = (su - m).exp();
            z += p;
            let vu = &v[u * d..(u + 1) * d];
            for c in 0..d {
                acc[c] += p * vu[c] as f64;
            }
        }
        for c in 0..d {
            ot[c] = (acc[c] / z) as f32;
        }
        lse[t] = (m + z.ln()) as f32;
    }
    (o, lse)
}

/// Full original pipeline on the process-wide shared pool. Returns
/// (o, routing indices, stats).
pub fn moba_naive_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
) -> (Vec<f32>, Vec<i32>, StageStats) {
    moba_naive_forward_ctx(ExecCtx::global(), q, k, v, shape)
}

/// [`moba_naive_forward`] on an explicit execution context.
pub fn moba_naive_forward_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
) -> (Vec<f32>, Vec<i32>, StageStats) {
    let MobaShape { n, d, block, topk } = shape;
    let nb = shape.n_blocks();
    let scale = 1.0 / (d as f32).sqrt();
    let mut st = StageStats::for_ctx(ctx);

    // ---- stage 1: gating (full score matrix!) --------------------------
    let (indices, gate_ws) = st.time("gating", || {
        let c = centroids_ctx(ctx, k, n, d, block);
        naive_topk_ctx(ctx, q, &c, n, d, block, topk)
    });
    st.add_workspace(gate_ws + ws_bytes(&[nb * d]));

    // ---- stage 2: global reindex (gather q copies per block) -----------
    let layout = st.time("reindex", || build_varlen(&indices, n, topk, nb));
    let gathered: Vec<Vec<f32>> = st.time("reindex", || {
        (0..nb)
            .map(|j| {
                let qs = layout.queries_of(j);
                let mut g = Vec::with_capacity(qs.len() * d);
                for &t in qs {
                    g.extend_from_slice(&q[t as usize * d..(t as usize + 1) * d]);
                }
                g
            })
            .collect()
    });
    st.add_workspace(ws_bytes(&[layout.total() * d + layout.total() + 2 * nb]));

    // ---- stage 3: routed attention (partial outputs materialized) ------
    // partials[p] = (query id, partial out, partial lse), grouped by
    // block: block j owns partial rows offsets[j]..offsets[j]+counts[j]
    let mut partial_o = Vec::with_capacity(layout.total() * d);
    let mut partial_l = Vec::with_capacity(layout.total());
    st.time("routed", || {
        let parts = ctx.pool().map_ranges(nb, |blocks| {
            let p0 = layout.offsets[blocks.start] as usize;
            let pend = if blocks.end < nb {
                layout.offsets[blocks.end] as usize
            } else {
                layout.total()
            };
            let mut po = vec![0.0f32; (pend - p0) * d];
            let mut pl = vec![0.0f32; pend - p0];
            let mut p_idx = 0usize;
            for j in blocks {
                let qs = layout.queries_of(j);
                let g = &gathered[j];
                let kb = &k[j * block * d..(j + 1) * block * d];
                let vb = &v[j * block * d..(j + 1) * block * d];
                for (row, _t) in qs.iter().enumerate() {
                    let qt = &g[row * d..(row + 1) * d];
                    let mut s = vec![0.0f32; block];
                    let mut m = NEG_INF;
                    for (u, su) in s.iter_mut().enumerate() {
                        *su = dot(qt, &kb[u * d..(u + 1) * d]) * scale;
                        if *su > m {
                            m = *su;
                        }
                    }
                    let mut z = 0.0f32;
                    let prow = &mut po[p_idx * d..(p_idx + 1) * d];
                    for (u, su) in s.iter().enumerate() {
                        let p = (su - m).exp();
                        z += p;
                        axpy(prow, p, &vb[u * d..(u + 1) * d]);
                    }
                    for c in prow.iter_mut() {
                        *c /= z;
                    }
                    pl[p_idx] = m + z.ln();
                    p_idx += 1;
                }
            }
            (po, pl)
        });
        for (po, pl) in parts {
            partial_o.extend_from_slice(&po);
            partial_l.extend_from_slice(&pl);
        }
    });
    st.add_workspace(ws_bytes(&[partial_o.len(), partial_l.len()]));

    // ---- stage 4: local (own block, causal) -----------------------------
    let mut local_o = Vec::with_capacity(n * d);
    let mut local_l = Vec::with_capacity(n);
    st.time("local", || {
        let parts = ctx.pool().map_ranges(n, |rows| {
            let mut lo_o = vec![0.0f32; rows.len() * d];
            let mut lo_l = vec![0.0f32; rows.len()];
            for (tt, t) in rows.enumerate() {
                let own = t / block;
                let base = own * block;
                let qt = &q[t * d..(t + 1) * d];
                let mut m = NEG_INF;
                let upto = t - base; // inclusive offset in own block
                let mut s = vec![0.0f32; upto + 1];
                for (u, su) in s.iter_mut().enumerate() {
                    *su = dot(qt, &k[(base + u) * d..(base + u + 1) * d]) * scale;
                    if *su > m {
                        m = *su;
                    }
                }
                let mut z = 0.0f32;
                let ot = &mut lo_o[tt * d..(tt + 1) * d];
                for (u, su) in s.iter().enumerate() {
                    let p = (su - m).exp();
                    z += p;
                    axpy(ot, p, &v[(base + u) * d..(base + u + 1) * d]);
                }
                for c in ot.iter_mut() {
                    *c /= z;
                }
                lo_l[tt] = m + z.ln();
            }
            (lo_o, lo_l)
        });
        for (lo_o, lo_l) in parts {
            local_o.extend_from_slice(&lo_o);
            local_l.extend_from_slice(&lo_l);
        }
    });
    st.add_workspace(ws_bytes(&[local_o.len(), local_l.len()]));

    // ---- stage 5: merge --------------------------------------------------
    // per query: max over (local, routed partials in ascending block
    // order), then the weighted combination in the same order — the
    // serial accumulation order, partitioned by query rows
    let mut o = Vec::with_capacity(n * d);
    st.time("merge", || {
        let parts = ctx.pool().map_ranges(n, |rows| {
            let (lo, hi) = (rows.start, rows.end);
            let count = hi - lo;
            // this range's routed sub-slice of every block's query list
            // (computed once; the max pass and the accumulate pass both
            // walk the same (a, b) windows)
            let windows: Vec<(usize, usize)> = (0..nb)
                .map(|j| {
                    let qs = layout.queries_of(j);
                    let a = qs.partition_point(|&t| (t as usize) < lo);
                    let b = qs.partition_point(|&t| (t as usize) < hi);
                    (a, b)
                })
                .collect();
            // global max per query over partials
            let mut m: Vec<f32> = local_l[lo..hi].to_vec();
            for (j, &(a, b)) in windows.iter().enumerate() {
                let qs = layout.queries_of(j);
                for (off, &t) in qs[a..b].iter().enumerate() {
                    let p = layout.offsets[j] as usize + a + off;
                    let ti = t as usize - lo;
                    if partial_l[p] > m[ti] {
                        m[ti] = partial_l[p];
                    }
                }
            }
            let mut z = vec![0.0f32; count];
            let mut og = vec![0.0f32; count * d];
            for (tt, t) in rows.enumerate() {
                let w = (local_l[t] - m[tt]).exp();
                z[tt] += w;
                axpy(&mut og[tt * d..(tt + 1) * d], w, &local_o[t * d..(t + 1) * d]);
            }
            for (j, &(a, b)) in windows.iter().enumerate() {
                let qs = layout.queries_of(j);
                for (off, &t) in qs[a..b].iter().enumerate() {
                    let p = layout.offsets[j] as usize + a + off;
                    let ti = t as usize - lo;
                    let w = (partial_l[p] - m[ti]).exp();
                    z[ti] += w;
                    axpy(
                        &mut og[ti * d..(ti + 1) * d],
                        w,
                        &partial_o[p * d..(p + 1) * d],
                    );
                }
            }
            for ti in 0..count {
                for c in 0..d {
                    og[ti * d + c] /= z[ti];
                }
            }
            og
        });
        for og in parts {
            o.extend_from_slice(&og);
        }
    });
    st.add_workspace(ws_bytes(&[2 * n]));

    (o, indices, st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::testutil::{max_abs_diff, qkv};

    #[test]
    fn naive_pipeline_matches_reference() {
        for (n, d, b, k) in [(128, 16, 16, 2), (256, 8, 32, 3), (64, 4, 16, 1)] {
            let shape = MobaShape::new(n, d, b, k);
            let (q, kk, v) = qkv(21, n, d);
            let (o, idx, _st) = moba_naive_forward(&q, &kk, &v, shape);
            let (oref, _) = moba_reference(&q, &kk, &v, shape, &idx);
            assert!(max_abs_diff(&o, &oref) < 3e-5, "n={n} b={b} k={k}");
        }
    }

    #[test]
    fn all_blocks_routed_equals_dense() {
        let (n, d, b) = (128, 8, 16);
        let shape = MobaShape::new(n, d, b, n / b); // k = nb: everything routed
        let (q, kk, v) = qkv(22, n, d);
        let (o, _, _) = moba_naive_forward(&q, &kk, &v, shape);
        let (oref, _) = naive_attention(&q, &kk, &v, n, d);
        assert!(max_abs_diff(&o, &oref) < 3e-5);
    }

    /// Partitioning the five stages across workers must not change a
    /// single bit of the output or the routing table.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let shape = MobaShape::new(5 * 16, 8, 16, 2); // 5 blocks: uneven splits
        let (q, kk, v) = qkv(25, shape.n, shape.d);
        let (o1, i1, _) = moba_naive_forward_ctx(&ExecCtx::serial(), &q, &kk, &v, shape);
        for threads in [2, 3, 4, 11] {
            let ctx = ExecCtx::with_threads(threads);
            let (o2, i2, st) = moba_naive_forward_ctx(&ctx, &q, &kk, &v, shape);
            assert_eq!(o1, o2, "o differs at threads={threads}");
            assert_eq!(i1, i2, "indices differ at threads={threads}");
            assert_eq!(st.threads(), threads);
        }
    }

    #[test]
    fn stage_labels_complete() {
        let shape = MobaShape::new(64, 4, 16, 1);
        let (q, kk, v) = qkv(23, 64, 4);
        let (_, _, st) = moba_naive_forward(&q, &kk, &v, shape);
        for label in ["gating", "reindex", "routed", "local", "merge"] {
            assert!(st.get(label).is_some(), "missing stage {label}");
        }
        assert!(st.workspace_bytes > 0);
    }

    #[test]
    fn reference_first_token_is_v0() {
        let shape = MobaShape::new(32, 4, 8, 1);
        let (q, kk, v) = qkv(24, 32, 4);
        let idx = vec![-1i32; 32];
        let (o, _) = moba_reference(&q, &kk, &v, shape, &idx);
        assert!(max_abs_diff(&o[..4], &v[..4]) < 1e-6);
    }
}
