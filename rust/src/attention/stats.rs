//! Stage-level instrumentation for the pipeline breakdown experiments
//! (Figure 4) and workspace-memory accounting (Figure 3 bottom).

use std::time::{Duration, Instant};

/// Named stage timings + logical workspace bytes for one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct StageStats {
    stages: Vec<(String, Duration)>,
    /// peak *extra* workspace allocated by the pipeline (bytes), beyond
    /// the q/k/v/o tensors themselves — the quantity that differs by
    /// orders of magnitude between original MoBA and FlashMoBA.
    pub workspace_bytes: u64,
}

impl StageStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.stages.push((name.to_string(), t0.elapsed()));
        out
    }

    pub fn add_workspace(&mut self, bytes: u64) {
        self.workspace_bytes += bytes;
    }

    pub fn stages(&self) -> &[(String, Duration)] {
        &self.stages
    }

    pub fn total(&self) -> Duration {
        self.stages.iter().map(|(_, d)| *d).sum()
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        // sum over repeated stages with the same label
        let tot: Duration =
            self.stages.iter().filter(|(n, _)| n == name).map(|(_, d)| *d).sum();
        if self.stages.iter().any(|(n, _)| n == name) {
            Some(tot)
        } else {
            None
        }
    }

    /// Pretty one-line summary, e.g. `topk 1.2ms | attn 3.4ms (total 4.6ms)`.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .stages
            .iter()
            .map(|(n, d)| format!("{n} {:.2}ms", d.as_secs_f64() * 1e3))
            .collect();
        format!("{} (total {:.2}ms, ws {:.1}MB)",
            parts.join(" | "),
            self.total().as_secs_f64() * 1e3,
            self.workspace_bytes as f64 / 1e6)
    }
}

/// f32 workspace size helper: number of elements -> bytes.
pub fn ws_bytes(lens: &[usize]) -> u64 {
    lens.iter().map(|&l| l as u64 * 4).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_stages_in_order() {
        let mut st = StageStats::new();
        let x = st.time("a", || 1 + 1);
        assert_eq!(x, 2);
        st.time("b", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(st.stages().len(), 2);
        assert!(st.get("b").unwrap() >= Duration::from_millis(2));
        assert!(st.get("c").is_none());
        assert!(st.total() >= st.get("b").unwrap());
        assert!(st.summary().contains("a "));
    }

    #[test]
    fn repeated_stage_names_accumulate() {
        let mut st = StageStats::new();
        st.time("x", || std::thread::sleep(Duration::from_millis(1)));
        st.time("x", || std::thread::sleep(Duration::from_millis(1)));
        assert!(st.get("x").unwrap() >= Duration::from_millis(2));
    }

    #[test]
    fn ws_bytes_sums() {
        assert_eq!(ws_bytes(&[2, 3]), 20);
    }
}
