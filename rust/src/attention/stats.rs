//! Stage-level instrumentation for the pipeline breakdown experiments
//! (Figure 4) and workspace-memory accounting (Figure 3 bottom), plus
//! the thread count each stage ran with (the multi-core execution
//! layer's per-stage telemetry, surfaced in the `BENCH_*.json` blobs).
//!
//! `StageStats` is entirely stack-allocated: stage names are `'static`
//! labels and the records live in a fixed inline array, so timing a
//! kernel costs the hot path **zero heap allocations** — part of the
//! allocation-free steady-state contract pinned by
//! `rust/tests/alloc_regression.rs`.

use std::time::{Duration, Instant};

use crate::util::pool::ExecCtx;

/// One timed pipeline stage.
#[derive(Debug, Clone, Copy)]
pub struct StageRecord {
    pub name: &'static str,
    /// wall-clock time of the stage
    pub wall: Duration,
    /// worker threads the stage's kernels could partition over
    /// (1 = serial path)
    pub threads: usize,
}

const EMPTY_RECORD: StageRecord = StageRecord { name: "", wall: Duration::ZERO, threads: 1 };

/// Inline record capacity — the deepest in-tree pipeline (original
/// MoBA) records 6 stages; further stages past the cap are dropped
/// (debug-asserted) rather than allocated.
const MAX_STAGES: usize = 8;

/// Named stage timings + logical workspace bytes for one pipeline run.
#[derive(Debug, Clone)]
pub struct StageStats {
    records: [StageRecord; MAX_STAGES],
    len: usize,
    /// thread budget stamped onto stages recorded via [`StageStats::time`]
    threads: usize,
    /// query heads the run's kernel launches covered (1 = single-head).
    /// Kernels iterate heads internally, so a stage's wall time folds
    /// all heads into one record — this stamp is how consumers recover
    /// the per-head share.
    heads: usize,
    /// peak *extra* workspace allocated by the pipeline (bytes), beyond
    /// the q/k/v/o tensors themselves — the quantity that differs by
    /// orders of magnitude between original MoBA and FlashMoBA. With
    /// multiple workers this sums each worker's private buffers (the
    /// true footprint of the parallel run).
    pub workspace_bytes: u64,
    /// KV heads that degraded to dense for this run via the runtime
    /// margin fallback (planned `HeadMode::Dense` heads don't count).
    /// 0 on the uniform / static path.
    pub fallback_heads: u32,
}

impl Default for StageStats {
    fn default() -> Self {
        Self::new()
    }
}

impl StageStats {
    /// Serial-stamped stats (threads = 1, heads = 1).
    pub fn new() -> Self {
        Self {
            records: [EMPTY_RECORD; MAX_STAGES],
            len: 0,
            threads: 1,
            heads: 1,
            workspace_bytes: 0,
            fallback_heads: 0,
        }
    }

    /// Stats whose stages are stamped with `ctx`'s worker count.
    pub fn for_ctx(ctx: &ExecCtx) -> Self {
        let mut st = Self::new();
        st.threads = ctx.threads();
        st
    }

    /// Stats stamped with `ctx`'s worker count and a query-head count
    /// (the backends construct these from their `AttnShape`).
    pub fn for_heads(ctx: &ExecCtx, heads: usize) -> Self {
        let mut st = Self::for_ctx(ctx);
        st.heads = heads.max(1);
        st
    }

    /// Thread budget stamped onto recorded stages.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Query heads the recorded stages covered per launch.
    pub fn heads(&self) -> usize {
        self.heads
    }

    /// Time `f` and record it under `name`.
    pub fn time<T>(&mut self, name: &'static str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        debug_assert!(self.len < MAX_STAGES, "stage record capacity exceeded at {name}");
        if self.len < MAX_STAGES {
            self.records[self.len] =
                StageRecord { name, wall: t0.elapsed(), threads: self.threads };
            self.len += 1;
        }
        out
    }

    pub fn add_workspace(&mut self, bytes: u64) {
        self.workspace_bytes += bytes;
    }

    pub fn stages(&self) -> &[StageRecord] {
        &self.records[..self.len]
    }

    pub fn total(&self) -> Duration {
        self.stages().iter().map(|r| r.wall).sum()
    }

    pub fn get(&self, name: &str) -> Option<Duration> {
        // sum over repeated stages with the same label
        let tot: Duration =
            self.stages().iter().filter(|r| r.name == name).map(|r| r.wall).sum();
        if self.stages().iter().any(|r| r.name == name) {
            Some(tot)
        } else {
            None
        }
    }

    /// Pretty one-line summary, e.g.
    /// `topk 1.2ms | attn 3.4ms (total 4.6ms, ws 0.1MB, 8 heads, 4 threads)`.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .stages()
            .iter()
            .map(|r| format!("{} {:.2}ms", r.name, r.wall.as_secs_f64() * 1e3))
            .collect();
        let heads = if self.heads == 1 {
            String::new()
        } else {
            format!("{} heads, ", self.heads)
        };
        let heads = if self.fallback_heads == 0 {
            heads
        } else {
            format!("{heads}{} dense-fallback, ", self.fallback_heads)
        };
        format!(
            "{} (total {:.2}ms, ws {:.1}MB, {heads}{} thread{})",
            parts.join(" | "),
            self.total().as_secs_f64() * 1e3,
            self.workspace_bytes as f64 / 1e6,
            self.threads,
            if self.threads == 1 { "" } else { "s" }
        )
    }
}

/// f32 workspace size helper: number of elements -> bytes.
pub fn ws_bytes(lens: &[usize]) -> u64 {
    lens.iter().map(|&l| l as u64 * 4).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_stages_in_order() {
        let mut st = StageStats::new();
        let x = st.time("a", || 1 + 1);
        assert_eq!(x, 2);
        st.time("b", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(st.stages().len(), 2);
        assert!(st.get("b").unwrap() >= Duration::from_millis(2));
        assert!(st.get("c").is_none());
        assert!(st.total() >= st.get("b").unwrap());
        assert!(st.summary().contains("a "));
    }

    #[test]
    fn repeated_stage_names_accumulate() {
        let mut st = StageStats::new();
        st.time("x", || std::thread::sleep(Duration::from_millis(1)));
        st.time("x", || std::thread::sleep(Duration::from_millis(1)));
        assert!(st.get("x").unwrap() >= Duration::from_millis(2));
    }

    #[test]
    fn stages_are_stamped_with_the_ctx_thread_count() {
        let ctx = ExecCtx::with_threads(3);
        let mut st = StageStats::for_ctx(&ctx);
        st.time("p", || ());
        assert_eq!(st.threads(), 3);
        assert_eq!(st.stages()[0].threads, 3);
        assert!(st.summary().contains("3 threads"));
        let mut serial = StageStats::new();
        serial.time("s", || ());
        assert_eq!(serial.stages()[0].threads, 1);
        assert!(serial.summary().contains("1 thread"));
    }

    #[test]
    fn ws_bytes_sums() {
        assert_eq!(ws_bytes(&[2, 3]), 20);
    }

    #[test]
    fn fallback_heads_surface_in_summary_only_when_nonzero() {
        let mut st = StageStats::new();
        st.time("fwd", || ());
        assert_eq!(st.fallback_heads, 0);
        assert!(!st.summary().contains("dense-fallback"));
        st.fallback_heads = 2;
        assert!(st.summary().contains("2 dense-fallback"));
    }

    #[test]
    fn head_stamp_folds_into_summary() {
        let ctx = ExecCtx::with_threads(2);
        let mut st = StageStats::for_heads(&ctx, 8);
        st.time("fwd", || ());
        assert_eq!(st.heads(), 8);
        assert_eq!(st.threads(), 2);
        assert!(st.summary().contains("8 heads"));
        // single-head stats keep the old summary shape
        assert_eq!(StageStats::for_ctx(&ctx).heads(), 1);
        assert!(!StageStats::new().summary().contains("heads"));
        // heads = 0 is clamped, not propagated
        assert_eq!(StageStats::for_heads(&ctx, 0).heads(), 1);
    }

    /// The inline record array never spills past its cap in release
    /// builds — extra stages are dropped, the run still reports.
    #[test]
    #[cfg(not(debug_assertions))]
    fn overflow_drops_instead_of_growing() {
        let mut st = StageStats::new();
        for _ in 0..12 {
            st.time("x", || ());
        }
        assert_eq!(st.stages().len(), 8);
    }
}
