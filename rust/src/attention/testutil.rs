//! Deterministic pseudo-random test data for the attention substrate.

/// xorshift64* — tiny, deterministic, dependency-free PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a vec with standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }
}

/// Random (q, k, v) triple for shape (n, d).
pub fn qkv(seed: u64, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    (r.normal_vec(n * d), r.normal_vec(n * d), r.normal_vec(n * d))
}

/// Random packed (q, k, v) triple: q is (h, n, d), k/v are (h_kv, n, d).
/// With `h = h_kv = 1` this draws exactly the same values as
/// [`qkv`] — the single-head bit-parity tests depend on that.
pub fn qkv_packed(
    seed: u64,
    h: usize,
    h_kv: usize,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    (
        r.normal_vec(h * n * d),
        r.normal_vec(h_kv * n * d),
        r.normal_vec(h_kv * n * d),
    )
}

/// Tile a packed (h_from, n, d) tensor up to (h_to, n, d) by repeating
/// each head `h_to / h_from` times in group order — the explicit-KV
/// form of GQA broadcasting (used by the GQA-semantics property tests).
pub fn repeat_heads(x: &[f32], h_from: usize, h_to: usize, n: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), h_from * n * d);
    assert!(h_from >= 1 && h_to % h_from == 0);
    let group = h_to / h_from;
    let mut out = Vec::with_capacity(h_to * n * d);
    for head in 0..h_from {
        for _ in 0..group {
            out.extend_from_slice(&x[head * n * d..(head + 1) * n * d]);
        }
    }
    out
}

/// Max |a - b|.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Serial **scalar oracles** for the microkernel-backed forward
/// kernels: the pre-refactor per-(row, col) `dot` / per-row `axpy` /
/// `scale` formulation, preserved verbatim (GQA- and ragged-capable)
/// so the register-blocked GEMM path can be pinned `to_bits`-identical
/// to it forever — see `prop_microkernels_bit_identical_to_scalar_
/// oracle` in `rust/tests/property.rs`. Shares only `simd::{dot, axpy,
/// scale}` (the scalar kernels always called exactly these) and the
/// untouched `build_varlen`.
pub mod scalar {
    use super::super::dense::NEG_INF;
    use super::super::flash_moba::FlashMobaConfig;
    use super::super::simd::{axpy, dot, scale as vscale};
    use super::super::varlen::build_varlen;
    use super::super::AttnShape;

    /// Pre-refactor packed blocked online-softmax attention (serial
    /// flattened (head, query-tile) unit order). Returns (o, lse).
    #[allow(clippy::too_many_arguments)]
    pub fn flash_attention_packed(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        h: usize,
        h_kv: usize,
        n: usize,
        d: usize,
        br: usize,
        bc: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let group = h / h_kv;
        let scale = 1.0 / (d as f32).sqrt();
        let tq = n.div_ceil(br);
        let mut o = Vec::with_capacity(h * n * d);
        let mut lse = Vec::with_capacity(h * n);
        let mut s = vec![0.0f32; br * bc];
        let mut acc = vec![0.0f32; br * d];
        let mut mrow = vec![NEG_INF; br];
        let mut lrow = vec![0.0f32; br];
        for u in 0..h * tq {
            let (head, it) = (u / tq, u % tq);
            let qh = &q[head * n * d..(head + 1) * n * d];
            let kvh = head / group;
            let kh = &k[kvh * n * d..(kvh + 1) * n * d];
            let vh = &v[kvh * n * d..(kvh + 1) * n * d];
            let r0 = it * br;
            let rows = br.min(n - r0);
            acc[..rows * d].fill(0.0);
            mrow[..rows].fill(NEG_INF);
            lrow[..rows].fill(0.0);
            let last_col = r0 + rows;
            let tk = last_col.div_ceil(bc);
            for jt in 0..tk {
                let c0 = jt * bc;
                let cols = bc.min(last_col - c0);
                for r in 0..rows {
                    let qt = &qh[(r0 + r) * d..(r0 + r + 1) * d];
                    let srow = &mut s[r * bc..r * bc + cols];
                    for (cc, sval) in srow.iter_mut().enumerate() {
                        let col = c0 + cc;
                        if col > r0 + r {
                            *sval = NEG_INF;
                            continue;
                        }
                        *sval = dot(qt, &kh[col * d..(col + 1) * d]) * scale;
                    }
                }
                for r in 0..rows {
                    let srow = &mut s[r * bc..r * bc + cols];
                    let mut mt = mrow[r];
                    for &x in srow.iter() {
                        if x > mt {
                            mt = x;
                        }
                    }
                    if mt == NEG_INF {
                        continue;
                    }
                    let corr = (mrow[r] - mt).exp();
                    let mut psum = 0.0f32;
                    for x in srow.iter_mut() {
                        *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                        psum += *x;
                    }
                    lrow[r] = lrow[r] * corr + psum;
                    let arow = &mut acc[r * d..(r + 1) * d];
                    if corr != 1.0 {
                        vscale(arow, corr);
                    }
                    for (cc, &p) in srow.iter().enumerate() {
                        if p == 0.0 {
                            continue;
                        }
                        axpy(arow, p, &vh[(c0 + cc) * d..(c0 + cc + 1) * d]);
                    }
                    mrow[r] = mt;
                }
            }
            for r in 0..rows {
                let l = if lrow[r] == 0.0 { 1.0 } else { lrow[r] };
                let arow = &acc[r * d..(r + 1) * d];
                for c in 0..d {
                    o.push(arow[c] / l);
                }
                lse.push(mrow[r] + lrow[r].max(1e-30).ln());
            }
        }
        (o, lse)
    }

    fn topk_insert(best_s: &mut [f32], best_i: &mut [i32], score: f32, index: i32) {
        let k = best_s.len();
        if score > best_s[k - 1] {
            let mut pos = k - 1;
            while pos > 0 && best_s[pos - 1] < score {
                best_s[pos] = best_s[pos - 1];
                best_i[pos] = best_i[pos - 1];
                pos -= 1;
            }
            best_s[pos] = score;
            best_i[pos] = index;
        }
    }

    /// One KV head's complete-block centroids (ragged tail skipped).
    fn centroids_head(k: &[f32], n: usize, d: usize, block: usize) -> Vec<f32> {
        let cb = n / block;
        let inv = 1.0 / block as f32;
        let mut out = vec![0.0f32; cb * d];
        for j in 0..cb {
            let dst = &mut out[j * d..(j + 1) * d];
            for r in 0..block {
                let src = &k[(j * block + r) * d..(j * block + r + 1) * d];
                for c in 0..d {
                    dst[c] += src[c];
                }
            }
            for c in dst.iter_mut() {
                *c *= inv;
            }
        }
        out
    }

    /// One query head's streaming tiled top-k (ragged-aware: tail rows
    /// see every complete block as a candidate).
    fn tiled_topk_head(
        q: &[f32],
        centroids: &[f32],
        n: usize,
        d: usize,
        block: usize,
        topk: usize,
        tile_c: usize,
    ) -> Vec<i32> {
        let cb = centroids.len() / d.max(1);
        let tile_c = tile_c.max(1);
        if topk == 0 {
            return Vec::new();
        }
        let mut out = vec![-1i32; n * topk];
        let mut best_s = vec![f32::NEG_INFINITY; topk];
        let mut best_i = vec![-1i32; topk];
        for t in 0..n {
            let own = (t / block).min(cb);
            let qt = &q[t * d..(t + 1) * d];
            best_s.fill(f32::NEG_INFINITY);
            best_i.fill(-1);
            let mut j0 = 0;
            while j0 < own {
                let jend = (j0 + tile_c).min(own);
                for j in j0..jend {
                    let dotv = dot(qt, &centroids[j * d..(j + 1) * d]);
                    topk_insert(&mut best_s, &mut best_i, dotv, j as i32);
                }
                j0 = jend;
            }
            out[t * topk..(t + 1) * topk].copy_from_slice(&best_i);
        }
        out
    }

    /// Pre-refactor packed FlashMoBA forward (serial, GQA + ragged
    /// tail): Flash TopK per query head + the gather-and-densify
    /// forward over all rows. Returns (o, lse, (h, n, topk) indices).
    pub fn flash_moba(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        shape: AttnShape,
        cfg: FlashMobaConfig,
    ) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let AttnShape { h, h_kv, n, d, block, topk } = shape;
        let cb = shape.complete_blocks();
        let group = shape.group();
        let cents: Vec<Vec<f32>> = (0..h_kv)
            .map(|kvh| centroids_head(&k[kvh * n * d..(kvh + 1) * n * d], n, d, block))
            .collect();
        let mut o = Vec::with_capacity(h * n * d);
        let mut lse = Vec::with_capacity(h * n);
        let mut indices = Vec::new();
        for qh in 0..h {
            let kvh = qh / group;
            let idx = tiled_topk_head(
                &q[qh * n * d..(qh + 1) * n * d],
                &cents[kvh],
                n,
                d,
                block,
                topk,
                cfg.topk_tile,
            );
            let layout = build_varlen(&idx, n, topk, cb);
            let (oh, lh) = forward_head(
                &q[qh * n * d..(qh + 1) * n * d],
                &k[kvh * n * d..(kvh + 1) * n * d],
                &v[kvh * n * d..(kvh + 1) * n * d],
                shape,
                cfg,
                &layout,
            );
            o.extend_from_slice(&oh);
            lse.extend_from_slice(&lh);
            indices.extend_from_slice(&idx);
        }
        (o, lse, indices)
    }

    /// The scalar gather-and-densify body for one whole head.
    fn forward_head(
        q: &[f32],
        k: &[f32],
        v: &[f32],
        shape: AttnShape,
        cfg: FlashMobaConfig,
        layout: &super::super::varlen::VarlenLayout,
    ) -> (Vec<f32>, Vec<f32>) {
        let AttnShape { n, d, block, .. } = shape;
        let nb = shape.n_blocks();
        let cb = shape.complete_blocks();
        let sm_scale = 1.0 / (d as f32).sqrt();
        let tile_r = cfg.tile_r;
        let tile_c = cfg.tile_c.min(block);
        let mut m = vec![NEG_INF; n];
        let mut l = vec![0.0f32; n];
        let mut acc = vec![0.0f32; n * d];
        let mut qg = vec![0.0f32; tile_r * d];
        let mut s = vec![0.0f32; tile_r * tile_c];

        for j in 0..nb {
            let blen = shape.block_len(j);
            let kb = &k[j * block * d..(j * block + blen) * d];
            let vb = &v[j * block * d..(j * block + blen) * d];
            let own_start = j * block;

            let mut process_tile = |rows: &[u32], causal: bool| {
                let rcount = rows.len();
                for (r, &t) in rows.iter().enumerate() {
                    qg[r * d..(r + 1) * d]
                        .copy_from_slice(&q[t as usize * d..(t as usize + 1) * d]);
                }
                let tcs = blen.div_ceil(tile_c);
                for ct in 0..tcs {
                    let c0 = ct * tile_c;
                    let cols = tile_c.min(blen - c0);
                    for r in 0..rcount {
                        let qt = &qg[r * d..(r + 1) * d];
                        let trow = rows[r] as usize;
                        let srow = &mut s[r * tile_c..r * tile_c + cols];
                        for (cc, sval) in srow.iter_mut().enumerate() {
                            let u = c0 + cc;
                            if causal && own_start + u > trow {
                                *sval = NEG_INF;
                                continue;
                            }
                            *sval = dot(qt, &kb[u * d..(u + 1) * d]) * sm_scale;
                        }
                    }
                    for r in 0..rcount {
                        let ti = rows[r] as usize;
                        let srow = &mut s[r * tile_c..r * tile_c + cols];
                        let mut mt = m[ti];
                        for &x in srow.iter() {
                            if x > mt {
                                mt = x;
                            }
                        }
                        if mt == NEG_INF {
                            continue;
                        }
                        let corr = (m[ti] - mt).exp();
                        let mut psum = 0.0f32;
                        for x in srow.iter_mut() {
                            *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                            psum += *x;
                        }
                        l[ti] = l[ti] * corr + psum;
                        let arow = &mut acc[ti * d..(ti + 1) * d];
                        if corr != 1.0 {
                            vscale(arow, corr);
                        }
                        for (cc, &p) in srow.iter().enumerate() {
                            if p == 0.0 {
                                continue;
                            }
                            axpy(arow, p, &vb[(c0 + cc) * d..(c0 + cc + 1) * d]);
                        }
                        m[ti] = mt;
                    }
                }
            };

            if j < cb {
                for chunk in layout.queries_of(j).chunks(tile_r) {
                    process_tile(chunk, false);
                }
            }
            let own_rows: Vec<u32> =
                (own_start as u32..(own_start + blen) as u32).collect();
            for chunk in own_rows.chunks(tile_r) {
                process_tile(chunk, true);
            }
        }

        let mut o = vec![0.0f32; n * d];
        let mut lse = vec![0.0f32; n];
        for ti in 0..n {
            let z = if l[ti] == 0.0 { 1.0 } else { l[ti] };
            for c in 0..d {
                o[ti * d + c] = acc[ti * d + c] / z;
            }
            lse[ti] = m[ti] + l[ti].max(1e-30).ln();
        }
        (o, lse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn qkv_packed_single_head_equals_qkv() {
        let (q1, k1, v1) = qkv(77, 12, 4);
        let (q2, k2, v2) = qkv_packed(77, 1, 1, 12, 4);
        assert_eq!(q1, q2);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn repeat_heads_tiles_in_group_order() {
        // 2 heads of (n=1, d=2) -> 4 heads: [a a b b]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(
            repeat_heads(&x, 2, 4, 1, 2),
            vec![1.0, 2.0, 1.0, 2.0, 3.0, 4.0, 3.0, 4.0]
        );
        assert_eq!(repeat_heads(&x, 2, 2, 1, 2), x);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
