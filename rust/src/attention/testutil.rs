//! Deterministic pseudo-random test data for the attention substrate.

/// xorshift64* — tiny, deterministic, dependency-free PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a vec with standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }
}

/// Random (q, k, v) triple for shape (n, d).
pub fn qkv(seed: u64, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    (r.normal_vec(n * d), r.normal_vec(n * d), r.normal_vec(n * d))
}

/// Random packed (q, k, v) triple: q is (h, n, d), k/v are (h_kv, n, d).
/// With `h = h_kv = 1` this draws exactly the same values as
/// [`qkv`] — the single-head bit-parity tests depend on that.
pub fn qkv_packed(
    seed: u64,
    h: usize,
    h_kv: usize,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    (
        r.normal_vec(h * n * d),
        r.normal_vec(h_kv * n * d),
        r.normal_vec(h_kv * n * d),
    )
}

/// Tile a packed (h_from, n, d) tensor up to (h_to, n, d) by repeating
/// each head `h_to / h_from` times in group order — the explicit-KV
/// form of GQA broadcasting (used by the GQA-semantics property tests).
pub fn repeat_heads(x: &[f32], h_from: usize, h_to: usize, n: usize, d: usize) -> Vec<f32> {
    assert_eq!(x.len(), h_from * n * d);
    assert!(h_from >= 1 && h_to % h_from == 0);
    let group = h_to / h_from;
    let mut out = Vec::with_capacity(h_to * n * d);
    for head in 0..h_from {
        for _ in 0..group {
            out.extend_from_slice(&x[head * n * d..(head + 1) * n * d]);
        }
    }
    out
}

/// Max |a - b|.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn qkv_packed_single_head_equals_qkv() {
        let (q1, k1, v1) = qkv(77, 12, 4);
        let (q2, k2, v2) = qkv_packed(77, 1, 1, 12, 4);
        assert_eq!(q1, q2);
        assert_eq!(k1, k2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn repeat_heads_tiles_in_group_order() {
        // 2 heads of (n=1, d=2) -> 4 heads: [a a b b]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(
            repeat_heads(&x, 2, 4, 1, 2),
            vec![1.0, 2.0, 1.0, 2.0, 3.0, 4.0, 3.0, 4.0]
        );
        assert_eq!(repeat_heads(&x, 2, 2, 1, 2), x);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
