//! Deterministic pseudo-random test data for the attention substrate.

/// xorshift64* — tiny, deterministic, dependency-free PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a vec with standard-normal f32.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }
}

/// Random (q, k, v) triple for shape (n, d).
pub fn qkv(seed: u64, n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut r = Rng::new(seed);
    (r.normal_vec(n * d), r.normal_vec(n * d), r.normal_vec(n * d))
}

/// Max |a - b|.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
