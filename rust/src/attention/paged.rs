//! Paged KV storage: fixed-size pages owned by a shared pool, with
//! copy-on-write prefix sharing — the vLLM-style allocator under the
//! serving cache (ROADMAP item 1; ground truth
//! `flash_causal_lm.py`-style block tables).
//!
//! * [`PagePool`] — the global allocator. A *page* holds the K/V rows
//!   (and the running centroid-sum metadata) of **one logical block**
//!   of one KV head; every page reserves `page_tokens` rows of
//!   capacity up front, so steady-state appends into a page never
//!   reallocate (the zero-alloc contract of
//!   `rust/tests/alloc_regression.rs` extends to paged caches). The
//!   pool is pure accounting + identity: pages live in the sessions'
//!   page tables as refcounted handles, the pool tracks how many are
//!   live against an optional budget (`max_pages`). The budget is
//!   **soft**: `alloc` never fails mid-step — admission control
//!   ([`crate::coordinator::scheduler`]) is the gate that keeps
//!   `live_pages` under budget, and [`PagePool::would_fit`] is what it
//!   asks.
//! * [`PageHandle`] — one page-table entry: `Clone` shares the page
//!   (refcount bump, no copy — how two sessions share a common
//!   prefix), `Drop` returns the page to the pool when the last
//!   handle goes. [`PageHandle::make_mut`] is the **copy-on-write
//!   rule**: writing through a uniquely-held handle mutates in place;
//!   writing through a shared handle first splits off a private copy
//!   (counted in `cow_splits`). Appends only ever touch the *last*
//!   (partial) page of a table, so after a fork the complete shared
//!   prefix pages stay shared forever — only the partial tail page
//!   splits, and only on the first divergent append.
//!
//! The paged cache's arithmetic is untouched by any of this: a page
//! stores exactly the rows the contiguous store kept for that block,
//! and the per-block centroid sum accumulates in the same arrival
//! order — so paged decode is bit-identical to the contiguous path
//! (pinned by `rust/tests/paged_parity.rs`).
//!
//! **Quantized pages & byte-true budgets.** A page's K/V rows live in a
//! [`KvBuf`] of the cache's [`KvDtype`] (f32 / f16 / bf16 / int8 with
//! per-row scales) while the centroid `sum` always accumulates the
//! *pre-quantization* f32 rows — routing reads only sums, so block
//! selection is dtype-invariant. Budget accounting is **byte-true**:
//! the pool charges each page `elem_bytes(dtype)` *units* (f32 = 4,
//! f16/bf16 = 2, i8 = 1) against a budget of `max_pages * 4` units —
//! i.e. `max_pages` is denominated in f32-page-equivalents, so an f16
//! cache really does fit twice the sessions in the same budget
//! (previously admission counted pages regardless of width). Page
//! *counts* (`live`, `peak`, table sizes) remain dtype-independent;
//! only admission cost is weighted.

use std::sync::{Arc, Mutex, Weak};

use super::dtype::{KvBuf, KvDtype, KvView};

/// One page: the K/V rows and running centroid-sum metadata of one
/// logical block of one KV head. Capacity (`cap_rows` == the pool's
/// `page_tokens`) is reserved at allocation, so [`PageData::append_row`]
/// never reallocates.
#[derive(Debug)]
pub struct PageData {
    d: usize,
    cap_rows: usize,
    /// token rows stored so far (<= cap_rows)
    len: usize,
    /// (len, d) row-major keys (post-kconv when the cache streams one),
    /// stored in the cache's [`KvDtype`]
    k: KvBuf,
    /// (len, d) row-major values, same dtype as `k`
    v: KvBuf,
    /// running key sum of this page's rows, (d) — divided by `len` at
    /// read time to form the block centroid, exactly like the
    /// contiguous store's `sums` slab. Always f32, accumulated from the
    /// *pre-quantization* rows, so routing never sees quantization.
    sum: Vec<f32>,
}

impl PageData {
    fn new(cap_rows: usize, d: usize, dtype: KvDtype) -> Self {
        Self {
            d,
            cap_rows,
            len: 0,
            k: KvBuf::with_capacity_rows(dtype, cap_rows, d),
            v: KvBuf::with_capacity_rows(dtype, cap_rows, d),
            sum: vec![0.0; d],
        }
    }

    /// Capacity-preserving deep copy (the CoW split body). A derived
    /// `Clone` would size the new buffers to `len * d` and lose the
    /// reserve, breaking the no-realloc append contract.
    fn split_copy(&self) -> Self {
        Self {
            d: self.d,
            cap_rows: self.cap_rows,
            len: self.len,
            k: self.k.split_copy(self.cap_rows, self.d),
            v: self.v.split_copy(self.cap_rows, self.d),
            sum: self.sum.clone(),
        }
    }

    /// Storage dtype of this page's K/V rows.
    pub fn dtype(&self) -> KvDtype {
        self.k.dtype()
    }

    /// Byte-true budget weight of this page: bytes per stored element
    /// (f32 = 4, f16/bf16 = 2, i8 = 1) — what the pool charges against
    /// its unit budget.
    pub fn units(&self) -> usize {
        self.k.dtype().elem_bytes()
    }

    /// Token rows stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the page holds its full `page_tokens` rows.
    pub fn is_full(&self) -> bool {
        self.len == self.cap_rows
    }

    /// Stored keys as raw f32, `(len, d)` row-major — the legacy f32
    /// accessor; panics on a quantized page (read [`PageData::k_view`]
    /// instead).
    pub fn k(&self) -> &[f32] {
        self.k.as_f32()
    }

    /// Stored values as raw f32, `(len, d)` row-major (f32 pages only).
    pub fn v(&self) -> &[f32] {
        self.v.as_f32()
    }

    /// Dtype-erased view of the stored keys — what the decode kernels
    /// attend through (dequantization happens inside the simd/gemm
    /// kernels, never as a materialized copy).
    pub fn k_view(&self) -> KvView<'_> {
        self.k.view_rows(0, self.len, self.d)
    }

    /// Dtype-erased view of the stored values.
    pub fn v_view(&self) -> KvView<'_> {
        self.v.view_rows(0, self.len, self.d)
    }

    /// Running key sum over this page's rows, `(d)`.
    pub fn sum(&self) -> &[f32] {
        &self.sum
    }

    /// Append one `(d)` key/value row, accumulating the centroid sum in
    /// arrival order (the same f32 additions as the contiguous store —
    /// the sum reads the caller's full-precision row, *then* the row is
    /// quantized into the store).
    pub fn append_row(&mut self, kr: &[f32], vr: &[f32]) {
        assert_eq!(kr.len(), self.d);
        assert_eq!(vr.len(), self.d);
        assert!(self.len < self.cap_rows, "page overflow: {} rows cap {}", self.len, self.cap_rows);
        for (s, &x) in self.sum.iter_mut().zip(kr) {
            *s += x;
        }
        self.k.append_row(kr);
        self.v.append_row(vr);
        self.len += 1;
    }
}

/// Pool-wide accounting, all under one lock.
#[derive(Debug, Default)]
struct PoolState {
    /// pages currently held by at least one handle
    live: usize,
    /// high-water mark of `live`
    peak: usize,
    /// byte-true budget charge of the live pages: each page counts its
    /// `elem_bytes(dtype)` (f32 = 4) — see [`PagePool::would_fit_units`]
    live_units: usize,
    /// high-water mark of `live_units`
    peak_units: usize,
    /// pages ever materialized (fresh allocs + CoW splits)
    allocated: u64,
    /// pages returned (last handle dropped)
    freed: u64,
    /// shared-handle writes that had to split a private copy
    cow_splits: u64,
    /// page-table entries satisfied by sharing an existing page
    /// ([`PagePool::note_share`] — a fork reports its table size here)
    prefix_shared: u64,
    next_id: u64,
}

#[derive(Debug)]
struct PoolShared {
    page_tokens: usize,
    max_pages: Option<usize>,
    state: Mutex<PoolState>,
}

impl PoolShared {
    /// Register one materialized page of `units` budget weight;
    /// returns its id.
    fn note_alloc(&self, splits: u64, units: usize) -> u64 {
        let mut st = self.state.lock().unwrap();
        st.allocated += 1;
        st.cow_splits += splits;
        st.live += 1;
        st.peak = st.peak.max(st.live);
        st.live_units += units;
        st.peak_units = st.peak_units.max(st.live_units);
        let id = st.next_id;
        st.next_id += 1;
        id
    }
}

/// Snapshot of a pool's counters (one lock, consistent view).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStats {
    pub live: usize,
    pub peak: usize,
    /// dtype-weighted budget charge of the live pages (f32 page = 4)
    pub live_units: usize,
    pub peak_units: usize,
    pub allocated: u64,
    pub freed: u64,
    pub cow_splits: u64,
    pub prefix_shared: u64,
}

/// The shared page allocator. `Clone` is a handle to the *same* pool
/// (sessions and the coordinator share one).
#[derive(Debug, Clone)]
pub struct PagePool {
    shared: Arc<PoolShared>,
}

impl PagePool {
    /// A pool of pages holding `page_tokens` rows each; `max_pages` is
    /// the soft budget admission control enforces (`None` = unbounded).
    /// The pool is d-agnostic: row width is fixed per page at
    /// [`PagePool::alloc`] time, so sessions with different head dims
    /// can share one pool.
    pub fn new(page_tokens: usize, max_pages: Option<usize>) -> Self {
        assert!(page_tokens >= 1, "pages must hold at least one token row");
        Self {
            shared: Arc::new(PoolShared {
                page_tokens,
                max_pages,
                state: Mutex::new(PoolState::default()),
            }),
        }
    }

    /// Rows per page. A paged cache requires every head's block size to
    /// divide into this (block <= page_tokens; one page per block).
    pub fn page_tokens(&self) -> usize {
        self.shared.page_tokens
    }

    /// The soft budget (`None` = unbounded).
    pub fn max_pages(&self) -> Option<usize> {
        self.shared.max_pages
    }

    /// Materialize a fresh f32 page with `(d)`-wide rows (the legacy
    /// entry point — see [`PagePool::alloc_dtype`]).
    pub fn alloc(&self, d: usize) -> PageHandle {
        self.alloc_dtype(d, KvDtype::F32)
    }

    /// Materialize a fresh page with `(d)`-wide rows stored as `dtype`.
    /// Never fails: the budget is enforced by admission control, not
    /// allocation — a decode step that was admitted must be able to
    /// finish. The page is charged `elem_bytes(dtype)` units against
    /// the byte-true budget.
    pub fn alloc_dtype(&self, d: usize, dtype: KvDtype) -> PageHandle {
        assert!(d >= 1);
        let id = self.shared.note_alloc(0, dtype.elem_bytes());
        PageHandle {
            id,
            pool: Arc::downgrade(&self.shared),
            data: Some(Arc::new(PageData::new(self.shared.page_tokens, d, dtype))),
        }
    }

    /// Would `extra` more live **f32** pages still fit under the
    /// budget? Compat wrapper over [`PagePool::would_fit_units`] for
    /// dtype-oblivious callers (charges the full 4 units per page).
    pub fn would_fit(&self, extra: usize) -> bool {
        self.would_fit_units(extra * KvDtype::F32.elem_bytes())
    }

    /// Would `units` more budget units still fit? The budget is
    /// byte-true: `max_pages` f32-page-equivalents = `max_pages * 4`
    /// units, and each live page charges its `elem_bytes(dtype)` —
    /// so halving the storage width really doubles admission capacity.
    pub fn would_fit_units(&self, units: usize) -> bool {
        match self.shared.max_pages {
            None => true,
            Some(m) => {
                self.shared.state.lock().unwrap().live_units + units
                    <= m * KvDtype::F32.elem_bytes()
            }
        }
    }

    /// Dtype-weighted budget charge of `pages` pages stored as `dtype`
    /// — the admission cost the coordinator passes to
    /// [`PagePool::would_fit_units`].
    pub fn units_for(pages: usize, dtype: KvDtype) -> usize {
        pages * dtype.elem_bytes()
    }

    /// Record `n` page-table entries satisfied by sharing existing
    /// pages (a fork reports its parent's table size).
    pub fn note_share(&self, n: u64) {
        self.shared.state.lock().unwrap().prefix_shared += n;
    }

    /// Consistent snapshot of the counters.
    pub fn stats(&self) -> PoolStats {
        let st = self.shared.state.lock().unwrap();
        PoolStats {
            live: st.live,
            peak: st.peak,
            live_units: st.live_units,
            peak_units: st.peak_units,
            allocated: st.allocated,
            freed: st.freed,
            cow_splits: st.cow_splits,
            prefix_shared: st.prefix_shared,
        }
    }

    /// Pages currently held by at least one handle.
    pub fn live_pages(&self) -> usize {
        self.shared.state.lock().unwrap().live
    }

    /// Dtype-weighted budget units currently charged (f32 page = 4).
    pub fn live_units(&self) -> usize {
        self.shared.state.lock().unwrap().live_units
    }

    /// Pages ever materialized (fresh allocs + CoW splits).
    pub fn pages_allocated(&self) -> u64 {
        self.shared.state.lock().unwrap().allocated
    }

    /// Shared-handle writes that split a private copy.
    pub fn cow_splits(&self) -> u64 {
        self.shared.state.lock().unwrap().cow_splits
    }

    /// Page-table entries satisfied by sharing instead of allocating.
    pub fn prefix_shared(&self) -> u64 {
        self.shared.state.lock().unwrap().prefix_shared
    }

    /// Fraction of page-table entries ever created that were satisfied
    /// by sharing an existing page instead of materializing a new one —
    /// the serve-soak bench's headline cache-reuse metric.
    pub fn prefix_hit_rate(&self) -> f64 {
        let st = self.shared.state.lock().unwrap();
        let total = st.prefix_shared + st.allocated;
        if total == 0 {
            0.0
        } else {
            st.prefix_shared as f64 / total as f64
        }
    }

    /// Two handles point at the same pool.
    pub fn same_pool(&self, other: &PagePool) -> bool {
        Arc::ptr_eq(&self.shared, &other.shared)
    }
}

/// One page-table entry: a refcounted handle to a [`PageData`].
#[derive(Debug)]
pub struct PageHandle {
    id: u64,
    pool: Weak<PoolShared>,
    /// `Some` until `Drop` takes it (so the drop accounting can run
    /// under the pool lock)
    data: Option<Arc<PageData>>,
}

impl PageHandle {
    /// Pool-unique page id (a CoW split assigns the private copy a new
    /// one, so two tables sharing a page agree on its id).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Read access to the page.
    pub fn data(&self) -> &PageData {
        self.data.as_ref().expect("live handle")
    }

    /// Whether another table also holds this page.
    pub fn is_shared(&self) -> bool {
        Arc::strong_count(self.data.as_ref().expect("live handle")) > 1
    }

    /// Write access — the copy-on-write rule. A uniquely-held page is
    /// mutated in place; a shared page first splits: this handle swaps
    /// to a capacity-preserving private copy (fresh id, `cow_splits`
    /// and `allocated` bumped) and the sibling tables keep the
    /// original. Appends only ever write the last, partial page of a
    /// table, so complete prefix pages shared by a fork never split.
    pub fn make_mut(&mut self) -> &mut PageData {
        let shared = Arc::get_mut(self.data.as_mut().expect("live handle")).is_none();
        if shared {
            let copy = Arc::new(self.data.as_ref().expect("live handle").split_copy());
            let units = copy.units();
            if let Some(pool) = self.pool.upgrade() {
                self.id = pool.note_alloc(1, units);
                // replace our entry under the lock-free Arc swap; the
                // refcount on the original drops by one, the sibling
                // keeps it live
                self.data = Some(copy);
            } else {
                // pool gone (tests tearing down): still split correctly,
                // keep the old id space moving
                self.id = u64::MAX - self.id;
                self.data = Some(copy);
            }
        }
        Arc::get_mut(self.data.as_mut().expect("live handle")).expect("uniquely held after split")
    }
}

impl Clone for PageHandle {
    /// Share the page: refcount bump, no copy, no pool accounting —
    /// the pool counts *pages*, not handles. Callers tracking prefix
    /// reuse report table-sized shares via [`PagePool::note_share`].
    fn clone(&self) -> Self {
        Self { id: self.id, pool: self.pool.clone(), data: self.data.clone() }
    }
}

impl Drop for PageHandle {
    fn drop(&mut self) {
        let Some(arc) = self.data.take() else { return };
        if let Some(pool) = self.pool.upgrade() {
            // hold the pool lock across the refcount check AND the drop
            // of our Arc: a concurrent drop of a sibling handle runs the
            // same critical section, so exactly one of us observes
            // strong_count == 1 and accounts the free
            let mut st = pool.state.lock().unwrap();
            if Arc::strong_count(&arc) == 1 {
                st.live -= 1;
                st.freed += 1;
                st.live_units -= arc.units();
            }
            drop(arc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_and_drop_account_live_pages() {
        let pool = PagePool::new(8, None);
        let a = pool.alloc(4);
        let b = pool.alloc(4);
        assert_ne!(a.id(), b.id());
        let st = pool.stats();
        assert_eq!((st.live, st.peak, st.allocated, st.freed), (2, 2, 2, 0));
        drop(a);
        assert_eq!(pool.live_pages(), 1);
        drop(b);
        let st = pool.stats();
        assert_eq!((st.live, st.peak, st.allocated, st.freed), (0, 2, 2, 2));
    }

    #[test]
    fn cloned_handles_share_one_page() {
        let pool = PagePool::new(8, None);
        let a = pool.alloc(2);
        let b = a.clone();
        assert_eq!(a.id(), b.id());
        assert!(a.is_shared() && b.is_shared());
        // one page live, however many handles
        assert_eq!(pool.live_pages(), 1);
        drop(a);
        assert!(!b.is_shared());
        assert_eq!(pool.live_pages(), 1); // survivor keeps it live
        drop(b);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.stats().freed, 1);
    }

    #[test]
    fn append_accumulates_rows_and_sum() {
        let pool = PagePool::new(4, None);
        let mut h = pool.alloc(2);
        h.make_mut().append_row(&[1.0, 2.0], &[5.0, 6.0]);
        h.make_mut().append_row(&[3.0, 4.0], &[7.0, 8.0]);
        let p = h.data();
        assert_eq!(p.len(), 2);
        assert!(!p.is_full());
        assert_eq!(p.k(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.v(), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(p.sum(), &[4.0, 6.0]);
        // no CoW happened: the handle was unique throughout
        assert_eq!(pool.cow_splits(), 0);
    }

    #[test]
    fn shared_write_splits_copy_on_write() {
        let pool = PagePool::new(4, None);
        let mut a = pool.alloc(2);
        a.make_mut().append_row(&[1.0, 1.0], &[0.0, 0.0]);
        let mut b = a.clone();
        assert_eq!(pool.live_pages(), 1);

        // first divergent write through `a` splits a private copy
        a.make_mut().append_row(&[2.0, 2.0], &[0.0, 0.0]);
        assert_ne!(a.id(), b.id());
        assert!(!a.is_shared() && !b.is_shared());
        let st = pool.stats();
        assert_eq!((st.live, st.allocated, st.cow_splits), (2, 2, 1));
        // `b` kept the original content; `a` got prefix + new row
        assert_eq!(b.data().len(), 1);
        assert_eq!(a.data().len(), 2);
        assert_eq!(&a.data().k()[..2], b.data().k());

        // `b` is unique now: its writes are in place, no further split
        b.make_mut().append_row(&[9.0, 9.0], &[0.0, 0.0]);
        assert_eq!(pool.cow_splits(), 1);
        assert_ne!(a.data().k(), b.data().k()); // genuinely diverged
        drop(a);
        drop(b);
        assert_eq!(pool.live_pages(), 0);
        assert_eq!(pool.stats().freed, 2);
    }

    #[test]
    fn split_preserves_append_capacity() {
        // after a CoW split the private copy must still absorb the rest
        // of its block without reallocating (asserted structurally: the
        // page accepts cap_rows rows — the alloc-regression suite pins
        // the no-realloc behavior end to end)
        let pool = PagePool::new(4, None);
        let mut a = pool.alloc(3);
        a.make_mut().append_row(&[0.0; 3], &[0.0; 3]);
        let _b = a.clone();
        let p = a.make_mut(); // split at len 1
        for _ in 1..4 {
            p.append_row(&[0.0; 3], &[0.0; 3]);
        }
        assert!(a.data().is_full());
    }

    #[test]
    #[should_panic(expected = "page overflow")]
    fn page_overflow_panics() {
        let pool = PagePool::new(1, None);
        let mut h = pool.alloc(2);
        h.make_mut().append_row(&[0.0; 2], &[0.0; 2]);
        h.make_mut().append_row(&[0.0; 2], &[0.0; 2]);
    }

    #[test]
    fn budget_is_soft_but_visible() {
        let pool = PagePool::new(8, Some(2));
        assert_eq!(pool.max_pages(), Some(2));
        assert!(pool.would_fit(2));
        let _a = pool.alloc(4);
        assert!(pool.would_fit(1));
        assert!(!pool.would_fit(2));
        let _b = pool.alloc(4);
        assert!(!pool.would_fit(1));
        // soft: an admitted step may still finish past the line
        let c = pool.alloc(4);
        assert_eq!(pool.live_pages(), 3);
        drop(c);
        assert!(pool.would_fit(0));
    }

    #[test]
    fn share_accounting_feeds_hit_rate() {
        let pool = PagePool::new(8, None);
        assert_eq!(pool.prefix_hit_rate(), 0.0);
        let a = pool.alloc(4);
        let _fork = a.clone();
        pool.note_share(1);
        assert_eq!(pool.prefix_shared(), 1);
        // 1 shared of (1 shared + 1 allocated)
        assert!((pool.prefix_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn pool_clone_is_same_pool() {
        let pool = PagePool::new(8, None);
        let alias = pool.clone();
        assert!(pool.same_pool(&alias));
        let _p = alias.alloc(4);
        assert_eq!(pool.live_pages(), 1);
        assert!(!pool.same_pool(&PagePool::new(8, None)));
    }

    /// A quantized page keeps its centroid sum in f32, accumulated from
    /// the pre-quantization rows — bitwise equal to an f32 page fed the
    /// same rows — while the stored K/V really is half width.
    #[test]
    fn quantized_pages_keep_f32_centroid_sums() {
        let pool = PagePool::new(4, None);
        let rows = [[1.5f32, -2.25, 0.125], [0.75, 3.0, -1.0]];
        let mut f32p = pool.alloc(3);
        let mut f16p = pool.alloc_dtype(3, KvDtype::F16);
        for r in &rows {
            f32p.make_mut().append_row(r, r);
            f16p.make_mut().append_row(r, r);
        }
        assert_eq!(f16p.data().dtype(), KvDtype::F16);
        assert_eq!(f16p.data().len(), 2);
        for (a, b) in f32p.data().sum().iter().zip(f16p.data().sum()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // these rows are f16-exact, so the view reads them back intact
        let deq = f16p.data().k_view().dequant_to_vec(3);
        assert_eq!(deq, f32p.data().k());
        assert_eq!(f16p.data().units(), 2);
        assert_eq!(f32p.data().units(), 4);
    }

    #[test]
    #[should_panic(expected = "as_f32 on a i8 store")]
    fn raw_f32_accessor_panics_on_quantized_page() {
        let pool = PagePool::new(4, None);
        let h = pool.alloc_dtype(2, KvDtype::I8);
        let _ = h.data().k();
    }

    /// The byte-true accounting satellite, pool level: under the same
    /// `max_pages` (f32-equivalent) budget, an f16 cache admits exactly
    /// twice the pages an f32 cache does, and int8 four times.
    #[test]
    fn f16_pages_admit_twice_as_many_under_the_same_budget() {
        let budget = 4; // 4 f32-page-equivalents = 16 units
        let admit = |dtype: KvDtype| {
            let pool = PagePool::new(8, Some(budget));
            let mut held = Vec::new();
            while pool.would_fit_units(PagePool::units_for(1, dtype)) {
                held.push(pool.alloc_dtype(2, dtype));
            }
            held.len()
        };
        assert_eq!(admit(KvDtype::F32), 4);
        assert_eq!(admit(KvDtype::F16), 8);
        assert_eq!(admit(KvDtype::Bf16), 8);
        assert_eq!(admit(KvDtype::I8), 16);
    }

    /// Unit accounting survives the full page lifecycle: alloc, CoW
    /// split, and drop all keep `live_units` == sum of live pages'
    /// weights (and the f32 compat `would_fit` still counts 4 each).
    #[test]
    fn unit_accounting_tracks_alloc_split_and_drop() {
        let pool = PagePool::new(4, Some(10));
        let mut a = pool.alloc_dtype(2, KvDtype::F16);
        a.make_mut().append_row(&[1.0, 2.0], &[3.0, 4.0]);
        let b = a.clone(); // shared: no new page, no new units
        assert_eq!(pool.live_units(), 2);
        a.make_mut().append_row(&[5.0, 6.0], &[7.0, 8.0]); // CoW split
        assert_eq!(pool.live_pages(), 2);
        assert_eq!(pool.live_units(), 4);
        assert_eq!(pool.stats().peak_units, 4);
        let c = pool.alloc(2); // f32 compat path charges 4
        assert_eq!(pool.live_units(), 8);
        assert!(pool.would_fit(8)); // 8 + 8*4 > 40? no: 8+32=40 <= 40
        assert!(!pool.would_fit(9));
        drop(c);
        drop(a);
        drop(b);
        assert_eq!(pool.live_units(), 0);
        assert_eq!(pool.live_pages(), 0);
    }
}
