//! Hot-path microkernels behind a runtime ISA dispatch table: dot /
//! axpy / scale over f32 rows, plus fused *dequantizing* variants that
//! read half-width (f16/bf16) or int8 K/V rows and widen them in
//! registers inside the reduction (see `dtype::KvView`).
//!
//! # Dispatch
//!
//! [`kernels`] resolves once (process-wide, `OnceLock`) to one of:
//!
//! * **avx2** — x86_64 with AVX2 + F16C detected at runtime
//!   (`is_x86_feature_detected!`),
//! * **neon** — aarch64 (runtime-checked, though every aarch64 target
//!   ships NEON),
//! * **scalar** — the original unrolled loops, everywhere else.
//!
//! `MOBA_SIMD={scalar,avx2,neon,auto}` overrides detection (the CI
//! scalar-dispatch leg sets `scalar`); naming an ISA the machine lacks
//! is a loud panic, not a silent fallback.
//!
//! # The lane-order rule, dtype/ISA-aware
//!
//! Every variant keeps the PR-5 reduction shape exactly: 8 independent
//! f32 accumulator lanes over ascending 8-wide chunks (lane `l` sums
//! elements `i*8 + l`), a scalar remainder in ascending order, and the
//! fixed tree `(l0+l4)+(l1+l5)+(l2+l6)+(l3+l7)+rest`. The SIMD paths
//! use separate multiply and add instructions — never FMA — and exact
//! conversions (f16→f32 widening is lossless; bf16 is a shift; i8
//! dequantizes element-wise as `q as f32 * scale` before the multiply),
//! so **every ISA variant is bit-identical to the scalar fallback**
//! (pinned by the dispatch parity tests below). That is deliberately
//! stronger than the per-`(KvDtype, ISA)` determinism contract: outputs
//! are in fact identical *across* ISAs, so the determinism suites need
//! only sweep dtypes.
//!
//! Perf note: the original autovectorized loops reached ~3–4× over
//! naive scalar with `-C target-cpu=native`; explicit dispatch keeps
//! that speed on default builds (no `target-cpu` flag) and gives the
//! dequant kernels a vector path LLVM cannot find on its own (the
//! convert-then-MAC body defeats autovectorization).

use std::sync::OnceLock;

use super::dtype::{bf16_to_f32, f16_to_f32};

/// The fixed 8-lane reduction tree + remainder — shared by every ISA so
/// the final combine cannot drift.
#[inline]
fn tree8(l: &[f32; 8], rest: f32) -> f32 {
    (l[0] + l[4]) + (l[1] + l[5]) + (l[2] + l[6]) + (l[3] + l[7]) + rest
}

/// One ISA's kernel set. All entries are bit-compatible: any two tables
/// produce identical bits for identical inputs.
pub struct Kernels {
    /// "scalar", "avx2" or "neon" — bench labels and test axes.
    pub isa: &'static str,
    pub dot: fn(&[f32], &[f32]) -> f32,
    pub axpy: fn(&mut [f32], f32, &[f32]),
    pub scale: fn(&mut [f32], f32),
    pub dot_f16: fn(&[f32], &[u16]) -> f32,
    pub dot_bf16: fn(&[f32], &[u16]) -> f32,
    pub dot_i8: fn(&[f32], &[i8], f32) -> f32,
    pub axpy_f16: fn(&mut [f32], f32, &[u16]),
    pub axpy_bf16: fn(&mut [f32], f32, &[u16]),
    pub axpy_i8: fn(&mut [f32], f32, &[i8], f32),
}

// ------------------------------------------------------------- scalar

/// The unrolled fallback loops (the pre-dispatch kernels, verbatim) —
/// the bit-reference every SIMD variant is tested against, and the
/// leg `MOBA_SIMD=scalar` forces.
pub mod scalar {
    use super::{bf16_to_f32, f16_to_f32, tree8};

    /// Dot product with 8 independent accumulator lanes.
    #[inline]
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; 8];
        let chunks = a.len() / 8;
        for i in 0..chunks {
            let ai = &a[i * 8..i * 8 + 8];
            let bi = &b[i * 8..i * 8 + 8];
            for l in 0..8 {
                lanes[l] += ai[l] * bi[l];
            }
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * b[i];
        }
        tree8(&lanes, rest)
    }

    /// y += a * x (multiply-accumulate over a row).
    #[inline]
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yi = &mut y[i * 8..i * 8 + 8];
            let xi = &x[i * 8..i * 8 + 8];
            for l in 0..8 {
                yi[l] += a * xi[l];
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * x[i];
        }
    }

    /// y *= a, unrolled into 8 independent lanes like `dot`/`axpy`.
    #[inline]
    pub fn scale(y: &mut [f32], a: f32) {
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yi = &mut y[i * 8..i * 8 + 8];
            for l in 0..8 {
                yi[l] *= a;
            }
        }
        for i in chunks * 8..y.len() {
            y[i] *= a;
        }
    }

    /// a · dequant(h): f16 rows widened element-wise inside the lanes.
    #[inline]
    pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; 8];
        let chunks = a.len() / 8;
        for i in 0..chunks {
            let ai = &a[i * 8..i * 8 + 8];
            let bi = &b[i * 8..i * 8 + 8];
            for l in 0..8 {
                lanes[l] += ai[l] * f16_to_f32(bi[l]);
            }
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * f16_to_f32(b[i]);
        }
        tree8(&lanes, rest)
    }

    /// a · dequant(h) for bf16 rows.
    #[inline]
    pub fn dot_bf16(a: &[f32], b: &[u16]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; 8];
        let chunks = a.len() / 8;
        for i in 0..chunks {
            let ai = &a[i * 8..i * 8 + 8];
            let bi = &b[i * 8..i * 8 + 8];
            for l in 0..8 {
                lanes[l] += ai[l] * bf16_to_f32(bi[l]);
            }
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * bf16_to_f32(b[i]);
        }
        tree8(&lanes, rest)
    }

    /// a · (q * scale): int8 rows dequantized element-wise — the value
    /// is widened and scaled *before* the lane multiply, so vector
    /// variants doing the same per lane match bitwise.
    #[inline]
    pub fn dot_i8(a: &[f32], b: &[i8], scale: f32) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut lanes = [0.0f32; 8];
        let chunks = a.len() / 8;
        for i in 0..chunks {
            let ai = &a[i * 8..i * 8 + 8];
            let bi = &b[i * 8..i * 8 + 8];
            for l in 0..8 {
                lanes[l] += ai[l] * (bi[l] as f32 * scale);
            }
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * (b[i] as f32 * scale);
        }
        tree8(&lanes, rest)
    }

    /// y += a * dequant(x) for f16 rows.
    #[inline]
    pub fn axpy_f16(y: &mut [f32], a: f32, x: &[u16]) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yi = &mut y[i * 8..i * 8 + 8];
            let xi = &x[i * 8..i * 8 + 8];
            for l in 0..8 {
                yi[l] += a * f16_to_f32(xi[l]);
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * f16_to_f32(x[i]);
        }
    }

    /// y += a * dequant(x) for bf16 rows.
    #[inline]
    pub fn axpy_bf16(y: &mut [f32], a: f32, x: &[u16]) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yi = &mut y[i * 8..i * 8 + 8];
            let xi = &x[i * 8..i * 8 + 8];
            for l in 0..8 {
                yi[l] += a * bf16_to_f32(xi[l]);
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * bf16_to_f32(x[i]);
        }
    }

    /// y += a * (q * scale) for int8 rows.
    #[inline]
    pub fn axpy_i8(y: &mut [f32], a: f32, x: &[i8], scale: f32) {
        debug_assert_eq!(y.len(), x.len());
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yi = &mut y[i * 8..i * 8 + 8];
            let xi = &x[i * 8..i * 8 + 8];
            for l in 0..8 {
                yi[l] += a * (xi[l] as f32 * scale);
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * (x[i] as f32 * scale);
        }
    }
}

// --------------------------------------------------------------- avx2

/// AVX2 + F16C variants. Separate `_mm256_mul_ps` + `_mm256_add_ps`
/// (never `fmadd`) keep each lane's rounding identical to the scalar
/// loops; `_mm256_cvtph_ps` is the (exact) IEEE f16→f32 widening, the
/// bf16 path is an integer shift, and i8 widens through
/// `cvtepi8_epi32`/`cvtepi32_ps` (exact for the i8 range) then scales
/// element-wise before the multiply — exactly the scalar order.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{f16_to_f32, tree8};
    use std::arch::x86_64::*;

    /// Spill the 8 vector lanes and run the shared scalar tree.
    #[inline]
    unsafe fn reduce(acc: __m256, rest: f32) -> f32 {
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        tree8(&lanes, rest)
    }

    // Safe wrappers: the dispatch table only installs these after
    // `is_x86_feature_detected!("avx2")` && `("f16c")` succeeded.
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl(a, b) }
    }
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        unsafe { axpy_impl(y, a, x) }
    }
    pub fn scale(y: &mut [f32], a: f32) {
        unsafe { scale_impl(y, a) }
    }
    pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        unsafe { dot_f16_impl(a, b) }
    }
    pub fn dot_bf16(a: &[f32], b: &[u16]) -> f32 {
        unsafe { dot_bf16_impl(a, b) }
    }
    pub fn dot_i8(a: &[f32], b: &[i8], s: f32) -> f32 {
        unsafe { dot_i8_impl(a, b, s) }
    }
    pub fn axpy_f16(y: &mut [f32], a: f32, x: &[u16]) {
        unsafe { axpy_f16_impl(y, a, x) }
    }
    pub fn axpy_bf16(y: &mut [f32], a: f32, x: &[u16]) {
        unsafe { axpy_bf16_impl(y, a, x) }
    }
    pub fn axpy_i8(y: &mut [f32], a: f32, x: &[i8], s: f32) {
        unsafe { axpy_i8_impl(y, a, x, s) }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let bv = _mm256_loadu_ps(b.as_ptr().add(i * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * b[i];
        }
        reduce(acc, rest)
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn axpy_impl(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let av = _mm256_set1_ps(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i * 8));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i * 8),
                _mm256_add_ps(yv, _mm256_mul_ps(av, xv)),
            );
        }
        for i in chunks * 8..y.len() {
            y[i] += a * x[i];
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn scale_impl(y: &mut [f32], a: f32) {
        let av = _mm256_set1_ps(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let yv = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), _mm256_mul_ps(yv, av));
        }
        for i in chunks * 8..y.len() {
            y[i] *= a;
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn dot_f16_impl(a: &[f32], b: &[u16]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let hv = _mm_loadu_si128(b.as_ptr().add(i * 8) as *const __m128i);
            let bv = _mm256_cvtph_ps(hv);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * f16_to_f32(b[i]);
        }
        reduce(acc, rest)
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn dot_bf16_impl(a: &[f32], b: &[u16]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let hv = _mm_loadu_si128(b.as_ptr().add(i * 8) as *const __m128i);
            let bv = _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(hv), 16));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * super::bf16_to_f32(b[i]);
        }
        reduce(acc, rest)
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn dot_i8_impl(a: &[f32], b: &[i8], scale: f32) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let sv = _mm256_set1_ps(scale);
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let av = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let qv = _mm_loadl_epi64(b.as_ptr().add(i * 8) as *const __m128i);
            let kv = _mm256_mul_ps(_mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv)), sv);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, kv));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * (b[i] as f32 * scale);
        }
        reduce(acc, rest)
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn axpy_f16_impl(y: &mut [f32], a: f32, x: &[u16]) {
        debug_assert_eq!(y.len(), x.len());
        let av = _mm256_set1_ps(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let hv = _mm_loadu_si128(x.as_ptr().add(i * 8) as *const __m128i);
            let xv = _mm256_cvtph_ps(hv);
            let yv = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i * 8),
                _mm256_add_ps(yv, _mm256_mul_ps(av, xv)),
            );
        }
        for i in chunks * 8..y.len() {
            y[i] += a * f16_to_f32(x[i]);
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn axpy_bf16_impl(y: &mut [f32], a: f32, x: &[u16]) {
        debug_assert_eq!(y.len(), x.len());
        let av = _mm256_set1_ps(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let hv = _mm_loadu_si128(x.as_ptr().add(i * 8) as *const __m128i);
            let xv = _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(hv), 16));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i * 8),
                _mm256_add_ps(yv, _mm256_mul_ps(av, xv)),
            );
        }
        for i in chunks * 8..y.len() {
            y[i] += a * super::bf16_to_f32(x[i]);
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn axpy_i8_impl(y: &mut [f32], a: f32, x: &[i8], scale: f32) {
        debug_assert_eq!(y.len(), x.len());
        let av = _mm256_set1_ps(a);
        let sv = _mm256_set1_ps(scale);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let qv = _mm_loadl_epi64(x.as_ptr().add(i * 8) as *const __m128i);
            let xv = _mm256_mul_ps(_mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv)), sv);
            let yv = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            _mm256_storeu_ps(
                y.as_mut_ptr().add(i * 8),
                _mm256_add_ps(yv, _mm256_mul_ps(av, xv)),
            );
        }
        for i in chunks * 8..y.len() {
            y[i] += a * (x[i] as f32 * scale);
        }
    }
}

// --------------------------------------------------------------- neon

/// NEON variants: two `float32x4` accumulators stand in for the 8
/// scalar lanes (`lo` = lanes 0–3, `hi` = lanes 4–7), `vaddq(lo, hi)`
/// produces exactly the tree's pair sums `[l0+l4, l1+l5, l2+l6,
/// l3+l7]`, and the final combine is the same left-to-right sum.
/// Multiplies and adds stay separate (`vmulq` + `vaddq`, never `fmla`).
/// f16 widens through the exact bit-manipulation conversion (no `vcvt`
/// half intrinsics, which would need the `fp16` feature gate).
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{bf16_to_f32, f16_to_f32};
    use std::arch::aarch64::*;

    /// `[p0,p1,p2,p3] = vaddq(lo, hi)`, then the scalar tree's
    /// left-associated combine.
    #[inline]
    unsafe fn reduce(lo: float32x4_t, hi: float32x4_t, rest: f32) -> f32 {
        let mut p = [0.0f32; 4];
        vst1q_f32(p.as_mut_ptr(), vaddq_f32(lo, hi));
        (p[0] + p[1]) + p[2] + p[3] + rest
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        unsafe { dot_impl(a, b) }
    }
    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        unsafe { axpy_impl(y, a, x) }
    }
    pub fn scale(y: &mut [f32], a: f32) {
        unsafe { scale_impl(y, a) }
    }
    pub fn dot_f16(a: &[f32], b: &[u16]) -> f32 {
        unsafe { dot_widen_impl(a, b, f16_to_f32) }
    }
    pub fn dot_bf16(a: &[f32], b: &[u16]) -> f32 {
        unsafe { dot_widen_impl(a, b, bf16_to_f32) }
    }
    pub fn dot_i8(a: &[f32], b: &[i8], s: f32) -> f32 {
        unsafe { dot_i8_impl(a, b, s) }
    }
    pub fn axpy_f16(y: &mut [f32], a: f32, x: &[u16]) {
        unsafe { axpy_widen_impl(y, a, x, f16_to_f32) }
    }
    pub fn axpy_bf16(y: &mut [f32], a: f32, x: &[u16]) {
        unsafe { axpy_widen_impl(y, a, x, bf16_to_f32) }
    }
    pub fn axpy_i8(y: &mut [f32], a: f32, x: &[i8], s: f32) {
        unsafe { axpy_i8_impl(y, a, x, s) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_impl(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let a0 = vld1q_f32(a.as_ptr().add(i * 8));
            let a1 = vld1q_f32(a.as_ptr().add(i * 8 + 4));
            let b0 = vld1q_f32(b.as_ptr().add(i * 8));
            let b1 = vld1q_f32(b.as_ptr().add(i * 8 + 4));
            lo = vaddq_f32(lo, vmulq_f32(a0, b0));
            hi = vaddq_f32(hi, vmulq_f32(a1, b1));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * b[i];
        }
        reduce(lo, hi, rest)
    }

    /// Shared f16/bf16 dot: widen 8 halfs through `widen` (the exact
    /// scalar conversion) into two quads, then vector MAC.
    #[target_feature(enable = "neon")]
    unsafe fn dot_widen_impl(a: &[f32], b: &[u16], widen: fn(u16) -> f32) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        let mut w = [0.0f32; 8];
        for i in 0..chunks {
            for (l, wv) in w.iter_mut().enumerate() {
                *wv = widen(b[i * 8 + l]);
            }
            let a0 = vld1q_f32(a.as_ptr().add(i * 8));
            let a1 = vld1q_f32(a.as_ptr().add(i * 8 + 4));
            lo = vaddq_f32(lo, vmulq_f32(a0, vld1q_f32(w.as_ptr())));
            hi = vaddq_f32(hi, vmulq_f32(a1, vld1q_f32(w.as_ptr().add(4))));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * widen(b[i]);
        }
        reduce(lo, hi, rest)
    }

    #[target_feature(enable = "neon")]
    unsafe fn dot_i8_impl(a: &[f32], b: &[i8], scale: f32) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let sv = vdupq_n_f32(scale);
        let chunks = a.len() / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for i in 0..chunks {
            let q8 = vld1_s8(b.as_ptr().add(i * 8));
            let q16 = vmovl_s8(q8);
            let k0 = vmulq_f32(vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16))), sv);
            let k1 = vmulq_f32(vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16))), sv);
            let a0 = vld1q_f32(a.as_ptr().add(i * 8));
            let a1 = vld1q_f32(a.as_ptr().add(i * 8 + 4));
            lo = vaddq_f32(lo, vmulq_f32(a0, k0));
            hi = vaddq_f32(hi, vmulq_f32(a1, k1));
        }
        let mut rest = 0.0f32;
        for i in chunks * 8..a.len() {
            rest += a[i] * (b[i] as f32 * scale);
        }
        reduce(lo, hi, rest)
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_impl(y: &mut [f32], a: f32, x: &[f32]) {
        debug_assert_eq!(y.len(), x.len());
        let av = vdupq_n_f32(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            for half in 0..2 {
                let off = i * 8 + half * 4;
                let xv = vld1q_f32(x.as_ptr().add(off));
                let yv = vld1q_f32(y.as_ptr().add(off));
                vst1q_f32(y.as_mut_ptr().add(off), vaddq_f32(yv, vmulq_f32(av, xv)));
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * x[i];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn scale_impl(y: &mut [f32], a: f32) {
        let av = vdupq_n_f32(a);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            for half in 0..2 {
                let off = i * 8 + half * 4;
                let yv = vld1q_f32(y.as_ptr().add(off));
                vst1q_f32(y.as_mut_ptr().add(off), vmulq_f32(yv, av));
            }
        }
        for i in chunks * 8..y.len() {
            y[i] *= a;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_widen_impl(y: &mut [f32], a: f32, x: &[u16], widen: fn(u16) -> f32) {
        debug_assert_eq!(y.len(), x.len());
        let av = vdupq_n_f32(a);
        let chunks = y.len() / 8;
        let mut w = [0.0f32; 8];
        for i in 0..chunks {
            for (l, wv) in w.iter_mut().enumerate() {
                *wv = widen(x[i * 8 + l]);
            }
            for half in 0..2 {
                let off = i * 8 + half * 4;
                let xv = vld1q_f32(w.as_ptr().add(half * 4));
                let yv = vld1q_f32(y.as_ptr().add(off));
                vst1q_f32(y.as_mut_ptr().add(off), vaddq_f32(yv, vmulq_f32(av, xv)));
            }
        }
        for i in chunks * 8..y.len() {
            y[i] += a * widen(x[i]);
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn axpy_i8_impl(y: &mut [f32], a: f32, x: &[i8], scale: f32) {
        debug_assert_eq!(y.len(), x.len());
        let av = vdupq_n_f32(a);
        let sv = vdupq_n_f32(scale);
        let chunks = y.len() / 8;
        for i in 0..chunks {
            let q8 = vld1_s8(x.as_ptr().add(i * 8));
            let q16 = vmovl_s8(q8);
            let x0 = vmulq_f32(vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16))), sv);
            let x1 = vmulq_f32(vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16))), sv);
            let y0 = vld1q_f32(y.as_ptr().add(i * 8));
            let y1 = vld1q_f32(y.as_ptr().add(i * 8 + 4));
            vst1q_f32(y.as_mut_ptr().add(i * 8), vaddq_f32(y0, vmulq_f32(av, x0)));
            vst1q_f32(y.as_mut_ptr().add(i * 8 + 4), vaddq_f32(y1, vmulq_f32(av, x1)));
        }
        for i in chunks * 8..y.len() {
            y[i] += a * (x[i] as f32 * scale);
        }
    }
}

// ----------------------------------------------------------- dispatch

static SCALAR_KERNELS: Kernels = Kernels {
    isa: "scalar",
    dot: scalar::dot,
    axpy: scalar::axpy,
    scale: scalar::scale,
    dot_f16: scalar::dot_f16,
    dot_bf16: scalar::dot_bf16,
    dot_i8: scalar::dot_i8,
    axpy_f16: scalar::axpy_f16,
    axpy_bf16: scalar::axpy_bf16,
    axpy_i8: scalar::axpy_i8,
};

#[cfg(target_arch = "x86_64")]
static AVX2_KERNELS: Kernels = Kernels {
    isa: "avx2",
    dot: avx2::dot,
    axpy: avx2::axpy,
    scale: avx2::scale,
    dot_f16: avx2::dot_f16,
    dot_bf16: avx2::dot_bf16,
    dot_i8: avx2::dot_i8,
    axpy_f16: avx2::axpy_f16,
    axpy_bf16: avx2::axpy_bf16,
    axpy_i8: avx2::axpy_i8,
};

#[cfg(target_arch = "aarch64")]
static NEON_KERNELS: Kernels = Kernels {
    isa: "neon",
    dot: neon::dot,
    axpy: neon::axpy,
    scale: neon::scale,
    dot_f16: neon::dot_f16,
    dot_bf16: neon::dot_bf16,
    dot_i8: neon::dot_i8,
    axpy_f16: neon::axpy_f16,
    axpy_bf16: neon::axpy_bf16,
    axpy_i8: neon::axpy_i8,
};

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// Every kernel table this machine can run: scalar always, plus the
/// detected vector ISA. The dispatch parity tests sweep this.
pub fn available_kernels() -> Vec<&'static Kernels> {
    let mut out = vec![&SCALAR_KERNELS];
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c") {
        out.push(&AVX2_KERNELS);
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        out.push(&NEON_KERNELS);
    }
    out
}

fn detect() -> &'static Kernels {
    // best detected table wins; they are all bit-identical anyway, so
    // this choice is pure throughput, never semantics
    *available_kernels().last().unwrap()
}

fn resolve() -> &'static Kernels {
    match std::env::var("MOBA_SIMD").as_deref() {
        Ok("scalar") => &SCALAR_KERNELS,
        Ok("avx2") => {
            #[cfg(target_arch = "x86_64")]
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("f16c") {
                return &AVX2_KERNELS;
            }
            panic!("MOBA_SIMD=avx2 but this machine has no AVX2+F16C")
        }
        Ok("neon") => {
            #[cfg(target_arch = "aarch64")]
            if std::arch::is_aarch64_feature_detected!("neon") {
                return &NEON_KERNELS;
            }
            panic!("MOBA_SIMD=neon but this machine has no NEON")
        }
        Ok("") | Ok("auto") | Err(_) => detect(),
        Ok(other) => panic!("MOBA_SIMD={other}: expected scalar|avx2|neon|auto"),
    }
}

/// The process-wide kernel table, resolved once on first use (honoring
/// `MOBA_SIMD`).
#[inline]
pub fn kernels() -> &'static Kernels {
    ACTIVE.get_or_init(resolve)
}

/// Name of the resolved ISA ("scalar" / "avx2" / "neon") — bench
/// metadata and log lines.
pub fn active_isa() -> &'static str {
    kernels().isa
}

// ------------------------------------------------- dispatched surface

/// Dot product in the canonical 8-lane order, on the active ISA.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (kernels().dot)(a, b)
}

/// y += a * x on the active ISA.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    (kernels().axpy)(y, a, x)
}

/// y *= a on the active ISA.
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    (kernels().scale)(y, a)
}

/// a · dequant(b) over an f16 row — fused widen + dot.
#[inline]
pub fn dequant_dot_f16(a: &[f32], b: &[u16]) -> f32 {
    (kernels().dot_f16)(a, b)
}

/// a · dequant(b) over a bf16 row.
#[inline]
pub fn dequant_dot_bf16(a: &[f32], b: &[u16]) -> f32 {
    (kernels().dot_bf16)(a, b)
}

/// a · (b * scale) over an int8 row.
#[inline]
pub fn dequant_dot_i8(a: &[f32], b: &[i8], scale: f32) -> f32 {
    (kernels().dot_i8)(a, b, scale)
}

/// y += a * dequant(x) over an f16 row.
#[inline]
pub fn dequant_axpy_f16(y: &mut [f32], a: f32, x: &[u16]) {
    (kernels().axpy_f16)(y, a, x)
}

/// y += a * dequant(x) over a bf16 row.
#[inline]
pub fn dequant_axpy_bf16(y: &mut [f32], a: f32, x: &[u16]) {
    (kernels().axpy_bf16)(y, a, x)
}

/// y += a * (x * scale) over an int8 row.
#[inline]
pub fn dequant_axpy_i8(y: &mut [f32], a: f32, x: &[i8], scale: f32) {
    (kernels().axpy_i8)(y, a, x, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dtype::{f32_to_bf16, f32_to_f16};
    use crate::attention::testutil::Rng;

    /// Lengths covering empty, sub-lane, the 8-lane boundary and ragged
    /// tails — the spans the kernel suites exercise everywhere.
    const LENS: [usize; 10] = [0, 1, 7, 8, 9, 16, 63, 64, 65, 128];

    #[test]
    fn dot_matches_scalar_all_lengths() {
        let mut rng = Rng::new(1);
        for len in LENS {
            let a = rng.normal_vec(len);
            let b = rng.normal_vec(len);
            let expect: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let got = dot(&a, &b);
            assert!((got as f64 - expect).abs() < 1e-3 * (1.0 + expect.abs()), "len={len}");
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let mut rng = Rng::new(2);
        for len in [1, 8, 13, 64, 100] {
            let x = rng.normal_vec(len);
            let mut y = rng.normal_vec(len);
            let y0 = y.clone();
            axpy(&mut y, 2.5, &x);
            for i in 0..len {
                assert!((y[i] - (y0[i] + 2.5 * x[i])).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn scale_works() {
        let mut y = vec![1.0f32, -2.0, 3.0];
        scale(&mut y, 0.5);
        assert_eq!(y, vec![0.5, -1.0, 1.5]);
    }

    /// The unrolled scale is exact (x * a element-wise, no reassociation)
    /// at every length across the 8-lane boundary.
    #[test]
    fn scale_matches_scalar_all_lengths() {
        let mut rng = Rng::new(3);
        for len in [0, 1, 7, 8, 9, 16, 63, 64, 65, 100] {
            let mut y = rng.normal_vec(len);
            let y0 = y.clone();
            scale(&mut y, -1.75);
            for i in 0..len {
                assert_eq!(y[i], y0[i] * -1.75, "len={len} i={i}");
            }
        }
    }

    #[test]
    fn active_isa_is_a_known_table() {
        let isa = active_isa();
        assert!(
            ["scalar", "avx2", "neon"].contains(&isa),
            "unexpected isa {isa}"
        );
        // and the available set always starts with the scalar reference
        assert_eq!(available_kernels()[0].isa, "scalar");
    }

    /// The dispatch parity satellite: every vector table this machine
    /// can run is bit-identical to the scalar fallback — dot, axpy and
    /// scale — on every suite length including ragged tails.
    #[test]
    fn dispatched_isa_variants_are_bit_identical_to_scalar() {
        let mut rng = Rng::new(4);
        for k in available_kernels() {
            for len in LENS {
                let a = rng.normal_vec(len);
                let b = rng.normal_vec(len);
                assert_eq!(
                    (k.dot)(&a, &b).to_bits(),
                    scalar::dot(&a, &b).to_bits(),
                    "{} dot len={len}",
                    k.isa
                );
                let mut y1 = rng.normal_vec(len);
                let mut y2 = y1.clone();
                (k.axpy)(&mut y1, -1.3, &a);
                scalar::axpy(&mut y2, -1.3, &a);
                for i in 0..len {
                    assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "{} axpy len={len} i={i}", k.isa);
                }
                (k.scale)(&mut y1, 0.77);
                scalar::scale(&mut y2, 0.77);
                for i in 0..len {
                    assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "{} scale len={len} i={i}", k.isa);
                }
            }
        }
    }

    /// Same parity sweep for the fused dequant kernels: every ISA's
    /// f16/bf16/i8 dot and axpy equals the scalar fallback bitwise.
    #[test]
    fn dequant_kernels_are_bit_identical_across_isas() {
        let mut rng = Rng::new(5);
        for k in available_kernels() {
            for len in LENS {
                let a = rng.normal_vec(len);
                let h16: Vec<u16> =
                    rng.normal_vec(len).iter().map(|&x| f32_to_f16(x)).collect();
                let hbf: Vec<u16> =
                    rng.normal_vec(len).iter().map(|&x| f32_to_bf16(x)).collect();
                let q8: Vec<i8> =
                    (0..len).map(|_| (rng.normal() * 40.0) as i8).collect();
                let s = 0.031_25f32;
                assert_eq!(
                    (k.dot_f16)(&a, &h16).to_bits(),
                    scalar::dot_f16(&a, &h16).to_bits(),
                    "{} dot_f16 len={len}",
                    k.isa
                );
                assert_eq!(
                    (k.dot_bf16)(&a, &hbf).to_bits(),
                    scalar::dot_bf16(&a, &hbf).to_bits(),
                    "{} dot_bf16 len={len}",
                    k.isa
                );
                assert_eq!(
                    (k.dot_i8)(&a, &q8, s).to_bits(),
                    scalar::dot_i8(&a, &q8, s).to_bits(),
                    "{} dot_i8 len={len}",
                    k.isa
                );
                let mut y1 = rng.normal_vec(len);
                let mut y2 = y1.clone();
                (k.axpy_f16)(&mut y1, 0.9, &h16);
                scalar::axpy_f16(&mut y2, 0.9, &h16);
                (k.axpy_bf16)(&mut y1, -0.4, &hbf);
                scalar::axpy_bf16(&mut y2, -0.4, &hbf);
                (k.axpy_i8)(&mut y1, 1.6, &q8, s);
                scalar::axpy_i8(&mut y2, 1.6, &q8, s);
                for i in 0..len {
                    assert_eq!(
                        y1[i].to_bits(),
                        y2[i].to_bits(),
                        "{} dequant axpy len={len} i={i}",
                        k.isa
                    );
                }
            }
        }
    }

    /// The element-wise dequant rule: a fused dequant kernel equals
    /// "expand the row to f32, then run the f32 kernel" bit for bit —
    /// the identity the dtype-aware lane-order rule rests on.
    #[test]
    fn fused_dequant_equals_expand_then_f32_kernel() {
        use crate::attention::dtype::{bf16_to_f32, f16_to_f32};
        let mut rng = Rng::new(6);
        for len in LENS {
            let a = rng.normal_vec(len);
            let h16: Vec<u16> = rng.normal_vec(len).iter().map(|&x| f32_to_f16(x)).collect();
            let hbf: Vec<u16> = rng.normal_vec(len).iter().map(|&x| f32_to_bf16(x)).collect();
            let q8: Vec<i8> = (0..len).map(|_| (rng.normal() * 40.0) as i8).collect();
            let s = 0.02f32;
            let w16: Vec<f32> = h16.iter().map(|&h| f16_to_f32(h)).collect();
            let wbf: Vec<f32> = hbf.iter().map(|&h| bf16_to_f32(h)).collect();
            let w8: Vec<f32> = q8.iter().map(|&q| q as f32 * s).collect();
            assert_eq!(dequant_dot_f16(&a, &h16).to_bits(), dot(&a, &w16).to_bits());
            assert_eq!(dequant_dot_bf16(&a, &hbf).to_bits(), dot(&a, &wbf).to_bits());
            assert_eq!(dequant_dot_i8(&a, &q8, s).to_bits(), dot(&a, &w8).to_bits());
            let mut y1 = rng.normal_vec(len);
            let mut y2 = y1.clone();
            dequant_axpy_f16(&mut y1, 0.6, &h16);
            axpy(&mut y2, 0.6, &w16);
            dequant_axpy_bf16(&mut y1, 1.1, &hbf);
            axpy(&mut y2, 1.1, &wbf);
            dequant_axpy_i8(&mut y1, -0.8, &q8, s);
            axpy(&mut y2, -0.8, &w8);
            for i in 0..len {
                assert_eq!(y1[i].to_bits(), y2[i].to_bits(), "len={len} i={i}");
            }
        }
    }
}
