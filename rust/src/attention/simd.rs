//! Hot-path microkernels: unrolled dot product and axpy written so LLVM
//! can autovectorize them (multiple independent accumulators lift the
//! f32-associativity constraint that blocks SIMD on naive loops).
//!
//! Perf pass result: replacing the scalar loops in the
//! attention substrate with these raised FlashMoBA forward throughput
//! ~3–4× on this machine (with `-C target-cpu=native`).

/// Dot product with 8 independent accumulator lanes.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let ai = &a[i * 8..i * 8 + 8];
        let bi = &b[i * 8..i * 8 + 8];
        for l in 0..8 {
            lanes[l] += ai[l] * bi[l];
        }
    }
    let mut rest = 0.0f32;
    for i in chunks * 8..a.len() {
        rest += a[i] * b[i];
    }
    (lanes[0] + lanes[4]) + (lanes[1] + lanes[5]) + (lanes[2] + lanes[6])
        + (lanes[3] + lanes[7])
        + rest
}

/// y += a * x (fused multiply-accumulate over a row).
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let chunks = y.len() / 8;
    for i in 0..chunks {
        let yi = &mut y[i * 8..i * 8 + 8];
        let xi = &x[i * 8..i * 8 + 8];
        for l in 0..8 {
            yi[l] += a * xi[l];
        }
    }
    for i in chunks * 8..y.len() {
        y[i] += a * x[i];
    }
}

/// y *= a, unrolled into 8 independent lanes like `dot`/`axpy` so the
/// accumulator-row rescale in the online-softmax kernels vectorizes.
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    let chunks = y.len() / 8;
    for i in 0..chunks {
        let yi = &mut y[i * 8..i * 8 + 8];
        for l in 0..8 {
            yi[l] *= a;
        }
    }
    for i in chunks * 8..y.len() {
        y[i] *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn dot_matches_scalar_all_lengths() {
        let mut rng = Rng::new(1);
        for len in [0, 1, 7, 8, 9, 16, 63, 64, 65, 128] {
            let a = rng.normal_vec(len);
            let b = rng.normal_vec(len);
            let expect: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
            let got = dot(&a, &b);
            assert!((got as f64 - expect).abs() < 1e-3 * (1.0 + expect.abs()), "len={len}");
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        let mut rng = Rng::new(2);
        for len in [1, 8, 13, 64, 100] {
            let x = rng.normal_vec(len);
            let mut y = rng.normal_vec(len);
            let y0 = y.clone();
            axpy(&mut y, 2.5, &x);
            for i in 0..len {
                assert!((y[i] - (y0[i] + 2.5 * x[i])).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn scale_works() {
        let mut y = vec![1.0f32, -2.0, 3.0];
        scale(&mut y, 0.5);
        assert_eq!(y, vec![0.5, -1.0, 1.5]);
    }

    /// The unrolled scale is exact (x * a element-wise, no reassociation)
    /// at every length across the 8-lane boundary.
    #[test]
    fn scale_matches_scalar_all_lengths() {
        let mut rng = Rng::new(3);
        for len in [0, 1, 7, 8, 9, 16, 63, 64, 65, 100] {
            let mut y = rng.normal_vec(len);
            let y0 = y.clone();
            scale(&mut y, -1.75);
            for i in 0..len {
                assert_eq!(y[i], y0[i] * -1.75, "len={len} i={i}");
            }
        }
    }
}
