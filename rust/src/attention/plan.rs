//! Per-head routing plans: each KV head carries its own `(block, topk)`
//! routing geometry — or a dense fallback — instead of the single static
//! pair baked into `AttnShape`.
//!
//! The paper's SNR model (Eq. 3: SNR = Δμ_eff · √(d/2B)) makes routing
//! accuracy a *per-head* property: heads with strong signal separation
//! retrieve reliably at large blocks and small top-k, weak heads need
//! smaller blocks, more top-k, or no routing at all. A [`RoutePlan`]
//! captures that choice per KV head (query heads in a GQA group share
//! their KV head's plan), and the substrate threads it end to end:
//! prefill via `AttentionBackend::forward_plan[_into]`, decode via
//! `DecodeSession::with_plan`, and the serving coordinator via
//! `serve.route_plan` / per-request overrides.
//!
//! Two invariants anchor the design:
//!
//! * **`RoutePlan::uniform` is the identity.** A uniform plan (every
//!   head routed at the same `(block, topk)`, fallback disabled)
//!   delegates to the exact pre-plan code path — same kernels, same
//!   reduction order — so its outputs are `to_bits`-identical to the
//!   static-`AttnShape` path at any `MOBA_THREADS`. The property suite
//!   pins this.
//! * **Determinism survives heterogeneity.** A mixed plan dispatches KV
//!   heads in ascending head order over contiguous packed slices; each
//!   per-head launch is itself bit-deterministic, so the composition is
//!   too.
//!
//! The runtime escape hatch lives here as a threshold: when
//! `fallback_margin` is finite and a head's observed routing score
//! margin (see `topk::routing_margin`) falls below it, that head
//! degrades to dense for the request. The default `-inf` disables the
//! probe entirely — nothing compares below `-inf`, so uniform plans
//! never take the fallback branch.

use super::dtype::KvDtype;
use crate::util::json::Json;

/// How one KV head attends: routed MoBA top-k, or full dense causal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeadMode {
    /// MoBA routing at this head's `(block, topk)`.
    Routed,
    /// Full causal attention; `topk` is ignored, `block` only sizes the
    /// decode cache's centroid accounting.
    Dense,
}

/// One KV head's routing geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeadPlan {
    pub block: usize,
    pub topk: usize,
    pub mode: HeadMode,
}

impl HeadPlan {
    /// A MoBA-routed head at `(block, topk)`.
    pub fn routed(block: usize, topk: usize) -> Self {
        HeadPlan { block, topk, mode: HeadMode::Routed }
    }

    /// A planned-dense head; `block` only sizes the cache accounting.
    pub fn dense(block: usize) -> Self {
        HeadPlan { block, topk: 0, mode: HeadMode::Dense }
    }

    /// Whether this head is planned dense (as opposed to routed).
    pub fn is_dense(&self) -> bool {
        self.mode == HeadMode::Dense
    }
}

/// A full per-KV-head routing plan plus the runtime fallback threshold.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutePlan {
    /// One entry per KV head, index = KV head id.
    pub heads: Vec<HeadPlan>,
    /// Runtime dense-fallback threshold on the observed routing score
    /// margin. `-inf` (the default) disables the probe.
    pub fallback_margin: f32,
    /// KV-cache storage dtype for sessions decoding under this plan.
    /// `None` defers to the deployment default (`MOBA_KV_DTYPE` env,
    /// then `serve.kv_dtype` config, then f32). Routing is f32
    /// regardless — the dtype only changes how cached K/V rows are
    /// stored and read, never which blocks are selected.
    pub kv_dtype: Option<KvDtype>,
}

impl RoutePlan {
    /// Every KV head routed at the same `(block, topk)`, fallback
    /// disabled — reproduces the static-`AttnShape` path bit for bit.
    pub fn uniform(h_kv: usize, block: usize, topk: usize) -> Self {
        RoutePlan {
            heads: vec![HeadPlan::routed(block, topk); h_kv.max(1)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        }
    }

    /// Number of KV heads this plan covers.
    pub fn h_kv(&self) -> usize {
        self.heads.len()
    }

    /// KV head `kv_head`'s plan entry.
    pub fn head(&self, kv_head: usize) -> &HeadPlan {
        &self.heads[kv_head]
    }

    /// `Some((block, topk))` when every head is `Routed` at one shared
    /// geometry — the fast path that delegates to the pre-plan kernels.
    /// (Purely geometric: the fallback threshold is checked separately.)
    pub fn is_uniform(&self) -> Option<(usize, usize)> {
        let first = self.heads.first()?;
        if first.mode != HeadMode::Routed {
            return None;
        }
        for hp in &self.heads[1..] {
            if hp != first {
                return None;
            }
        }
        Some((first.block, first.topk))
    }

    /// True when the margin probe can fire (threshold is finite).
    pub fn fallback_enabled(&self) -> bool {
        self.fallback_margin > f32::NEG_INFINITY
    }

    /// Structural validity for a given sequence length: at least one
    /// head, every block >= 1, and routed heads need topk >= 1.
    ///
    /// `n == 0` means "length unknown / nothing cached yet" — the shape
    /// of a decode session at `session_create`, whose cache grows from
    /// empty — so the `block <= n` bound is only enforced for `n > 0`.
    /// (A plan valid for a length-unknown session is still rejected
    /// per-request when the request's actual `n` is shorter than a
    /// head's block.)
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if self.heads.is_empty() {
            return Err("route plan has no heads".into());
        }
        for (i, hp) in self.heads.iter().enumerate() {
            if hp.block == 0 {
                return Err(format!("head {i}: block must be >= 1"));
            }
            if n > 0 && hp.block > n {
                return Err(format!("head {i}: block {} exceeds n {}", hp.block, n));
            }
            if hp.mode == HeadMode::Routed && hp.topk == 0 {
                return Err(format!("head {i}: routed head needs topk >= 1"));
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------ JSON
    //
    // Schema (the autotuner emits this, the coordinator loads it):
    //
    //   {
    //     "n_kv_heads": 2,
    //     "fallback_margin": 0.05,          // omitted when disabled
    //     "kv_dtype": "f16",                // omitted when deferred
    //     "heads": [
    //       {"block": 32, "topk": 4, "mode": "routed"},
    //       {"block": 64, "topk": 0, "mode": "dense"}
    //     ]
    //   }

    /// Serialize to the plan-file JSON schema above.
    pub fn to_json(&self) -> Json {
        let heads = self
            .heads
            .iter()
            .map(|hp| {
                Json::obj(vec![
                    ("block", Json::from(hp.block)),
                    ("topk", Json::from(hp.topk)),
                    (
                        "mode",
                        Json::from(match hp.mode {
                            HeadMode::Routed => "routed",
                            HeadMode::Dense => "dense",
                        }),
                    ),
                ])
            })
            .collect();
        let mut pairs = vec![("n_kv_heads", Json::from(self.heads.len()))];
        // -inf is not representable in JSON; absence means "disabled"
        if self.fallback_enabled() {
            pairs.push(("fallback_margin", Json::from(self.fallback_margin as f64)));
        }
        // absence means "defer to the deployment default"
        if let Some(dt) = self.kv_dtype {
            pairs.push(("kv_dtype", Json::from(dt.as_str())));
        }
        pairs.push(("heads", Json::Arr(heads)));
        Json::obj(pairs)
    }

    /// Deserialize from the plan-file JSON schema (inverse of
    /// [`RoutePlan::to_json`]); structural errors name the bad field.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let heads_json = j
            .get("heads")
            .and_then(|h| h.as_arr())
            .ok_or_else(|| "route plan: missing \"heads\" array".to_string())?;
        let mut heads = Vec::with_capacity(heads_json.len());
        for (i, hj) in heads_json.iter().enumerate() {
            let block = hj
                .get("block")
                .and_then(|x| x.as_usize())
                .ok_or_else(|| format!("route plan head {i}: missing \"block\""))?;
            let topk = hj.get("topk").and_then(|x| x.as_usize()).unwrap_or(0);
            let mode = match hj.get("mode").and_then(|x| x.as_str()).unwrap_or("routed") {
                "routed" => HeadMode::Routed,
                "dense" => HeadMode::Dense,
                other => return Err(format!("route plan head {i}: unknown mode {other:?}")),
            };
            heads.push(HeadPlan { block, topk, mode });
        }
        if let Some(declared) = j.get("n_kv_heads").and_then(|x| x.as_usize()) {
            if declared != heads.len() {
                return Err(format!(
                    "route plan: n_kv_heads {declared} != {} head entries",
                    heads.len()
                ));
            }
        }
        let fallback_margin = j
            .get("fallback_margin")
            .and_then(|x| x.as_f64())
            .map(|x| x as f32)
            .unwrap_or(f32::NEG_INFINITY);
        let kv_dtype = match j.get("kv_dtype") {
            None => None,
            Some(x) => {
                let s = x
                    .as_str()
                    .ok_or_else(|| "route plan: \"kv_dtype\" must be a string".to_string())?;
                Some(
                    KvDtype::parse(s)
                        .ok_or_else(|| format!("route plan: unknown kv_dtype {s:?}"))?,
                )
            }
        };
        Ok(RoutePlan { heads, fallback_margin, kv_dtype })
    }

    /// Parse a plan from JSON text (a plan file's contents).
    pub fn parse(text: &str) -> Result<Self, String> {
        let j = Json::parse(text).map_err(|e| format!("route plan: {e}"))?;
        RoutePlan::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_is_uniform() {
        let p = RoutePlan::uniform(3, 64, 8);
        assert_eq!(p.h_kv(), 3);
        assert_eq!(p.is_uniform(), Some((64, 8)));
        assert!(!p.fallback_enabled());
        assert!(p.validate(256).is_ok());
    }

    #[test]
    fn mixed_or_dense_is_not_uniform() {
        let mut p = RoutePlan::uniform(2, 64, 8);
        p.heads[1] = HeadPlan::routed(32, 4);
        assert_eq!(p.is_uniform(), None);
        let mut q = RoutePlan::uniform(2, 64, 8);
        q.heads[0] = HeadPlan::dense(64);
        assert_eq!(q.is_uniform(), None);
        // all-dense single head: not uniform either (uniform == routed)
        let r = RoutePlan {
            heads: vec![HeadPlan::dense(16)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        };
        assert_eq!(r.is_uniform(), None);
    }

    #[test]
    fn validation_catches_degenerate_heads() {
        let mut p = RoutePlan::uniform(2, 64, 8);
        p.heads[0].block = 0;
        assert!(p.validate(128).is_err());
        let mut q = RoutePlan::uniform(2, 64, 8);
        q.heads[1].topk = 0;
        assert!(q.validate(128).is_err());
        // dense heads don't need topk
        let mut r = RoutePlan::uniform(2, 64, 8);
        r.heads[1] = HeadPlan::dense(64);
        assert!(r.validate(128).is_ok());
        let empty =
            RoutePlan { heads: vec![], fallback_margin: f32::NEG_INFINITY, kv_dtype: None };
        assert!(empty.validate(128).is_err());
    }

    /// n = 0 is "length unknown" (an empty decode session at
    /// `session_create`): the block <= n bound must not fire — the old
    /// `block > n.max(1)` check spuriously rejected every plan with
    /// block > 1 — while degenerate heads are still caught, and a
    /// known-short n still rejects an oversized block.
    #[test]
    fn validate_skips_block_bound_at_unknown_length() {
        let p = RoutePlan::uniform(2, 128, 8);
        assert!(p.validate(0).is_ok());
        assert!(p.validate(64).is_err()); // known n shorter than block
        assert!(p.validate(128).is_ok());
        // degenerate heads are rejected even at n = 0
        let mut z = RoutePlan::uniform(1, 0, 8);
        assert!(z.validate(0).is_err());
        z = RoutePlan::uniform(1, 32, 0);
        assert!(z.validate(0).is_err());
        let empty =
            RoutePlan { heads: vec![], fallback_margin: f32::NEG_INFINITY, kv_dtype: None };
        assert!(empty.validate(0).is_err());
    }

    #[test]
    fn json_roundtrip_mixed() {
        let p = RoutePlan {
            heads: vec![HeadPlan::routed(32, 4), HeadPlan::dense(64)],
            fallback_margin: 0.125,
            kv_dtype: None,
        };
        let text = p.to_json().to_string_pretty();
        let q = RoutePlan::parse(&text).unwrap();
        assert_eq!(p, q);
    }

    /// `kv_dtype` round-trips through the plan file when set and is
    /// omitted (deferring to the deployment default) when `None`.
    #[test]
    fn json_roundtrip_kv_dtype() {
        for dt in KvDtype::ALL {
            let mut p = RoutePlan::uniform(2, 32, 4);
            p.kv_dtype = Some(dt);
            let j = p.to_json();
            assert_eq!(j.get("kv_dtype").and_then(|x| x.as_str()), Some(dt.as_str()));
            assert_eq!(RoutePlan::from_json(&j).unwrap(), p);
        }
        let p = RoutePlan::uniform(2, 32, 4);
        assert!(p.to_json().get("kv_dtype").is_none());
        assert_eq!(RoutePlan::from_json(&p.to_json()).unwrap().kv_dtype, None);
    }

    #[test]
    fn json_rejects_unknown_kv_dtype() {
        let bad = r#"{"kv_dtype": "f8", "heads": [{"block": 16, "topk": 2}]}"#;
        assert!(RoutePlan::parse(bad).unwrap_err().contains("kv_dtype"));
        let not_str = r#"{"kv_dtype": 16, "heads": [{"block": 16, "topk": 2}]}"#;
        assert!(RoutePlan::parse(not_str).unwrap_err().contains("kv_dtype"));
    }

    #[test]
    fn json_roundtrip_disabled_margin_omits_key() {
        let p = RoutePlan::uniform(2, 128, 8);
        let j = p.to_json();
        assert!(j.get("fallback_margin").is_none());
        let q = RoutePlan::from_json(&j).unwrap();
        assert_eq!(p, q);
        assert!(!q.fallback_enabled());
    }

    #[test]
    fn json_rejects_malformed() {
        assert!(RoutePlan::parse("{}").is_err());
        assert!(RoutePlan::parse(r#"{"heads": [{"topk": 4}]}"#).is_err());
        assert!(RoutePlan::parse(r#"{"heads": [{"block": 8, "mode": "???"}]}"#).is_err());
        assert!(
            RoutePlan::parse(r#"{"n_kv_heads": 3, "heads": [{"block": 8, "topk": 1}]}"#).is_err()
        );
    }

    #[test]
    fn mode_defaults_to_routed() {
        let p = RoutePlan::parse(r#"{"heads": [{"block": 16, "topk": 2}]}"#).unwrap();
        assert_eq!(p.heads[0].mode, HeadMode::Routed);
        assert_eq!(p.is_uniform(), Some((16, 2)));
    }
}
