//! CPU attention substrate — the performance testbed for the paper's
//! efficiency claims (§4, §5.3, Figures 3–4).
//!
//! The paper's kernels are CUDA; this machine is a single CPU core. We
//! reproduce the *algorithms* (and their asymptotics, overheads and
//! crossovers) as faithful f32 implementations (see README.md
//! §Architecture for the hardware-adaptation rationale):
//!
//! * [`dense`] — naive O(N²) attention plus a blocked online-softmax
//!   implementation (the FlashAttention-2 analogue on this hardware).
//! * [`moba_naive`] — the original MoBA pipeline from Lu et al. (2025):
//!   five stages incl. full N×n score-matrix materialization and global
//!   reindexing, whose overheads dominate Figure 4.
//! * [`flash_moba`] — the paper's FlashMoBA: fused tiled top-k (no score
//!   matrix) + gather-and-densify forward, plus the recomputation-based
//!   backward (Algorithm 5) in [`backward`].
//! * [`topk`], [`centroid`], [`varlen`], [`kconv`] — shared building
//!   blocks (Algorithms 2–4, Appendix B).
//! * [`gemm`] — register-blocked GEMM microkernels (score micro-tiles,
//!   fused online-softmax accumulate) under every kernel above; the
//!   lane-order rule keeps them bit-identical to the scalar
//!   [`simd`]-based formulation (see README.md §Performance).
//! * [`dtype`] — the [`dtype::KvDtype`] storage axis: cached K/V rows
//!   may be f16 / bf16 / int8-with-per-row-scales, dequantized inside
//!   the [`simd`] / [`gemm`] kernels (never materialized back to f32);
//!   centroid sums stay f32 so routing is dtype-invariant. [`simd`]
//!   itself resolves a runtime ISA table (AVX2 / NEON / scalar) whose
//!   variants are bit-identical to each other.
//! * [`decode`] — incremental autoregressive decode: per-session block
//!   KV cache with running centroids and streaming MoBA routing, parity
//!   locked against the prefill kernels.
//! * [`paged`] — the shared page allocator under paged KV caches:
//!   fixed-size pages (one per logical block, centroid sum in the page
//!   metadata), copy-on-write prefix sharing, and the soft page budget
//!   admission control enforces. Paged decode is bit-identical to the
//!   contiguous layout (`rust/tests/paged_parity.rs`).
//! * [`backend`] — the [`backend::AttentionBackend`] trait unifying the
//!   implementations behind one call convention (prefill `forward` +
//!   incremental `forward_decode`), plus the registry and cross-backend
//!   parity harness every consumer layer dispatches through.
//! * [`plan`] — per-head routing plans: [`plan::RoutePlan`] gives every
//!   KV head its own `(block, topk)` or a dense fallback, dispatched
//!   through `AttentionBackend::forward_plan[_into]`; uniform plans
//!   reproduce the static-`AttnShape` path bit for bit.
//!
//! Tensor layout: packed row-major `(h, n, d)` f32 — queries carry `h`
//! heads, keys/values carry `h_kv` KV heads (GQA: `h % h_kv == 0`, each
//! group of `h / h_kv` query heads reads one KV head). Kernels iterate
//! heads *internally*: centroids/kconv are computed once per KV head,
//! routing/top-k once per query head, and the thread pool partitions
//! `head × query-row` work units — so one kernel launch covers the whole
//! head dimension. `h = h_kv = 1` reproduces the single-head path
//! bit-for-bit (pinned by `rust/tests/singlehead_regression.rs`).

pub mod backend;
pub mod backward;
pub mod centroid;
pub mod decode;
pub mod dense;
pub mod dtype;
pub mod flash_moba;
pub mod gemm;
pub mod kconv;
pub mod moba_naive;
pub mod paged;
pub mod plan;
pub mod simd;
pub mod stats;
pub mod testutil;
pub mod topk;
pub mod varlen;

pub use backend::{AttentionBackend, BackendRegistry};
pub use decode::{DecodeSession, KvCache};
pub use dtype::KvDtype;
pub use paged::{PagePool, PoolStats};
pub use plan::{HeadMode, HeadPlan, RoutePlan};
pub use stats::StageStats;
// the execution context every backend call takes (canonical home:
// `crate::util::pool`; re-exported here for trait consumers)
pub use crate::util::pool::ExecCtx;

/// Geometry of one (possibly multi-head / GQA) MoBA attention problem.
///
/// Buffers are packed row-major: `q`/`o` are `(h, n, d)`, `k`/`v` are
/// `(h_kv, n, d)`. Query head `qh` routes and attends against KV head
/// `qh / (h / h_kv)` ([`AttnShape::kv_head_of`]).
///
/// The sequence may end in a ragged (partial) final block: the tail
/// block is always attended causally by its own queries but is never a
/// routing candidate — routing selects among *complete* strictly-past
/// blocks only, exactly as in streaming decode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    /// query heads
    pub h: usize,
    /// KV heads (GQA groups of `h / h_kv` query heads; `h % h_kv == 0`)
    pub h_kv: usize,
    /// sequence length (need not be a multiple of `block`)
    pub n: usize,
    /// head dimension (paper: 64)
    pub d: usize,
    /// MoBA block size B
    pub block: usize,
    /// routed blocks per query (excluding the always-attended own block)
    pub topk: usize,
}

impl AttnShape {
    pub fn new(h: usize, h_kv: usize, n: usize, d: usize, block: usize, topk: usize) -> Self {
        Self::try_new(h, h_kv, n, d, block, topk).unwrap_or_else(|| {
            panic!(
                "invalid attention geometry h={h} h_kv={h_kv} N={n} d={d} B={block}: \
                 need h a positive multiple of h_kv, and n, d, block > 0"
            )
        })
    }

    /// Non-panicking constructor: `None` when the geometry is invalid
    /// (empty problem, or `h` not a positive multiple of `h_kv`). Used
    /// by callers that must *decide* rather than assert — e.g. the
    /// serving router falling back to a dense backend for unsupported
    /// request shapes. A ragged final block (`n % block != 0`) is a
    /// *valid* geometry: the tail block is always-attended and excluded
    /// from routing.
    pub fn try_new(
        h: usize,
        h_kv: usize,
        n: usize,
        d: usize,
        block: usize,
        topk: usize,
    ) -> Option<Self> {
        if h == 0 || h_kv == 0 || h % h_kv != 0 || n == 0 || d == 0 || block == 0 {
            return None;
        }
        Some(Self { h, h_kv, n, d, block, topk })
    }

    /// The single-head geometry (`h = h_kv = 1`) — bit-for-bit the
    /// pre-multi-head behavior.
    pub fn single(n: usize, d: usize, block: usize, topk: usize) -> Self {
        Self::new(1, 1, n, d, block, topk)
    }

    /// The same routing geometry with a different head layout.
    pub fn with_heads(mut self, h: usize, h_kv: usize) -> Self {
        assert!(h >= 1 && h_kv >= 1 && h % h_kv == 0, "h={h} must be a multiple of h_kv={h_kv}");
        self.h = h;
        self.h_kv = h_kv;
        self
    }

    /// Logical blocks covering the sequence, `ceil(n / block)` — the
    /// last may be partial.
    pub fn n_blocks(&self) -> usize {
        self.n.div_ceil(self.block)
    }

    /// Blocks holding exactly `block` tokens, `n / block` — the routing
    /// candidate universe.
    pub fn complete_blocks(&self) -> usize {
        self.n / self.block
    }

    /// Does the sequence end in a partial block?
    pub fn has_partial_tail(&self) -> bool {
        self.n % self.block != 0
    }

    /// Tokens in logical block `j`.
    pub fn block_len(&self, j: usize) -> usize {
        assert!(j < self.n_blocks());
        (self.n - j * self.block).min(self.block)
    }

    /// Query heads per KV head (the GQA group size).
    pub fn group(&self) -> usize {
        self.h / self.h_kv
    }

    /// The KV head that query head `qh` routes and attends against.
    pub fn kv_head_of(&self, qh: usize) -> usize {
        debug_assert!(qh < self.h);
        qh / self.group()
    }

    /// Element count of the packed `(h, n, d)` query/output tensors.
    pub fn q_elems(&self) -> usize {
        self.h * self.n * self.d
    }

    /// Element count of the packed `(h_kv, n, d)` key/value tensors.
    pub fn kv_elems(&self) -> usize {
        self.h_kv * self.n * self.d
    }

    /// Largest routing candidate count any query row sees: tail-block
    /// queries see every complete block; with an aligned n the last
    /// row's own block is complete, leaving `complete_blocks - 1`
    /// strict-past candidates.
    pub fn max_candidates(&self) -> usize {
        let cb = self.complete_blocks();
        if self.has_partial_tail() {
            cb
        } else {
            cb.saturating_sub(1)
        }
    }

    /// Attended fraction of the causal matrix (sparsity complement),
    /// ≈ (k+1)·B / N for long sequences. Head layout does not change
    /// the per-head density.
    pub fn density(&self) -> f64 {
        ((self.topk + 1) as f64 * self.block as f64 / self.n as f64).min(1.0)
    }
}

/// Gather token `t`'s row from every head of a packed `(heads, n, d)`
/// tensor into one `(heads, d)` row — the per-token slice the decode
/// path streams.
pub fn packed_rows(x: &[f32], heads: usize, n: usize, d: usize, t: usize) -> Vec<f32> {
    assert_eq!(x.len(), heads * n * d);
    assert!(t < n);
    let mut out = Vec::with_capacity(heads * d);
    for head in 0..heads {
        out.extend_from_slice(&x[(head * n + t) * d..(head * n + t + 1) * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_basics() {
        let s = AttnShape::single(1024, 64, 128, 2);
        assert_eq!(s.n_blocks(), 8);
        assert_eq!(s.complete_blocks(), 8);
        assert!(!s.has_partial_tail());
        assert_eq!(s.group(), 1);
        assert_eq!(s.kv_head_of(0), 0);
        assert!((s.density() - 3.0 * 128.0 / 1024.0).abs() < 1e-12);
    }

    #[test]
    fn gqa_head_mapping() {
        let s = AttnShape::new(8, 2, 256, 16, 32, 2);
        assert_eq!(s.group(), 4);
        assert_eq!(s.kv_head_of(0), 0);
        assert_eq!(s.kv_head_of(3), 0);
        assert_eq!(s.kv_head_of(4), 1);
        assert_eq!(s.kv_head_of(7), 1);
        assert_eq!(s.q_elems(), 8 * 256 * 16);
        assert_eq!(s.kv_elems(), 2 * 256 * 16);
    }

    #[test]
    fn ragged_tail_is_a_valid_geometry() {
        // the shape the old MobaShape::try_new rejected
        let s = AttnShape::try_new(1, 1, 700, 64, 128, 8).expect("ragged n is supported");
        assert_eq!(s.n_blocks(), 6);
        assert_eq!(s.complete_blocks(), 5);
        assert!(s.has_partial_tail());
        assert_eq!(s.block_len(4), 128);
        assert_eq!(s.block_len(5), 700 - 5 * 128);
        assert_eq!(s.max_candidates(), 5); // tail queries see all complete blocks
        let aligned = AttnShape::single(640, 64, 128, 8);
        assert_eq!(aligned.max_candidates(), 4);
    }

    #[test]
    fn try_new_decides_instead_of_panicking() {
        assert!(AttnShape::try_new(1, 1, 1024, 64, 128, 8).is_some());
        assert!(AttnShape::try_new(4, 2, 1024, 64, 128, 8).is_some());
        assert!(AttnShape::try_new(0, 1, 1024, 64, 128, 8).is_none()); // no heads
        assert!(AttnShape::try_new(2, 0, 1024, 64, 128, 8).is_none()); // no KV heads
        assert!(AttnShape::try_new(3, 2, 1024, 64, 128, 8).is_none()); // ragged groups
        assert!(AttnShape::try_new(2, 4, 1024, 64, 128, 8).is_none()); // h < h_kv
        assert!(AttnShape::try_new(1, 1, 0, 64, 128, 8).is_none());
        assert!(AttnShape::try_new(1, 1, 128, 0, 128, 8).is_none());
        assert!(AttnShape::try_new(1, 1, 128, 64, 0, 8).is_none());
    }

    #[test]
    #[should_panic]
    fn ragged_groups_rejected() {
        AttnShape::new(6, 4, 128, 8, 32, 2);
    }

    #[test]
    fn with_heads_preserves_routing_geometry() {
        let s = AttnShape::single(256, 8, 32, 3).with_heads(4, 2);
        assert_eq!((s.h, s.h_kv), (4, 2));
        assert_eq!((s.n, s.d, s.block, s.topk), (256, 8, 32, 3));
    }

    #[test]
    fn packed_rows_gathers_across_heads() {
        // 2 heads, n=3, d=2: x[h][t][c] = 100h + 10t + c
        let mut x = Vec::new();
        for h in 0..2 {
            for t in 0..3 {
                for c in 0..2 {
                    x.push((100 * h + 10 * t + c) as f32);
                }
            }
        }
        assert_eq!(packed_rows(&x, 2, 3, 2, 1), vec![10.0, 11.0, 110.0, 111.0]);
    }
}
