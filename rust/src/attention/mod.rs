//! CPU attention substrate — the performance testbed for the paper's
//! efficiency claims (§4, §5.3, Figures 3–4).
//!
//! The paper's kernels are CUDA; this machine is a single CPU core. We
//! reproduce the *algorithms* (and their asymptotics, overheads and
//! crossovers) as faithful f32 implementations (see README.md
//! §Architecture for the hardware-adaptation rationale):
//!
//! * [`dense`] — naive O(N²) attention plus a blocked online-softmax
//!   implementation (the FlashAttention-2 analogue on this hardware).
//! * [`moba_naive`] — the original MoBA pipeline from Lu et al. (2025):
//!   five stages incl. full N×n score-matrix materialization and global
//!   reindexing, whose overheads dominate Figure 4.
//! * [`flash_moba`] — the paper's FlashMoBA: fused tiled top-k (no score
//!   matrix) + gather-and-densify forward, plus the recomputation-based
//!   backward (Algorithm 5) in [`backward`].
//! * [`topk`], [`centroid`], [`varlen`], [`kconv`] — shared building
//!   blocks (Algorithms 2–4, Appendix B).
//! * [`decode`] — incremental autoregressive decode: per-session block
//!   KV cache with running centroids and streaming MoBA routing, parity
//!   locked against the prefill kernels.
//! * [`backend`] — the [`backend::AttentionBackend`] trait unifying the
//!   implementations behind one call convention (prefill `forward` +
//!   incremental `forward_decode`), plus the registry and cross-backend
//!   parity harness every consumer layer dispatches through.
//!
//! All single-head (N, d) row-major f32; multi-head benches loop heads.

pub mod backend;
pub mod backward;
pub mod centroid;
pub mod decode;
pub mod dense;
pub mod flash_moba;
pub mod kconv;
pub mod moba_naive;
pub mod simd;
pub mod stats;
pub mod testutil;
pub mod topk;
pub mod varlen;

pub use backend::{AttentionBackend, BackendRegistry};
pub use decode::{DecodeSession, KvCache};
pub use stats::StageStats;
// the execution context every backend call takes (canonical home:
// `crate::util::pool`; re-exported here for trait consumers)
pub use crate::util::pool::ExecCtx;

/// Geometry of one MoBA attention problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MobaShape {
    /// sequence length
    pub n: usize,
    /// head dimension (paper: 64)
    pub d: usize,
    /// MoBA block size B
    pub block: usize,
    /// routed blocks per query (excluding the always-attended own block)
    pub topk: usize,
}

impl MobaShape {
    pub fn new(n: usize, d: usize, block: usize, topk: usize) -> Self {
        Self::try_new(n, d, block, topk).unwrap_or_else(|| {
            panic!(
                "invalid MoBA geometry N={n} d={d} B={block}: \
                 N must be a positive multiple of B, and d > 0"
            )
        })
    }

    /// Non-panicking constructor: `None` when the geometry is invalid
    /// (ragged block partition or empty problem). Used by callers that
    /// must *decide* rather than assert — e.g. the serving router
    /// falling back to a dense backend for unsupported request shapes.
    pub fn try_new(n: usize, d: usize, block: usize, topk: usize) -> Option<Self> {
        if n == 0 || d == 0 || block == 0 || n % block != 0 {
            return None;
        }
        Some(Self { n, d, block, topk })
    }

    pub fn n_blocks(&self) -> usize {
        self.n / self.block
    }

    /// Attended fraction of the causal matrix (sparsity complement),
    /// ≈ (k+1)·B / N for long sequences.
    pub fn density(&self) -> f64 {
        ((self.topk + 1) as f64 * self.block as f64 / self.n as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_basics() {
        let s = MobaShape::new(1024, 64, 128, 2);
        assert_eq!(s.n_blocks(), 8);
        assert!((s.density() - 3.0 * 128.0 / 1024.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn ragged_rejected() {
        MobaShape::new(100, 64, 32, 2);
    }

    #[test]
    fn try_new_decides_instead_of_panicking() {
        assert!(MobaShape::try_new(1024, 64, 128, 8).is_some());
        assert!(MobaShape::try_new(700, 64, 128, 8).is_none()); // ragged
        assert!(MobaShape::try_new(0, 64, 128, 8).is_none());
        assert!(MobaShape::try_new(128, 0, 128, 8).is_none());
        assert!(MobaShape::try_new(128, 64, 0, 8).is_none());
    }
}
