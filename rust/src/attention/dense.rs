//! Dense causal attention baselines.
//!
//! * [`naive_attention`] — textbook O(N²) with a materialized score row
//!   (the correctness oracle for everything else); single-head.
//!   [`naive_attention_packed`] runs it per query head over packed
//!   `(h, n, d)` / `(h_kv, n, d)` tensors with the GQA head mapping.
//! * [`flash_attention_packed`] — blocked, online-softmax, cache-tiled:
//!   the FlashAttention-2 analogue on this hardware (used as the dense
//!   baseline in Figure 3/4 reproductions). Iterates heads internally:
//!   one call covers the whole head dimension, with the thread pool
//!   partitioning flattened `(head, query-tile)` work units.
//!   [`flash_attention`]/[`flash_attention_ctx`] are the single-head
//!   form (`h = h_kv = 1`), preserved for the microbenches and the
//!   bit-parity regression suite.
//!
//! All forms return the output and the per-row logsumexp L (needed by
//! the merge stage of the original-MoBA pipeline and by the backward
//! pass).
//!
//! Note on per-head route plans: a plan's `Dense` heads are *not*
//! served by these kernels — the dispatcher runs them through the
//! routed backend as fully-routed launches so one request stays on one
//! backend and one determinism contract. These baselines remain the
//! correctness oracles the plan path is tested against.

use std::sync::atomic::{AtomicU64, Ordering};

use super::gemm::{qkt_tile, softmax_accum};
use super::simd::{axpy, dot};
use super::stats::ws_bytes;
use crate::util::pool::ExecCtx;

pub const NEG_INF: f32 = -1.0e30;

/// Textbook causal attention. q,k,v: (n, d) row-major. Returns (o, lse).
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = vec![0.0f32; n * d];
    let mut lse = vec![0.0f32; n];
    let mut s = vec![0.0f32; n];
    for t in 0..n {
        let qt = &q[t * d..(t + 1) * d];
        let mut m = NEG_INF;
        for u in 0..=t {
            let val = dot(qt, &k[u * d..(u + 1) * d]) * scale;
            s[u] = val;
            if val > m {
                m = val;
            }
        }
        let mut z = 0.0f32;
        for u in 0..=t {
            s[u] = (s[u] - m).exp();
            z += s[u];
        }
        let ot = &mut o[t * d..(t + 1) * d];
        for u in 0..=t {
            axpy(ot, s[u] / z, &v[u * d..(u + 1) * d]);
        }
        lse[t] = m + z.ln();
    }
    (o, lse)
}

/// [`naive_attention`] per query head over packed tensors: q is
/// `(h, n, d)`, k/v are `(h_kv, n, d)`, query head `qh` attends KV head
/// `qh / (h / h_kv)`. Serial (it is the oracle). Returns the packed
/// `(h, n, d)` output and `(h, n)` logsumexp.
pub fn naive_attention_packed(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    h_kv: usize,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert!(h >= 1 && h_kv >= 1 && h % h_kv == 0);
    assert_eq!(q.len(), h * n * d);
    assert_eq!(k.len(), h_kv * n * d);
    assert_eq!(v.len(), h_kv * n * d);
    let group = h / h_kv;
    let mut o = Vec::with_capacity(h * n * d);
    let mut lse = Vec::with_capacity(h * n);
    for qh in 0..h {
        let kvh = qh / group;
        let (oh, lh) = naive_attention(
            &q[qh * n * d..(qh + 1) * n * d],
            &k[kvh * n * d..(kvh + 1) * n * d],
            &v[kvh * n * d..(kvh + 1) * n * d],
            n,
            d,
        );
        o.extend_from_slice(&oh);
        lse.extend_from_slice(&lh);
    }
    (o, lse)
}

/// Blocked online-softmax causal attention (FlashAttention-2 style),
/// single-head, on the process-wide shared pool.
pub fn flash_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
) -> (Vec<f32>, Vec<f32>, u64) {
    flash_attention_ctx(ExecCtx::global(), q, k, v, n, d, br, bc)
}

/// Single-head [`flash_attention`] on an explicit execution context —
/// the `h = h_kv = 1` slice of [`flash_attention_packed`], kept as its
/// own entry point for the microbenches and regression suites.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
) -> (Vec<f32>, Vec<f32>, u64) {
    flash_attention_packed(ctx, q, k, v, 1, 1, n, d, br, bc)
}

/// Packed multi-head blocked online-softmax causal attention. q is
/// `(h, n, d)`, k/v are `(h_kv, n, d)` (GQA: query head `qh` reads KV
/// head `qh / (h / h_kv)`). Returns the packed `(h, n, d)` output, the
/// `(h, n)` logsumexp, and workspace bytes.
///
/// Work units are flattened `(head, query-tile)` pairs in head-major
/// order: each tile carries its own (m, l, acc) state and visits key
/// tiles in the same ascending order, so partitioning the flattened
/// tile sequence across workers is bit-identical to the serial path —
/// and `h = 1` partitions exactly as the pre-multi-head kernel did.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_packed(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    h_kv: usize,
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
) -> (Vec<f32>, Vec<f32>, u64) {
    let mut o = Vec::new();
    let mut lse = Vec::new();
    let ws = flash_attention_packed_into(ctx, q, k, v, h, h_kv, n, d, br, bc, &mut o, &mut lse);
    (o, lse, ws)
}

/// [`flash_attention_packed`] writing into caller-provided output
/// buffers, with every per-worker tile buffer (score tile, (m, l, acc)
/// accumulators) drawn from the context's scratch arenas — the
/// zero-allocation steady-state path (serial repeats of the same shape
/// allocate nothing after warmup; `rust/tests/alloc_regression.rs`).
///
/// Score tiles run on the register-blocked [`qkt_tile`] microkernel
/// and the accumulator update on the fused [`softmax_accum`]; both
/// preserve the per-element f32 operation order of the scalar
/// dot/axpy/scale formulation, so outputs are `to_bits`-identical to
/// the pre-microkernel kernel (pinned by the scalar-oracle property
/// test and the single-head legacy regression suite). Causal masking
/// is applied by overwriting the dense tile after the GEMM — masked
/// entries never survive, so the surviving values are unchanged.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_packed_into(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    h: usize,
    h_kv: usize,
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
    o: &mut Vec<f32>,
    lse: &mut Vec<f32>,
) -> u64 {
    assert!(h >= 1 && h_kv >= 1 && h % h_kv == 0, "h={h} must be a multiple of h_kv={h_kv}");
    assert_eq!(q.len(), h * n * d);
    assert_eq!(k.len(), h_kv * n * d);
    assert_eq!(v.len(), h_kv * n * d);
    let group = h / h_kv;
    let scale = 1.0 / (d as f32).sqrt();
    let tq = n.div_ceil(br);
    // resize only (no clear): every element is overwritten by the tile
    // epilogues, and a same-length resize is a no-op — clearing first
    // would re-fill the whole output on every steady-state call
    o.resize(h * n * d, 0.0);
    lse.resize(h * n, 0.0);
    // first output row of unit u, in packed (h, n) row coordinates
    let row_off = |u: usize| {
        let (head, it) = (u / tq, u % tq);
        head * n + (it * br).min(n)
    };
    let workspace = AtomicU64::new(0);
    ctx.pool().for_ranges_split(
        h * tq,
        o.as_mut_slice(),
        lse.as_mut_slice(),
        |u| {
            let ro = row_off(u);
            (ro * d, ro)
        },
        |slot, units, o_chunk, lse_chunk| {
            let mut scratch = ctx.scratch(slot);
            let mut s = scratch.take_f32(br * bc, 0.0);
            let mut acc = scratch.take_f32(br * d, 0.0);
            let mut mrow = scratch.take_f32(br, NEG_INF);
            let mut lrow = scratch.take_f32(br, 0.0);
            workspace.fetch_add(
                ws_bytes(&[s.len(), acc.len(), mrow.len(), lrow.len()]),
                Ordering::Relaxed,
            );
            let chunk_base = row_off(units.start);

            for u in units {
                let (head, it) = (u / tq, u % tq);
                let qh = &q[head * n * d..(head + 1) * n * d];
                let kvh = head / group;
                let kh = &k[kvh * n * d..(kvh + 1) * n * d];
                let vh = &v[kvh * n * d..(kvh + 1) * n * d];

                let r0 = it * br;
                let rows = br.min(n - r0);
                acc[..rows * d].fill(0.0);
                mrow[..rows].fill(NEG_INF);
                lrow[..rows].fill(0.0);
                // causal: key tiles only up to the query tile's end
                let last_col = r0 + rows; // exclusive
                let tk = last_col.div_ceil(bc);
                for jt in 0..tk {
                    let c0 = jt * bc;
                    let cols = bc.min(last_col - c0).min(bc);
                    // dense register-blocked score tile ...
                    qkt_tile(
                        &qh[r0 * d..(r0 + rows) * d],
                        &kh[c0 * d..(c0 + cols) * d],
                        d,
                        rows,
                        cols,
                        scale,
                        &mut s,
                        bc,
                    );
                    // ... then the causal mask: row r keeps columns
                    // c0 + cc <= r0 + r
                    for r in 0..rows {
                        let keep = (r0 + r + 1).saturating_sub(c0).min(cols);
                        for x in s[r * bc + keep..r * bc + cols].iter_mut() {
                            *x = NEG_INF;
                        }
                    }
                    // online softmax update
                    for r in 0..rows {
                        let srow = &mut s[r * bc..r * bc + cols];
                        let mut mt = mrow[r];
                        for &x in srow.iter() {
                            if x > mt {
                                mt = x;
                            }
                        }
                        if mt == NEG_INF {
                            continue; // whole tile masked for this row
                        }
                        let corr = (mrow[r] - mt).exp();
                        let mut psum = 0.0f32;
                        for x in srow.iter_mut() {
                            *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                            psum += *x;
                        }
                        lrow[r] = lrow[r] * corr + psum;
                        softmax_accum(
                            &mut acc[r * d..(r + 1) * d],
                            corr,
                            &s[r * bc..r * bc + cols],
                            &vh[c0 * d..(c0 + cols) * d],
                        );
                        mrow[r] = mt;
                    }
                }
                // tile epilogue: normalize into the unit's rows of the
                // chunk (units are emitted in flattened order, which is
                // exactly the packed (h, n, d) row order)
                let local = row_off(u) - chunk_base;
                for r in 0..rows {
                    let l = if lrow[r] == 0.0 { 1.0 } else { lrow[r] };
                    let arow = &acc[r * d..(r + 1) * d];
                    let orow = &mut o_chunk[(local + r) * d..(local + r + 1) * d];
                    for c in 0..d {
                        orow[c] = arow[c] / l;
                    }
                    lse_chunk[local + r] = mrow[r] + lrow[r].max(1e-30).ln();
                }
            }
            scratch.give_f32(lrow);
            scratch.give_f32(mrow);
            scratch.give_f32(acc);
            scratch.give_f32(s);
        },
    );
    workspace.into_inner()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{max_abs_diff, qkv, qkv_packed};

    #[test]
    fn flash_matches_naive() {
        for (n, d, br, bc) in [(128, 16, 32, 32), (96, 8, 32, 16), (64, 4, 64, 64), (100, 8, 32, 48)] {
            let (q, k, v) = qkv(1, n, d);
            let (o1, l1) = naive_attention(&q, &k, &v, n, d);
            let (o2, l2, _) = flash_attention(&q, &k, &v, n, d, br, bc);
            assert!(max_abs_diff(&o1, &o2) < 2e-5, "n={n} d={d}");
            assert!(max_abs_diff(&l1, &l2) < 2e-5);
        }
    }

    /// Partitioning (head, query-tile) units across workers must not
    /// change a single bit of o or lse.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let (n, d) = (101, 8); // ragged against both tile size and worker count
        let (q, k, v) = qkv(9, n, d);
        let (o1, l1, _) = flash_attention_ctx(&ExecCtx::serial(), &q, &k, &v, n, d, 32, 48);
        for threads in [2, 3, 5] {
            let ctx = ExecCtx::with_threads(threads);
            let (o2, l2, _) = flash_attention_ctx(&ctx, &q, &k, &v, n, d, 32, 48);
            assert_eq!(o1, o2, "threads={threads}");
            assert_eq!(l1, l2, "threads={threads}");
        }
    }

    /// The packed kernel at any head count equals per-head single-head
    /// runs with the GQA mapping — and stays bit-stable across thread
    /// counts.
    #[test]
    fn packed_matches_per_head_single_head() {
        for (h, h_kv) in [(1, 1), (2, 2), (4, 2), (3, 1)] {
            let (n, d) = (53, 8);
            let (q, k, v) = qkv_packed(11, h, h_kv, n, d);
            let serial = flash_attention_packed(&ExecCtx::serial(), &q, &k, &v, h, h_kv, n, d, 16, 24);
            for qh in 0..h {
                let kvh = qh / (h / h_kv);
                let (oh, lh, _) = flash_attention_ctx(
                    &ExecCtx::serial(),
                    &q[qh * n * d..(qh + 1) * n * d],
                    &k[kvh * n * d..(kvh + 1) * n * d],
                    &v[kvh * n * d..(kvh + 1) * n * d],
                    n,
                    d,
                    16,
                    24,
                );
                assert_eq!(&serial.0[qh * n * d..(qh + 1) * n * d], &oh[..], "h={h} head {qh}");
                assert_eq!(&serial.1[qh * n..(qh + 1) * n], &lh[..], "h={h} head {qh}");
            }
            for threads in [2, 5] {
                let par = flash_attention_packed(
                    &ExecCtx::with_threads(threads),
                    &q,
                    &k,
                    &v,
                    h,
                    h_kv,
                    n,
                    d,
                    16,
                    24,
                );
                assert_eq!(serial.0, par.0, "h={h} threads={threads}");
                assert_eq!(serial.1, par.1, "h={h} threads={threads}");
            }
        }
    }

    /// Packed GQA output == the dense oracle per head.
    #[test]
    fn packed_gqa_matches_oracle() {
        let (h, h_kv, n, d) = (4, 2, 96, 8);
        let (q, k, v) = qkv_packed(12, h, h_kv, n, d);
        let (o, lse, _) = flash_attention_packed(ExecCtx::global(), &q, &k, &v, h, h_kv, n, d, 32, 32);
        let (oref, lref) = naive_attention_packed(&q, &k, &v, h, h_kv, n, d);
        assert!(max_abs_diff(&o, &oref) < 5e-5);
        assert!(max_abs_diff(&lse, &lref) < 5e-5);
    }

    #[test]
    fn first_row_is_v0() {
        let (q, k, v) = qkv(2, 16, 8);
        let (o, _) = naive_attention(&q, &k, &v, 16, 8);
        assert!(max_abs_diff(&o[..8], &v[..8]) < 1e-6);
    }

    #[test]
    fn rows_are_convex_combinations() {
        // each output must lie within [min, max] of the value column range
        let (q, k, v) = qkv(3, 64, 4);
        let (o, _) = naive_attention(&q, &k, &v, 64, 4);
        for c in 0..4 {
            let lo = v.iter().skip(c).step_by(4).fold(f32::MAX, |a, &b| a.min(b));
            let hi = v.iter().skip(c).step_by(4).fold(f32::MIN, |a, &b| a.max(b));
            for t in 0..64 {
                let x = o[t * 4 + c];
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn lse_is_finite_and_ordered_sane() {
        let (q, k, v) = qkv(4, 32, 8);
        let (_, lse) = naive_attention(&q, &k, &v, 32, 8);
        assert!(lse.iter().all(|x| x.is_finite()));
    }
}
