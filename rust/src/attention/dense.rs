//! Dense causal attention baselines.
//!
//! * [`naive_attention`] — textbook O(N²) with a materialized score row
//!   (the correctness oracle for everything else).
//! * [`flash_attention`] — blocked, online-softmax, cache-tiled: the
//!   FlashAttention-2 analogue on this hardware (used as the dense
//!   baseline in Figure 3/4 reproductions).
//!
//! Both return the output and the per-row logsumexp L (needed by the
//! merge stage of the original-MoBA pipeline and by the backward pass).

use super::simd::{axpy, dot, scale as vscale};
use super::stats::ws_bytes;
use crate::util::pool::ExecCtx;

pub const NEG_INF: f32 = -1.0e30;

/// Textbook causal attention. q,k,v: (n, d) row-major. Returns (o, lse).
pub fn naive_attention(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut o = vec![0.0f32; n * d];
    let mut lse = vec![0.0f32; n];
    let mut s = vec![0.0f32; n];
    for t in 0..n {
        let qt = &q[t * d..(t + 1) * d];
        let mut m = NEG_INF;
        for u in 0..=t {
            let val = dot(qt, &k[u * d..(u + 1) * d]) * scale;
            s[u] = val;
            if val > m {
                m = val;
            }
        }
        let mut z = 0.0f32;
        for u in 0..=t {
            s[u] = (s[u] - m).exp();
            z += s[u];
        }
        let ot = &mut o[t * d..(t + 1) * d];
        for u in 0..=t {
            axpy(ot, s[u] / z, &v[u * d..(u + 1) * d]);
        }
        lse[t] = m + z.ln();
    }
    (o, lse)
}

/// Blocked online-softmax causal attention (FlashAttention-2 style), on
/// the process-wide shared pool.
///
/// Processes queries in `br`-row tiles and keys in `bc`-column tiles,
/// carrying (m, l, acc) across key tiles; only O(br·bc + br·d) workspace
/// per worker.
pub fn flash_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
) -> (Vec<f32>, Vec<f32>, u64) {
    flash_attention_ctx(ExecCtx::global(), q, k, v, n, d, br, bc)
}

/// [`flash_attention`] on an explicit execution context. Query tiles
/// are independent work units (each carries its own (m, l, acc) state
/// and visits key tiles in the same ascending order), so partitioning
/// the tile loop across workers is bit-identical to the serial path.
#[allow(clippy::too_many_arguments)]
pub fn flash_attention_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    br: usize,
    bc: usize,
) -> (Vec<f32>, Vec<f32>, u64) {
    let scale = 1.0 / (d as f32).sqrt();
    let tq = n.div_ceil(br);
    let parts = ctx.pool().map_ranges(tq, |tiles| {
        let row0 = tiles.start * br;
        let row_end = (tiles.end * br).min(n);
        let mut o = vec![0.0f32; (row_end - row0) * d];
        let mut lse = vec![0.0f32; row_end - row0];
        let mut s = vec![0.0f32; br * bc];
        let mut acc = vec![0.0f32; br * d];
        let mut mrow = vec![NEG_INF; br];
        let mut lrow = vec![0.0f32; br];
        let workspace = ws_bytes(&[s.len(), acc.len(), mrow.len(), lrow.len()]);

        for it in tiles {
            let r0 = it * br;
            let rows = br.min(n - r0);
            acc[..rows * d].fill(0.0);
            mrow[..rows].fill(NEG_INF);
            lrow[..rows].fill(0.0);
            // causal: key tiles only up to the query tile's end
            let last_col = r0 + rows; // exclusive
            let tk = last_col.div_ceil(bc);
            for jt in 0..tk {
                let c0 = jt * bc;
                let cols = bc.min(last_col - c0).min(bc);
                // scores tile
                for r in 0..rows {
                    let qt = &q[(r0 + r) * d..(r0 + r + 1) * d];
                    let srow = &mut s[r * bc..r * bc + cols];
                    for (cc, sval) in srow.iter_mut().enumerate() {
                        let u = c0 + cc;
                        if u > r0 + r {
                            *sval = NEG_INF;
                            continue;
                        }
                        *sval = dot(qt, &k[u * d..(u + 1) * d]) * scale;
                    }
                }
                // online softmax update
                for r in 0..rows {
                    let srow = &mut s[r * bc..r * bc + cols];
                    let mut mt = mrow[r];
                    for &x in srow.iter() {
                        if x > mt {
                            mt = x;
                        }
                    }
                    if mt == NEG_INF {
                        continue; // whole tile masked for this row
                    }
                    let corr = (mrow[r] - mt).exp();
                    let mut psum = 0.0f32;
                    for x in srow.iter_mut() {
                        *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                        psum += *x;
                    }
                    lrow[r] = lrow[r] * corr + psum;
                    let arow = &mut acc[r * d..(r + 1) * d];
                    if corr != 1.0 {
                        vscale(arow, corr);
                    }
                    for (cc, &p) in srow.iter().enumerate() {
                        if p == 0.0 {
                            continue;
                        }
                        axpy(arow, p, &v[(c0 + cc) * d..(c0 + cc + 1) * d]);
                    }
                    mrow[r] = mt;
                }
            }
            for r in 0..rows {
                let l = if lrow[r] == 0.0 { 1.0 } else { lrow[r] };
                let ot = &mut o[(r0 - row0 + r) * d..(r0 - row0 + r + 1) * d];
                let arow = &acc[r * d..(r + 1) * d];
                for c in 0..d {
                    ot[c] = arow[c] / l;
                }
                lse[r0 - row0 + r] = mrow[r] + lrow[r].max(1e-30).ln();
            }
        }
        (o, lse, workspace)
    });

    let mut o = Vec::with_capacity(n * d);
    let mut lse = Vec::with_capacity(n);
    let mut workspace = 0u64;
    for (op, lp, ws) in parts {
        o.extend_from_slice(&op);
        lse.extend_from_slice(&lp);
        workspace += ws;
    }
    (o, lse, workspace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{max_abs_diff, qkv};

    #[test]
    fn flash_matches_naive() {
        for (n, d, br, bc) in [(128, 16, 32, 32), (96, 8, 32, 16), (64, 4, 64, 64), (100, 8, 32, 48)] {
            let (q, k, v) = qkv(1, n, d);
            let (o1, l1) = naive_attention(&q, &k, &v, n, d);
            let (o2, l2, _) = flash_attention(&q, &k, &v, n, d, br, bc);
            assert!(max_abs_diff(&o1, &o2) < 2e-5, "n={n} d={d}");
            assert!(max_abs_diff(&l1, &l2) < 2e-5);
        }
    }

    /// Partitioning query tiles across workers must not change a single
    /// bit of o or lse.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let (n, d) = (101, 8); // ragged against both tile size and worker count
        let (q, k, v) = qkv(9, n, d);
        let (o1, l1, _) = flash_attention_ctx(&ExecCtx::serial(), &q, &k, &v, n, d, 32, 48);
        for threads in [2, 3, 5] {
            let ctx = ExecCtx::with_threads(threads);
            let (o2, l2, _) = flash_attention_ctx(&ctx, &q, &k, &v, n, d, 32, 48);
            assert_eq!(o1, o2, "threads={threads}");
            assert_eq!(l1, l2, "threads={threads}");
        }
    }

    #[test]
    fn first_row_is_v0() {
        let (q, k, v) = qkv(2, 16, 8);
        let (o, _) = naive_attention(&q, &k, &v, 16, 8);
        assert!(max_abs_diff(&o[..8], &v[..8]) < 1e-6);
    }

    #[test]
    fn rows_are_convex_combinations() {
        // each output must lie within [min, max] of the value column range
        let (q, k, v) = qkv(3, 64, 4);
        let (o, _) = naive_attention(&q, &k, &v, 64, 4);
        for c in 0..4 {
            let lo = v.iter().skip(c).step_by(4).fold(f32::MAX, |a, &b| a.min(b));
            let hi = v.iter().skip(c).step_by(4).fold(f32::MIN, |a, &b| a.max(b));
            for t in 0..64 {
                let x = o[t * 4 + c];
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn lse_is_finite_and_ordered_sane() {
        let (q, k, v) = qkv(4, 32, 8);
        let (_, lse) = naive_attention(&q, &k, &v, 32, 8);
        assert!(lse.iter().all(|x| x.is_finite()));
    }
}
