//! Key-block centroid computation (paper Algorithm 2): K~_j = mean of
//! block j's keys. Mirror of the Pallas kernel in
//! `python/compile/kernels/centroid.py`.
//!
//! Two entry points share the per-block arithmetic: the single-head
//! [`centroids`] (block-aligned n, the original kernel) and the packed
//! [`centroids_packed`], which computes centroids once per *KV head*
//! over a `(h_kv, n, d)` key tensor and skips a ragged tail block (the
//! tail is never a routing candidate — see `AttnShape`).
//!
//! Parallelized over (head ×) block ranges: each block's mean is an
//! independent work unit computed with the unchanged serial arithmetic,
//! so the result is bit-identical at any thread count.
//!
//! Under per-head route plans each KV head may carry its own block
//! size; the dispatcher's per-head sub-launches land here as
//! independent `h_kv = 1` calls, so differing geometries never share a
//! centroid buffer.

use crate::util::pool::ExecCtx;

/// k: (n, d) row-major -> centroids (n / block, d), on the process-wide
/// shared pool.
pub fn centroids(k: &[f32], n: usize, d: usize, block: usize) -> Vec<f32> {
    centroids_ctx(ExecCtx::global(), k, n, d, block)
}

/// [`centroids`] on an explicit execution context — the `h_kv = 1`
/// slice of [`centroids_packed`] (one mean implementation; the
/// pre-refactor single-head behavior is pinned independently by
/// `rust/tests/singlehead_regression.rs`). Unlike the packed form,
/// which silently skips a ragged tail, the single-head entry point
/// keeps its block-aligned contract and panics on ragged n.
pub fn centroids_ctx(ctx: &ExecCtx, k: &[f32], n: usize, d: usize, block: usize) -> Vec<f32> {
    assert_eq!(k.len(), n * d);
    assert!(n % block == 0, "N={n} not divisible by B={block}");
    centroids_packed(ctx, k, 1, n, d, block)
}

/// Packed multi-head centroids: k is `(h_kv, n, d)` row-major; returns
/// `(h_kv, cb, d)` where `cb = n / block` counts the *complete* blocks
/// (tail rows of a ragged sequence are excluded — the partial block is
/// never routed). Work units are flattened `(head, block)` pairs in
/// head-major order, so `h_kv = 1` with aligned n partitions exactly as
/// [`centroids_ctx`] does — bit-identical to the single-head kernel.
pub fn centroids_packed(
    ctx: &ExecCtx,
    k: &[f32],
    h_kv: usize,
    n: usize,
    d: usize,
    block: usize,
) -> Vec<f32> {
    let cb = n / block;
    let mut out = vec![0.0f32; h_kv * cb * d];
    centroids_packed_into(ctx, k, h_kv, n, d, block, &mut out);
    out
}

/// [`centroids_packed`] writing into a caller-provided `(h_kv, cb, d)`
/// buffer — the zero-allocation steady-state path (no per-range chunk
/// vectors, no concat copy; the serial path allocates nothing).
pub fn centroids_packed_into(
    ctx: &ExecCtx,
    k: &[f32],
    h_kv: usize,
    n: usize,
    d: usize,
    block: usize,
    out: &mut [f32],
) {
    assert_eq!(k.len(), h_kv * n * d);
    let cb = n / block;
    assert_eq!(out.len(), h_kv * cb * d);
    let inv = 1.0 / block as f32;
    let none: &mut [f32] = &mut [];
    ctx.pool().for_ranges_split(h_kv * cb, out, none, |u| (u * d, 0), |_, range, chunk, _| {
        for (uu, u) in range.enumerate() {
            let (head, j) = (u / cb, u % cb);
            let base = head * n + j * block;
            let dst = &mut chunk[uu * d..(uu + 1) * d];
            dst.fill(0.0);
            for r in 0..block {
                let src = &k[(base + r) * d..(base + r + 1) * d];
                for c in 0..d {
                    dst[c] += src[c];
                }
            }
            for c in dst.iter_mut() {
                *c *= inv;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn constant_blocks_are_exact() {
        let (nb, b, d) = (4, 8, 3);
        let mut k = Vec::new();
        for j in 0..nb {
            for _ in 0..b {
                for c in 0..d {
                    k.push((j * 10 + c) as f32);
                }
            }
        }
        let c = centroids(&k, nb * b, d, b);
        for j in 0..nb {
            for cc in 0..d {
                assert_eq!(c[j * d + cc], (j * 10 + cc) as f32);
            }
        }
    }

    #[test]
    fn mean_matches_direct_computation() {
        let mut rng = Rng::new(5);
        let (n, d, b) = (64, 16, 16);
        let k = rng.normal_vec(n * d);
        let c = centroids(&k, n, d, b);
        for j in 0..n / b {
            for cc in 0..d {
                let mut s = 0.0f32;
                for r in 0..b {
                    s += k[(j * b + r) * d + cc];
                }
                assert!((c[j * d + cc] - s / b as f32).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic]
    fn ragged_panics() {
        centroids(&[0.0; 30], 10, 3, 4);
    }

    /// Partitioning blocks across workers must not change a single bit.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Rng::new(6);
        let (n, d, b) = (7 * 16, 8, 16); // 7 blocks: uneven over any worker count
        let k = rng.normal_vec(n * d);
        let serial = centroids_ctx(&ExecCtx::serial(), &k, n, d, b);
        for threads in [2, 3, 5, 16] {
            let par = centroids_ctx(&ExecCtx::with_threads(threads), &k, n, d, b);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    /// Packed multi-head == per-head single-head runs, and a ragged
    /// tail contributes no centroid.
    #[test]
    fn packed_covers_heads_and_skips_ragged_tail() {
        let mut rng = Rng::new(8);
        let (h_kv, n, d, b) = (3, 2 * 8 + 5, 4, 8); // ragged: cb = 2
        let k = rng.normal_vec(h_kv * n * d);
        let ctx = ExecCtx::with_threads(2);
        let packed = centroids_packed(&ctx, &k, h_kv, n, d, b);
        let cb = n / b;
        assert_eq!(packed.len(), h_kv * cb * d);
        for head in 0..h_kv {
            let aligned = &k[head * n * d..head * n * d + cb * b * d];
            let single = centroids_ctx(&ctx, aligned, cb * b, d, b);
            assert_eq!(&packed[head * cb * d..(head + 1) * cb * d], &single[..], "head {head}");
        }
    }
}
