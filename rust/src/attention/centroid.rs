//! Key-block centroid computation (paper Algorithm 2): K~_j = mean of
//! block j's keys. Mirror of the Pallas kernel in
//! `python/compile/kernels/centroid.py`.

/// k: (n, d) row-major -> centroids (n / block, d).
pub fn centroids(k: &[f32], n: usize, d: usize, block: usize) -> Vec<f32> {
    assert_eq!(k.len(), n * d);
    assert!(n % block == 0, "N={n} not divisible by B={block}");
    let nb = n / block;
    let inv = 1.0 / block as f32;
    let mut out = vec![0.0f32; nb * d];
    for j in 0..nb {
        let dst = &mut out[j * d..(j + 1) * d];
        for r in 0..block {
            let src = &k[(j * block + r) * d..(j * block + r + 1) * d];
            for c in 0..d {
                dst[c] += src[c];
            }
        }
        for c in dst.iter_mut() {
            *c *= inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn constant_blocks_are_exact() {
        let (nb, b, d) = (4, 8, 3);
        let mut k = Vec::new();
        for j in 0..nb {
            for _ in 0..b {
                for c in 0..d {
                    k.push((j * 10 + c) as f32);
                }
            }
        }
        let c = centroids(&k, nb * b, d, b);
        for j in 0..nb {
            for cc in 0..d {
                assert_eq!(c[j * d + cc], (j * 10 + cc) as f32);
            }
        }
    }

    #[test]
    fn mean_matches_direct_computation() {
        let mut rng = Rng::new(5);
        let (n, d, b) = (64, 16, 16);
        let k = rng.normal_vec(n * d);
        let c = centroids(&k, n, d, b);
        for j in 0..n / b {
            for cc in 0..d {
                let mut s = 0.0f32;
                for r in 0..b {
                    s += k[(j * b + r) * d + cc];
                }
                assert!((c[j * d + cc] - s / b as f32).abs() < 1e-5);
            }
        }
    }

    #[test]
    #[should_panic]
    fn ragged_panics() {
        centroids(&[0.0; 30], 10, 3, 4);
    }
}
