//! The KV-cache storage precision axis: [`KvDtype`] and the quantized
//! row stores behind it.
//!
//! The small-block decode regime is memory-bandwidth-bound — bytes
//! moved ≈ wall time — and the KV cache is where the bytes live. This
//! module lets the cache layer store K/V rows at half width (`F16`,
//! `Bf16`) or quarter width (`I8` with one f32 scale per row) while
//! every kernel consumes them through borrowed [`KvView`]s that
//! dequantize register-locally inside the fused `simd` kernels — no
//! materialized f32 copy of a block ever exists, so the PR-5 zero-alloc
//! steady-state contract survives quantization untouched.
//!
//! Two rules keep the numerics auditable:
//!
//! * **Routing stays full precision.** Centroid key-sums accumulate the
//!   *pre-quantization* f32 rows (see `decode::store_row` /
//!   `paged::PageData::append_row`), so q·centroid scores — and hence
//!   the selected block indices — are bitwise identical across every
//!   `KvDtype`. Quantization perturbs attention *weights*, never the
//!   paper's SNR-driven block selection.
//! * **Dequantization is element-wise.** `dequant(q[i]) * a[i]` in the
//!   fused kernels is the same arithmetic as first expanding the row to
//!   f32 and then running the f32 kernel, in the same lane order — so a
//!   quantized cache attends bit-identically to an f32 cache holding
//!   the dequantized rows (pinned by the decode tests), and the PR-5
//!   lane-order rule restates per dtype rather than dissolving.
//!
//! Conversions are exact bit manipulation (f16→f32 is lossless; f32→f16
//! and f32→bf16 round to nearest even), deliberately avoiding hardware
//! convert intrinsics in the scalar path so every ISA's dequant agrees
//! bit-for-bit with the scalar fallback.

use super::simd;

/// Storage element type of cached K/V rows. Centroid sums, queries and
/// outputs stay f32 regardless.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KvDtype {
    /// Full precision — the legacy layout; byte-identical to the
    /// pre-dtype cache, and the default everywhere.
    #[default]
    F32,
    /// IEEE binary16: 1+5+10 bits, round-to-nearest-even on store.
    F16,
    /// bfloat16: the top 16 bits of an f32, round-to-nearest-even on
    /// store (f32 dynamic range, 8-bit mantissa).
    Bf16,
    /// Symmetric int8 with one f32 scale per stored row
    /// (`scale = max|row| / 127`); a streaming append cannot know a
    /// block's dynamic range up front, so scales are per row, not per
    /// block.
    I8,
}

impl KvDtype {
    /// Every dtype, in test/bench sweep order.
    pub const ALL: [KvDtype; 4] = [KvDtype::F32, KvDtype::F16, KvDtype::Bf16, KvDtype::I8];

    /// Bytes per stored K/V element (the I8 per-row scale is accounted
    /// separately where byte-exactness matters; as a *cost weight* one
    /// unit = one byte per element — see `paged::PagePool`).
    pub fn elem_bytes(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 | KvDtype::Bf16 => 2,
            KvDtype::I8 => 1,
        }
    }

    /// Stable lowercase name (config / plan JSON / bench labels).
    pub fn as_str(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::Bf16 => "bf16",
            KvDtype::I8 => "i8",
        }
    }

    /// Parse a config/JSON name. Case-insensitive; `None` on anything
    /// unrecognized (callers decide whether that is a default or an
    /// error).
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(KvDtype::F32),
            "f16" | "fp16" | "half" | "float16" => Some(KvDtype::F16),
            "bf16" | "bfloat16" => Some(KvDtype::Bf16),
            "i8" | "int8" => Some(KvDtype::I8),
            _ => None,
        }
    }

    /// The `MOBA_KV_DTYPE` environment override (the CI determinism
    /// matrix leg), if set and parseable.
    pub fn from_env() -> Option<KvDtype> {
        std::env::var("MOBA_KV_DTYPE").ok().and_then(|s| KvDtype::parse(&s))
    }
}

// ------------------------------------------------------------ convert

/// f16 bits -> f32. Exact: every binary16 value (normals, subnormals,
/// ±inf, NaN payloads) is representable in binary32.
#[inline]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = match (exp, man) {
        (0, 0) => sign,
        (0, mut m) => {
            // subnormal: renormalize (shift the leading 1 into place)
            let mut e = 113u32; // unbiased -14, f32-biased
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
        (31, 0) => sign | 0x7f80_0000,
        (31, m) => sign | 0x7fc0_0000 | (m << 13),
        _ => sign | ((exp + 112) << 23) | (man << 13),
    };
    f32::from_bits(bits)
}

/// f32 -> f16 bits, round to nearest, ties to even (overflow -> ±inf,
/// underflow -> ±0 through the subnormal range).
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;
    if exp == 255 {
        // inf / NaN (force a quiet payload bit so NaN survives)
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7e00 };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00;
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below half the smallest subnormal
        }
        // subnormal target: mantissa with its implicit 1, shifted out
        let m = man | 0x80_0000;
        let shift = (14 - e) as u32;
        let h = (m >> shift) as u16;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        return sign
            | if rem > halfway || (rem == halfway && h & 1 == 1) { h + 1 } else { h };
    }
    let h = ((e as u32) << 10) as u16 | (man >> 13) as u16;
    let rem = man & 0x1fff;
    // a mantissa carry rolls into the exponent field correctly (and
    // 0x7bff + 1 = 0x7c00 = inf, the right saturation)
    sign | if rem > 0x1000 || (rem == 0x1000 && h & 1 == 1) { h + 1 } else { h }
}

/// bf16 bits -> f32. Exact by construction (bf16 is the top half of an
/// f32).
#[inline]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 -> bf16 bits, round to nearest, ties to even.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep sign + a quiet payload; plain truncation could round a
        // NaN to inf
        return ((bits >> 16) as u16) | 0x0040;
    }
    let h = (bits >> 16) as u16;
    let rem = bits & 0xffff;
    if rem > 0x8000 || (rem == 0x8000 && h & 1 == 1) {
        h.wrapping_add(1)
    } else {
        h
    }
}

/// What one f32 value stores back as under `dtype` — the reference
/// round-trip the error-bound and bitwise-oracle tests are written
/// against. For `I8` the *row maximum magnitude* must be supplied
/// (quantization is per row, not per element); the inverse scale is
/// recomputed exactly as the append path computes it, so the round-trip
/// is bit-identical to storage.
#[inline]
pub fn quantize_roundtrip(dtype: KvDtype, x: f32, i8_amax: f32) -> f32 {
    match dtype {
        KvDtype::F32 => x,
        KvDtype::F16 => f16_to_f32(f32_to_f16(x)),
        KvDtype::Bf16 => bf16_to_f32(f32_to_bf16(x)),
        KvDtype::I8 => {
            // mirrors the append path's non-finite saturation: Inf
            // clips to ±127 steps, NaN stores 0 (and `i8_amax` is the
            // row's max FINITE magnitude, matching storage)
            if i8_amax == 0.0 {
                0.0
            } else if x.is_finite() {
                ((x * (127.0 / i8_amax)).round() as i8) as f32 * (i8_amax / 127.0)
            } else if x == f32::INFINITY {
                127.0 * (i8_amax / 127.0)
            } else if x == f32::NEG_INFINITY {
                -127.0 * (i8_amax / 127.0)
            } else {
                0.0
            }
        }
    }
}

// ------------------------------------------------------------- stores

/// A growable store of quantized rows — the K (or V) side of one
/// contiguous block slab or one page. Appends quantize; reads go
/// through borrowed [`KvView`]s. Capacity reserved up front via
/// [`KvBuf::with_capacity_rows`] keeps steady-state appends
/// allocation-free (the zero-alloc contract).
#[derive(Debug, Clone)]
pub enum KvBuf {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Bf16(Vec<u16>),
    I8 {
        q: Vec<i8>,
        /// one scale per stored row, `max|row| / 127`
        scales: Vec<f32>,
    },
}

impl KvBuf {
    pub fn new(dtype: KvDtype) -> Self {
        match dtype {
            KvDtype::F32 => KvBuf::F32(Vec::new()),
            KvDtype::F16 => KvBuf::F16(Vec::new()),
            KvDtype::Bf16 => KvBuf::Bf16(Vec::new()),
            KvDtype::I8 => KvBuf::I8 { q: Vec::new(), scales: Vec::new() },
        }
    }

    /// An empty store with room for `rows` d-length rows (and their
    /// scales), so appends up to that capacity never reallocate.
    pub fn with_capacity_rows(dtype: KvDtype, rows: usize, d: usize) -> Self {
        match dtype {
            KvDtype::F32 => KvBuf::F32(Vec::with_capacity(rows * d)),
            KvDtype::F16 => KvBuf::F16(Vec::with_capacity(rows * d)),
            KvDtype::Bf16 => KvBuf::Bf16(Vec::with_capacity(rows * d)),
            KvDtype::I8 => KvBuf::I8 {
                q: Vec::with_capacity(rows * d),
                scales: Vec::with_capacity(rows),
            },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            KvBuf::F32(_) => KvDtype::F32,
            KvBuf::F16(_) => KvDtype::F16,
            KvBuf::Bf16(_) => KvDtype::Bf16,
            KvBuf::I8 { .. } => KvDtype::I8,
        }
    }

    /// Stored rows (element count / `d`).
    pub fn rows(&self, d: usize) -> usize {
        match self {
            KvBuf::F32(b) => b.len() / d,
            KvBuf::F16(b) | KvBuf::Bf16(b) => b.len() / d,
            KvBuf::I8 { scales, .. } => scales.len(),
        }
    }

    /// Drop all stored rows, keeping the dtype and capacity (the
    /// eviction path — a replay of the same appends rebuilds the store
    /// bit for bit).
    pub fn clear(&mut self) {
        match self {
            KvBuf::F32(b) => b.clear(),
            KvBuf::F16(b) | KvBuf::Bf16(b) => b.clear(),
            KvBuf::I8 { q, scales } => {
                q.clear();
                scales.clear();
            }
        }
    }

    /// Grow capacity to hold `rows` additional rows beyond the current
    /// length (used by the contiguous slab open-block path).
    pub fn reserve_rows(&mut self, rows: usize, d: usize) {
        match self {
            KvBuf::F32(b) => b.reserve(rows * d),
            KvBuf::F16(b) | KvBuf::Bf16(b) => b.reserve(rows * d),
            KvBuf::I8 { q, scales } => {
                q.reserve(rows * d);
                scales.reserve(rows);
            }
        }
    }

    /// Quantize-and-append one f32 row. Within reserved capacity this
    /// allocates nothing.
    pub fn append_row(&mut self, row: &[f32]) {
        match self {
            KvBuf::F32(b) => b.extend_from_slice(row),
            KvBuf::F16(b) => b.extend(row.iter().map(|&x| f32_to_f16(x))),
            KvBuf::Bf16(b) => b.extend(row.iter().map(|&x| f32_to_bf16(x))),
            KvBuf::I8 { q, scales } => {
                // the scale comes from FINITE magnitudes only: an Inf
                // element would otherwise drive `amax = Inf`, storing
                // `scale = Inf` and dequantizing the whole row to
                // NaN/Inf. Non-finite elements saturate to the clip
                // range instead (Inf -> ±127 steps, NaN -> 0) — the
                // serving stack rejects such rows up front, so this is
                // defense in depth for direct cache users.
                let mut amax = 0.0f32;
                for &x in row.iter() {
                    let a = x.abs();
                    if a.is_finite() {
                        amax = amax.max(a);
                    }
                }
                if amax == 0.0 {
                    // all-zero or all-non-finite: NaN stores 0, ±Inf
                    // saturates to ±127 of a zero scale (still 0.0 on
                    // dequant — nothing finite to scale against)
                    q.extend(row.iter().map(|&x| {
                        if x == f32::INFINITY {
                            127i8
                        } else if x == f32::NEG_INFINITY {
                            -127i8
                        } else {
                            0i8
                        }
                    }));
                    scales.push(0.0);
                } else {
                    let inv = 127.0 / amax;
                    q.extend(row.iter().map(|&x| {
                        if x.is_finite() {
                            (x * inv).round() as i8
                        } else if x == f32::INFINITY {
                            127
                        } else if x == f32::NEG_INFINITY {
                            -127
                        } else {
                            0 // NaN
                        }
                    }));
                    scales.push(amax / 127.0);
                }
            }
        }
    }

    /// Borrow rows `r0..r1` (row width `d`) as a [`KvView`].
    pub fn view_rows(&self, r0: usize, r1: usize, d: usize) -> KvView<'_> {
        let (a, b) = (r0 * d, r1 * d);
        match self {
            KvBuf::F32(buf) => KvView::F32(&buf[a..b]),
            KvBuf::F16(buf) => KvView::F16(&buf[a..b]),
            KvBuf::Bf16(buf) => KvView::Bf16(&buf[a..b]),
            KvBuf::I8 { q, scales } => KvView::I8 { q: &q[a..b], scales: &scales[r0..r1] },
        }
    }

    /// The raw f32 slab — only meaningful for `F32` stores (the legacy
    /// accessors that promise `&[f32]` keep working on f32 caches;
    /// quantized rows have no f32 slab to hand out).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            KvBuf::F32(b) => b,
            other => panic!(
                "as_f32 on a {} store: quantized rows must be read through KvView",
                other.dtype().as_str()
            ),
        }
    }

    /// A copy with capacity for `cap_rows` rows — the page CoW split
    /// (capacity-preserving so the copy keeps appending without
    /// reallocating).
    pub fn split_copy(&self, cap_rows: usize, d: usize) -> KvBuf {
        let mut out = KvBuf::with_capacity_rows(self.dtype(), cap_rows, d);
        match (&mut out, self) {
            (KvBuf::F32(dst), KvBuf::F32(src)) => dst.extend_from_slice(src),
            (KvBuf::F16(dst), KvBuf::F16(src)) => dst.extend_from_slice(src),
            (KvBuf::Bf16(dst), KvBuf::Bf16(src)) => dst.extend_from_slice(src),
            (KvBuf::I8 { q: dq, scales: ds }, KvBuf::I8 { q: sq, scales: ss }) => {
                dq.extend_from_slice(sq);
                ds.extend_from_slice(ss);
            }
            _ => unreachable!("split_copy preserves dtype"),
        }
        out
    }
}

/// A borrowed, possibly-quantized span of rows. The kernels consume
/// this instead of `&[f32]`: each accessor dispatches to the fused
/// dequantizing `simd` kernel for its dtype, so dequantization happens
/// in registers inside the reduction — never into a buffer.
#[derive(Debug, Clone, Copy)]
pub enum KvView<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
    Bf16(&'a [u16]),
    I8 { q: &'a [i8], scales: &'a [f32] },
}

impl<'a> KvView<'a> {
    pub fn dtype(&self) -> KvDtype {
        match self {
            KvView::F32(_) => KvDtype::F32,
            KvView::F16(_) => KvDtype::F16,
            KvView::Bf16(_) => KvDtype::Bf16,
            KvView::I8 { .. } => KvDtype::I8,
        }
    }

    /// Rows in the view at row width `d`.
    pub fn rows(&self, d: usize) -> usize {
        match self {
            KvView::F32(b) => b.len() / d,
            KvView::F16(b) | KvView::Bf16(b) => b.len() / d,
            KvView::I8 { scales, .. } => scales.len(),
        }
    }

    /// q · dequant(row r): the fused dequantizing dot, in the exact
    /// lane order of `simd::dot` per the dtype-aware lane-order rule.
    #[inline]
    pub fn dot_row(&self, q: &[f32], r: usize, d: usize) -> f32 {
        match *self {
            KvView::F32(k) => simd::dot(q, &k[r * d..(r + 1) * d]),
            KvView::F16(k) => simd::dequant_dot_f16(q, &k[r * d..(r + 1) * d]),
            KvView::Bf16(k) => simd::dequant_dot_bf16(q, &k[r * d..(r + 1) * d]),
            KvView::I8 { q: kq, scales } => {
                simd::dequant_dot_i8(q, &kq[r * d..(r + 1) * d], scales[r])
            }
        }
    }

    /// y += a * dequant(row r): the fused dequantizing axpy, lane order
    /// of `simd::axpy`.
    #[inline]
    pub fn axpy_row(&self, y: &mut [f32], a: f32, r: usize, d: usize) {
        match *self {
            KvView::F32(v) => simd::axpy(y, a, &v[r * d..(r + 1) * d]),
            KvView::F16(v) => simd::dequant_axpy_f16(y, a, &v[r * d..(r + 1) * d]),
            KvView::Bf16(v) => simd::dequant_axpy_bf16(y, a, &v[r * d..(r + 1) * d]),
            KvView::I8 { q: vq, scales } => {
                simd::dequant_axpy_i8(y, a, &vq[r * d..(r + 1) * d], scales[r])
            }
        }
    }

    /// Materialize the dequantized f32 rows (tests and diagnostics
    /// only — the hot paths never do this; that is the whole point).
    pub fn dequant_to_vec(&self, d: usize) -> Vec<f32> {
        let rows = self.rows(d);
        let mut out = Vec::with_capacity(rows * d);
        for r in 0..rows {
            match *self {
                KvView::F32(b) => out.extend_from_slice(&b[r * d..(r + 1) * d]),
                KvView::F16(b) => {
                    out.extend(b[r * d..(r + 1) * d].iter().map(|&h| f16_to_f32(h)))
                }
                KvView::Bf16(b) => {
                    out.extend(b[r * d..(r + 1) * d].iter().map(|&h| bf16_to_f32(h)))
                }
                KvView::I8 { q, scales } => out
                    .extend(q[r * d..(r + 1) * d].iter().map(|&v| v as f32 * scales[r])),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn dtype_names_round_trip() {
        for dt in KvDtype::ALL {
            assert_eq!(KvDtype::parse(dt.as_str()), Some(dt));
        }
        assert_eq!(KvDtype::parse("FP16"), Some(KvDtype::F16));
        assert_eq!(KvDtype::parse("bogus"), None);
        assert_eq!(KvDtype::default(), KvDtype::F32);
        assert_eq!(KvDtype::F32.elem_bytes(), 4);
        assert_eq!(KvDtype::F16.elem_bytes(), 2);
        assert_eq!(KvDtype::Bf16.elem_bytes(), 2);
        assert_eq!(KvDtype::I8.elem_bytes(), 1);
    }

    /// f16 -> f32 -> f16 is the identity on every one of the 65536 bit
    /// patterns (NaNs compare by payload class: still NaN).
    #[test]
    fn f16_f32_f16_is_identity() {
        for h in 0u16..=u16::MAX {
            let x = f16_to_f32(h);
            if x.is_nan() {
                assert!(f16_to_f32(f32_to_f16(x)).is_nan(), "h={h:#06x}");
            } else {
                assert_eq!(f32_to_f16(x), h, "h={h:#06x} x={x}");
            }
        }
    }

    /// bf16 -> f32 -> bf16 identity over all non-NaN patterns.
    #[test]
    fn bf16_f32_bf16_is_identity() {
        for h in 0u16..=u16::MAX {
            let x = bf16_to_f32(h);
            if x.is_nan() {
                assert!(bf16_to_f32(f32_to_bf16(x)).is_nan(), "h={h:#06x}");
            } else {
                assert_eq!(f32_to_bf16(x), h, "h={h:#06x} x={x}");
            }
        }
    }

    /// Round-to-nearest-even at the halfway points: 1 + 2^-11 is exactly
    /// between 1.0 and the next f16 (1 + 2^-10) — it must round to the
    /// even mantissa (1.0); 1 + 3*2^-11 rounds up to 1 + 2*2^-10.
    #[test]
    fn f16_rounds_ties_to_even() {
        assert_eq!(f32_to_f16(1.0 + 2.0f32.powi(-11)), f32_to_f16(1.0));
        assert_eq!(
            f32_to_f16(1.0 + 3.0 * 2.0f32.powi(-11)),
            f32_to_f16(1.0 + 2.0 * 2.0f32.powi(-10))
        );
        // overflow saturates to inf, tiny values flush through subnormals to 0
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(-1e6)), f32::NEG_INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(1e-10)), 0.0);
        // subnormal range survives: 2^-24 is the smallest f16 subnormal
        assert_eq!(f16_to_f32(f32_to_f16(2.0f32.powi(-24))), 2.0f32.powi(-24));
    }

    /// f16 relative error on normals is bounded by 2^-11 (half ulp).
    #[test]
    fn f16_relative_error_bound() {
        let mut rng = Rng::new(42);
        for _ in 0..10_000 {
            let x = rng.normal() as f32 * 3.0;
            let r = f16_to_f32(f32_to_f16(x));
            assert!(
                (r - x).abs() <= x.abs() * 2.0f32.powi(-11) + f32::EPSILON,
                "x={x} r={r}"
            );
        }
    }

    /// I8 rows: round-trip error per element is bounded by half a
    /// quantization step (scale / 2), and an all-zero row stays zero.
    #[test]
    fn i8_row_quantization_error_bound() {
        let mut rng = Rng::new(7);
        for d in [1usize, 3, 8, 16, 64] {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let mut buf = KvBuf::new(KvDtype::I8);
            buf.append_row(&row);
            let back = buf.view_rows(0, 1, d).dequant_to_vec(d);
            let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let step = amax / 127.0;
            for (a, b) in row.iter().zip(&back) {
                assert!((a - b).abs() <= step * 0.5 + 1e-7, "{a} vs {b} (step {step})");
            }
        }
        let mut z = KvBuf::new(KvDtype::I8);
        z.append_row(&[0.0; 4]);
        assert_eq!(z.view_rows(0, 1, 4).dequant_to_vec(4), vec![0.0; 4]);
    }

    /// Non-finite rows must never poison the i8 scale: an Inf element
    /// used to drive `amax = Inf` (storing `scale = Inf`, dequantizing
    /// the whole row to NaN), and a NaN slipped through `f32::max` as
    /// if absent. Now the scale comes from finite magnitudes only and
    /// non-finite elements saturate: Inf -> +clip, -Inf -> -clip,
    /// NaN -> 0 — every dequantized value stays finite.
    #[test]
    fn i8_non_finite_rows_saturate_instead_of_nan_scales() {
        // mixed row: finite values set the scale, Inf/NaN saturate
        let row = [1.0f32, f32::INFINITY, -2.0, f32::NAN, f32::NEG_INFINITY, 0.5];
        let d = row.len();
        let mut buf = KvBuf::new(KvDtype::I8);
        buf.append_row(&row);
        let back = buf.view_rows(0, 1, d).dequant_to_vec(d);
        assert!(back.iter().all(|x| x.is_finite()), "non-finite dequant: {back:?}");
        let scale = 2.0 / 127.0; // amax over finite elements = 2.0
        assert!((back[0] - 1.0).abs() <= scale, "{back:?}");
        assert_eq!(back[1], 127.0 * scale); // +Inf clips to +amax
        assert!((back[2] + 2.0).abs() <= scale);
        assert_eq!(back[3], 0.0); // NaN stores 0
        assert_eq!(back[4], -127.0 * scale); // -Inf clips to -amax
        // the roundtrip reference mirrors storage bit-for-bit
        for (c, &x) in row.iter().enumerate() {
            assert_eq!(
                back[c].to_bits(),
                quantize_roundtrip(KvDtype::I8, x, 2.0).to_bits(),
                "c={c}"
            );
        }
        // an all-non-finite row stores a zero scale, not Inf/NaN
        let mut buf = KvBuf::new(KvDtype::I8);
        buf.append_row(&[f32::INFINITY, f32::NAN, f32::NEG_INFINITY]);
        let back = buf.view_rows(0, 1, 3).dequant_to_vec(3);
        assert_eq!(back, vec![0.0; 3], "zero scale dequantizes to zero");
    }

    /// Append/view bookkeeping across all dtypes: row counts, reserved
    /// capacity, split_copy equality and capacity preservation.
    #[test]
    fn kvbuf_rows_views_and_split_copy() {
        let mut rng = Rng::new(9);
        let d = 8;
        for dt in KvDtype::ALL {
            let mut buf = KvBuf::with_capacity_rows(dt, 16, d);
            assert_eq!(buf.dtype(), dt);
            let mut rows = Vec::new();
            for _ in 0..5 {
                let row = rng.normal_vec(d);
                buf.append_row(&row);
                rows.push(row);
            }
            assert_eq!(buf.rows(d), 5);
            let full = buf.view_rows(0, 5, d);
            assert_eq!(full.rows(d), 5);
            let deq = full.dequant_to_vec(d);
            // a sub-view dequantizes to the matching slice of the full view
            let sub = buf.view_rows(2, 4, d).dequant_to_vec(d);
            assert_eq!(&deq[2 * d..4 * d], &sub[..]);
            // split_copy: same contents, requested capacity
            let copy = buf.split_copy(16, d);
            assert_eq!(copy.rows(d), 5);
            assert_eq!(copy.view_rows(0, 5, d).dequant_to_vec(d), deq);
            // round-trip agrees with the scalar reference per element
            for (r, row) in rows.iter().enumerate() {
                let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for (c, &x) in row.iter().enumerate() {
                    assert_eq!(
                        deq[r * d + c].to_bits(),
                        quantize_roundtrip(dt, x, amax).to_bits(),
                        "{dt:?} r={r} c={c}"
                    );
                }
            }
        }
    }

    /// F32 stores are byte-transparent: what goes in comes out bitwise
    /// through both the view and the legacy `as_f32` slab.
    #[test]
    fn f32_store_is_transparent() {
        let mut rng = Rng::new(11);
        let d = 6;
        let mut buf = KvBuf::new(KvDtype::F32);
        let rows: Vec<f32> = rng.normal_vec(3 * d);
        for r in 0..3 {
            buf.append_row(&rows[r * d..(r + 1) * d]);
        }
        assert_eq!(buf.as_f32(), &rows[..]);
        assert_eq!(buf.view_rows(0, 3, d).dequant_to_vec(d), rows);
    }

    #[test]
    #[should_panic]
    fn as_f32_panics_on_quantized_store() {
        let mut buf = KvBuf::new(KvDtype::F16);
        buf.append_row(&[1.0, 2.0]);
        let _ = buf.as_f32();
    }
}
