//! Register-blocked GEMM microkernels for the small-block hot path.
//!
//! [`super::simd::dot`] computes one score at a time: every `s[r][c]`
//! of a tile reloads the same query and key rows from cache, so the
//! kernels are load-bound (1 FMA per 2 vector loads). The microkernels
//! here compute an RxC **micro-tile** of `s = Q·Kᵀ` per pass — R query
//! rows against C key rows held live across the shared 8-lane k-loop —
//! raising the FMA-to-load ratio (8 FMAs per 6 loads at 2x4) exactly
//! where the paper says small blocks go memory-bound (FlashMoBA, §4).
//!
//! **The lane-order rule (bit-determinism contract).** Every output
//! element is reduced in the *exact* f32 operation order of
//! `simd::dot`: 8 independent accumulator lanes over ascending 8-wide
//! chunks, a scalar remainder accumulated in ascending index order,
//! then the fixed reduction tree `(l0+l4)+(l1+l5)+(l2+l6)+(l3+l7)+rest`
//! and one optional trailing `* scale`. Register blocking only changes
//! *which* outputs share a pass over the k-dimension — never the
//! per-output operation sequence — so the microkernel results are
//! `to_bits`-identical to the scalar path they replaced (pinned by the
//! unit tests below and by `prop_microkernels_bit_identical_to_scalar_
//! oracle` in `rust/tests/property.rs`).
//!
//! The same rule governs the fused accumulator updates:
//! [`softmax_accum`] / [`accum_rows`] interchange the (row, element)
//! loops so the accumulator is loaded once per 8-lane chunk instead of
//! once per value row, but each accumulator *element* still sees its
//! multiply-adds in ascending value-row order — element-wise the
//! identical f32 sequence as the `scale` + per-row `axpy` formulation.

//! **Dtype-specialized variants.** Each kernel has a `_view` twin
//! taking a [`super::dtype::KvView`] instead of an `&[f32]` K/V
//! operand. `KvView::F32` delegates to the f32 kernel unchanged (bit
//! transparency for the legacy store); quantized views (f16 / bf16 /
//! int8-with-scale) go through the fused `simd::dequant_*` kernels,
//! which widen each element in registers inside the reduction — no
//! f32 copy of a row or block is ever materialized, preserving the
//! zero-alloc contract. Because every fused dequant kernel is
//! bit-identical to "expand the row to f32, then run the f32 kernel"
//! (pinned in `simd.rs` tests), a quantized `_view` call equals the
//! f32 kernel on the dequantized store, bit for bit — that identity is
//! what makes per-dtype determinism inherit from the lane-order rule.

use super::dtype::KvView;
use super::simd::dot;

const LANES: usize = 8;

/// Raw RxC micro-tile: `out[r][c] = dot(q_row_r, k_row_c)`, every
/// element reduced in `simd::dot`'s exact lane order. `q` holds R rows
/// and `k` C rows, both row-major with stride `d`.
#[inline(always)]
fn micro_rc<const R: usize, const C: usize>(q: &[f32], k: &[f32], d: usize) -> [[f32; C]; R] {
    debug_assert!(q.len() >= R * d && k.len() >= C * d);
    let mut lanes = [[[0.0f32; LANES]; C]; R];
    let chunks = d / LANES;
    for i in 0..chunks {
        let base = i * LANES;
        for r in 0..R {
            let a = &q[r * d + base..r * d + base + LANES];
            for (c, lc) in lanes[r].iter_mut().enumerate() {
                let b = &k[c * d + base..c * d + base + LANES];
                for l in 0..LANES {
                    lc[l] += a[l] * b[l];
                }
            }
        }
    }
    let mut rest = [[0.0f32; C]; R];
    for i in chunks * LANES..d {
        for r in 0..R {
            let a = q[r * d + i];
            for (c, rc) in rest[r].iter_mut().enumerate() {
                *rc += a * k[c * d + i];
            }
        }
    }
    let mut out = [[0.0f32; C]; R];
    for r in 0..R {
        for c in 0..C {
            let l = &lanes[r][c];
            out[r][c] = (l[0] + l[4]) + (l[1] + l[5]) + (l[2] + l[6])
                + (l[3] + l[7])
                + rest[r][c];
        }
    }
    out
}

/// Score tile `s[r * s_stride + c] = dot(q_row_r, k_row_c) * scale`
/// for `r in 0..rows`, `c in 0..cols` — 2x4 register micro-tiles with
/// `dot`-kernel edges, so every element is bit-identical to the
/// per-(row, col) `dot(..) * scale` it replaces.
///
/// `q` holds `rows` rows and `k` holds `cols` rows, row-major with
/// stride `d`; `s` must fit `(rows - 1) * s_stride + cols` elements.
#[allow(clippy::too_many_arguments)]
pub fn qkt_tile(
    q: &[f32],
    k: &[f32],
    d: usize,
    rows: usize,
    cols: usize,
    scale: f32,
    s: &mut [f32],
    s_stride: usize,
) {
    debug_assert!(q.len() >= rows * d);
    debug_assert!(k.len() >= cols * d);
    debug_assert!(rows == 0 || s.len() >= (rows - 1) * s_stride + cols);
    const R: usize = 2;
    const C: usize = 4;
    let mut r = 0;
    while r + R <= rows {
        let qr = &q[r * d..];
        let mut c = 0;
        while c + C <= cols {
            let out = micro_rc::<R, C>(qr, &k[c * d..], d);
            for (rr, orow) in out.iter().enumerate() {
                let srow = &mut s[(r + rr) * s_stride + c..(r + rr) * s_stride + c + C];
                for (cc, &val) in orow.iter().enumerate() {
                    srow[cc] = val * scale;
                }
            }
            c += C;
        }
        while c < cols {
            for rr in 0..R {
                s[(r + rr) * s_stride + c] =
                    dot(&q[(r + rr) * d..(r + rr + 1) * d], &k[c * d..(c + 1) * d]) * scale;
            }
            c += 1;
        }
        r += R;
    }
    while r < rows {
        let srow = &mut s[r * s_stride..r * s_stride + cols];
        qk_row(&q[r * d..(r + 1) * d], k, d, cols, scale, srow);
        r += 1;
    }
}

/// One query row against `cols` key rows: `s[c] = dot(q, k_row_c) *
/// scale` — the 1x4 register-blocked gemv (single-row decode, dense
/// tile edges).
pub fn qk_row(q: &[f32], k: &[f32], d: usize, cols: usize, scale: f32, s: &mut [f32]) {
    debug_assert!(q.len() >= d);
    debug_assert!(k.len() >= cols * d);
    debug_assert!(s.len() >= cols);
    const C: usize = 4;
    let mut c = 0;
    while c + C <= cols {
        let out = micro_rc::<1, C>(q, &k[c * d..], d);
        for (cc, &val) in out[0].iter().enumerate() {
            s[c + cc] = val * scale;
        }
        c += C;
    }
    while c < cols {
        s[c] = dot(q, &k[c * d..(c + 1) * d]) * scale;
        c += 1;
    }
}

/// [`qk_row`] without the trailing scale multiply: `s[c] = dot(q,
/// k_row_c)` exactly (the routing/top-k scoring form — gating scores
/// are raw dots, and `x * 1.0` is not guaranteed bit-transparent for
/// every NaN payload, so the raw form is its own kernel).
pub fn qk_row_raw(q: &[f32], k: &[f32], d: usize, cols: usize, s: &mut [f32]) {
    debug_assert!(q.len() >= d);
    debug_assert!(k.len() >= cols * d);
    debug_assert!(s.len() >= cols);
    const C: usize = 4;
    let mut c = 0;
    while c + C <= cols {
        let out = micro_rc::<1, C>(q, &k[c * d..], d);
        s[c..c + C].copy_from_slice(&out[0]);
        c += C;
    }
    while c < cols {
        s[c] = dot(q, &k[c * d..(c + 1) * d]);
        c += 1;
    }
}

/// Fused online-softmax accumulator update for one query row:
/// `acc *= corr` (skipped when `corr == 1.0`), then `acc += p[c] *
/// v_row_c` for every `c` with `p[c] != 0.0`, in ascending `c`.
/// `v` is `(p.len(), acc.len())` row-major.
///
/// Loop-interchanged so `acc` is loaded/stored once per 8-lane chunk
/// instead of once per value row; element-wise the operation sequence
/// is identical to `scale(acc, corr)` followed by per-row `axpy` with
/// the `p == 0.0` skip — the exact arithmetic (including the skip,
/// which matters for `-0.0` accumulators) of the kernels it replaces.
pub fn softmax_accum(acc: &mut [f32], corr: f32, p: &[f32], v: &[f32]) {
    let d = acc.len();
    debug_assert!(v.len() >= p.len() * d);
    let chunks = d / LANES;
    for ch in 0..chunks {
        let base = ch * LANES;
        let a = &mut acc[base..base + LANES];
        if corr != 1.0 {
            for x in a.iter_mut() {
                *x *= corr;
            }
        }
        for (c, &pc) in p.iter().enumerate() {
            if pc == 0.0 {
                continue;
            }
            let vb = &v[c * d + base..c * d + base + LANES];
            for l in 0..LANES {
                a[l] += pc * vb[l];
            }
        }
    }
    for i in chunks * LANES..d {
        let mut x = acc[i];
        if corr != 1.0 {
            x *= corr;
        }
        for (c, &pc) in p.iter().enumerate() {
            if pc == 0.0 {
                continue;
            }
            x += pc * v[c * d + i];
        }
        acc[i] = x;
    }
}

/// Fused multi-row weighted accumulate *without* the zero-weight skip
/// or rescale: `acc += p[c] * v_row_c` for every `c` in ascending
/// order — element-wise identical to a plain per-row `axpy` sequence
/// (the original-pipeline partial/local combines and the decode
/// single-row path, which never skip).
pub fn accum_rows(acc: &mut [f32], p: &[f32], v: &[f32]) {
    let d = acc.len();
    debug_assert!(v.len() >= p.len() * d);
    let chunks = d / LANES;
    for ch in 0..chunks {
        let base = ch * LANES;
        let a = &mut acc[base..base + LANES];
        for (c, &pc) in p.iter().enumerate() {
            let vb = &v[c * d + base..c * d + base + LANES];
            for l in 0..LANES {
                a[l] += pc * vb[l];
            }
        }
    }
    for i in chunks * LANES..d {
        let mut x = acc[i];
        for (c, &pc) in p.iter().enumerate() {
            x += pc * v[c * d + i];
        }
        acc[i] = x;
    }
}

/// [`qkt_tile`] over a dtype-erased key store: `KvView::F32` delegates
/// to the register-blocked f32 tile; quantized views compute each
/// element with the fused dequant dot (dequantization stays inside the
/// dot's register lanes), so the result equals the f32 tile on the
/// dequantized rows bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn qkt_tile_view(
    q: &[f32],
    k: &KvView<'_>,
    d: usize,
    rows: usize,
    cols: usize,
    scale: f32,
    s: &mut [f32],
    s_stride: usize,
) {
    if let KvView::F32(kf) = k {
        return qkt_tile(q, kf, d, rows, cols, scale, s, s_stride);
    }
    debug_assert!(q.len() >= rows * d);
    debug_assert!(k.rows(d) >= cols);
    for r in 0..rows {
        let qt = &q[r * d..(r + 1) * d];
        let srow = &mut s[r * s_stride..r * s_stride + cols];
        for (c, sval) in srow.iter_mut().enumerate() {
            *sval = k.dot_row(qt, c, d) * scale;
        }
    }
}

/// [`qk_row`] over a dtype-erased key store (single-row decode form).
pub fn qk_row_view(q: &[f32], k: &KvView<'_>, d: usize, cols: usize, scale: f32, s: &mut [f32]) {
    if let KvView::F32(kf) = k {
        return qk_row(q, kf, d, cols, scale, s);
    }
    debug_assert!(k.rows(d) >= cols);
    debug_assert!(s.len() >= cols);
    for (c, sval) in s.iter_mut().enumerate().take(cols) {
        *sval = k.dot_row(q, c, d) * scale;
    }
}

/// [`qk_row_raw`] over a dtype-erased key store — raw dots, no trailing
/// scale (the routing/top-k form). Routing normally scores f32
/// centroids, so this only runs when a caller scores quantized keys
/// directly.
pub fn qk_row_raw_view(q: &[f32], k: &KvView<'_>, d: usize, cols: usize, s: &mut [f32]) {
    if let KvView::F32(kf) = k {
        return qk_row_raw(q, kf, d, cols, s);
    }
    debug_assert!(k.rows(d) >= cols);
    debug_assert!(s.len() >= cols);
    for (c, sval) in s.iter_mut().enumerate().take(cols) {
        *sval = k.dot_row(q, c, d);
    }
}

/// [`softmax_accum`] over a dtype-erased value store. Quantized views
/// apply `corr` once then the per-row dequant axpy sequence with the
/// `p == 0.0` skip — element-wise the identical f32 operation order as
/// the fused f32 kernel on the dequantized rows.
pub fn softmax_accum_view(acc: &mut [f32], corr: f32, p: &[f32], v: &KvView<'_>) {
    if let KvView::F32(vf) = v {
        return softmax_accum(acc, corr, p, vf);
    }
    let d = acc.len();
    debug_assert!(v.rows(d) >= p.len());
    if corr != 1.0 {
        super::simd::scale(acc, corr);
    }
    for (c, &pc) in p.iter().enumerate() {
        if pc == 0.0 {
            continue;
        }
        v.axpy_row(acc, pc, c, d);
    }
}

/// [`accum_rows`] over a dtype-erased value store: the skip-free
/// ascending axpy sequence (decode single-row semantics), dequantizing
/// per row in registers.
pub fn accum_rows_view(acc: &mut [f32], p: &[f32], v: &KvView<'_>) {
    if let KvView::F32(vf) = v {
        return accum_rows(acc, p, vf);
    }
    let d = acc.len();
    debug_assert!(v.rows(d) >= p.len());
    for (c, &pc) in p.iter().enumerate() {
        v.axpy_row(acc, pc, c, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dtype::KvBuf;
    use crate::attention::simd::{axpy, scale as vscale};
    use crate::attention::testutil::Rng;
    use crate::attention::KvDtype;

    fn bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} ({x} vs {y})");
        }
    }

    /// The tile kernel is bit-identical to per-(row, col) dot * scale
    /// at every (rows, cols, d) combination crossing the 2x4 micro and
    /// 8-lane boundaries, including strided output rows.
    #[test]
    fn qkt_tile_bits_match_dot() {
        let mut rng = Rng::new(1);
        for d in [1, 3, 7, 8, 9, 16, 24, 33] {
            for rows in [1, 2, 3, 4, 5, 8] {
                for cols in [1, 2, 3, 4, 5, 7, 8, 9] {
                    let q = rng.normal_vec(rows * d);
                    let k = rng.normal_vec(cols * d);
                    let stride = cols + 3;
                    let mut s = vec![0.0f32; rows * stride];
                    qkt_tile(&q, &k, d, rows, cols, 0.37, &mut s, stride);
                    for r in 0..rows {
                        for c in 0..cols {
                            let expect =
                                dot(&q[r * d..(r + 1) * d], &k[c * d..(c + 1) * d]) * 0.37;
                            assert_eq!(
                                s[r * stride + c].to_bits(),
                                expect.to_bits(),
                                "d={d} rows={rows} cols={cols} r={r} c={c}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn qk_row_bits_match_dot_scaled_and_raw() {
        let mut rng = Rng::new(2);
        for d in [1, 4, 8, 13, 32] {
            for cols in [0, 1, 3, 4, 5, 8, 11] {
                let q = rng.normal_vec(d);
                let k = rng.normal_vec(cols * d);
                let mut s = vec![0.0f32; cols];
                qk_row(&q, &k, d, cols, 1.7, &mut s);
                let expect: Vec<f32> =
                    (0..cols).map(|c| dot(&q, &k[c * d..(c + 1) * d]) * 1.7).collect();
                bits_eq(&s, &expect, &format!("qk_row d={d} cols={cols}"));
                qk_row_raw(&q, &k, d, cols, &mut s);
                let expect: Vec<f32> =
                    (0..cols).map(|c| dot(&q, &k[c * d..(c + 1) * d])).collect();
                bits_eq(&s, &expect, &format!("qk_row_raw d={d} cols={cols}"));
            }
        }
    }

    /// The fused update == scale() then per-row axpy() with the zero
    /// skip, bit for bit — including corr == 1.0 (no rescale) and
    /// p rows that are exactly zero.
    #[test]
    fn softmax_accum_bits_match_scale_plus_axpy() {
        let mut rng = Rng::new(3);
        for d in [1, 5, 8, 9, 16, 24] {
            for cols in [1, 2, 4, 7] {
                for corr in [1.0f32, 0.625] {
                    let v = rng.normal_vec(cols * d);
                    let mut p = rng.normal_vec(cols);
                    p[cols / 2] = 0.0; // exercise the skip
                    let acc0 = rng.normal_vec(d);
                    let mut fused = acc0.clone();
                    softmax_accum(&mut fused, corr, &p, &v);
                    let mut plain = acc0.clone();
                    if corr != 1.0 {
                        vscale(&mut plain, corr);
                    }
                    for (c, &pc) in p.iter().enumerate() {
                        if pc == 0.0 {
                            continue;
                        }
                        axpy(&mut plain, pc, &v[c * d..(c + 1) * d]);
                    }
                    bits_eq(&fused, &plain, &format!("softmax_accum d={d} cols={cols}"));
                }
            }
        }
    }

    /// accum_rows == the skip-free axpy sequence, bit for bit (zero
    /// weights are applied, not skipped — the decode/original-pipeline
    /// semantics).
    #[test]
    fn accum_rows_bits_match_axpy_sequence() {
        let mut rng = Rng::new(4);
        for d in [1, 8, 11, 16] {
            for cols in [1, 3, 6] {
                let v = rng.normal_vec(cols * d);
                let mut p = rng.normal_vec(cols);
                p[0] = 0.0; // applied, not skipped
                let acc0 = rng.normal_vec(d);
                let mut fused = acc0.clone();
                accum_rows(&mut fused, &p, &v);
                let mut plain = acc0;
                for (c, &pc) in p.iter().enumerate() {
                    axpy(&mut plain, pc, &v[c * d..(c + 1) * d]);
                }
                bits_eq(&fused, &plain, &format!("accum_rows d={d} cols={cols}"));
            }
        }
    }

    #[test]
    fn empty_shapes_are_noops() {
        let mut s: Vec<f32> = Vec::new();
        qkt_tile(&[], &[], 4, 0, 0, 1.0, &mut s, 0);
        qk_row(&[0.0; 4], &[], 4, 0, 1.0, &mut s);
        let mut acc = [1.0f32, 2.0];
        softmax_accum(&mut acc, 1.0, &[], &[]);
        accum_rows(&mut acc, &[], &[]);
        assert_eq!(acc, [1.0, 2.0]);
    }

    fn quantized_store(rng: &mut Rng, dtype: KvDtype, rows: usize, d: usize) -> KvBuf {
        let mut buf = KvBuf::new(dtype);
        for _ in 0..rows {
            buf.append_row(&rng.normal_vec(d));
        }
        buf
    }

    /// An F32 view delegates straight to the f32 kernels — the legacy
    /// store's outputs are untouched by the view layer.
    #[test]
    fn view_kernels_on_f32_store_are_bit_transparent() {
        let mut rng = Rng::new(11);
        let (rows, cols, d) = (3, 7, 13);
        let q = rng.normal_vec(rows * d);
        let k = quantized_store(&mut rng, KvDtype::F32, cols, d);
        let kf = k.as_f32().to_vec();
        let view = k.view_rows(0, cols, d);
        let stride = cols + 2;
        let mut s1 = vec![0.0f32; rows * stride];
        let mut s2 = s1.clone();
        qkt_tile_view(&q, &view, d, rows, cols, 0.41, &mut s1, stride);
        qkt_tile(&q, &kf, d, rows, cols, 0.41, &mut s2, stride);
        bits_eq(&s1, &s2, "qkt_tile f32 view");
        let mut r1 = vec![0.0f32; cols];
        let mut r2 = r1.clone();
        qk_row_view(&q[..d], &view, d, cols, 1.3, &mut r1);
        qk_row(&q[..d], &kf, d, cols, 1.3, &mut r2);
        bits_eq(&r1, &r2, "qk_row f32 view");
        qk_row_raw_view(&q[..d], &view, d, cols, &mut r1);
        qk_row_raw(&q[..d], &kf, d, cols, &mut r2);
        bits_eq(&r1, &r2, "qk_row_raw f32 view");
        let p = rng.normal_vec(cols);
        let mut a1 = rng.normal_vec(d);
        let mut a2 = a1.clone();
        softmax_accum_view(&mut a1, 0.625, &p, &view);
        softmax_accum(&mut a2, 0.625, &p, &kf);
        bits_eq(&a1, &a2, "softmax_accum f32 view");
        accum_rows_view(&mut a1, &p, &view);
        accum_rows(&mut a2, &p, &kf);
        bits_eq(&a1, &a2, "accum_rows f32 view");
    }

    /// A quantized view kernel == the f32 kernel run on the dequantized
    /// rows, bit for bit — for every quantized dtype, crossing the 2x4
    /// micro-tile and 8-lane boundaries.
    #[test]
    fn quantized_view_kernels_equal_f32_kernels_on_dequantized_rows() {
        for dtype in [KvDtype::F16, KvDtype::Bf16, KvDtype::I8] {
            let mut rng = Rng::new(12);
            for d in [1, 8, 9, 16, 24] {
                for cols in [1, 3, 4, 5, 9] {
                    let rows = 3;
                    let q = rng.normal_vec(rows * d);
                    let store = quantized_store(&mut rng, dtype, cols, d);
                    let view = store.view_rows(0, cols, d);
                    let deq = view.dequant_to_vec(d);
                    let stride = cols + 1;
                    let mut s1 = vec![0.0f32; rows * stride];
                    let mut s2 = s1.clone();
                    qkt_tile_view(&q, &view, d, rows, cols, 0.37, &mut s1, stride);
                    qkt_tile(&q, &deq, d, rows, cols, 0.37, &mut s2, stride);
                    bits_eq(&s1, &s2, &format!("qkt_tile {dtype:?} d={d} cols={cols}"));
                    let mut r1 = vec![0.0f32; cols];
                    let mut r2 = r1.clone();
                    qk_row_view(&q[..d], &view, d, cols, 1.7, &mut r1);
                    qk_row(&q[..d], &deq, d, cols, 1.7, &mut r2);
                    bits_eq(&r1, &r2, &format!("qk_row {dtype:?} d={d} cols={cols}"));
                    qk_row_raw_view(&q[..d], &view, d, cols, &mut r1);
                    qk_row_raw(&q[..d], &deq, d, cols, &mut r2);
                    bits_eq(&r1, &r2, &format!("qk_row_raw {dtype:?} d={d} cols={cols}"));
                    for corr in [1.0f32, 0.625] {
                        let mut p = rng.normal_vec(cols);
                        p[cols / 2] = 0.0;
                        let mut a1 = rng.normal_vec(d);
                        let mut a2 = a1.clone();
                        softmax_accum_view(&mut a1, corr, &p, &view);
                        softmax_accum(&mut a2, corr, &p, &deq);
                        bits_eq(&a1, &a2, &format!("softmax_accum {dtype:?} d={d}"));
                        accum_rows_view(&mut a1, &p, &view);
                        accum_rows(&mut a2, &p, &deq);
                        bits_eq(&a1, &a2, &format!("accum_rows {dtype:?} d={d}"));
                    }
                }
            }
        }
    }
}
