//! Varlen index reformatting (paper Algorithm 4): query-centric (N, k)
//! top-k indices -> key-block-centric layout `(counts, offsets, flat)`
//! where `flat[offsets[j] .. offsets[j] + counts[j]]` lists the queries
//! routed to block j (ascending).
//!
//! The CUDA kernel scatters with atomics; single-threaded we get the
//! deterministic ascending order for free by iterating queries in order.

/// Key-block-centric routing layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VarlenLayout {
    pub counts: Vec<u32>,
    pub offsets: Vec<u32>,
    /// flat query ids, grouped by key block
    pub flat: Vec<u32>,
}

impl VarlenLayout {
    /// Queries routed to block `j`.
    pub fn queries_of(&self, j: usize) -> &[u32] {
        let o = self.offsets[j] as usize;
        &self.flat[o..o + self.counts[j] as usize]
    }

    pub fn total(&self) -> usize {
        self.flat.len()
    }
}

/// Build the layout from (n, k) indices (-1 = unused slot).
pub fn build_varlen(indices: &[i32], n: usize, topk: usize, n_blocks: usize) -> VarlenLayout {
    assert_eq!(indices.len(), n * topk);
    // stage 1: histogram + exclusive prefix sum (offsets)
    let mut counts = vec![0u32; n_blocks];
    for &j in indices {
        if j >= 0 {
            counts[j as usize] += 1;
        }
    }
    let mut offsets = vec![0u32; n_blocks];
    let mut acc = 0u32;
    for j in 0..n_blocks {
        offsets[j] = acc;
        acc += counts[j];
    }
    // stage 2: scatter query ids
    let mut flat = vec![0u32; acc as usize];
    let mut cursor = offsets.clone();
    for t in 0..n {
        for s in 0..topk {
            let j = indices[t * topk + s];
            if j >= 0 {
                let c = &mut cursor[j as usize];
                flat[*c as usize] = t as u32;
                *c += 1;
            }
        }
    }
    VarlenLayout { counts, offsets, flat }
}

/// Build one layout per query head from a packed `(h, n, topk)` routing
/// table — head `qh`'s layout indexes *its own* `(n, topk)` slab, so
/// `queries_of` stays in per-head row coordinates.
pub fn build_varlen_heads(
    indices: &[i32],
    h: usize,
    n: usize,
    topk: usize,
    n_blocks: usize,
) -> Vec<VarlenLayout> {
    assert_eq!(indices.len(), h * n * topk);
    (0..h)
        .map(|qh| build_varlen(&indices[qh * n * topk..(qh + 1) * n * topk], n, topk, n_blocks))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn small_example() {
        // 3 queries, k=2, 4 blocks
        let idx = [0, 1, -1, 1, 0, 3];
        let l = build_varlen(&idx, 3, 2, 4);
        assert_eq!(l.counts, vec![2, 2, 0, 1]);
        assert_eq!(l.offsets, vec![0, 2, 4, 4]);
        assert_eq!(l.queries_of(0), &[0, 2]);
        assert_eq!(l.queries_of(1), &[0, 1]);
        assert_eq!(l.queries_of(2), &[0u32; 0]);
        assert_eq!(l.queries_of(3), &[2]);
        assert_eq!(l.total(), 5);
    }

    #[test]
    fn is_permutation_of_valid_entries() {
        let mut rng = Rng::new(9);
        let (n, k, nb) = (200, 4, 16);
        let idx: Vec<i32> =
            (0..n * k).map(|_| rng.below(nb + 1) as i32 - 1).collect();
        let l = build_varlen(&idx, n, k, nb);
        assert_eq!(l.total(), idx.iter().filter(|&&x| x >= 0).count());
        // each (t, j) pair appears exactly as many times as in the table
        for j in 0..nb {
            let mut got: Vec<u32> = l.queries_of(j).to_vec();
            let mut expect: Vec<u32> = Vec::new();
            for t in 0..n {
                for s in 0..k {
                    if idx[t * k + s] == j as i32 {
                        expect.push(t as u32);
                    }
                }
            }
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect, "block {j}");
        }
    }

    #[test]
    fn per_head_layouts_slice_the_packed_table() {
        // 2 heads x 2 queries, k=1, 3 blocks
        let idx = [0, 2, 1, -1]; // head 0: q0->b0, q1->b2; head 1: q0->b1
        let ls = build_varlen_heads(&idx, 2, 2, 1, 3);
        assert_eq!(ls.len(), 2);
        assert_eq!(ls[0].queries_of(0), &[0]);
        assert_eq!(ls[0].queries_of(2), &[1]);
        assert_eq!(ls[1].queries_of(1), &[0]);
        assert_eq!(ls[1].total(), 1);
        // single head == plain build_varlen
        let single = build_varlen(&idx[..2], 2, 1, 3);
        assert_eq!(build_varlen_heads(&idx[..2], 1, 2, 1, 3)[0], single);
    }

    #[test]
    fn queries_sorted_ascending_per_block() {
        let mut rng = Rng::new(10);
        let (n, k, nb) = (100, 3, 8);
        let idx: Vec<i32> = (0..n * k)
            .map(|_| if rng.uniform() < 0.3 { -1 } else { rng.below(nb) as i32 })
            .collect();
        let l = build_varlen(&idx, n, k, nb);
        for j in 0..nb {
            let qs = l.queries_of(j);
            assert!(qs.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}
