//! Varlen index reformatting (paper Algorithm 4): query-centric (N, k)
//! top-k indices -> key-block-centric layout `(counts, offsets, flat)`
//! where `flat[offsets[j] .. offsets[j] + counts[j]]` lists the queries
//! routed to block j (ascending).
//!
//! The CUDA kernel scatters with atomics; single-threaded we get the
//! deterministic ascending order for free by iterating queries in order.
//!
//! Two representations share the build arithmetic: the per-head
//! [`VarlenLayout`] (owned vectors, the original API) and the
//! flattened [`VarlenHeads`], which packs *every* head's layout into
//! five reusable `u32` buffers so the steady-state forward can rebuild
//! its routing layout with zero heap allocations (buffers come from a
//! [`Scratch`] arena and go back when the call ends).
//!
//! Route-plan interaction: a layout is built per kernel launch, and
//! the plan dispatcher gives each KV head of a mixed plan its own
//! launch — so a layout never mixes block geometries, and the block
//! count it is sized for is always the launching head's own.

use crate::util::scratch::Scratch;

/// Key-block-centric routing layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VarlenLayout {
    pub counts: Vec<u32>,
    pub offsets: Vec<u32>,
    /// flat query ids, grouped by key block
    pub flat: Vec<u32>,
}

impl VarlenLayout {
    /// Queries routed to block `j`.
    pub fn queries_of(&self, j: usize) -> &[u32] {
        let o = self.offsets[j] as usize;
        &self.flat[o..o + self.counts[j] as usize]
    }

    pub fn total(&self) -> usize {
        self.flat.len()
    }
}

/// Build the layout from (n, k) indices (-1 = unused slot).
pub fn build_varlen(indices: &[i32], n: usize, topk: usize, n_blocks: usize) -> VarlenLayout {
    assert_eq!(indices.len(), n * topk);
    // stage 1: histogram + exclusive prefix sum (offsets)
    let mut counts = vec![0u32; n_blocks];
    for &j in indices {
        if j >= 0 {
            counts[j as usize] += 1;
        }
    }
    let mut offsets = vec![0u32; n_blocks];
    let mut acc = 0u32;
    for j in 0..n_blocks {
        offsets[j] = acc;
        acc += counts[j];
    }
    // stage 2: scatter query ids
    let mut flat = vec![0u32; acc as usize];
    let mut cursor = offsets.clone();
    for t in 0..n {
        for s in 0..topk {
            let j = indices[t * topk + s];
            if j >= 0 {
                let c = &mut cursor[j as usize];
                flat[*c as usize] = t as u32;
                *c += 1;
            }
        }
    }
    VarlenLayout { counts, offsets, flat }
}

/// Build one layout per query head from a packed `(h, n, topk)` routing
/// table — head `qh`'s layout indexes *its own* `(n, topk)` slab, so
/// `queries_of` stays in per-head row coordinates.
pub fn build_varlen_heads(
    indices: &[i32],
    h: usize,
    n: usize,
    topk: usize,
    n_blocks: usize,
) -> Vec<VarlenLayout> {
    assert_eq!(indices.len(), h * n * topk);
    (0..h)
        .map(|qh| build_varlen(&indices[qh * n * topk..(qh + 1) * n * topk], n, topk, n_blocks))
        .collect()
}

/// Every query head's key-block-centric layout in five flat reusable
/// buffers — the arena-backed twin of a `Vec<VarlenLayout>`. Per-head
/// query ids stay in head-local row coordinates, exactly as in the
/// per-head struct.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct VarlenHeads {
    h: usize,
    nb: usize,
    /// (h, nb) per-block routed-query counts, head-major
    counts: Vec<u32>,
    /// (h, nb) head-local exclusive prefix offsets
    offsets: Vec<u32>,
    /// concatenated per-head flat query ids
    flat: Vec<u32>,
    /// (h + 1) per-head bases into `flat`
    base: Vec<u32>,
    /// scatter cursor, reused between builds
    cursor: Vec<u32>,
}

/// Borrowed single-head view into a [`VarlenHeads`] — the shape
/// [`VarlenLayout`] exposes, without owning anything.
#[derive(Debug, Clone, Copy)]
pub struct VarlenView<'a> {
    pub counts: &'a [u32],
    pub offsets: &'a [u32],
    pub flat: &'a [u32],
}

impl VarlenView<'_> {
    /// Queries routed to block `j` (head-local row ids, ascending).
    pub fn queries_of(&self, j: usize) -> &[u32] {
        let o = self.offsets[j] as usize;
        &self.flat[o..o + self.counts[j] as usize]
    }

    pub fn total(&self) -> usize {
        self.flat.len()
    }
}

impl VarlenHeads {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assemble from arena buffers sized for an `(h, n, topk)` table
    /// over `nb` blocks — the zero-allocation path. Pair with
    /// [`VarlenHeads::release`].
    pub fn take(scratch: &mut Scratch, h: usize, n: usize, topk: usize, nb: usize) -> Self {
        Self {
            h: 0,
            nb: 0,
            counts: scratch.take_u32(h * nb, 0),
            offsets: scratch.take_u32(h * nb, 0),
            flat: scratch.take_u32(h * n * topk, 0),
            base: scratch.take_u32(h + 1, 0),
            cursor: scratch.take_u32(h * nb, 0),
        }
    }

    /// Return the internal buffers to the arena.
    pub fn release(self, scratch: &mut Scratch) {
        scratch.give_u32(self.counts);
        scratch.give_u32(self.offsets);
        scratch.give_u32(self.flat);
        scratch.give_u32(self.base);
        scratch.give_u32(self.cursor);
    }

    /// Query heads covered by the last build.
    pub fn h(&self) -> usize {
        self.h
    }

    /// Head `qh`'s layout view.
    pub fn head(&self, qh: usize) -> VarlenView<'_> {
        let nb = self.nb;
        let b = self.base[qh] as usize;
        let e = self.base[qh + 1] as usize;
        VarlenView {
            counts: &self.counts[qh * nb..(qh + 1) * nb],
            offsets: &self.offsets[qh * nb..(qh + 1) * nb],
            flat: &self.flat[b..e],
        }
    }

    /// Total routed (query, block) pairs over all heads.
    pub fn total(&self) -> usize {
        self.flat.len()
    }

    /// Clone every head out as owned [`VarlenLayout`]s (compat shim for
    /// consumers of the per-head struct, e.g. the backward pass).
    pub fn to_layouts(&self) -> Vec<VarlenLayout> {
        (0..self.h)
            .map(|qh| {
                let v = self.head(qh);
                VarlenLayout {
                    counts: v.counts.to_vec(),
                    offsets: v.offsets.to_vec(),
                    flat: v.flat.to_vec(),
                }
            })
            .collect()
    }
}

/// Build every head's layout into `out`, reusing its buffers — the
/// in-place twin of [`build_varlen_heads`] (identical counts, offsets
/// and per-block query order).
pub fn build_varlen_heads_into(
    indices: &[i32],
    h: usize,
    n: usize,
    topk: usize,
    nb: usize,
    out: &mut VarlenHeads,
) {
    assert_eq!(indices.len(), h * n * topk);
    out.h = h;
    out.nb = nb;
    // stage 1: histogram per (head, block)
    out.counts.clear();
    out.counts.resize(h * nb, 0);
    for qh in 0..h {
        let slab = &indices[qh * n * topk..(qh + 1) * n * topk];
        let counts = &mut out.counts[qh * nb..(qh + 1) * nb];
        for &j in slab {
            if j >= 0 {
                counts[j as usize] += 1;
            }
        }
    }
    // head-local exclusive prefix sums + per-head flat bases
    out.offsets.clear();
    out.offsets.resize(h * nb, 0);
    out.base.clear();
    out.base.resize(h + 1, 0);
    let mut total = 0u32;
    for qh in 0..h {
        out.base[qh] = total;
        let mut acc = 0u32;
        for j in 0..nb {
            out.offsets[qh * nb + j] = acc;
            acc += out.counts[qh * nb + j];
        }
        total += acc;
    }
    out.base[h] = total;
    // stage 2: scatter query ids (queries ascending per block, exactly
    // as the serial per-head build)
    out.flat.clear();
    out.flat.resize(total as usize, 0);
    out.cursor.clear();
    out.cursor.extend_from_slice(&out.offsets);
    for qh in 0..h {
        let slab = &indices[qh * n * topk..(qh + 1) * n * topk];
        let base = out.base[qh];
        for t in 0..n {
            for s in 0..topk {
                let j = slab[t * topk + s];
                if j >= 0 {
                    let cur = &mut out.cursor[qh * nb + j as usize];
                    out.flat[(base + *cur) as usize] = t as u32;
                    *cur += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn small_example() {
        // 3 queries, k=2, 4 blocks
        let idx = [0, 1, -1, 1, 0, 3];
        let l = build_varlen(&idx, 3, 2, 4);
        assert_eq!(l.counts, vec![2, 2, 0, 1]);
        assert_eq!(l.offsets, vec![0, 2, 4, 4]);
        assert_eq!(l.queries_of(0), &[0, 2]);
        assert_eq!(l.queries_of(1), &[0, 1]);
        assert_eq!(l.queries_of(2), &[0u32; 0]);
        assert_eq!(l.queries_of(3), &[2]);
        assert_eq!(l.total(), 5);
    }

    #[test]
    fn is_permutation_of_valid_entries() {
        let mut rng = Rng::new(9);
        let (n, k, nb) = (200, 4, 16);
        let idx: Vec<i32> =
            (0..n * k).map(|_| rng.below(nb + 1) as i32 - 1).collect();
        let l = build_varlen(&idx, n, k, nb);
        assert_eq!(l.total(), idx.iter().filter(|&&x| x >= 0).count());
        // each (t, j) pair appears exactly as many times as in the table
        for j in 0..nb {
            let mut got: Vec<u32> = l.queries_of(j).to_vec();
            let mut expect: Vec<u32> = Vec::new();
            for t in 0..n {
                for s in 0..k {
                    if idx[t * k + s] == j as i32 {
                        expect.push(t as u32);
                    }
                }
            }
            got.sort_unstable();
            expect.sort_unstable();
            assert_eq!(got, expect, "block {j}");
        }
    }

    #[test]
    fn per_head_layouts_slice_the_packed_table() {
        // 2 heads x 2 queries, k=1, 3 blocks
        let idx = [0, 2, 1, -1]; // head 0: q0->b0, q1->b2; head 1: q0->b1
        let ls = build_varlen_heads(&idx, 2, 2, 1, 3);
        assert_eq!(ls.len(), 2);
        assert_eq!(ls[0].queries_of(0), &[0]);
        assert_eq!(ls[0].queries_of(2), &[1]);
        assert_eq!(ls[1].queries_of(1), &[0]);
        assert_eq!(ls[1].total(), 1);
        // single head == plain build_varlen
        let single = build_varlen(&idx[..2], 2, 1, 3);
        assert_eq!(build_varlen_heads(&idx[..2], 1, 2, 1, 3)[0], single);
    }

    /// The flattened multi-head build agrees with the per-head builds
    /// exactly — counts, offsets, flat order — and its buffers round-
    /// trip through a scratch arena without re-growing.
    #[test]
    fn varlen_heads_matches_per_head_layouts() {
        let mut rng = Rng::new(11);
        let (h, n, k, nb) = (3, 60, 3, 7);
        let idx: Vec<i32> = (0..h * n * k)
            .map(|_| if rng.uniform() < 0.3 { -1 } else { rng.below(nb) as i32 })
            .collect();
        let per_head = build_varlen_heads(&idx, h, n, k, nb);
        let mut scratch = Scratch::new();
        let mut warmed = 0u64;
        for round in 0..3 {
            let mut heads = VarlenHeads::take(&mut scratch, h, n, k, nb);
            build_varlen_heads_into(&idx, h, n, k, nb, &mut heads);
            assert_eq!(heads.h(), h);
            assert_eq!(heads.total(), per_head.iter().map(|l| l.total()).sum::<usize>());
            for (qh, l) in per_head.iter().enumerate() {
                let v = heads.head(qh);
                assert_eq!(v.counts, &l.counts[..], "round {round} head {qh}");
                assert_eq!(v.offsets, &l.offsets[..], "head {qh}");
                assert_eq!(v.flat, &l.flat[..], "head {qh}");
                for j in 0..nb {
                    assert_eq!(v.queries_of(j), l.queries_of(j), "head {qh} block {j}");
                }
            }
            assert_eq!(heads.to_layouts(), per_head);
            heads.release(&mut scratch);
            if round == 0 {
                warmed = scratch.grown_bytes();
                assert!(warmed > 0);
            } else {
                // buffers warmed on round 0; later rounds reuse them
                assert_eq!(scratch.grown_bytes(), warmed, "round {round} re-grew");
            }
        }
    }

    #[test]
    fn varlen_heads_handles_empty_and_single_head() {
        let mut heads = VarlenHeads::new();
        build_varlen_heads_into(&[0, 1, -1, 1, 0, 3], 1, 3, 2, 4, &mut heads);
        let single = build_varlen(&[0, 1, -1, 1, 0, 3], 3, 2, 4);
        let v = heads.head(0);
        assert_eq!(v.queries_of(0), single.queries_of(0));
        assert_eq!(v.queries_of(3), single.queries_of(3));
        assert_eq!(v.total(), single.total());
        // a table with no valid entries
        build_varlen_heads_into(&[-1, -1], 2, 1, 1, 3, &mut heads);
        assert_eq!(heads.total(), 0);
        assert_eq!(heads.head(1).queries_of(0), &[0u32; 0]);
    }

    #[test]
    fn queries_sorted_ascending_per_block() {
        let mut rng = Rng::new(10);
        let (n, k, nb) = (100, 3, 8);
        let idx: Vec<i32> = (0..n * k)
            .map(|_| if rng.uniform() < 0.3 { -1 } else { rng.below(nb) as i32 })
            .collect();
        let l = build_varlen(&idx, n, k, nb);
        for j in 0..nb {
            let qs = l.queries_of(j);
            assert!(qs.windows(2).all(|w| w[0] <= w[1]));
        }
    }
}
