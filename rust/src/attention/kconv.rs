//! Depthwise causal key convolution (paper Appendix B), rust mirror of
//! `python/compile/kernels/kconv.py`:
//!
//!   k'_t = k_t + SiLU( Σ_l w_l ⊙ k_{t-l} )
//!
//! Two forms share the arithmetic: [`kconv`] transforms a whole (n, d)
//! key tensor at once (prefill), [`KconvStream`] transforms keys one at
//! a time over a ring buffer of the last `width` raw keys (decode). The
//! streaming form accumulates lags in the same order as the batch form,
//! so the two are bit-identical — locked down by the decode parity
//! suite.

use crate::util::pool::{concat, ExecCtx};

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// k: (n, d); w: (width, d) depthwise taps. Returns (n, d). Runs on the
/// process-wide shared pool.
pub fn kconv(k: &[f32], w: &[f32], n: usize, d: usize, width: usize) -> Vec<f32> {
    kconv_ctx(ExecCtx::global(), k, w, n, d, width)
}

/// [`kconv`] on an explicit execution context. Each output row reads
/// only rows `t-width+1..=t` of the immutable input, so rows are
/// independent work units: partitioning them across workers keeps the
/// per-row lag accumulation order — and therefore every bit — identical
/// to the serial path (and to [`KconvStream`]).
pub fn kconv_ctx(
    ctx: &ExecCtx,
    k: &[f32],
    w: &[f32],
    n: usize,
    d: usize,
    width: usize,
) -> Vec<f32> {
    assert_eq!(k.len(), n * d);
    assert_eq!(w.len(), width * d);
    concat(ctx.pool().map_ranges(n, |range| {
        let mut out = vec![0.0f32; range.len() * d];
        for (tt, t) in range.enumerate() {
            for c in 0..d {
                let mut acc = 0.0f32;
                for lag in 0..width.min(t + 1) {
                    acc += w[lag * d + c] * k[(t - lag) * d + c];
                }
                out[tt * d + c] = k[t * d + c] + silu(acc);
            }
        }
        out
    }))
}

/// [`kconv`] applied independently to every KV head of a packed
/// `(h_kv, n, d)` key tensor (the taps are shared across heads, as in
/// the multi-head decode cache). Serial per head — it is the batch
/// oracle the decode-parity suite compares streaming caches against.
pub fn kconv_heads(k: &[f32], w: &[f32], h_kv: usize, n: usize, d: usize, width: usize) -> Vec<f32> {
    assert_eq!(k.len(), h_kv * n * d);
    let mut out = Vec::with_capacity(h_kv * n * d);
    for head in 0..h_kv {
        out.extend(kconv_ctx(
            &ExecCtx::serial(),
            &k[head * n * d..(head + 1) * n * d],
            w,
            n,
            d,
            width,
        ));
    }
    out
}

/// Streaming kconv over a ring buffer of the last `width` raw keys —
/// the decode-path twin of [`kconv`]. O(width · d) per pushed key.
#[derive(Debug, Clone)]
pub struct KconvStream {
    /// (width, d) depthwise taps
    w: Vec<f32>,
    width: usize,
    d: usize,
    /// last `width` raw keys; slot for token t is `t % width`
    ring: Vec<f32>,
    /// tokens pushed so far
    len: usize,
}

impl KconvStream {
    pub fn new(w: &[f32], width: usize, d: usize) -> Self {
        assert!(width >= 1 && d >= 1, "kconv needs width >= 1 and d >= 1");
        assert_eq!(w.len(), width * d);
        Self { w: w.to_vec(), width, d, ring: vec![0.0; width * d], len: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reset to the empty-stream state, keeping the taps: zero the ring
    /// buffer and forget every pushed token. An evicted cache resets
    /// its streams before re-prefill, so replaying the original key
    /// sequence reproduces the convolved keys bit for bit.
    pub fn reset(&mut self) {
        self.ring.fill(0.0);
        self.len = 0;
    }

    /// Push raw key k_t, returning the convolved key k'_t. Accumulates
    /// lag 0..min(width, t+1) in the same order as the batch [`kconv`].
    pub fn push(&mut self, kt: &[f32]) -> Vec<f32> {
        assert_eq!(kt.len(), self.d);
        let t = self.len;
        let slot = t % self.width;
        self.ring[slot * self.d..(slot + 1) * self.d].copy_from_slice(kt);
        let mut out = vec![0.0f32; self.d];
        for c in 0..self.d {
            let mut acc = 0.0f32;
            for lag in 0..self.width.min(t + 1) {
                let src = (t - lag) % self.width;
                acc += self.w[lag * self.d + c] * self.ring[src * self.d + c];
            }
            out[c] = kt[c] + silu(acc);
        }
        self.len += 1;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn zero_weights_identity() {
        let mut rng = Rng::new(1);
        let k = rng.normal_vec(32 * 4);
        let out = kconv(&k, &[0.0; 3 * 4], 32, 4, 3);
        assert_eq!(out, k);
    }

    #[test]
    fn causal() {
        let mut rng = Rng::new(2);
        let k = rng.normal_vec(16 * 2);
        let w = rng.normal_vec(5 * 2);
        let a = kconv(&k, &w, 16, 2, 5);
        let mut k2 = k.clone();
        k2[10 * 2] += 7.0;
        let b = kconv(&k2, &w, 16, 2, 5);
        assert_eq!(&a[..10 * 2], &b[..10 * 2]);
        assert_ne!(a[10 * 2], b[10 * 2]);
    }

    #[test]
    fn matches_direct_formula_at_t0() {
        // at t=0 only lag 0 contributes
        let k = vec![2.0f32, -1.0];
        let w = vec![0.5f32, 0.5, 9.0, 9.0]; // width 2, d 2
        let out = kconv(&k, &w, 1, 2, 2);
        let exp0 = 2.0 + silu(1.0);
        let exp1 = -1.0 + silu(-0.5);
        assert!((out[0] - exp0).abs() < 1e-6);
        assert!((out[1] - exp1).abs() < 1e-6);
    }

    /// Partitioning rows across workers must not change a single bit
    /// (each row's lag accumulation is untouched).
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let mut rng = Rng::new(4);
        let (n, d, width) = (53, 6, 4); // 53 rows: uneven over any worker count
        let k = rng.normal_vec(n * d);
        let w = rng.normal_vec(width * d);
        let serial = kconv_ctx(&ExecCtx::serial(), &k, &w, n, d, width);
        for threads in [2, 3, 7] {
            let par = kconv_ctx(&ExecCtx::with_threads(threads), &k, &w, n, d, width);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    /// Per-head batch form == the single-head kernel on each head slice.
    #[test]
    fn heads_form_is_per_head_batch() {
        let mut rng = Rng::new(5);
        let (h_kv, n, d, width) = (3, 12, 4, 3);
        let k = rng.normal_vec(h_kv * n * d);
        let w = rng.normal_vec(width * d);
        let all = kconv_heads(&k, &w, h_kv, n, d, width);
        for head in 0..h_kv {
            let single = kconv(&k[head * n * d..(head + 1) * n * d], &w, n, d, width);
            assert_eq!(&all[head * n * d..(head + 1) * n * d], &single[..], "head {head}");
        }
    }

    /// The streaming ring-buffer form is bit-identical to the batch
    /// form: same taps, same lag order, same f32 operations.
    #[test]
    fn stream_matches_batch_exactly() {
        let mut rng = Rng::new(3);
        for (n, d, width) in [(1, 4, 1), (7, 2, 3), (40, 8, 4), (64, 3, 7), (16, 5, 32)] {
            let k = rng.normal_vec(n * d);
            let w = rng.normal_vec(width * d);
            let batch = kconv(&k, &w, n, d, width);
            let mut stream = KconvStream::new(&w, width, d);
            for t in 0..n {
                let got = stream.push(&k[t * d..(t + 1) * d]);
                assert_eq!(&got[..], &batch[t * d..(t + 1) * d], "t={t} n={n} width={width}");
            }
            assert_eq!(stream.len(), n);
        }
    }

    /// Reset forgets all history: replaying the same keys reproduces
    /// the original outputs bit for bit (the evict/re-prefill path).
    #[test]
    fn reset_then_replay_is_bitwise_identical() {
        let mut rng = Rng::new(6);
        let (n, d, width) = (23, 4, 3);
        let k = rng.normal_vec(n * d);
        let w = rng.normal_vec(width * d);
        let mut stream = KconvStream::new(&w, width, d);
        let first: Vec<Vec<f32>> = (0..n).map(|t| stream.push(&k[t * d..(t + 1) * d])).collect();
        stream.reset();
        assert!(stream.is_empty());
        for (t, orig) in first.iter().enumerate() {
            let got = stream.push(&k[t * d..(t + 1) * d]);
            assert!(
                got.iter().zip(orig).all(|(a, b)| a.to_bits() == b.to_bits()),
                "t={t} diverged after reset"
            );
        }
    }
}
