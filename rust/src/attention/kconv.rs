//! Depthwise causal key convolution (paper Appendix B), rust mirror of
//! `python/compile/kernels/kconv.py`:
//!
//!   k'_t = k_t + SiLU( Σ_l w_l ⊙ k_{t-l} )

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// k: (n, d); w: (width, d) depthwise taps. Returns (n, d).
pub fn kconv(k: &[f32], w: &[f32], n: usize, d: usize, width: usize) -> Vec<f32> {
    assert_eq!(k.len(), n * d);
    assert_eq!(w.len(), width * d);
    let mut out = vec![0.0f32; n * d];
    for t in 0..n {
        for c in 0..d {
            let mut acc = 0.0f32;
            for lag in 0..width.min(t + 1) {
                acc += w[lag * d + c] * k[(t - lag) * d + c];
            }
            out[t * d + c] = k[t * d + c] + silu(acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::Rng;

    #[test]
    fn zero_weights_identity() {
        let mut rng = Rng::new(1);
        let k = rng.normal_vec(32 * 4);
        let out = kconv(&k, &vec![0.0; 3 * 4], 32, 4, 3);
        assert_eq!(out, k);
    }

    #[test]
    fn causal() {
        let mut rng = Rng::new(2);
        let k = rng.normal_vec(16 * 2);
        let w = rng.normal_vec(5 * 2);
        let a = kconv(&k, &w, 16, 2, 5);
        let mut k2 = k.clone();
        k2[10 * 2] += 7.0;
        let b = kconv(&k2, &w, 16, 2, 5);
        assert_eq!(&a[..10 * 2], &b[..10 * 2]);
        assert_ne!(a[10 * 2], b[10 * 2]);
    }

    #[test]
    fn matches_direct_formula_at_t0() {
        // at t=0 only lag 0 contributes
        let k = vec![2.0f32, -1.0];
        let w = vec![0.5f32, 0.5, 9.0, 9.0]; // width 2, d 2
        let out = kconv(&k, &w, 1, 2, 2);
        let exp0 = 2.0 + silu(1.0);
        let exp1 = -1.0 + silu(-0.5);
        assert!((out[0] - exp0).abs() < 1e-6);
        assert!((out[1] - exp1).abs() < 1e-6);
    }
}
