//! FlashMoBA backward pass (paper Algorithm 5): recomputation-based,
//! parallelized over the key dimension, gather-and-densify mirrored from
//! the forward, with dQ accumulated into a high-precision global buffer
//! (the CUDA atomicAdd analogue; sequential here, same arithmetic).
//!
//! Also [`naive_backward`], an original-style backward that materializes
//! the full masked probability matrix — the memory-hog baseline.
//!
//! Both backwards are *single-head* (`shape.h == 1`): the backward pass
//! is not part of the `AttentionBackend` trait, and the bench harness
//! times it per head. Only `n/d/block/topk` of the [`AttnShape`] are
//! read.

use super::simd::{axpy, dot as sdot};
use super::varlen::VarlenLayout;
use super::AttnShape;

/// Gradients of (q, k, v).
pub struct Grads {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

/// Is token u attended by query t under the routing table?
fn attended(t: usize, u: usize, block: usize, indices: &[i32], topk: usize) -> bool {
    if u > t {
        return false;
    }
    let ub = u / block;
    ub == t / block || indices[t * topk..(t + 1) * topk].contains(&(ub as i32))
}

/// Materializing backward (f64 accumulation; correctness oracle).
pub fn naive_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dout: &[f32],
    shape: AttnShape,
    indices: &[i32],
) -> Grads {
    assert_eq!(shape.h, 1, "backward is single-head; loop heads in the caller");
    let AttnShape { n, d, block, topk, .. } = shape;
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    assert_eq!(dout.len(), n * d);
    let scale = 1.0 / (d as f64).sqrt();
    let mut dq = vec![0.0f64; n * d];
    let mut dk = vec![0.0f64; n * d];
    let mut dv = vec![0.0f64; n * d];
    for t in 0..n {
        // recompute p_t
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            if !attended(t, u, block, indices, topk) {
                continue;
            }
            let mut dot = 0.0f64;
            for c in 0..d {
                dot += q[t * d + c] as f64 * k[u * d + c] as f64;
            }
            *su = dot * scale;
        }
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let z: f64 = s.iter().filter(|x| x.is_finite()).map(|x| (x - m).exp()).sum();
        let p: Vec<f64> =
            s.iter().map(|x| if x.is_finite() { (x - m).exp() / z } else { 0.0 }).collect();
        // dv_u += p_u * do_t ; dp_u = do_t . v_u
        let mut dsum = 0.0f64; // sum_u p_u dp_u  (= do . o)
        let mut dp = vec![0.0f64; t + 1];
        for u in 0..=t {
            if p[u] == 0.0 {
                continue;
            }
            let mut dot = 0.0f64;
            for c in 0..d {
                dv[u * d + c] += p[u] * dout[t * d + c] as f64;
                dot += dout[t * d + c] as f64 * v[u * d + c] as f64;
            }
            dp[u] = dot;
            dsum += p[u] * dot;
        }
        for u in 0..=t {
            if p[u] == 0.0 {
                continue;
            }
            let ds = p[u] * (dp[u] - dsum) * scale;
            for c in 0..d {
                dq[t * d + c] += ds * k[u * d + c] as f64;
                dk[u * d + c] += ds * q[t * d + c] as f64;
            }
        }
    }
    Grads {
        dq: dq.into_iter().map(|x| x as f32).collect(),
        dk: dk.into_iter().map(|x| x as f32).collect(),
        dv: dv.into_iter().map(|x| x as f32).collect(),
    }
}

/// FlashMoBA backward (Algorithm 5).
///
/// Inputs mirror the forward: routing `layout` + `indices`, the forward
/// output `o` and per-row logsumexp `lse`, upstream gradient `dout`.
#[allow(clippy::too_many_arguments)]
pub fn flash_moba_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    lse: &[f32],
    dout: &[f32],
    shape: AttnShape,
    layout: &VarlenLayout,
) -> Grads {
    assert_eq!(shape.h, 1, "backward is single-head; loop heads in the caller");
    let AttnShape { n, d, block, .. } = shape;
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    assert_eq!(dout.len(), n * d);
    let nb = shape.complete_blocks();
    let scale = 1.0 / (d as f32).sqrt();

    // preprocessing kernel: D_t = rowsum(dO ∘ O)
    let mut dvec = vec![0.0f32; n];
    for t in 0..n {
        let mut s = 0.0f32;
        for c in 0..d {
            s += dout[t * d + c] * o[t * d + c];
        }
        dvec[t] = s;
    }

    // high-precision global dQ accumulator (atomicAdd analogue)
    let mut dq_accum = vec![0.0f64; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];

    // main kernel: one pass per logical key block (the ragged tail, if
    // any, appears only as its own queries' causal pass)
    for j in 0..shape.n_blocks() {
        let blen = shape.block_len(j);
        let kb = &k[j * block * d..(j * block + blen) * d];
        let vb = &v[j * block * d..(j * block + blen) * d];
        let dkb_off = j * block * d;
        let own_start = j * block;

        let mut process_rows = |rows: &[u32], causal: bool, dk: &mut [f32], dv: &mut [f32]| {
            for &t_ in rows {
                let t = t_ as usize;
                let qt = &q[t * d..(t + 1) * d];
                let dot_ = &dout[t * d..(t + 1) * d];
                // recompute p over this block: p_u = exp(s_u - lse_t)
                for u in 0..blen {
                    if causal && own_start + u > t {
                        break;
                    }
                    let ku = &kb[u * d..(u + 1) * d];
                    let p = (sdot(qt, ku) * scale - lse[t]).exp();
                    if p == 0.0 {
                        continue;
                    }
                    // dV_j += P^T dO ; dP = dO · V_j^T   (vectorized)
                    axpy(&mut dv[dkb_off + u * d..dkb_off + (u + 1) * d], p, dot_);
                    let dp = sdot(dot_, &vb[u * d..(u + 1) * d]);
                    // dS = P ∘ (dP - D)
                    let ds = p * (dp - dvec[t]) * scale;
                    // dK_j += dS^T Q (vectorized); dQ accumulates in the
                    // high-precision buffer (atomicAdd analogue)
                    axpy(&mut dk[dkb_off + u * d..dkb_off + (u + 1) * d], ds, qt);
                    for c in 0..d {
                        dq_accum[t * d + c] += (ds * ku[c]) as f64;
                    }
                }
            }
        };

        if j < nb {
            process_rows(layout.queries_of(j), false, &mut dk, &mut dv);
        }
        let own_rows: Vec<u32> =
            (own_start as u32..(own_start + blen) as u32).collect();
        process_rows(&own_rows, true, &mut dk, &mut dv);
    }

    // postprocess kernel: convert dQ to output dtype
    let dq = dq_accum.into_iter().map(|x| x as f32).collect();
    Grads { dq, dk, dv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::flash_moba::{flash_moba_forward, FlashMobaConfig};
    use crate::attention::moba_naive::moba_reference;
    use crate::attention::testutil::{max_abs_diff, qkv, Rng};

    fn setup(n: usize, d: usize, b: usize, k: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, AttnShape) {
        let shape = AttnShape::single(n, d, b, k);
        let (q, kk, v) = qkv(seed, n, d);
        (q, kk, v, shape)
    }

    #[test]
    fn flash_backward_matches_naive_backward() {
        for (n, d, b, k) in [(64, 8, 16, 2), (128, 16, 32, 2), (96, 4, 16, 3)] {
            let (q, kk, v, shape) = setup(n, d, b, k, 41);
            let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
            let mut rng = Rng::new(42);
            let dout = rng.normal_vec(n * d);
            let g1 = naive_backward(&q, &kk, &v, &dout, shape, &out.indices);
            let g2 = flash_moba_backward(&q, &kk, &v, &out.o, &out.lse, &dout, shape, &out.layouts[0]);
            assert!(max_abs_diff(&g1.dq, &g2.dq) < 5e-4, "dq n={n}");
            assert!(max_abs_diff(&g1.dk, &g2.dk) < 5e-4, "dk n={n}");
            assert!(max_abs_diff(&g1.dv, &g2.dv) < 5e-4, "dv n={n}");
        }
    }

    /// central finite differences on a scalar loss sum(o * w)
    #[test]
    fn gradients_match_finite_differences() {
        let (n, d, b, k) = (32, 4, 8, 1);
        let (q, kk, v, shape) = setup(n, d, b, k, 43);
        let mut rng = Rng::new(44);
        let w = rng.normal_vec(n * d);

        let loss = |q_: &[f32], k_: &[f32], v_: &[f32], idx: &[i32]| -> f64 {
            let (o, _) = moba_reference(q_, k_, v_, shape, idx);
            o.iter().zip(&w).map(|(a, b)| *a as f64 * *b as f64).sum()
        };

        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let g = flash_moba_backward(&q, &kk, &v, &out.o, &out.lse, &w, shape, &out.layouts[0]);

        let eps = 1e-3f32;
        let check = |arr: &[f32], grad: &[f32], which: usize| {
            let mut rng = Rng::new(45 + which as u64);
            for _ in 0..12 {
                let i = rng.below(arr.len());
                let mut plus = arr.to_vec();
                let mut minus = arr.to_vec();
                plus[i] += eps;
                minus[i] -= eps;
                // routing held fixed (straight-through, as in training)
                let (lp, lm) = match which {
                    0 => (loss(&plus, &kk, &v, &out.indices), loss(&minus, &kk, &v, &out.indices)),
                    1 => (loss(&q, &plus, &v, &out.indices), loss(&q, &minus, &v, &out.indices)),
                    _ => (loss(&q, &kk, &plus, &out.indices), loss(&q, &kk, &minus, &out.indices)),
                };
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grad[i];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "which={which} i={i} fd={fd} an={an}"
                );
            }
        };
        check(&q, &g.dq, 0);
        check(&kk, &g.dk, 1);
        check(&v, &g.dv, 2);
    }

    #[test]
    fn dv_rows_of_unattended_tokens_are_zero() {
        // token in a never-routed block (other than by its own queries)
        let (n, d, b, k) = (64, 4, 16, 1);
        let (q, kk, v, shape) = setup(n, d, b, k, 46);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let mut rng = Rng::new(47);
        let dout = rng.normal_vec(n * d);
        let g = flash_moba_backward(&q, &kk, &v, &out.o, &out.lse, &dout, shape, &out.layouts[0]);
        // gradient exists exactly where some query attends the token
        for u in 0..n {
            let touched = (0..n).any(|t| attended(t, u, b, &out.indices, k));
            let norm: f32 = g.dv[u * d..(u + 1) * d].iter().map(|x| x * x).sum();
            if !touched {
                assert_eq!(norm, 0.0, "u={u} should be untouched");
            }
        }
    }
}
