//! FlashMoBA forward (paper §4.2, Algorithm 1): fused tiled top-k +
//! gather-and-densify attention.
//!
//! Two stages instead of the original's five:
//!   1. `flash_topk` — centroids (once per KV head) + streaming tiled
//!      selection per query head (no score tensor) + varlen epilogue
//!      (Algorithms 2–4)
//!   2. `fwd`        — per logical KV block, gather the routed queries
//!      into dense tiles and run blocked GEMM + online softmax, with the
//!      own-block causal pass fused into the same accumulators
//!
//! Tensors are packed: q/o `(h, n, d)`, k/v `(h_kv, n, d)` with GQA
//! head grouping; one call covers the whole head dimension. A ragged
//! final block is supported: its queries attend it causally (fused own
//! pass, clamped to the tail length) and route among the complete
//! strictly-past blocks only.
//!
//! Multi-core adaptation: the CUDA kernel keeps (m, l, acc) per query
//! tile in SRAM; here each worker owns a contiguous range of flattened
//! `(head, query-row)` units with its own (m, l, acc) accumulators and
//! walks its head's KV blocks in the same ascending order the serial
//! kernel does, visiting only the rows of its range. A query row's
//! update sequence — which (block, column tile) pairs it sees, in which
//! order, with which scores — is independent of how rows are grouped
//! into physical tiles, so the result is bit-identical to the serial
//! path at any worker count (pinned by the determinism property suite
//! and the CI thread matrix), and `h = h_kv = 1` is bit-identical to
//! the pre-multi-head kernel.
//!
//! Per-head route plans (`attention::plan`) never reach this kernel:
//! the backend dispatcher decomposes a mixed [`RoutePlan`] into one
//! uniform-geometry sub-launch per KV head, so every call here still
//! sees a single `(block, topk)` for its whole shape. A planned-dense
//! head arrives as a fully-routed launch (`topk = max_candidates`),
//! which keeps the dense fallback on this kernel's own-block + routed
//! arithmetic and therefore bit-deterministic at any thread count.
//!
//! [`RoutePlan`]: super::plan::RoutePlan

use std::sync::atomic::{AtomicU64, Ordering};

use super::centroid::centroids_packed_into;
use super::dense::NEG_INF;
use super::gemm::{qkt_tile, softmax_accum};
use super::stats::{ws_bytes, StageStats};
use super::topk::tiled_topk_packed_into;
use super::varlen::{build_varlen_heads_into, VarlenHeads, VarlenLayout, VarlenView};
use super::AttnShape;
use crate::util::pool::ExecCtx;
use crate::util::scratch::Scratch;

/// Tuning knobs (physical tile sizes; logical block size comes from
/// [`AttnShape`]).
#[derive(Debug, Clone, Copy)]
pub struct FlashMobaConfig {
    /// query rows gathered per dense tile (CUDA: B_r)
    pub tile_r: usize,
    /// key columns per inner tile (CUDA: B_c)
    pub tile_c: usize,
    /// centroid tile width in the top-k pass
    pub topk_tile: usize,
}

impl Default for FlashMobaConfig {
    fn default() -> Self {
        Self { tile_r: 64, tile_c: 64, topk_tile: 64 }
    }
}

/// Forward pass output.
pub struct FlashMobaOut {
    /// packed (h, n, d) attention output
    pub o: Vec<f32>,
    /// packed (h, n) per-row logsumexp
    pub lse: Vec<f32>,
    /// packed (h, n, topk) routing table (-1 padded)
    pub indices: Vec<i32>,
    /// one key-block-centric routing layout per query head
    pub layouts: Vec<VarlenLayout>,
    pub stats: StageStats,
}

/// Run the fused pipeline on the process-wide shared pool.
pub fn flash_moba_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    cfg: FlashMobaConfig,
) -> FlashMobaOut {
    flash_moba_forward_ctx(ExecCtx::global(), q, k, v, shape, cfg)
}

/// [`flash_moba_forward`] on an explicit execution context.
pub fn flash_moba_forward_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    cfg: FlashMobaConfig,
) -> FlashMobaOut {
    let mut centroids = Vec::new();
    let mut indices = Vec::new();
    let mut heads = VarlenHeads::new();
    let mut o = Vec::new();
    let mut lse = Vec::new();
    let stats = forward_core(
        ctx, q, k, v, shape, cfg, &mut centroids, &mut indices, &mut heads, &mut o, &mut lse,
    );
    FlashMobaOut { o, lse, indices, layouts: heads.to_layouts(), stats }
}

/// The zero-allocation steady-state entry point: the packed `(h, n, d)`
/// output lands in the caller's reusable `o`, and every intermediate
/// (centroids, routing table, varlen layout, lse, per-worker tile
/// state) is borrowed from the context's scratch arenas and returned
/// when the call ends. Repeating the same shape on a serial context
/// performs zero heap allocations after warmup
/// (`rust/tests/alloc_regression.rs`). Bit-identical to
/// [`flash_moba_forward_ctx`].
pub fn flash_moba_forward_into(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    cfg: FlashMobaConfig,
    o: &mut Vec<f32>,
) -> StageStats {
    let AttnShape { h, h_kv, n, d, topk, .. } = shape;
    let cb = shape.complete_blocks();
    let (mut centroids, mut indices, mut heads, mut lse, pooled) = {
        // hold slot 0 only while taking: the parallel region's task 0
        // locks the same slot for its own tile buffers
        let mut s = ctx.scratch(0);
        let pooled = s.is_pooled();
        (
            s.take_f32(h_kv * cb * d, 0.0),
            s.take_i32(h * n * topk, -1),
            VarlenHeads::take(&mut s, h, n, topk, cb),
            s.take_f32(h * n, 0.0),
            pooled,
        )
    };
    let stats = forward_core(
        ctx, q, k, v, shape, cfg, &mut centroids, &mut indices, &mut heads, o, &mut lse,
    );
    // pooled-taken buffers go back to the pool, waiting for the slot
    // rather than falling back (a pooled buffer must not be lost just
    // because the slot is momentarily contended); buffers taken from a
    // contention-fallback arena are throwaway and simply drop here
    if pooled {
        let mut s = ctx.scratch_wait(0);
        s.give_f32(centroids);
        s.give_i32(indices);
        heads.release(&mut s);
        s.give_f32(lse);
    }
    stats
}

/// Shared pipeline body: stage 1 (Flash TopK + varlen epilogue) and
/// stage 2 (gather-and-densify forward), writing every product into
/// the caller's buffers. Both public entry points are thin wrappers —
/// one allocates fresh buffers, one borrows them from the arena.
#[allow(clippy::too_many_arguments)]
fn forward_core(
    ctx: &ExecCtx,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    cfg: FlashMobaConfig,
    centroids: &mut Vec<f32>,
    indices: &mut Vec<i32>,
    heads: &mut VarlenHeads,
    o: &mut Vec<f32>,
    lse: &mut Vec<f32>,
) -> StageStats {
    let AttnShape { h, h_kv, n, d, block, topk } = shape;
    assert_eq!(q.len(), shape.q_elems());
    assert_eq!(k.len(), shape.kv_elems());
    assert_eq!(v.len(), shape.kv_elems());
    let cb = shape.complete_blocks();
    let mut st = StageStats::for_heads(ctx, h);

    // ---- stage 1: Flash TopK + varlen epilogue -------------------------
    // (buffers are resized without clearing: every element is fully
    // overwritten by the kernels, and a same-length resize is a no-op,
    // so steady-state calls skip the redundant refill)
    let topk_ws = st.time("flash_topk", || {
        centroids.resize(h_kv * cb * d, 0.0);
        centroids_packed_into(ctx, k, h_kv, n, d, block, centroids);
        let ws = tiled_topk_packed_into(ctx, q, centroids, &shape, cfg.topk_tile, indices);
        build_varlen_heads_into(indices, h, n, topk, cb, heads);
        ws + ws_bytes(&[h_kv * cb * d])
    });
    st.add_workspace(topk_ws + ws_bytes(&[heads.total() + 2 * h * cb]));

    // ---- stage 2: gather-and-densify forward (in place) ----------------
    o.resize(h * n * d, 0.0);
    lse.resize(h * n, 0.0);
    let fwd_ws = st.time("fwd", || {
        let ws = AtomicU64::new(0);
        ctx.pool().for_ranges_split(
            h * n,
            o.as_mut_slice(),
            lse.as_mut_slice(),
            |u| (u * d, u),
            |slot, rows, o_chunk, lse_chunk| {
                // a flattened range may span head boundaries; split it
                // so every sub-range runs against its own head's K/V
                // and layout
                let mut scratch = ctx.scratch(slot);
                let base = rows.start;
                let mut w = 0u64;
                let mut start = rows.start;
                while start < rows.end {
                    let qh = start / n;
                    let head_end = ((qh + 1) * n).min(rows.end);
                    let (lo, hi) = (start % n, start % n + (head_end - start));
                    let kvh = shape.kv_head_of(qh);
                    w += forward_range(
                        &q[qh * n * d..(qh + 1) * n * d],
                        &k[kvh * n * d..(kvh + 1) * n * d],
                        &v[kvh * n * d..(kvh + 1) * n * d],
                        shape,
                        cfg,
                        heads.head(qh),
                        lo,
                        hi,
                        &mut scratch,
                        &mut o_chunk[(start - base) * d..(head_end - base) * d],
                        &mut lse_chunk[start - base..head_end - base],
                    );
                    start = head_end;
                }
                ws.fetch_add(w, Ordering::Relaxed);
            },
        );
        ws.into_inner()
    });
    st.add_workspace(fwd_ws);
    st
}

/// The gather-and-densify kernel body (Algorithm 1) for one query
/// head's rows `lo..hi` against its KV head's (n, d) slices: walk every
/// logical KV block in ascending order, processing the routed queries
/// of the range first and the (causal) own-block rows second — the
/// exact per-row visit order of the serial kernel. Routed passes exist
/// only for complete blocks; the ragged tail block (if any) appears
/// only as its own queries' causal pass, clamped to its length.
///
/// Score tiles run on the register-blocked [`qkt_tile`] microkernel
/// (causal tiles are computed dense and masked by overwrite — the
/// surviving values are bit-identical to the skip-and-dot path) and
/// the accumulator update on the fused [`softmax_accum`]; every
/// working buffer — the (m, l, acc) "SRAM state", the gather/score
/// tiles and the own-rows list — is borrowed from `scratch` and
/// returned, so steady-state repeats allocate nothing. The range's
/// output lands in `o`/`lse` (length `(hi - lo) * d` / `hi - lo`).
/// Returns the range's workspace bytes.
#[allow(clippy::too_many_arguments)]
fn forward_range(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: AttnShape,
    cfg: FlashMobaConfig,
    layout: VarlenView<'_>,
    lo: usize,
    hi: usize,
    scratch: &mut Scratch,
    o: &mut [f32],
    lse: &mut [f32],
) -> u64 {
    let AttnShape { d, block, .. } = shape;
    let nb = shape.n_blocks(); // logical blocks incl. a partial tail
    let cb = shape.complete_blocks();
    let sm_scale = 1.0 / (d as f32).sqrt();
    let tile_r = cfg.tile_r;
    let tile_c = cfg.tile_c.min(block);
    let rows_total = hi - lo;
    debug_assert_eq!(o.len(), rows_total * d);
    debug_assert_eq!(lse.len(), rows_total);

    // this range's online-softmax accumulators (the SRAM state) and
    // dense gather tiles, all arena-backed
    let mut m = scratch.take_f32(rows_total, NEG_INF);
    let mut l = scratch.take_f32(rows_total, 0.0);
    let mut acc = scratch.take_f32(rows_total * d, 0.0);
    let mut qg = scratch.take_f32(tile_r * d, 0.0);
    let mut s = scratch.take_f32(tile_r * tile_c, 0.0);
    // the own-block row list, reused across blocks (sized to the
    // largest own pass this range can see)
    let mut own_rows = scratch.take_u32(block.min(rows_total), 0);
    let ws = ws_bytes(&[m.len(), l.len(), acc.len(), qg.len(), s.len()]);

    for j in 0..nb {
        let blen = shape.block_len(j); // == block except for the tail
        let kb = &k[j * block * d..(j * block + blen) * d];
        let vb = &v[j * block * d..(j * block + blen) * d];
        let own_start = j * block;

        // process in dense physical tiles: first routed, then own block
        let mut process_tile = |rows: &[u32], causal: bool| {
            let rcount = rows.len();
            // gather-load queries into the dense buffer
            for (r, &t) in rows.iter().enumerate() {
                qg[r * d..(r + 1) * d].copy_from_slice(&q[t as usize * d..(t as usize + 1) * d]);
            }
            let tcs = blen.div_ceil(tile_c);
            for ct in 0..tcs {
                let c0 = ct * tile_c;
                let cols = tile_c.min(blen - c0);
                // dense register-blocked GEMM tile: s = qg · kb_tile^T
                qkt_tile(
                    &qg[..rcount * d],
                    &kb[c0 * d..(c0 + cols) * d],
                    d,
                    rcount,
                    cols,
                    sm_scale,
                    &mut s,
                    tile_c,
                );
                if causal {
                    // row t keeps columns own_start + c0 + cc <= t
                    for r in 0..rcount {
                        let trow = rows[r] as usize;
                        let keep = (trow + 1).saturating_sub(own_start + c0).min(cols);
                        for x in s[r * tile_c + keep..r * tile_c + cols].iter_mut() {
                            *x = NEG_INF;
                        }
                    }
                }
                // online softmax scatter-update
                for r in 0..rcount {
                    let ti = rows[r] as usize - lo;
                    let srow = &mut s[r * tile_c..r * tile_c + cols];
                    let mut mt = m[ti];
                    for &x in srow.iter() {
                        if x > mt {
                            mt = x;
                        }
                    }
                    if mt == NEG_INF {
                        continue;
                    }
                    let corr = (m[ti] - mt).exp();
                    let mut psum = 0.0f32;
                    for x in srow.iter_mut() {
                        *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                        psum += *x;
                    }
                    l[ti] = l[ti] * corr + psum;
                    softmax_accum(
                        &mut acc[ti * d..(ti + 1) * d],
                        corr,
                        &s[r * tile_c..r * tile_c + cols],
                        &vb[c0 * d..(c0 + cols) * d],
                    );
                    m[ti] = mt;
                }
            }
        };

        if j < cb {
            // routed queries (strictly future of block j) restricted to
            // the range — `queries_of` is ascending, so that's a subslice
            let routed_all = layout.queries_of(j);
            let a = routed_all.partition_point(|&t| (t as usize) < lo);
            let b = routed_all.partition_point(|&t| (t as usize) < hi);
            for chunk in routed_all[a..b].chunks(tile_r) {
                process_tile(chunk, false);
            }
        }
        // fused local pass: own-block rows within the range, causal
        let os = own_start.max(lo);
        let oe = (own_start + blen).min(hi);
        if os < oe {
            own_rows.clear();
            own_rows.extend(os as u32..oe as u32);
            for chunk in own_rows.chunks(tile_r) {
                process_tile(chunk, true);
            }
        }
    }

    // epilogue: normalize into the caller's output window
    for ti in 0..rows_total {
        let z = if l[ti] == 0.0 { 1.0 } else { l[ti] };
        for c in 0..d {
            o[ti * d + c] = acc[ti * d + c] / z;
        }
        lse[ti] = m[ti] + l[ti].max(1e-30).ln();
    }
    scratch.give_u32(own_rows);
    scratch.give_f32(s);
    scratch.give_f32(qg);
    scratch.give_f32(acc);
    scratch.give_f32(l);
    scratch.give_f32(m);
    ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::{naive_attention, naive_attention_packed};
    use crate::attention::moba_naive::{moba_naive_forward, moba_reference};
    use crate::attention::testutil::{max_abs_diff, qkv, qkv_packed};

    #[test]
    fn matches_reference_and_naive_pipeline() {
        for (n, d, b, k) in [(128, 16, 16, 2), (256, 8, 32, 3), (256, 64, 64, 2), (64, 4, 16, 1)] {
            let shape = AttnShape::single(n, d, b, k);
            let (q, kk, v) = qkv(31, n, d);
            let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
            let (oref, lref) = moba_reference(&q, &kk, &v, shape, &out.indices);
            assert!(max_abs_diff(&out.o, &oref) < 3e-5, "n={n} b={b} k={k}");
            assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
            let (onaive, idx_naive, _) = moba_naive_forward(&q, &kk, &v, shape);
            assert!(crate::attention::topk::same_selection(&out.indices, &idx_naive, k));
            assert!(max_abs_diff(&out.o, &onaive) < 5e-5);
        }
    }

    #[test]
    fn multi_head_gqa_matches_reference_and_pipeline() {
        for (h, h_kv, n) in [(2, 2, 128), (4, 2, 96), (8, 2, 64), (4, 1, 64)] {
            let shape = AttnShape::new(h, h_kv, n, 8, 16, 2);
            let (q, kk, v) = qkv_packed(37, h, h_kv, n, 8);
            let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
            assert_eq!(out.o.len(), shape.q_elems());
            assert_eq!(out.layouts.len(), h);
            assert_eq!(out.stats.heads(), h);
            let (oref, lref) = moba_reference(&q, &kk, &v, shape, &out.indices);
            assert!(max_abs_diff(&out.o, &oref) < 3e-5, "h={h} h_kv={h_kv}");
            assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
            let (onaive, idx_naive, _) = moba_naive_forward(&q, &kk, &v, shape);
            assert!(crate::attention::topk::same_selection(&out.indices, &idx_naive, shape.topk));
            assert!(max_abs_diff(&out.o, &onaive) < 5e-5);
        }
    }

    #[test]
    fn ragged_tail_matches_reference() {
        for shape in [
            AttnShape::single(100, 8, 16, 2),
            AttnShape::new(4, 2, 90, 8, 16, 3),
        ] {
            let (q, kk, v) = qkv_packed(38, shape.h, shape.h_kv, shape.n, shape.d);
            let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
            assert!(out.indices.iter().all(|&j| j < shape.complete_blocks() as i32));
            let (oref, _) = moba_reference(&q, &kk, &v, shape, &out.indices);
            assert!(max_abs_diff(&out.o, &oref) < 3e-5, "{shape:?}");
        }
    }

    #[test]
    fn small_tiles_still_correct() {
        let shape = AttnShape::single(128, 8, 32, 2);
        let (q, kk, v) = qkv(32, 128, 8);
        let cfg = FlashMobaConfig { tile_r: 3, tile_c: 5, topk_tile: 3 };
        let out = flash_moba_forward(&q, &kk, &v, shape, cfg);
        let (oref, _) = moba_reference(&q, &kk, &v, shape, &out.indices);
        assert!(max_abs_diff(&out.o, &oref) < 3e-5);
    }

    #[test]
    fn full_routing_equals_dense() {
        let (n, d, b) = (96, 8, 16);
        let shape = AttnShape::single(n, d, b, n / b);
        let (q, kk, v) = qkv(33, n, d);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let (oref, lref) = naive_attention(&q, &kk, &v, n, d);
        assert!(max_abs_diff(&out.o, &oref) < 3e-5);
        assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
    }

    #[test]
    fn gqa_full_routing_equals_dense() {
        let shape = AttnShape::new(4, 2, 96, 8, 16, 6); // topk == n_blocks
        let (q, kk, v) = qkv_packed(39, 4, 2, 96, 8);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let (oref, lref) = naive_attention_packed(&q, &kk, &v, 4, 2, 96, 8);
        assert!(max_abs_diff(&out.o, &oref) < 3e-5);
        assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
    }

    /// Partitioning flattened (head, query-row) units across workers
    /// must not change a single bit of o, lse or the routing table —
    /// including at worker counts that split heads, blocks and tiles
    /// unevenly.
    #[test]
    fn parallel_is_bit_identical_to_serial() {
        for shape in [
            AttnShape::single(7 * 32, 8, 32, 2), // 7 blocks: uneven splits
            AttnShape::new(4, 2, 3 * 32, 8, 32, 2),
            AttnShape::new(2, 1, 100, 8, 32, 2), // ragged tail
        ] {
            let (q, kk, v) = qkv_packed(36, shape.h, shape.h_kv, shape.n, shape.d);
            let cfg = FlashMobaConfig { tile_r: 5, tile_c: 9, topk_tile: 3 };
            let serial = flash_moba_forward_ctx(&ExecCtx::serial(), &q, &kk, &v, shape, cfg);
            for threads in [2, 3, 4, 13] {
                let ctx = ExecCtx::with_threads(threads);
                let par = flash_moba_forward_ctx(&ctx, &q, &kk, &v, shape, cfg);
                assert_eq!(serial.o, par.o, "o differs at threads={threads} {shape:?}");
                assert_eq!(serial.lse, par.lse, "lse differs at threads={threads} {shape:?}");
                assert_eq!(serial.indices, par.indices, "indices differ at threads={threads}");
                assert_eq!(par.stats.threads(), threads);
            }
        }
    }

    #[test]
    fn uses_less_workspace_than_naive() {
        let shape = AttnShape::single(1024, 64, 64, 4);
        let (q, kk, v) = qkv(34, 1024, 64);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let (_, _, st_naive) = moba_naive_forward(&q, &kk, &v, shape);
        assert!(out.stats.workspace_bytes < st_naive.workspace_bytes);
    }

    #[test]
    fn two_stage_labels() {
        let shape = AttnShape::single(64, 4, 16, 1);
        let (q, kk, v) = qkv(35, 64, 4);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        assert!(out.stats.get("flash_topk").is_some());
        assert!(out.stats.get("fwd").is_some());
        assert_eq!(out.stats.stages().len(), 2);
    }
}
