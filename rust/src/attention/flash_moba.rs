//! FlashMoBA forward (paper §4.2, Algorithm 1): fused tiled top-k +
//! gather-and-densify attention.
//!
//! Two stages instead of the original's five:
//!   1. `flash_topk` — centroids + streaming tiled selection (no N×n
//!      score matrix) + varlen epilogue (Algorithms 2–4)
//!   2. `fwd`        — per logical KV block, gather the routed queries
//!      into dense tiles and run blocked GEMM + online softmax, with the
//!      own-block causal pass fused into the same accumulators
//!
//! Single-threaded adaptation: the CUDA kernel keeps (m, l, acc) per
//! query tile in SRAM and revisits query blocks from one thread block;
//! sequentially we keep the accumulators in one O(N·d) buffer and visit
//! key blocks outer-loop — the same arithmetic in the same order per
//! (query, block) pair, with the same O(N·k·B·d) complexity.

use super::centroid::centroids;
use super::simd::{axpy, dot, scale};
use super::dense::NEG_INF;
use super::stats::{ws_bytes, StageStats};
use super::topk::tiled_topk;
use super::varlen::{build_varlen, VarlenLayout};
use super::MobaShape;

/// Tuning knobs (physical tile sizes; logical block size comes from
/// [`MobaShape`]).
#[derive(Debug, Clone, Copy)]
pub struct FlashMobaConfig {
    /// query rows gathered per dense tile (CUDA: B_r)
    pub tile_r: usize,
    /// key columns per inner tile (CUDA: B_c)
    pub tile_c: usize,
    /// centroid tile width in the top-k pass
    pub topk_tile: usize,
}

impl Default for FlashMobaConfig {
    fn default() -> Self {
        Self { tile_r: 64, tile_c: 64, topk_tile: 64 }
    }
}

/// Forward pass output.
pub struct FlashMobaOut {
    pub o: Vec<f32>,
    pub lse: Vec<f32>,
    pub indices: Vec<i32>,
    pub layout: VarlenLayout,
    pub stats: StageStats,
}

/// Run the fused pipeline.
pub fn flash_moba_forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
    cfg: FlashMobaConfig,
) -> FlashMobaOut {
    let MobaShape { n, d, block, topk } = shape;
    let nb = shape.n_blocks();
    let mut st = StageStats::new();

    // ---- stage 1: Flash TopK + varlen epilogue -------------------------
    let (indices, layout, topk_ws) = st.time("flash_topk", || {
        let c = centroids(k, n, d, block);
        let (idx, ws) = tiled_topk(q, &c, n, d, block, topk, cfg.topk_tile);
        let layout = build_varlen(&idx, n, topk, nb);
        (idx, layout, ws + ws_bytes(&[nb * d]))
    });
    st.add_workspace(topk_ws + ws_bytes(&[layout.total() + 2 * nb]));

    // ---- stage 2: gather-and-densify forward ---------------------------
    let mut o = vec![0.0f32; n * d];
    let mut lse = vec![0.0f32; n];
    let fwd_ws = st.time("fwd", || forward_core(q, k, v, shape, cfg, &layout, &mut o, &mut lse));
    st.add_workspace(fwd_ws);

    FlashMobaOut { o, lse, indices, layout, stats: st }
}

/// The gather-and-densify kernel body (Algorithm 1), shared with benches.
/// Returns the workspace bytes it allocated.
#[allow(clippy::too_many_arguments)]
fn forward_core(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    shape: MobaShape,
    cfg: FlashMobaConfig,
    layout: &VarlenLayout,
    o: &mut [f32],
    lse: &mut [f32],
) -> u64 {
    let MobaShape { n, d, block, .. } = shape;
    let nb = shape.n_blocks();
    let sm_scale = 1.0 / (d as f32).sqrt();
    let tile_r = cfg.tile_r;
    let tile_c = cfg.tile_c.min(block);

    // global online-softmax accumulators (the SRAM state, sequentially)
    let mut m = vec![NEG_INF; n];
    let mut l = vec![0.0f32; n];
    let mut acc = vec![0.0f32; n * d];
    // dense gather buffers (the SRAM tiles)
    let mut qg = vec![0.0f32; tile_r * d];
    let mut s = vec![0.0f32; tile_r * tile_c];
    let ws = ws_bytes(&[m.len(), l.len(), acc.len(), qg.len(), s.len()]);

    for j in 0..nb {
        let kb = &k[j * block * d..(j + 1) * block * d];
        let vb = &v[j * block * d..(j + 1) * block * d];

        // routed queries (strictly future of block j) + own-block queries
        let routed = layout.queries_of(j);
        let own_start = j * block;

        // process in dense physical tiles: first routed, then own block
        let mut process_tile = |rows: &[u32], causal: bool| {
            let rcount = rows.len();
            // gather-load queries into the dense buffer
            for (r, &t) in rows.iter().enumerate() {
                qg[r * d..(r + 1) * d].copy_from_slice(&q[t as usize * d..(t as usize + 1) * d]);
            }
            let tcs = block.div_ceil(tile_c);
            for ct in 0..tcs {
                let c0 = ct * tile_c;
                let cols = tile_c.min(block - c0);
                // dense GEMM tile: s = qg · kb_tile^T
                for r in 0..rcount {
                    let qt = &qg[r * d..(r + 1) * d];
                    let trow = rows[r] as usize;
                    let srow = &mut s[r * tile_c..r * tile_c + cols];
                    for (cc, sval) in srow.iter_mut().enumerate() {
                        let u = c0 + cc;
                        if causal && own_start + u > trow {
                            *sval = NEG_INF;
                            continue;
                        }
                        *sval = dot(qt, &kb[u * d..(u + 1) * d]) * sm_scale;
                    }
                }
                // online softmax scatter-update
                for r in 0..rcount {
                    let t = rows[r] as usize;
                    let srow = &mut s[r * tile_c..r * tile_c + cols];
                    let mut mt = m[t];
                    for &x in srow.iter() {
                        if x > mt {
                            mt = x;
                        }
                    }
                    if mt == NEG_INF {
                        continue;
                    }
                    let corr = (m[t] - mt).exp();
                    let mut psum = 0.0f32;
                    for x in srow.iter_mut() {
                        *x = if *x <= NEG_INF / 2.0 { 0.0 } else { (*x - mt).exp() };
                        psum += *x;
                    }
                    l[t] = l[t] * corr + psum;
                    let arow = &mut acc[t * d..(t + 1) * d];
                    if corr != 1.0 {
                        scale(arow, corr);
                    }
                    for (cc, &p) in srow.iter().enumerate() {
                        if p == 0.0 {
                            continue;
                        }
                        axpy(arow, p, &vb[(c0 + cc) * d..(c0 + cc + 1) * d]);
                    }
                    m[t] = mt;
                }
            }
        };

        for chunk in routed.chunks(tile_r) {
            process_tile(chunk, false);
        }
        // fused local pass: own-block rows, causal
        let own_rows: Vec<u32> = (own_start as u32..(own_start + block) as u32)
            .take_while(|&t| (t as usize) < n)
            .collect();
        for chunk in own_rows.chunks(tile_r) {
            process_tile(chunk, true);
        }
    }

    // epilogue: normalize
    for t in 0..n {
        let z = if l[t] == 0.0 { 1.0 } else { l[t] };
        for c in 0..d {
            o[t * d + c] = acc[t * d + c] / z;
        }
        lse[t] = m[t] + l[t].max(1e-30).ln();
    }
    ws
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::moba_naive::{moba_naive_forward, moba_reference};
    use crate::attention::testutil::{max_abs_diff, qkv};

    #[test]
    fn matches_reference_and_naive_pipeline() {
        for (n, d, b, k) in [(128, 16, 16, 2), (256, 8, 32, 3), (256, 64, 64, 2), (64, 4, 16, 1)] {
            let shape = MobaShape::new(n, d, b, k);
            let (q, kk, v) = qkv(31, n, d);
            let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
            let (oref, lref) = moba_reference(&q, &kk, &v, shape, &out.indices);
            assert!(max_abs_diff(&out.o, &oref) < 3e-5, "n={n} b={b} k={k}");
            assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
            let (onaive, idx_naive, _) = moba_naive_forward(&q, &kk, &v, shape);
            assert!(crate::attention::topk::same_selection(&out.indices, &idx_naive, k));
            assert!(max_abs_diff(&out.o, &onaive) < 5e-5);
        }
    }

    #[test]
    fn small_tiles_still_correct() {
        let shape = MobaShape::new(128, 8, 32, 2);
        let (q, kk, v) = qkv(32, 128, 8);
        let cfg = FlashMobaConfig { tile_r: 3, tile_c: 5, topk_tile: 3 };
        let out = flash_moba_forward(&q, &kk, &v, shape, cfg);
        let (oref, _) = moba_reference(&q, &kk, &v, shape, &out.indices);
        assert!(max_abs_diff(&out.o, &oref) < 3e-5);
    }

    #[test]
    fn full_routing_equals_dense() {
        let (n, d, b) = (96, 8, 16);
        let shape = MobaShape::new(n, d, b, n / b);
        let (q, kk, v) = qkv(33, n, d);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let (oref, lref) = naive_attention(&q, &kk, &v, n, d);
        assert!(max_abs_diff(&out.o, &oref) < 3e-5);
        assert!(max_abs_diff(&out.lse, &lref) < 3e-5);
    }

    #[test]
    fn uses_less_workspace_than_naive() {
        let shape = MobaShape::new(1024, 64, 64, 4);
        let (q, kk, v) = qkv(34, 1024, 64);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        let (_, _, st_naive) = moba_naive_forward(&q, &kk, &v, shape);
        assert!(out.stats.workspace_bytes < st_naive.workspace_bytes);
    }

    #[test]
    fn two_stage_labels() {
        let shape = MobaShape::new(64, 4, 16, 1);
        let (q, kk, v) = qkv(35, 64, 4);
        let out = flash_moba_forward(&q, &kk, &v, shape, FlashMobaConfig::default());
        assert!(out.stats.get("flash_topk").is_some());
        assert!(out.stats.get("fwd").is_some());
        assert_eq!(out.stats.stages().len(), 2);
    }
}
