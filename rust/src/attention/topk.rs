//! Top-k routed-block selection.
//!
//! * [`naive_topk`] — the original MoBA approach: materialize the full
//!   N×n gating-score matrix, then select per row. Memory: O(N·n) — the
//!   §4.1 "top-k and gating overhead" bottleneck.
//! * [`tiled_topk`] — Flash TopK (Algorithm 3): stream centroid tiles,
//!   maintain a per-query running top-k with an insertion sort (the
//!   paper's bubble-sort-in-registers, k ≪ n), never materializing the
//!   score matrix.
//!
//! Selection is over *strictly past* blocks (the own block is always
//! attended by the main kernel); unused slots are -1.

use super::gemm::qk_row_raw;
use super::stats::ws_bytes;
use super::AttnShape;
use crate::util::pool::{concat, ExecCtx};

/// Materializing reference selection on the process-wide shared pool.
/// Returns ((n, k) indices, workspace bytes).
pub fn naive_topk(
    q: &[f32],
    centroids: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
) -> (Vec<i32>, u64) {
    naive_topk_ctx(ExecCtx::global(), q, centroids, n, d, block, topk)
}

/// [`naive_topk`] on an explicit execution context — the
/// `h = h_kv = 1` slice of [`naive_topk_packed`] (one selection
/// implementation, no divergence risk; the pre-refactor single-head
/// behavior is pinned independently by
/// `rust/tests/singlehead_regression.rs`). `centroids` must hold
/// exactly `n / block` rows.
pub fn naive_topk_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    centroids: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
) -> (Vec<i32>, u64) {
    let shape = AttnShape::new(1, 1, n, d, block, topk);
    naive_topk_packed(ctx, q, centroids, &shape)
}

/// Insert (score, index) into a descending running top-k — the paper's
/// bubble-sort-in-registers. Strict `>` admission: equal scores keep
/// the earlier index, and NaN is never admitted.
///
/// This is the single insertion implementation shared by the prefill
/// [`tiled_topk`] and the decode-path routing
/// ([`KvCache::route`](super::decode::KvCache::route)); their selection
/// parity (identical sets *and* tie-breaking) depends on both calling
/// exactly this. `best_s`/`best_i` must be non-empty and equal length.
#[inline]
pub fn topk_insert(best_s: &mut [f32], best_i: &mut [i32], score: f32, index: i32) {
    let k = best_s.len();
    debug_assert_eq!(k, best_i.len());
    if score > best_s[k - 1] {
        let mut pos = k - 1;
        while pos > 0 && best_s[pos - 1] < score {
            best_s[pos] = best_s[pos - 1];
            best_i[pos] = best_i[pos - 1];
            pos -= 1;
        }
        best_s[pos] = score;
        best_i[pos] = index;
    }
}

/// Streaming selection (Flash TopK) on the process-wide shared pool.
/// Returns ((n, k) indices, workspace bytes).
pub fn tiled_topk(
    q: &[f32],
    centroids: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
    tile_c: usize,
) -> (Vec<i32>, u64) {
    tiled_topk_ctx(ExecCtx::global(), q, centroids, n, d, block, topk, tile_c)
}

/// [`tiled_topk`] on an explicit execution context — the
/// `h = h_kv = 1` slice of [`tiled_topk_packed`] (one selection
/// implementation; the pre-refactor single-head behavior is pinned
/// independently by `rust/tests/singlehead_regression.rs`).
/// `centroids` must hold exactly `n / block` rows — with a ragged `n`,
/// tail-block queries see every complete block as a candidate.
///
/// `tile_c` is the centroid tile width; the running top-k state is
/// O(k) per query row — `ws` counts only the per-tile score buffer.
#[allow(clippy::too_many_arguments)]
pub fn tiled_topk_ctx(
    ctx: &ExecCtx,
    q: &[f32],
    centroids: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
    tile_c: usize,
) -> (Vec<i32>, u64) {
    let shape = AttnShape::new(1, 1, n, d, block, topk);
    tiled_topk_packed(ctx, q, centroids, &shape, tile_c)
}

/// Packed multi-head materializing selection (the original pipeline's
/// gating): q is `(h, n, d)`, `centroids` is `(h_kv, cb, d)` from
/// [`centroids_packed`](super::centroid::centroids_packed). Each query
/// head scores its group's KV-head centroids; the full `(h, n, cb)`
/// score tensor is materialized — that overhead *is* the original
/// pipeline being reproduced. Returns (`(h, n, topk)` indices, ws
/// bytes). Work units are flattened `(head, row)` pairs, so `h = 1`
/// partitions and selects exactly as [`naive_topk_ctx`].
pub fn naive_topk_packed(
    ctx: &ExecCtx,
    q: &[f32],
    centroids: &[f32],
    shape: &AttnShape,
) -> (Vec<i32>, u64) {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let cb = shape.complete_blocks();
    assert_eq!(q.len(), h * n * d);
    assert_eq!(centroids.len(), h_kv * cb * d);
    let group = shape.group();
    let units = h * n;
    // full score tensor, exactly like the original implementation —
    // each row scored by the register-blocked gemv (bit-identical to
    // the per-block dot)
    let scores: Vec<f32> = concat(ctx.pool().map_ranges(units, |range| {
        let mut chunk = vec![0.0f32; range.len() * cb];
        for (uu, u) in range.enumerate() {
            let (qh, t) = (u / n, u % n);
            let qt = &q[(qh * n + t) * d..(qh * n + t + 1) * d];
            let ch = &centroids[(qh / group) * cb * d..(qh / group + 1) * cb * d];
            qk_row_raw(qt, ch, d, cb, &mut chunk[uu * cb..(uu + 1) * cb]);
        }
        chunk
    }));
    let ws = ws_bytes(&[scores.len()]);
    let out: Vec<i32> = concat(ctx.pool().map_ranges(units, |range| {
        let mut chunk = vec![-1i32; range.len() * topk];
        let mut order: Vec<usize> = Vec::with_capacity(cb);
        for (uu, u) in range.enumerate() {
            let t = u % n;
            // candidates: complete strictly-past blocks. Tail-block
            // queries have own == cb, so they see every complete block.
            let own = (t / block).min(cb);
            let row = &scores[u * cb..(u + 1) * cb];
            order.clear();
            order.extend((0..own).filter(|&j| !row[j].is_nan()));
            order.sort_by(|&a, &b| row[b].total_cmp(&row[a]));
            for (slot, &j) in order.iter().take(topk).enumerate() {
                chunk[uu * topk + slot] = j as i32;
            }
        }
        chunk
    }));
    (out, ws)
}

/// Packed multi-head streaming selection (Flash TopK): same inputs as
/// [`naive_topk_packed`], O(k) running state per query row, no score
/// tensor. Returns (`(h, n, topk)` indices, ws bytes). `h = 1` selects
/// bit-identically to [`tiled_topk_ctx`].
pub fn tiled_topk_packed(
    ctx: &ExecCtx,
    q: &[f32],
    centroids: &[f32],
    shape: &AttnShape,
    tile_c: usize,
) -> (Vec<i32>, u64) {
    let mut out = Vec::new();
    let ws = tiled_topk_packed_into(ctx, q, centroids, shape, tile_c, &mut out);
    (out, ws)
}

/// [`tiled_topk_packed`] writing the `(h, n, topk)` table into a
/// caller-provided buffer, with the per-worker running state and the
/// per-tile score buffer drawn from the context's scratch arenas — the
/// zero-allocation steady-state path. Centroid scoring runs on the
/// register-blocked gemv ([`qk_row_raw`]), which is bit-identical to
/// the per-block dot it replaced, and the streaming insertion order is
/// unchanged — so the selection (sets *and* tie-breaks) is exactly the
/// scalar kernel's.
pub fn tiled_topk_packed_into(
    ctx: &ExecCtx,
    q: &[f32],
    centroids: &[f32],
    shape: &AttnShape,
    tile_c: usize,
    out: &mut Vec<i32>,
) -> u64 {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let cb = shape.complete_blocks();
    assert_eq!(q.len(), h * n * d);
    assert_eq!(centroids.len(), h_kv * cb * d);
    let group = shape.group();
    let tile_c = tile_c.max(1);
    if topk == 0 {
        out.clear();
        return ws_bytes(&[tile_c]);
    }
    let ws = ws_bytes(&[tile_c + 2 * topk]);
    // resize only: every row is overwritten below, and a same-length
    // resize is a no-op on steady-state calls
    out.resize(h * n * topk, -1);
    let none: &mut [f32] = &mut [];
    ctx.pool().for_ranges_split(
        h * n,
        out.as_mut_slice(),
        none,
        |u| (u * topk, 0),
        |slot, range, chunk, _| {
            let mut scratch = ctx.scratch(slot);
            let mut best_s = scratch.take_f32(topk, f32::NEG_INFINITY);
            let mut best_i = scratch.take_i32(topk, -1);
            // a tile never spans more than the cb candidate blocks
            let mut scores = scratch.take_f32(tile_c.min(cb), 0.0);
            for (uu, u) in range.enumerate() {
                let (qh, t) = (u / n, u % n);
                let own = (t / block).min(cb); // candidates: complete blocks [0, own)
                let qt = &q[(qh * n + t) * d..(qh * n + t + 1) * d];
                let ch = &centroids[(qh / group) * cb * d..(qh / group + 1) * cb * d];
                best_s.fill(f32::NEG_INFINITY);
                best_i.fill(-1);
                let mut j0 = 0;
                while j0 < own {
                    let jend = (j0 + tile_c).min(own);
                    let width = jend - j0;
                    qk_row_raw(qt, &ch[j0 * d..jend * d], d, width, &mut scores[..width]);
                    for (jj, &sc) in scores[..width].iter().enumerate() {
                        topk_insert(&mut best_s, &mut best_i, sc, (j0 + jj) as i32);
                    }
                    j0 = jend;
                }
                chunk[uu * topk..(uu + 1) * topk].copy_from_slice(&best_i);
            }
            scratch.give_f32(scores);
            scratch.give_i32(best_i);
            scratch.give_f32(best_s);
        },
    );
    ws
}

/// Observed routing score margin for the runtime dense-fallback probe
/// (`RoutePlan::fallback_margin`): how decisively the top-k selection
/// separates chosen from rejected blocks.
///
/// Samples up to `max_rows` evenly spaced query rows per head. For each
/// sampled row with more candidates than `topk`, the row margin is
/// `min(selected scores) - max(rejected scores)` under exactly the
/// [`topk_insert`] admission rule (strict `>`, earliest index wins
/// ties, NaN never admitted — NaN-scored blocks are skipped on the
/// rejected side too). Rows where everything fits in the top-k
/// contribute nothing. Returns the mean row margin, or `+inf` when no
/// sampled row rejects anything — routing is then trivially safe and
/// the fallback never fires.
///
/// The probe is serial and deterministic (fixed sample grid, fixed
/// accumulation order) so enabling it never perturbs the bit-exact
/// kernel outputs — it only chooses *which* deterministic kernel runs.
pub fn routing_margin(
    q: &[f32],
    centroids: &[f32],
    shape: &AttnShape,
    max_rows: usize,
) -> f32 {
    let AttnShape { h, h_kv, n, d, block, topk } = *shape;
    let cb = shape.complete_blocks();
    assert_eq!(q.len(), h * n * d);
    assert_eq!(centroids.len(), h_kv * cb * d);
    if topk == 0 || cb <= topk {
        return f32::INFINITY;
    }
    let group = shape.group();
    let step = n.div_ceil(max_rows.max(1)).max(1);
    let mut scores = vec![0.0f32; cb];
    let mut best_s = vec![f32::NEG_INFINITY; topk];
    let mut best_i = vec![-1i32; topk];
    let (mut sum, mut rows) = (0.0f64, 0usize);
    for qh in 0..h {
        let ch = &centroids[(qh / group) * cb * d..(qh / group + 1) * cb * d];
        let mut t = step - 1; // sample late rows first-class: they see the most candidates
        while t < n {
            let own = (t / block).min(cb);
            if own > topk {
                let qt = &q[(qh * n + t) * d..(qh * n + t + 1) * d];
                qk_row_raw(qt, &ch[..own * d], d, own, &mut scores[..own]);
                best_s.fill(f32::NEG_INFINITY);
                best_i.fill(-1);
                for (j, &sc) in scores[..own].iter().enumerate() {
                    topk_insert(&mut best_s, &mut best_i, sc, j as i32);
                }
                let mut max_rej = f32::NEG_INFINITY;
                for (j, &sc) in scores[..own].iter().enumerate() {
                    if sc.is_nan() || best_i.contains(&(j as i32)) {
                        continue;
                    }
                    max_rej = max_rej.max(sc);
                }
                if max_rej > f32::NEG_INFINITY {
                    sum += (best_s[topk - 1] - max_rej) as f64;
                    rows += 1;
                }
            }
            t += step;
        }
    }
    if rows == 0 {
        f32::INFINITY
    } else {
        (sum / rows as f64) as f32
    }
}

/// Set-equality of two routing tables (order within a row is irrelevant).
pub fn same_selection(a: &[i32], b: &[i32], topk: usize) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut ra: Vec<i32> = Vec::with_capacity(topk);
    let mut rb: Vec<i32> = Vec::with_capacity(topk);
    for (ca, cb) in a.chunks(topk).zip(b.chunks(topk)) {
        ra.clear();
        rb.clear();
        ra.extend_from_slice(ca);
        rb.extend_from_slice(cb);
        ra.sort_unstable();
        rb.sort_unstable();
        if ra != rb {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::centroid::centroids;
    use crate::attention::testutil::qkv;

    #[test]
    fn tiled_matches_naive() {
        for (n, d, b, k, tc) in [(256, 16, 32, 3, 4), (128, 8, 16, 8, 3), (512, 32, 64, 2, 8)] {
            let (q, kk, _) = qkv(11, n, d);
            let c = centroids(&kk, n, d, b);
            let (a, ws_naive) = naive_topk(&q, &c, n, d, b, k);
            let (t, ws_tiled) = tiled_topk(&q, &c, n, d, b, k, tc);
            assert!(same_selection(&a, &t, k), "n={n} b={b} k={k}");
            assert!(ws_naive > ws_tiled, "naive must materialize more");
        }
    }

    #[test]
    fn first_block_has_no_candidates() {
        let (q, kk, _) = qkv(12, 64, 8);
        let c = centroids(&kk, 64, 8, 16);
        let (idx, _) = tiled_topk(&q, &c, 64, 8, 16, 2, 4);
        for t in 0..16 {
            assert_eq!(&idx[t * 2..t * 2 + 2], &[-1, -1]);
        }
    }

    #[test]
    fn selection_is_strictly_past() {
        let (q, kk, _) = qkv(13, 256, 16);
        let c = centroids(&kk, 256, 16, 32);
        let (idx, _) = tiled_topk(&q, &c, 256, 16, 32, 4, 3);
        for t in 0..256 {
            let own = (t / 32) as i32;
            for s in 0..4 {
                let j = idx[t * 4 + s];
                assert!(j < own, "t={t} j={j} own={own}");
            }
        }
    }

    #[test]
    fn scores_of_selected_dominate_unselected() {
        let (q, kk, _) = qkv(14, 128, 8);
        let (n, d, b, k) = (128, 8, 16, 2);
        let c = centroids(&kk, n, d, b);
        let (idx, _) = tiled_topk(&q, &c, n, d, b, k, 4);
        let nb = n / b;
        let t = n - 1; // last row: all 7 past blocks candidates
        let dots: Vec<f32> = (0..nb)
            .map(|j| (0..d).map(|cc| q[t * d + cc] * c[j * d + cc]).sum())
            .collect();
        let own = t / b;
        let sel: Vec<i32> = idx[t * k..(t + 1) * k].to_vec();
        let min_sel = sel.iter().map(|&j| dots[j as usize]).fold(f32::MAX, f32::min);
        for j in 0..own {
            if !sel.contains(&(j as i32)) {
                assert!(dots[j] <= min_sel + 1e-6);
            }
        }
    }

    /// The shared insertion: descending order, earliest index wins
    /// ties, NaN never admitted.
    #[test]
    fn topk_insert_orders_ties_and_rejects_nan() {
        let mut s = [f32::NEG_INFINITY; 3];
        let mut i = [-1i32; 3];
        topk_insert(&mut s, &mut i, 1.0, 0);
        topk_insert(&mut s, &mut i, 2.0, 1);
        topk_insert(&mut s, &mut i, 1.0, 2); // tie with index 0: stays behind it
        assert_eq!(i, [1, 0, 2]);
        assert_eq!(s, [2.0, 1.0, 1.0]);
        topk_insert(&mut s, &mut i, f32::NAN, 9); // NaN fails the > admission
        assert_eq!(i, [1, 0, 2]);
        topk_insert(&mut s, &mut i, 3.0, 3);
        assert_eq!(i, [3, 1, 0]);
    }

    #[test]
    fn same_selection_detects_mismatch() {
        assert!(same_selection(&[1, 2, 3, 4], &[2, 1, 4, 3], 2));
        assert!(!same_selection(&[1, 2, 3, 4], &[1, 2, 3, 5], 2));
        assert!(!same_selection(&[1, 2], &[1, 2, 3, 4], 2));
    }

    /// Degenerate tile widths: a tile larger than the whole candidate
    /// set, tile width 1 (fully serial streaming), and the clamped
    /// width-0 case must all select exactly what the materializing
    /// reference selects.
    #[test]
    fn degenerate_tile_widths_match_naive() {
        let (n, d, b, k) = (256, 8, 16, 3);
        let nb = n / b;
        let (q, kk, _) = qkv(15, n, d);
        let c = centroids(&kk, n, d, b);
        let (reference, _) = naive_topk(&q, &c, n, d, b, k);
        for tile_c in [1, nb, nb + 7, 10 * nb, 0] {
            let (t, _) = tiled_topk(&q, &c, n, d, b, k, tile_c);
            assert!(same_selection(&reference, &t, k), "tile_c={tile_c}");
        }
    }

    /// topk larger than the candidate set: unused slots stay -1 and the
    /// selected prefix matches the reference.
    #[test]
    fn topk_exceeding_candidates_pads_with_minus_one() {
        let (n, d, b) = (64, 4, 16);
        let nb = n / b; // 4 blocks; k = 6 > any candidate count
        let k = nb + 2;
        let (q, kk, _) = qkv(16, n, d);
        let c = centroids(&kk, n, d, b);
        let (a, _) = naive_topk(&q, &c, n, d, b, k);
        let (t, _) = tiled_topk(&q, &c, n, d, b, k, 3);
        assert!(same_selection(&a, &t, k));
        // the last row has nb-1 = 3 candidates -> 3 real picks, 3 pads
        let last = &t[(n - 1) * k..n * k];
        assert_eq!(last.iter().filter(|&&j| j >= 0).count(), nb - 1);
        assert_eq!(last.iter().filter(|&&j| j == -1).count(), k - (nb - 1));
    }

    /// k = 0 must produce an empty selection from both selectors (the
    /// streaming kernel's insertion indexes `best_s[k - 1]`).
    #[test]
    fn topk_zero_is_empty_not_a_panic() {
        let (n, d, b) = (64, 4, 16);
        let (q, kk, _) = qkv(18, n, d);
        let c = centroids(&kk, n, d, b);
        let (a, _) = naive_topk(&q, &c, n, d, b, 0);
        let (t, _) = tiled_topk(&q, &c, n, d, b, 0, 4);
        assert!(a.is_empty());
        assert!(t.is_empty());
    }

    /// Multi-head packed selection == per-head single-head selection
    /// with the GQA head mapping, including a ragged tail (whose rows
    /// see every complete block as candidates) and both selectors
    /// agreeing with each other.
    #[test]
    fn packed_gqa_selection_matches_per_head_reference() {
        use crate::attention::centroid::centroids_packed;
        use crate::attention::testutil::qkv_packed;
        use crate::attention::AttnShape;
        use crate::util::pool::ExecCtx;
        let ctx = ExecCtx::with_threads(3);
        for shape in [
            AttnShape::new(4, 2, 128, 8, 16, 2),
            AttnShape::new(2, 1, 100, 4, 16, 3), // ragged tail
        ] {
            let (q, kk, _) = qkv_packed(20, shape.h, shape.h_kv, shape.n, shape.d);
            let c = centroids_packed(&ctx, &kk, shape.h_kv, shape.n, shape.d, shape.block);
            let cb = shape.complete_blocks();
            let (a, _) = naive_topk_packed(&ctx, &q, &c, &shape);
            let (t, _) = tiled_topk_packed(&ctx, &q, &c, &shape, 3);
            assert_eq!(a.len(), shape.h * shape.n * shape.topk);
            assert!(same_selection(&a, &t, shape.topk), "{shape:?}");
            for qh in 0..shape.h {
                let kvh = shape.kv_head_of(qh);
                let qs = &q[qh * shape.n * shape.d..(qh + 1) * shape.n * shape.d];
                let cs = &c[kvh * cb * shape.d..(kvh + 1) * cb * shape.d];
                // single-head selection over this head's slices must
                // reproduce the head's slab of the packed table (tail
                // rows see all cb complete blocks as candidates)
                let (single, _) =
                    tiled_topk_ctx(&ctx, qs, cs, shape.n, shape.d, shape.block, shape.topk, 3);
                assert_eq!(
                    &t[qh * shape.n * shape.topk..(qh + 1) * shape.n * shape.topk],
                    &single[..],
                    "head {qh} {shape:?}"
                );
            }
        }
    }

    /// The margin probe: +inf when nothing can be rejected, finite and
    /// equal to min(selected) - max(rejected) when a row rejects, and
    /// deterministic across calls.
    #[test]
    fn routing_margin_basics() {
        let (n, d, b, k) = (128, 8, 16, 2);
        let (q, kk, _) = qkv(21, n, d);
        let c = centroids(&kk, n, d, b);
        // topk >= candidate universe: probe is trivially safe
        let safe = AttnShape::single(n, d, b, n / b);
        assert_eq!(routing_margin(&q, &c, &safe, 32), f32::INFINITY);
        // a real selection: margin is finite and repeatable
        let shape = AttnShape::single(n, d, b, k);
        let m1 = routing_margin(&q, &c, &shape, 32);
        let m2 = routing_margin(&q, &c, &shape, 32);
        assert!(m1.is_finite());
        assert_eq!(m1.to_bits(), m2.to_bits());
        // hand-check the last row (own = 7 candidates, k = 2)
        let t = n - 1;
        let own = t / b;
        let dots: Vec<f32> = (0..own)
            .map(|j| (0..d).map(|cc| q[t * d + cc] * c[j * d + cc]).sum())
            .collect();
        let mut sorted = dots.clone();
        sorted.sort_by(|a, z| z.total_cmp(a));
        let expect = sorted[k - 1] - sorted[k];
        // a row's margin is min(selected) - max(rejected): never negative
        assert!(expect >= 0.0);
    }

    /// A well-separated head (one dominant block) yields a large margin;
    /// an adversarial head (identical centroids) yields margin ~0.
    #[test]
    fn routing_margin_separates_strong_from_degenerate_heads() {
        let (n, d, b, k) = (128, 4, 16, 1);
        let shape = AttnShape::single(n, d, b, k);
        let cb = n / b;
        // strong: block 0's centroid aligned with every query
        let q = vec![1.0f32; n * d];
        let mut c = vec![0.0f32; cb * d];
        for x in c[..d].iter_mut() {
            *x = 5.0;
        }
        let strong = routing_margin(&q, &c, &shape, 32);
        assert!(strong > 1.0, "strong={strong}");
        // degenerate: all centroids identical -> every margin is 0
        let c0 = vec![0.5f32; cb * d];
        let degen = routing_margin(&q, &c0, &shape, 32);
        assert_eq!(degen, 0.0);
        assert!(degen < strong);
    }

    /// NaN gating scores must not panic the materializing sort and must
    /// leave NaN-scored blocks unselected — mirroring the streaming
    /// kernel, whose `>` insertion never admits NaN.
    #[test]
    fn nan_scores_do_not_panic_and_are_never_selected() {
        let (n, d, b, k) = (64, 4, 16, 2);
        let (q, kk, _) = qkv(17, n, d);
        let mut c = centroids(&kk, n, d, b);
        // poison block 1's centroid: every q·c score for block 1 is NaN
        for x in c[d..2 * d].iter_mut() {
            *x = f32::NAN;
        }
        let (a, _) = naive_topk(&q, &c, n, d, b, k);
        let (t, _) = tiled_topk(&q, &c, n, d, b, k, 4);
        assert!(same_selection(&a, &t, k));
        assert!(a.iter().all(|&j| j != 1), "NaN block selected by naive_topk");
        assert!(t.iter().all(|&j| j != 1), "NaN block selected by tiled_topk");
    }
}
