//! The [`AttentionBackend`] trait: one call convention for every
//! attention implementation in the substrate, plus a [`BackendRegistry`]
//! and the cross-backend parity harness.
//!
//! Before this existed, `dense::flash_attention`, `moba_naive` and
//! `flash_moba` were three disconnected signatures and every consumer
//! (coordinator, evaluators, bench harness) hard-coded all three. The
//! trait makes "which attention" a runtime value, so new backends
//! (varlen batching, kconv-routed selection, adaptive block sizes) plug
//! in by registering one object — and inherit the parity harness, the
//! figure sweeps and the serving router for free.
//!
//! Call convention: packed row-major tensors. `q` and the returned
//! output are `(h, n, d)`, `k`/`v` are `(h_kv, n, d)`; the head layout
//! and routing geometry ride in the [`AttnShape`]. One `forward` (or
//! `forward_decode`) call covers the whole head dimension — backends
//! iterate heads internally, computing centroids once per KV head and
//! routing once per query head.
//!
//! Every call takes an [`ExecCtx`]: the shared thread pool the kernels
//! partition their work over. Consumers hand one pool to all backends
//! (the coordinator's worker, the bench harness, the evaluators) rather
//! than each spawning its own; results are bit-identical at any worker
//! count (the determinism contract of `crate::util::pool`).

use super::centroid::centroids_packed;
use super::decode::DecodeSession;
use super::dense::{flash_attention_packed_into, naive_attention_packed};
use super::flash_moba::{flash_moba_forward_ctx, flash_moba_forward_into, FlashMobaConfig};
use super::moba_naive::moba_naive_forward_ctx;
use super::plan::RoutePlan;
use super::stats::StageStats;
use super::testutil::{max_abs_diff, qkv_packed};
use super::topk::routing_margin;
use super::AttnShape;
use crate::util::pool::{partition, ExecCtx};

/// Query rows sampled per head by the runtime dense-fallback margin
/// probe (`RoutePlan::fallback_margin`).
const MARGIN_PROBE_ROWS: usize = 32;

/// A causal attention implementation over packed multi-head tensors.
///
/// Inputs are packed row-major f32: `q` is `(h, n, d)`, `k`/`v` are
/// `(h_kv, n, d)`; the head layout and routing geometry (block size,
/// top-k) ride in the [`AttnShape`]. Implementations that ignore
/// routing (dense) simply read the head layout and `n`/`d`.
pub trait AttentionBackend: Send + Sync {
    /// Stable registry key (also the display name in reports).
    fn name(&self) -> &'static str;

    /// Supported-config predicate: can this backend run this geometry?
    /// Callers must check before `forward` (routers use this to fall
    /// back, harnesses to skip).
    fn supports(&self, shape: &AttnShape) -> bool;

    /// `true` when the output equals dense attention for *any* routing
    /// (no sparsity approximation). Exact backends are compared against
    /// the dense oracle on every shape by the parity harness; sparse
    /// ones only at full routing, plus pairwise against each other.
    fn is_exact(&self) -> bool {
        false
    }

    /// Run the forward pass on `ctx`'s thread pool. Returns the packed
    /// `(h, n, d)` output and the stage timings / workspace accounting
    /// of the run (stamped with the shape's head count — one launch
    /// covers all heads).
    ///
    /// Contract: the output is bit-identical for any `ctx.threads()` —
    /// implementations parallelize by partitioning independent
    /// `head × query-row` work units, never by reordering reductions
    /// (asserted for every registered backend by the determinism
    /// property suite and the CI `MOBA_THREADS` matrix) — and
    /// `h = h_kv = 1` reproduces the single-head path bit-for-bit
    /// (pinned by `rust/tests/singlehead_regression.rs`).
    fn forward(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, StageStats);

    /// [`forward`](AttentionBackend::forward) writing the packed
    /// `(h, n, d)` output into a caller-provided buffer — the
    /// steady-state serving entry point. The output is bit-identical
    /// to `forward`'s. The default clones through `forward`; the
    /// `dense` and `flash_moba` backends override it with genuinely
    /// allocation-free paths (intermediates drawn from `ctx`'s scratch
    /// arenas), so a caller that reuses `o` across same-shape calls
    /// allocates nothing after warmup (pinned by
    /// `rust/tests/alloc_regression.rs`). `moba_naive` only avoids the
    /// output copy: its five-stage pipeline materializes intermediates
    /// by design — that overhead *is* the baseline being reproduced.
    fn forward_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        let (out, st) = self.forward(ctx, shape, q, k, v);
        o.clear();
        o.extend_from_slice(&out);
        st
    }

    /// Run the forward pass under a per-head [`RoutePlan`]: each KV
    /// head attends at its own `(block, topk)` (query heads in a GQA
    /// group share their KV head's plan), or densely for
    /// `HeadMode::Dense` heads and for heads the runtime margin probe
    /// degrades. Returns the packed `(h, n, d)` output and stats whose
    /// `fallback_heads` counts the probe-degraded heads.
    fn forward_plan(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        plan: &RoutePlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, StageStats) {
        let mut o = Vec::new();
        let st = self.forward_plan_into(ctx, shape, plan, q, k, v, &mut o);
        (o, st)
    }

    /// [`forward_plan`](AttentionBackend::forward_plan) writing into a
    /// caller-provided buffer. The default implementation covers every
    /// backend:
    ///
    /// * **Uniform plan, probe disabled** — delegates to
    ///   [`forward_into`](AttentionBackend::forward_into) with the
    ///   plan's `(block, topk)` substituted into the shape: literally
    ///   the pre-plan code path, so `RoutePlan::uniform` output is
    ///   `to_bits`-identical to the static-`AttnShape` path at any
    ///   thread count (pinned by the property suite).
    /// * **Mixed or probed plan** — dispatches KV heads in ascending
    ///   order over their contiguous packed slices, each as an
    ///   `(h = group, h_kv = 1)` sub-launch of this backend's own
    ///   `forward_into`. The kernels treat heads independently, so the
    ///   composition equals a per-head reference splice bit for bit,
    ///   and stays deterministic at any thread count. A planned-dense
    ///   or probe-degraded head runs *fully routed* (`topk` covering
    ///   every candidate), which equals dense causal attention through
    ///   this backend's own kernels (numerically within the parity
    ///   tolerance of the dense oracle; `DenseBackend` overrides the
    ///   whole method since every plan is dense to it). This path
    ///   allocates per-head staging; only the uniform fast path is
    ///   allocation-free.
    ///
    /// When `plan.fallback_enabled()`, each routed head is first probed
    /// with [`routing_margin`]; a head whose observed margin falls
    /// below `plan.fallback_margin` degrades to dense for this call and
    /// increments `StageStats::fallback_heads`.
    #[allow(clippy::too_many_arguments)]
    fn forward_plan_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        plan: &RoutePlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        assert_eq!(
            plan.h_kv(),
            shape.h_kv,
            "route plan covers {} KV heads, shape has {}",
            plan.h_kv(),
            shape.h_kv
        );
        if !plan.fallback_enabled() {
            if let Some((block, topk)) = plan.is_uniform() {
                let uni = AttnShape { block, topk, ..*shape };
                return self.forward_into(ctx, &uni, q, k, v, o);
            }
        }
        let AttnShape { h, h_kv, n, d, .. } = *shape;
        let group = shape.group();
        let mut st = StageStats::for_heads(ctx, h);
        o.clear();
        o.resize(h * n * d, 0.0);
        // one timed stage for the whole dispatch (a per-head record
        // pair would overflow the inline stage cap at large h_kv);
        // fallback / workspace tallies accumulate in locals because the
        // closure must not borrow `st`
        let mut fallback = 0u32;
        let mut ws = 0u64;
        let mut sub_o: Vec<f32> = Vec::new();
        st.time("plan_fwd", || {
            for kvh in 0..h_kv {
                let hp = *plan.head(kvh);
                let qs = &q[kvh * group * n * d..(kvh + 1) * group * n * d];
                let ks = &k[kvh * n * d..(kvh + 1) * n * d];
                let vs = &v[kvh * n * d..(kvh + 1) * n * d];
                let sub = AttnShape::new(group, 1, n, d, hp.block, hp.topk);
                let mut dense = hp.is_dense();
                if !dense && plan.fallback_enabled() && !fully_routed(&sub) {
                    let cents = centroids_packed(ctx, ks, 1, n, d, hp.block);
                    let margin = routing_margin(qs, &cents, &sub, MARGIN_PROBE_ROWS);
                    if margin < plan.fallback_margin {
                        dense = true;
                        fallback += 1;
                    }
                }
                let run = if dense {
                    // fully routed == dense causal through this backend
                    AttnShape { topk: sub.max_candidates().max(1), ..sub }
                } else {
                    sub
                };
                sub_o.clear();
                ws += self.forward_into(ctx, &run, qs, ks, vs, &mut sub_o).workspace_bytes;
                o[kvh * group * n * d..(kvh + 1) * group * n * d].copy_from_slice(&sub_o);
            }
        });
        st.add_workspace(ws);
        st.fallback_heads = fallback;
        st
    }

    /// One autoregressive decode step: attention of the packed
    /// `(h, d)` query `q_t` (at the session's current position, i.e.
    /// its last appended token) over the session's KV cache. One call
    /// covers all query heads; returns the packed `(h, d)` output row.
    ///
    /// Contract: token-by-token decode must reproduce this backend's
    /// prefill [`forward`](AttentionBackend::forward) row-for-row (the
    /// decode parity suite asserts this for every registered backend).
    /// The default is the exact dense fallback over everything cached —
    /// correct for exact backends; sparse backends override with the
    /// routed path. A decode step is h single O((k+1)·B·d) rows, below
    /// the threshold where fan-out pays, so implementations run serial
    /// regardless of `ctx` — the parameter keeps the call convention
    /// uniform (one pool per consumer) for heavier future backends.
    fn forward_decode(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
    ) -> Vec<f32> {
        session.decode_dense(q_t)
    }

    /// [`forward_decode`](AttentionBackend::forward_decode) writing the
    /// packed `(h, d)` output row into a caller-provided buffer — the
    /// serving decode lane's entry point. Bit-identical to
    /// `forward_decode`. With the session's persistent step workspace,
    /// the in-tree backends' overrides make a steady-state step
    /// perform zero heap allocations.
    fn forward_decode_into(
        &self,
        ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
        o: &mut Vec<f32>,
    ) {
        let out = self.forward_decode(ctx, session, q_t);
        o.clear();
        o.extend_from_slice(&out);
    }

    /// Batched cross-session decode: one launch steps B independent
    /// sessions. `q` is the concatenation of each session's packed
    /// `(h_i, d_i)` query row in slice order (sessions may have
    /// heterogeneous head layouts, dims and plans); the returned buffer
    /// holds each session's `(h_i, d_i)` output row at the matching
    /// prefix-sum offset.
    ///
    /// Contract: the output — and every per-session side effect
    /// (routing choices, margin fallbacks, step counters) — is
    /// bit-identical to calling
    /// [`forward_decode`](AttentionBackend::forward_decode) on each
    /// session in slice order, at any `ctx.threads()`. Implementations
    /// parallelize by partitioning whole sessions across workers
    /// (per-session arithmetic unchanged, outputs through disjoint
    /// windows), never by splitting a session's reduction. The default
    /// is literally the sequential loop.
    fn forward_decode_batch(
        &self,
        ctx: &ExecCtx,
        sessions: &mut [DecodeSession],
        q: &[f32],
    ) -> Vec<f32> {
        let mut o = Vec::new();
        self.forward_decode_batch_into(ctx, sessions, q, &mut o);
        o
    }

    /// [`forward_decode_batch`](AttentionBackend::forward_decode_batch)
    /// writing the packed batch output into a caller-provided buffer —
    /// the serving decode lane's entry point. With each session's
    /// persistent step workspace and a reused `o`, the in-tree
    /// overrides make a steady-state serial batch step perform zero
    /// heap allocations (the parallel path boxes one task per worker,
    /// matching the pool's convention that only the serial path is
    /// allocation-free).
    fn forward_decode_batch_into(
        &self,
        ctx: &ExecCtx,
        sessions: &mut [DecodeSession],
        q: &[f32],
        o: &mut Vec<f32>,
    ) {
        let total: usize = sessions.iter().map(|s| s.h() * s.d()).sum();
        assert_eq!(q.len(), total, "packed batch query length mismatch");
        o.clear();
        let mut off = 0;
        for sess in sessions.iter_mut() {
            let e = sess.h() * sess.d();
            let row = self.forward_decode(ctx, sess, &q[off..off + e]);
            o.extend_from_slice(&row);
            off += e;
        }
    }
}

/// Shared engine behind the in-tree backends'
/// [`AttentionBackend::forward_decode_batch_into`] overrides: step
/// every session through `step` (the session's dense or routed slice
/// path). Serial contexts — and single-session batches — run the plain
/// loop with zero allocations; parallel contexts partition *whole
/// sessions* into contiguous ranges ([`partition`]'s deterministic
/// split), carve matching disjoint query/output windows via sequential
/// `split_at_mut`, and fan the ranges out over the pool. Per-session
/// arithmetic is identical in both paths, so outputs and session
/// counters are bit-identical to the sequential loop at any worker
/// count.
fn batched_decode_dispatch(
    ctx: &ExecCtx,
    sessions: &mut [DecodeSession],
    q: &[f32],
    o: &mut Vec<f32>,
    step: fn(&mut DecodeSession, &[f32], &mut [f32]),
) {
    let total: usize = sessions.iter().map(|s| s.h() * s.d()).sum();
    assert_eq!(q.len(), total, "packed batch query length mismatch");
    // resize only: every window is fully rewritten by its session
    o.resize(total, 0.0);
    let workers = ctx.threads().min(sessions.len());
    if workers <= 1 {
        let mut off = 0;
        for sess in sessions.iter_mut() {
            let e = sess.h() * sess.d();
            step(sess, &q[off..off + e], &mut o[off..off + e]);
            off += e;
        }
        return;
    }
    let ranges = partition(sessions.len(), workers);
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut sess_rest = sessions;
    let mut q_rest = q;
    let mut o_rest = &mut o[..];
    for range in ranges {
        let count = range.len();
        let elems: usize = sess_rest[..count].iter().map(|s| s.h() * s.d()).sum();
        let (sess_chunk, sr) = std::mem::take(&mut sess_rest).split_at_mut(count);
        let (q_chunk, qr) = q_rest.split_at(elems);
        let (o_chunk, or) = std::mem::take(&mut o_rest).split_at_mut(elems);
        sess_rest = sr;
        q_rest = qr;
        o_rest = or;
        tasks.push(Box::new(move || {
            let mut off = 0;
            for sess in sess_chunk.iter_mut() {
                let e = sess.h() * sess.d();
                step(sess, &q_chunk[off..off + e], &mut o_chunk[off..off + e]);
                off += e;
            }
        }));
    }
    ctx.pool().run_tasks(tasks);
}

/// Blocked online-softmax dense attention (the FlashAttention-2
/// analogue) behind the trait.
#[derive(Debug, Clone, Copy)]
pub struct DenseBackend {
    pub br: usize,
    pub bc: usize,
}

impl Default for DenseBackend {
    fn default() -> Self {
        Self { br: 64, bc: 64 }
    }
}

impl AttentionBackend for DenseBackend {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn supports(&self, _shape: &AttnShape) -> bool {
        true
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn forward(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, StageStats) {
        let mut o = Vec::new();
        let st = self.forward_into(ctx, shape, q, k, v, &mut o);
        (o, st)
    }

    fn forward_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        let mut st = StageStats::for_heads(ctx, shape.h);
        // the lse row is internal on this path; borrow it from the arena
        let (mut lse, pooled) = {
            let mut s = ctx.scratch(0);
            let pooled = s.is_pooled();
            (s.take_f32(shape.h * shape.n, 0.0), pooled)
        };
        let ws = st.time("fwd", || {
            flash_attention_packed_into(
                ctx, q, k, v, shape.h, shape.h_kv, shape.n, shape.d, self.br, self.bc, o, &mut lse,
            )
        });
        // pooled-taken goes back (waiting out any contention); a
        // fallback-taken row is throwaway and drops here
        if pooled {
            ctx.scratch_wait(0).give_f32(lse);
        }
        st.add_workspace(ws);
        st
    }

    /// Dense attention ignores routing geometry entirely: every plan —
    /// uniform, mixed, or probed — produces the same dense causal
    /// output, so the plan path *is* the plain path (bit-identical,
    /// allocation-free, no probe overhead).
    fn forward_plan_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        plan: &RoutePlan,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        assert_eq!(
            plan.h_kv(),
            shape.h_kv,
            "route plan covers {} KV heads, shape has {}",
            plan.h_kv(),
            shape.h_kv
        );
        self.forward_into(ctx, shape, q, k, v, o)
    }

    fn forward_decode_into(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
        o: &mut Vec<f32>,
    ) {
        session.decode_dense_into(q_t, o);
    }

    fn forward_decode_batch_into(
        &self,
        ctx: &ExecCtx,
        sessions: &mut [DecodeSession],
        q: &[f32],
        o: &mut Vec<f32>,
    ) {
        batched_decode_dispatch(ctx, sessions, q, o, DecodeSession::decode_dense_slice);
    }
}

/// The original five-stage MoBA pipeline (Lu et al., 2025) behind the
/// trait — the overhead-laden baseline of Figures 3–4.
#[derive(Debug, Clone, Copy, Default)]
pub struct MobaNaiveBackend;

impl AttentionBackend for MobaNaiveBackend {
    fn name(&self) -> &'static str {
        "moba_naive"
    }

    fn supports(&self, shape: &AttnShape) -> bool {
        // a ragged tail is fine (always-attended, never routed); only a
        // routing-free geometry is rejected
        shape.topk >= 1
    }

    fn forward(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, StageStats) {
        let (o, _indices, st) = moba_naive_forward_ctx(ctx, q, k, v, *shape);
        (o, st)
    }

    /// Moves the pipeline's output into `o` instead of copying it. The
    /// five-stage baseline allocates its intermediates by design (the
    /// overhead under study), so this is NOT an allocation-free path —
    /// only the redundant output copy of the default impl is avoided.
    fn forward_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        let (out, _indices, st) = moba_naive_forward_ctx(ctx, q, k, v, *shape);
        *o = out;
        st
    }

    /// Streaming MoBA routing over the cached centroids. Per step there
    /// is no five-stage pipeline to reproduce — the selected block set
    /// is identical to the prefill gating, so the routed per-head
    /// path *is* this backend's decode semantics.
    fn forward_decode(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
    ) -> Vec<f32> {
        session.decode_routed(q_t)
    }

    fn forward_decode_into(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
        o: &mut Vec<f32>,
    ) {
        session.decode_routed_into(q_t, o);
    }

    fn forward_decode_batch_into(
        &self,
        ctx: &ExecCtx,
        sessions: &mut [DecodeSession],
        q: &[f32],
        o: &mut Vec<f32>,
    ) {
        batched_decode_dispatch(ctx, sessions, q, o, DecodeSession::decode_routed_slice);
    }
}

/// The paper's fused FlashMoBA forward behind the trait.
#[derive(Debug, Clone, Copy)]
pub struct FlashMobaBackend {
    pub cfg: FlashMobaConfig,
}

impl Default for FlashMobaBackend {
    fn default() -> Self {
        Self { cfg: FlashMobaConfig::default() }
    }
}

impl AttentionBackend for FlashMobaBackend {
    fn name(&self) -> &'static str {
        "flash_moba"
    }

    fn supports(&self, shape: &AttnShape) -> bool {
        shape.topk >= 1
    }

    fn forward(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> (Vec<f32>, StageStats) {
        let out = flash_moba_forward_ctx(ctx, q, k, v, *shape, self.cfg);
        (out.o, out.stats)
    }

    fn forward_into(
        &self,
        ctx: &ExecCtx,
        shape: &AttnShape,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        o: &mut Vec<f32>,
    ) -> StageStats {
        flash_moba_forward_into(ctx, q, k, v, *shape, self.cfg, o)
    }

    /// Streaming tiled top-k against the cache's running centroids +
    /// per-head single-row attention over the gathered blocks — the
    /// decode analogue of the fused two-stage forward.
    fn forward_decode(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
    ) -> Vec<f32> {
        session.decode_routed(q_t)
    }

    fn forward_decode_into(
        &self,
        _ctx: &ExecCtx,
        session: &mut DecodeSession,
        q_t: &[f32],
        o: &mut Vec<f32>,
    ) {
        session.decode_routed_into(q_t, o);
    }

    /// Batched cross-session decode: B sessions' routed single-row
    /// attentions are independent, so the batch partitions whole
    /// sessions across the pool — the launch that finally gives decode
    /// enough work per call to scale with cores (see `bench
    /// decode-batch`).
    fn forward_decode_batch_into(
        &self,
        ctx: &ExecCtx,
        sessions: &mut [DecodeSession],
        q: &[f32],
        o: &mut Vec<f32>,
    ) {
        batched_decode_dispatch(ctx, sessions, q, o, DecodeSession::decode_routed_slice);
    }
}

/// Ordered collection of registered backends, keyed by name.
pub struct BackendRegistry {
    backends: Vec<Box<dyn AttentionBackend>>,
}

impl BackendRegistry {
    pub fn new() -> Self {
        Self { backends: Vec::new() }
    }

    /// The three in-tree implementations, in report display order.
    pub fn with_defaults() -> Self {
        let mut r = Self::new();
        r.register(Box::new(DenseBackend::default()));
        r.register(Box::new(MobaNaiveBackend));
        r.register(Box::new(FlashMobaBackend::default()));
        r
    }

    /// Add a backend (replacing any existing one with the same name, so
    /// callers can override e.g. tile configs).
    pub fn register(&mut self, backend: Box<dyn AttentionBackend>) {
        self.backends.retain(|b| b.name() != backend.name());
        self.backends.push(backend);
    }

    pub fn get(&self, name: &str) -> Option<&dyn AttentionBackend> {
        self.backends.iter().find(|b| b.name() == name).map(|b| b.as_ref())
    }

    pub fn iter(&self) -> impl Iterator<Item = &dyn AttentionBackend> + '_ {
        self.backends.iter().map(|b| b.as_ref())
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.backends.iter().map(|b| b.name()).collect()
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }
}

impl Default for BackendRegistry {
    /// An *empty* registry, matching [`BackendRegistry::new`] (use
    /// [`BackendRegistry::with_defaults`] for the stock backends).
    fn default() -> Self {
        Self::new()
    }
}

// --------------------------------------------------------------- parity

/// Agreement tolerances (max |Δ| over all output elements).
#[derive(Debug, Clone, Copy)]
pub struct ParityTolerance {
    /// vs the textbook dense oracle ([`naive_attention_packed`]): exact
    /// backends on any shape; every backend at full routing
    pub dense: f32,
    /// pairwise between sparse backends on the same routing geometry
    pub cross: f32,
}

impl Default for ParityTolerance {
    fn default() -> Self {
        // generous vs f32 accumulation noise (~1e-5 at these sizes) but
        // orders of magnitude below any real routing/parity bug (~1e-1)
        Self { dense: 5e-4, cross: 5e-4 }
    }
}

/// Is every complete strictly-past block routed for every query of
/// every head (MoBA == dense)? With a ragged tail the worst row sees
/// every complete block as a candidate; aligned, the last row sees all
/// but its own.
pub fn fully_routed(shape: &AttnShape) -> bool {
    shape.topk >= shape.max_candidates()
}

/// Run every supporting backend on one seeded packed problem (on the
/// shared process pool) and check: exact backends (and, at full
/// routing, all backends) against the textbook dense oracle; sparse
/// backends pairwise against each other. `Err` carries a
/// human-readable violation description.
pub fn check_shape_parity(
    registry: &BackendRegistry,
    shape: AttnShape,
    seed: u64,
    tol: &ParityTolerance,
) -> std::result::Result<(), String> {
    let ctx = ExecCtx::global();
    let (q, k, v) = qkv_packed(seed, shape.h, shape.h_kv, shape.n, shape.d);
    let (oracle, _) = naive_attention_packed(&q, &k, &v, shape.h, shape.h_kv, shape.n, shape.d);
    let full = fully_routed(&shape);
    let mut sparse: Vec<(&str, Vec<f32>)> = Vec::new();
    for b in registry.iter() {
        if !b.supports(&shape) {
            continue;
        }
        let (o, _st) = b.forward(ctx, &shape, &q, &k, &v);
        if o.len() != shape.q_elems() {
            return Err(format!(
                "{}: output length {} != h*n*d {} (shape {shape:?})",
                b.name(),
                o.len(),
                shape.q_elems()
            ));
        }
        if b.is_exact() || full {
            let dev = max_abs_diff(&o, &oracle);
            if dev > tol.dense {
                return Err(format!(
                    "{} deviates from the dense oracle by {dev:.2e} > {:.2e} \
                     (shape {shape:?}, seed {seed}, full_routing={full})",
                    b.name(),
                    tol.dense
                ));
            }
        }
        if !b.is_exact() {
            sparse.push((b.name(), o));
        }
    }
    for i in 0..sparse.len() {
        for j in i + 1..sparse.len() {
            let dev = max_abs_diff(&sparse[i].1, &sparse[j].1);
            if dev > tol.cross {
                return Err(format!(
                    "sparse backends {} and {} disagree by {dev:.2e} > {:.2e} \
                     (shape {shape:?}, seed {seed})",
                    sparse[i].0, sparse[j].0, tol.cross
                ));
            }
        }
    }
    Ok(())
}

/// The default verification grid: the single-head shapes (a mix of
/// sparse routings and fully-routed shapes where MoBA must reproduce
/// dense exactly), multi-head and GQA layouts, and ragged-n shapes
/// whose tail block is always-attended but never routed.
pub fn parity_grid() -> Vec<AttnShape> {
    vec![
        AttnShape::single(64, 4, 16, 1),
        AttnShape::single(128, 16, 16, 2),
        AttnShape::single(128, 8, 16, 8),   // fully routed (k = n_blocks)
        AttnShape::single(96, 8, 16, 6),    // fully routed
        AttnShape::single(256, 8, 32, 3),
        AttnShape::single(256, 32, 64, 4),  // fully routed
        AttnShape::single(512, 16, 64, 2),
        AttnShape::new(4, 4, 128, 8, 32, 2),  // MHA
        AttnShape::new(4, 2, 128, 8, 16, 3),  // GQA
        AttnShape::new(8, 2, 64, 4, 16, 1),   // wide GQA groups
        AttnShape::single(100, 8, 16, 2),     // ragged tail
        AttnShape::new(4, 2, 72, 8, 16, 4),   // ragged GQA, fully routed
    ]
}

/// Assert parity over the whole default grid.
pub fn check_grid_parity(
    registry: &BackendRegistry,
    tol: &ParityTolerance,
) -> std::result::Result<(), String> {
    for (i, shape) in parity_grid().into_iter().enumerate() {
        check_shape_parity(registry, shape, 0x9A17 + i as u64, tol)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::dense::naive_attention;
    use super::super::packed_rows;
    use super::super::testutil::qkv;

    #[test]
    fn registry_defaults_cover_all_three() {
        let r = BackendRegistry::with_defaults();
        assert_eq!(r.names(), vec!["dense", "moba_naive", "flash_moba"]);
        assert!(r.get("dense").is_some());
        assert!(r.get("flash_moba").is_some());
        assert!(r.get("nope").is_none());
        assert_eq!(r.len(), 3);
        assert!(!r.is_empty());
    }

    #[test]
    fn register_replaces_same_name() {
        let mut r = BackendRegistry::with_defaults();
        r.register(Box::new(DenseBackend { br: 32, bc: 32 }));
        assert_eq!(r.len(), 3);
        // replaced entry moves to the back
        assert_eq!(r.names().last().copied(), Some("dense"));
    }

    #[test]
    fn supports_predicates() {
        let shape = AttnShape::single(128, 8, 32, 2);
        let ragged = AttnShape::single(100, 8, 32, 2);
        let no_topk = AttnShape::single(128, 8, 32, 0);
        let r = BackendRegistry::with_defaults();
        for b in r.iter() {
            assert!(b.supports(&shape), "{}", b.name());
            // the ragged-tail prefill path is a supported geometry now
            assert!(b.supports(&ragged), "{} must accept ragged n", b.name());
        }
        assert!(r.get("dense").unwrap().supports(&no_topk));
        assert!(!r.get("moba_naive").unwrap().supports(&no_topk));
        assert!(!r.get("flash_moba").unwrap().supports(&no_topk));
    }

    #[test]
    fn dense_backend_matches_oracle_everywhere() {
        let ctx = ExecCtx::global();
        let r = BackendRegistry::with_defaults();
        let dense = r.get("dense").unwrap();
        assert!(dense.is_exact());
        for shape in [
            AttnShape::single(96, 8, 16, 1),
            AttnShape::single(128, 4, 32, 2),
            AttnShape::new(4, 2, 96, 8, 16, 1),
        ] {
            let (q, k, v) = qkv_packed(5, shape.h, shape.h_kv, shape.n, shape.d);
            let (o, st) = dense.forward(ctx, &shape, &q, &k, &v);
            let (oracle, _) =
                naive_attention_packed(&q, &k, &v, shape.h, shape.h_kv, shape.n, shape.d);
            assert!(max_abs_diff(&o, &oracle) < 5e-5);
            assert!(st.get("fwd").is_some());
            assert!(st.workspace_bytes > 0);
            assert_eq!(st.threads(), ctx.threads());
            assert_eq!(st.heads(), shape.h);
        }
    }

    #[test]
    fn moba_backends_report_their_stages() {
        let ctx = ExecCtx::global();
        let shape = AttnShape::new(2, 2, 64, 4, 16, 1);
        let (q, k, v) = qkv_packed(6, 2, 2, 64, 4);
        let r = BackendRegistry::with_defaults();
        let (_, st) = r.get("moba_naive").unwrap().forward(ctx, &shape, &q, &k, &v);
        assert!(st.get("gating").is_some() && st.get("merge").is_some());
        assert_eq!(st.heads(), 2);
        let (_, st) = r.get("flash_moba").unwrap().forward(ctx, &shape, &q, &k, &v);
        assert!(st.get("flash_topk").is_some() && st.get("fwd").is_some());
        assert_eq!(st.heads(), 2);
    }

    #[test]
    fn grid_parity_holds_for_default_registry() {
        let r = BackendRegistry::with_defaults();
        check_grid_parity(&r, &ParityTolerance::default()).unwrap();
    }

    #[test]
    fn parity_detects_a_broken_backend() {
        /// Deliberately wrong "dense" impl: returns zeros.
        struct Broken;
        impl AttentionBackend for Broken {
            fn name(&self) -> &'static str {
                "broken"
            }
            fn supports(&self, _s: &AttnShape) -> bool {
                true
            }
            fn is_exact(&self) -> bool {
                true
            }
            fn forward(
                &self,
                _ctx: &ExecCtx,
                shape: &AttnShape,
                _q: &[f32],
                _k: &[f32],
                _v: &[f32],
            ) -> (Vec<f32>, StageStats) {
                (vec![0.0; shape.q_elems()], StageStats::new())
            }
        }
        let mut r = BackendRegistry::with_defaults();
        r.register(Box::new(Broken));
        let err = check_grid_parity(&r, &ParityTolerance::default()).unwrap_err();
        assert!(err.contains("broken"), "{err}");
    }

    #[test]
    fn fully_routed_detection() {
        assert!(fully_routed(&AttnShape::single(128, 8, 16, 8)));
        assert!(fully_routed(&AttnShape::single(128, 8, 16, 7)));
        assert!(!fully_routed(&AttnShape::single(128, 8, 16, 6)));
        // ragged: the tail row sees every complete block as a candidate
        assert!(fully_routed(&AttnShape::single(100, 8, 16, 6)));
        assert!(!fully_routed(&AttnShape::single(100, 8, 16, 5)));
        // head layout is irrelevant to routing density
        assert!(fully_routed(&AttnShape::new(4, 2, 128, 8, 16, 7)));
    }

    /// Token-by-token decode through the trait reproduces each
    /// backend's prefill rows — one packed step per token covering all
    /// heads (the full grid lives in `rust/tests/decode_parity.rs`;
    /// this is the smoke version).
    #[test]
    fn forward_decode_matches_prefill_rows() {
        let ctx = ExecCtx::global();
        for shape in [AttnShape::single(96, 8, 16, 2), AttnShape::new(4, 2, 64, 8, 16, 2)] {
            let (q, k, v) = qkv_packed(77, shape.h, shape.h_kv, shape.n, shape.d);
            let r = BackendRegistry::with_defaults();
            for b in r.iter() {
                let (prefill, _) = b.forward(ctx, &shape, &q, &k, &v);
                let mut sess =
                    DecodeSession::new(shape.h, shape.h_kv, shape.d, shape.block, shape.topk);
                for t in 0..shape.n {
                    sess.append(
                        &packed_rows(&k, shape.h_kv, shape.n, shape.d, t),
                        &packed_rows(&v, shape.h_kv, shape.n, shape.d, t),
                    );
                    let o = b.forward_decode(
                        ctx,
                        &mut sess,
                        &packed_rows(&q, shape.h, shape.n, shape.d, t),
                    );
                    assert_eq!(o.len(), shape.h * shape.d);
                    let expect = packed_rows(&prefill, shape.h, shape.n, shape.d, t);
                    let dev = max_abs_diff(&o, &expect);
                    assert!(dev < 1e-4, "{} row {t} dev {dev:.2e} ({shape:?})", b.name());
                }
            }
        }
    }

    /// The `_into` surface is bit-identical to the allocating one for
    /// every registered backend — prefill and decode — and reusing the
    /// output buffer across calls changes nothing.
    #[test]
    fn into_paths_match_allocating_paths_bitwise() {
        let ctx = ExecCtx::global();
        let r = BackendRegistry::with_defaults();
        for shape in [AttnShape::single(96, 8, 16, 2), AttnShape::new(4, 2, 100, 8, 16, 2)] {
            let (q, k, v) = qkv_packed(91, shape.h, shape.h_kv, shape.n, shape.d);
            let mut o = vec![7.0f32; 3]; // stale contents must be replaced
            for b in r.iter() {
                if !b.supports(&shape) {
                    continue;
                }
                let (expect, _) = b.forward(ctx, &shape, &q, &k, &v);
                for _ in 0..2 {
                    let st = b.forward_into(ctx, &shape, &q, &k, &v, &mut o);
                    assert_eq!(o.len(), expect.len(), "{}", b.name());
                    assert!(
                        o.iter().zip(&expect).all(|(a, z)| a.to_bits() == z.to_bits()),
                        "{} forward_into differs ({shape:?})",
                        b.name()
                    );
                    assert_eq!(st.heads(), shape.h);
                }
            }
            // decode: two identical sessions, one stepped through each API
            for b in r.iter() {
                let mut s1 =
                    DecodeSession::new(shape.h, shape.h_kv, shape.d, shape.block, shape.topk);
                let mut s2 = s1.clone();
                let mut row = Vec::new();
                for t in 0..shape.n.min(40) {
                    let kt = packed_rows(&k, shape.h_kv, shape.n, shape.d, t);
                    let vt = packed_rows(&v, shape.h_kv, shape.n, shape.d, t);
                    s1.append(&kt, &vt);
                    s2.append(&kt, &vt);
                    let qt = packed_rows(&q, shape.h, shape.n, shape.d, t);
                    let expect = b.forward_decode(ctx, &mut s1, &qt);
                    b.forward_decode_into(ctx, &mut s2, &qt, &mut row);
                    assert_eq!(row.len(), expect.len());
                    assert!(
                        row.iter().zip(&expect).all(|(a, z)| a.to_bits() == z.to_bits()),
                        "{} forward_decode_into differs at t={t}",
                        b.name()
                    );
                }
            }
        }
    }

    /// `RoutePlan::uniform` through `forward_plan[_into]` is the
    /// pre-plan path bit for bit, for every backend and thread count —
    /// the tentpole identity (the cross-shape sweep lives in
    /// `rust/tests/property.rs`; this is the smoke version).
    #[test]
    fn uniform_plan_is_bitwise_identical_to_static_path() {
        use super::super::plan::RoutePlan;
        let r = BackendRegistry::with_defaults();
        for shape in [AttnShape::single(96, 8, 16, 2), AttnShape::new(4, 2, 100, 8, 16, 2)] {
            let plan = RoutePlan::uniform(shape.h_kv, shape.block, shape.topk);
            let (q, k, v) = qkv_packed(31, shape.h, shape.h_kv, shape.n, shape.d);
            for threads in [1usize, 3] {
                let ctx = ExecCtx::with_threads(threads);
                for b in r.iter() {
                    if !b.supports(&shape) {
                        continue;
                    }
                    let (expect, _) = b.forward(&ctx, &shape, &q, &k, &v);
                    let (o, st) = b.forward_plan(&ctx, &shape, &plan, &q, &k, &v);
                    assert_eq!(st.fallback_heads, 0);
                    assert_eq!(o.len(), expect.len());
                    assert!(
                        o.iter().zip(&expect).all(|(a, z)| a.to_bits() == z.to_bits()),
                        "{} uniform plan differs ({shape:?}, {threads} threads)",
                        b.name()
                    );
                }
            }
        }
    }

    /// A uniform plan whose geometry differs from the carrier shape's
    /// substitutes its own `(block, topk)` — same output as running the
    /// static path at the plan's geometry.
    #[test]
    fn uniform_plan_overrides_shape_geometry() {
        use super::super::plan::RoutePlan;
        let ctx = ExecCtx::global();
        let r = BackendRegistry::with_defaults();
        let carrier = AttnShape::new(2, 2, 128, 8, 32, 1);
        let planned = AttnShape::new(2, 2, 128, 8, 16, 3);
        let plan = RoutePlan::uniform(2, 16, 3);
        let (q, k, v) = qkv_packed(33, 2, 2, 128, 8);
        for b in r.iter() {
            if !b.supports(&planned) {
                continue;
            }
            let (expect, _) = b.forward(ctx, &planned, &q, &k, &v);
            let (o, _) = b.forward_plan(ctx, &carrier, &plan, &q, &k, &v);
            assert!(
                o.iter().zip(&expect).all(|(a, z)| a.to_bits() == z.to_bits()),
                "{} plan geometry not substituted",
                b.name()
            );
        }
    }

    /// Mixed per-head plans: the dispatch equals a hand-spliced
    /// per-head composition bitwise, and a planned-dense head matches
    /// the dense oracle numerically.
    #[test]
    fn mixed_plan_composes_per_head_and_dense_heads_match_oracle() {
        use super::super::plan::{HeadPlan, RoutePlan};
        let ctx = ExecCtx::global();
        let r = BackendRegistry::with_defaults();
        let shape = AttnShape::new(4, 2, 128, 8, 16, 2); // carrier geometry
        let group = shape.group();
        let (n, d) = (shape.n, shape.d);
        let plan = RoutePlan {
            heads: vec![HeadPlan::routed(16, 2), HeadPlan::dense(32)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        };
        let (q, k, v) = qkv_packed(35, shape.h, shape.h_kv, n, d);
        let (oracle, _) = naive_attention_packed(&q, &k, &v, shape.h, shape.h_kv, n, d);
        for b in r.iter() {
            if !b.supports(&shape) {
                continue;
            }
            let (o, st) = b.forward_plan(ctx, &shape, &plan, &q, &k, &v);
            assert_eq!(o.len(), shape.q_elems(), "{}", b.name());
            // planned-dense heads are not *fallbacks* (nothing degraded)
            assert_eq!(st.fallback_heads, 0, "{}", b.name());
            // head 1 (dense mode): numerically the dense oracle
            let slab = &o[group * n * d..2 * group * n * d];
            let ref_slab = &oracle[group * n * d..2 * group * n * d];
            let dev = max_abs_diff(slab, ref_slab);
            assert!(dev < 5e-4, "{} dense-mode head deviates {dev:.2e}", b.name());
            // bitwise: the whole output equals the per-head splice
            let mut expect = vec![0.0f32; shape.q_elems()];
            for kvh in 0..shape.h_kv {
                let hp = plan.head(kvh);
                let sub = if hp.is_dense() {
                    let s = AttnShape::new(group, 1, n, d, hp.block, 0);
                    AttnShape { topk: s.max_candidates().max(1), ..s }
                } else {
                    AttnShape::new(group, 1, n, d, hp.block, hp.topk)
                };
                let (so, _) = b.forward(
                    ctx,
                    &sub,
                    &q[kvh * group * n * d..(kvh + 1) * group * n * d],
                    &k[kvh * n * d..(kvh + 1) * n * d],
                    &v[kvh * n * d..(kvh + 1) * n * d],
                );
                expect[kvh * group * n * d..(kvh + 1) * group * n * d].copy_from_slice(&so);
            }
            if b.name() == "dense" {
                // DenseBackend's override ignores the plan; numeric
                // parity with the splice is all that's promised
                assert!(max_abs_diff(&o, &expect) < 5e-4);
            } else {
                assert!(
                    o.iter().zip(&expect).all(|(a, z)| a.to_bits() == z.to_bits()),
                    "{} mixed plan differs from per-head composition",
                    b.name()
                );
            }
        }
    }

    /// The runtime escape hatch: a head whose routing margin collapses
    /// (identical centroids -> margin 0) degrades to dense when the
    /// threshold is above it, and `fallback_heads` records it; with the
    /// probe below the margin nothing degrades and the output is the
    /// routed one bit for bit.
    #[test]
    fn margin_probe_degrades_collapsed_heads_to_dense() {
        use super::super::plan::RoutePlan;
        let ctx = ExecCtx::global();
        let r = BackendRegistry::with_defaults();
        let shape = AttnShape::single(128, 8, 16, 2);
        // keys constant within the whole sequence: every block centroid
        // is identical, so every routing margin is exactly 0
        let (q, _, v) = qkv_packed(37, 1, 1, shape.n, shape.d);
        let k = vec![0.25f32; shape.n * shape.d];
        for b in r.iter() {
            if !b.supports(&shape) || b.is_exact() {
                continue; // dense ignores plans; probe only matters for sparse
            }
            let mut plan = RoutePlan::uniform(1, shape.block, shape.topk);
            plan.fallback_margin = 0.5; // margin 0 < 0.5 -> degrade
            let (o, st) = b.forward_plan(ctx, &shape, &plan, &q, &k, &v);
            assert_eq!(st.fallback_heads, 1, "{}", b.name());
            let full = AttnShape { topk: shape.max_candidates(), ..shape };
            let (dense, _) = b.forward(ctx, &full, &q, &k, &v);
            assert!(
                o.iter().zip(&dense).all(|(a, z)| a.to_bits() == z.to_bits()),
                "{} degraded head is not the fully-routed output",
                b.name()
            );
            // threshold below the observed margin: stays routed
            let mut keep = RoutePlan::uniform(1, shape.block, shape.topk);
            keep.fallback_margin = -1.0;
            let (o2, st2) = b.forward_plan(ctx, &shape, &keep, &q, &k, &v);
            assert_eq!(st2.fallback_heads, 0, "{}", b.name());
            let (routed, _) = b.forward(ctx, &shape, &q, &k, &v);
            assert!(
                o2.iter().zip(&routed).all(|(a, z)| a.to_bits() == z.to_bits()),
                "{} probed-but-kept head differs from the routed path",
                b.name()
            );
        }
    }

    /// The default trait impl (dense fallback) is exact: a backend that
    /// overrides nothing decodes the dense oracle.
    #[test]
    fn default_forward_decode_is_dense_fallback() {
        struct Plain;
        impl AttentionBackend for Plain {
            fn name(&self) -> &'static str {
                "plain"
            }
            fn supports(&self, _s: &AttnShape) -> bool {
                true
            }
            fn forward(
                &self,
                _ctx: &ExecCtx,
                shape: &AttnShape,
                q: &[f32],
                k: &[f32],
                v: &[f32],
            ) -> (Vec<f32>, StageStats) {
                let (o, _) = naive_attention(q, k, v, shape.n, shape.d);
                (o, StageStats::new())
            }
        }
        let ctx = ExecCtx::global();
        let (n, d) = (48, 8);
        let (q, k, v) = qkv(78, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let b = Plain;
        let mut sess = DecodeSession::new(1, 1, d, 16, 1); // routing geometry ignored by the fallback
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = b.forward_decode(ctx, &mut sess, &q[t * d..(t + 1) * d]);
            assert!(max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4, "row {t}");
        }
    }
}
