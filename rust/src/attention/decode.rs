//! Incremental (autoregressive) decode over a block KV cache with
//! streaming MoBA routing — the serving-side twin of the prefill
//! kernels.
//!
//! The paper's routing model (§3; the tiled top-k of Algorithm 1)
//! extends to decode by maintaining block statistics *incrementally* as
//! keys arrive:
//!
//! * [`KvCache`] — per-session K/V storage, one block-partitioned store
//!   *per KV head* — each head at its **own block size** (a per-head
//!   [`RoutePlan`]'s geometry) — with a running per-block key sum so
//!   the centroid of any block is one O(d) multiply away. Appending a
//!   token is amortized O(h_kv · d); with key convolution enabled, a
//!   per-head ring buffer of the last `width` raw keys
//!   ([`KconvStream`]) makes the streaming kconv bit-identical to the
//!   batch [`kconv`](super::kconv::kconv).
//! * [`DecodeSession`] — one decode step covers *all* query heads:
//!   each query head routes against its GQA group's KV-head centroids
//!   (its KV head's planned top-k over complete, strictly-past blocks,
//!   plus the always-attended current block — the paper's causal
//!   own-block rule) and computes single-row softmax attention over
//!   the gathered blocks. Planned-dense heads attend the whole cache;
//!   a finite `fallback_margin` degrades routed heads whose per-row
//!   score margin collapses. `h = h_kv = 1` with a uniform plan
//!   reproduces the single-head decode path bit-for-bit.
//!
//! Parity contract: feeding tokens one at a time through a session
//! reproduces the prefill `forward` of the matching backend
//! row-for-row (see `rust/tests/decode_parity.rs`). The load-bearing
//! detail is that the running block sums are accumulated in arrival
//! order and divided once at read time — exactly the arithmetic of the
//! batch [`centroids`](super::centroid::centroids) — so the routing
//! scores, and therefore the selected block sets, are bit-identical to
//! prefill's.
//!
//! Storage comes in two layouts behind one [`KvCache`] API: the
//! original *contiguous* per-head slabs ([`KvCache::new`] /
//! [`KvCache::with_blocks`]) and *paged* storage
//! ([`KvCache::paged_with_blocks`]) where each logical block lives in
//! one fixed-size page from a shared [`PagePool`] — per-session page
//! tables, copy-on-write prefix sharing ([`KvCache::fork`]) and
//! eviction/re-prefill ([`KvCache::evict`]). A page stores exactly the
//! rows and centroid sum the contiguous slab kept for that block, and
//! every kernel reads blocks through the same per-block slices — so
//! the two layouts are bit-identical step for step (pinned by
//! `rust/tests/paged_parity.rs`).

use super::centroid::centroids;
use super::dense::NEG_INF;
use super::dtype::{KvBuf, KvDtype, KvView};
use super::gemm::{accum_rows_view, qk_row_view};
use super::kconv::KconvStream;
use super::paged::{PageHandle, PagePool};
use super::plan::RoutePlan;
use super::simd::dot;
use super::topk::{tiled_topk, topk_insert};

/// One KV head's block storage, in one of two layouts with identical
/// per-block contents (and therefore identical arithmetic).
#[derive(Debug, Clone)]
enum HeadStorage {
    /// Contiguous slabs: cached (possibly kconv'd) keys and values,
    /// (len, d) row-major in the cache's [`KvDtype`], plus the running
    /// per-block key sums (num_blocks, d) — always f32, accumulated
    /// from the pre-quantization rows, divided by the block's token
    /// count at read time to form the centroid.
    Contig { k: KvBuf, v: KvBuf, sums: Vec<f32> },
    /// Page table: logical block `b` lives in `pages[b]`, a refcounted
    /// page holding that block's rows and its running centroid sum.
    /// Cloning the table shares every page (CoW fork).
    Paged { pages: Vec<PageHandle> },
}

/// One KV head's storage plus its optional streaming key convolution.
#[derive(Debug, Clone)]
struct HeadStore {
    storage: HeadStorage,
    kconv: Option<KconvStream>,
}

/// Append one (k, v) row into a head's storage, opening a fresh block
/// (contiguous sum slab / pool page) at block boundaries. The centroid
/// sum accumulates element-by-element in arrival order on both layouts
/// — the bit-determinism hinge.
#[allow(clippy::too_many_arguments)]
fn store_row(
    storage: &mut HeadStorage,
    pool: Option<&PagePool>,
    block: usize,
    t: usize,
    d: usize,
    dtype: KvDtype,
    kr: &[f32],
    vr: &[f32],
) {
    match storage {
        HeadStorage::Contig { k, v, sums } => {
            let b = t / block;
            if t % block == 0 {
                // first token of a fresh block: open its running sum
                let len = sums.len();
                sums.resize(len + d, 0.0);
            }
            // the sum reads the caller's full-precision row *before*
            // quantization — routing never sees the storage dtype
            let sum = &mut sums[b * d..(b + 1) * d];
            for (c, s) in sum.iter_mut().enumerate() {
                *s += kr[c];
            }
            k.append_row(kr);
            v.append_row(vr);
        }
        HeadStorage::Paged { pages } => {
            if t % block == 0 {
                // first token of a fresh block: materialize its page
                pages.push(
                    pool.expect("paged storage always has a pool").alloc_dtype(d, dtype),
                );
            }
            // make_mut is the CoW rule: a page shared with a forked
            // sibling splits off a private copy on this first divergent
            // append; complete shared prefix pages are never written
            pages.last_mut().expect("block opened").make_mut().append_row(kr, vr);
        }
    }
}

/// Per-session K/V block storage with running centroids, one store per
/// KV head.
///
/// Keys stored here are post-kconv when a [`KconvStream`] is attached
/// (one independent stream per head, shared taps); values are stored as
/// given. Each KV head has its *own* block size (a per-head routing
/// plan's geometry): head `i`'s `len` tokens occupy
/// `ceil(len / blocks[i])` logical blocks, of which the last may be
/// partial. [`KvCache::new`] is the uniform special case (every head at
/// one block size) — bit-identical to the pre-plan cache.
#[derive(Debug, Clone)]
pub struct KvCache {
    h_kv: usize,
    d: usize,
    /// per-KV-head block size (len == h_kv)
    blocks: Vec<usize>,
    /// tokens cached (identical across heads; explicit so paged and
    /// contiguous layouts share one source of truth)
    len: usize,
    heads: Vec<HeadStore>,
    /// the shared page allocator of a paged cache; `None` = contiguous
    pool: Option<PagePool>,
    /// storage dtype of the cached K/V rows (centroid sums stay f32)
    dtype: KvDtype,
}

impl KvCache {
    /// A contiguous cache with every KV head block-partitioned at
    /// `block` (the uniform-plan store).
    pub fn new(h_kv: usize, d: usize, block: usize) -> Self {
        Self::with_blocks(h_kv, d, &vec![block; h_kv.max(1)])
    }

    /// A cache whose KV head `i` is block-partitioned at `blocks[i]` —
    /// the decode store of a mixed per-head [`RoutePlan`]. All heads
    /// hold the same tokens; only the block boundaries (and therefore
    /// the running centroid sums) differ per head.
    pub fn with_blocks(h_kv: usize, d: usize, blocks: &[usize]) -> Self {
        Self::build(h_kv, d, blocks, None)
    }

    /// The paged twin of [`KvCache::with_blocks`]: KV head `i`'s
    /// logical block `b` lives in page `b` of its table, allocated from
    /// `pool` as blocks open. Requires every head's block size to fit
    /// one page (`block <= pool.page_tokens()`). Bit-identical to the
    /// contiguous layout step for step.
    pub fn paged_with_blocks(h_kv: usize, d: usize, blocks: &[usize], pool: &PagePool) -> Self {
        for &b in blocks {
            assert!(
                b <= pool.page_tokens(),
                "block size {b} exceeds the pool's page_tokens {}",
                pool.page_tokens()
            );
        }
        Self::build(h_kv, d, blocks, Some(pool.clone()))
    }

    fn build(h_kv: usize, d: usize, blocks: &[usize], pool: Option<PagePool>) -> Self {
        assert!(h_kv >= 1 && d >= 1, "KvCache needs h_kv >= 1 and d >= 1");
        assert_eq!(blocks.len(), h_kv, "need one block size per KV head");
        assert!(blocks.iter().all(|&b| b >= 1), "block sizes must be >= 1");
        let heads = (0..h_kv)
            .map(|_| HeadStore {
                storage: match &pool {
                    None => HeadStorage::Contig {
                        k: KvBuf::new(KvDtype::F32),
                        v: KvBuf::new(KvDtype::F32),
                        sums: Vec::new(),
                    },
                    Some(_) => HeadStorage::Paged { pages: Vec::new() },
                },
                kconv: None,
            })
            .collect();
        Self { h_kv, d, blocks: blocks.to_vec(), len: 0, heads, pool, dtype: KvDtype::F32 }
    }

    /// Switch the storage dtype of an *empty* cache (builder-style):
    /// appended K/V rows are quantized to `dtype` at store time, while
    /// centroid sums keep accumulating the pre-quantization f32 rows —
    /// routing is dtype-invariant by construction. Panics if any token
    /// has already been appended.
    pub fn with_dtype(mut self, dtype: KvDtype) -> Self {
        assert!(self.is_empty(), "with_dtype must be called before any append");
        self.dtype = dtype;
        for store in &mut self.heads {
            if let HeadStorage::Contig { k, v, .. } = &mut store.storage {
                *k = KvBuf::new(dtype);
                *v = KvBuf::new(dtype);
            }
            // paged tables adopt the dtype when their pages are allocated
        }
        self
    }

    /// Storage dtype of the cached K/V rows.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// A cache that applies the depthwise causal key convolution
    /// (paper Appendix B) to every appended key before storing it —
    /// one independent stream per KV head, sharing the (width, d) tap
    /// tensor `w`.
    pub fn with_kconv(h_kv: usize, d: usize, block: usize, w: &[f32], width: usize) -> Self {
        let mut c = Self::new(h_kv, d, block);
        for store in &mut c.heads {
            store.kconv = Some(KconvStream::new(w, width, d));
        }
        c
    }

    /// [`KvCache::with_kconv`] over paged storage.
    pub fn paged_with_kconv(
        h_kv: usize,
        d: usize,
        block: usize,
        w: &[f32],
        width: usize,
        pool: &PagePool,
    ) -> Self {
        let mut c = Self::paged_with_blocks(h_kv, d, &vec![block; h_kv.max(1)], pool);
        for store in &mut c.heads {
            store.kconv = Some(KconvStream::new(w, width, d));
        }
        c
    }

    /// KV heads stored.
    pub fn h_kv(&self) -> usize {
        self.h_kv
    }

    /// Head dimension of the cached rows.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Head 0's block size — the cache-wide block size of a uniform
    /// cache (the [`KvCache::new`] path). Mixed caches should ask per
    /// head via [`KvCache::block_of`].
    pub fn block(&self) -> usize {
        self.blocks[0]
    }

    /// KV head `head`'s block size.
    pub fn block_of(&self, head: usize) -> usize {
        self.blocks[head]
    }

    /// Tokens cached (identical across heads).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no tokens are cached yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether this cache stores blocks in pool pages.
    pub fn is_paged(&self) -> bool {
        self.pool.is_some()
    }

    /// The shared allocator of a paged cache.
    pub fn pool(&self) -> Option<&PagePool> {
        self.pool.as_ref()
    }

    /// Page-table entries this cache currently holds across all heads
    /// (0 for a contiguous cache). Shared pages count once per table
    /// that references them — this is the admission-budget view, not
    /// the pool's deduplicated `live_pages`.
    pub fn total_pages(&self) -> usize {
        self.heads
            .iter()
            .map(|s| match &s.storage {
                HeadStorage::Contig { .. } => 0,
                HeadStorage::Paged { pages } => pages.len(),
            })
            .sum()
    }

    /// Page-table entries a replay of `tokens` tokens would occupy
    /// across all heads — the scheduler's restore-cost estimate.
    pub fn pages_for(&self, tokens: usize) -> usize {
        self.blocks.iter().map(|&b| tokens.div_ceil(b)).sum()
    }

    /// Upper bound on the pages appending `extra` tokens would
    /// materialize: newly opened blocks per head, plus one CoW split
    /// per head whose partial tail page is currently shared — the
    /// scheduler's admission-cost estimate for a prefill.
    pub fn append_page_cost(&self, extra: usize) -> usize {
        let len = self.len;
        let mut cost = 0;
        for (head, store) in self.heads.iter().enumerate() {
            let b = self.blocks[head];
            cost += (len + extra).div_ceil(b) - len.div_ceil(b);
            if extra > 0 && len % b != 0 {
                if let HeadStorage::Paged { pages } = &store.storage {
                    if pages.last().is_some_and(|p| p.is_shared()) {
                        cost += 1;
                    }
                }
            }
        }
        cost
    }

    /// [`KvCache::append_page_cost`] in budget *units* (1 unit = one
    /// f32 page's worth of bytes is `4 × page elems`; see
    /// [`PagePool::would_fit_units`]): pages × this cache's per-element
    /// byte width. An f16 cache's prefill charges half the units of an
    /// f32 cache's — the byte-true admission cost.
    pub fn append_page_cost_units(&self, extra: usize) -> usize {
        self.append_page_cost(extra) * self.dtype.elem_bytes()
    }

    /// Logical blocks head 0 currently occupies, `ceil(len / block)` —
    /// the cache-wide count of a uniform cache.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks_of(0)
    }

    /// Logical blocks KV head `head` currently occupies.
    pub fn num_blocks_of(&self, head: usize) -> usize {
        self.len().div_ceil(self.blocks[head])
    }

    /// Head 0's blocks holding exactly `block` tokens, `len / block`.
    pub fn complete_blocks(&self) -> usize {
        self.complete_blocks_of(0)
    }

    /// KV head `head`'s complete blocks.
    pub fn complete_blocks_of(&self, head: usize) -> usize {
        self.len() / self.blocks[head]
    }

    /// Tokens stored in head 0's block `b`.
    pub fn block_len(&self, b: usize) -> usize {
        self.block_len_of(0, b)
    }

    /// Tokens stored in KV head `head`'s block `b`.
    pub fn block_len_of(&self, head: usize, b: usize) -> usize {
        assert!(b < self.num_blocks_of(head));
        let block = self.blocks[head];
        (self.len() - b * block).min(block)
    }

    /// KV head `head`'s cached (post-kconv) keys, (len, d) row-major.
    /// Contiguous f32 caches only — a paged cache has no single slab
    /// (read per block via [`KvCache::block_keys`]) and a quantized
    /// store has no raw f32 rows (read via [`KvCache::block_keys_view`]).
    pub fn keys_of(&self, head: usize) -> &[f32] {
        match &self.heads[head].storage {
            HeadStorage::Contig { k, .. } => k.as_f32(),
            HeadStorage::Paged { .. } => {
                panic!("paged caches have no contiguous view; use block_keys(head, b)")
            }
        }
    }

    /// KV head `head`'s cached values, (len, d) row-major. Contiguous
    /// f32 caches only — see [`KvCache::keys_of`].
    pub fn values_of(&self, head: usize) -> &[f32] {
        match &self.heads[head].storage {
            HeadStorage::Contig { v, .. } => v.as_f32(),
            HeadStorage::Paged { .. } => {
                panic!("paged caches have no contiguous view; use block_values(head, b)")
            }
        }
    }

    /// KV head `head`'s block `b` keys, `(block_len_of(head, b), d)`
    /// row-major — the layout-agnostic per-block f32 view (a contiguous
    /// slab slice or the block's page). F32 caches only; quantized
    /// stores are read through [`KvCache::block_keys_view`].
    pub fn block_keys(&self, head: usize, b: usize) -> &[f32] {
        let (start, end) = self.block_span(head, b);
        match &self.heads[head].storage {
            HeadStorage::Contig { k, .. } => &k.as_f32()[start * self.d..end * self.d],
            HeadStorage::Paged { pages } => {
                let rows = pages[b].data().k();
                debug_assert_eq!(rows.len(), (end - start) * self.d);
                rows
            }
        }
    }

    /// KV head `head`'s block `b` values — see [`KvCache::block_keys`].
    pub fn block_values(&self, head: usize, b: usize) -> &[f32] {
        let (start, end) = self.block_span(head, b);
        match &self.heads[head].storage {
            HeadStorage::Contig { v, .. } => &v.as_f32()[start * self.d..end * self.d],
            HeadStorage::Paged { pages } => {
                let rows = pages[b].data().v();
                debug_assert_eq!(rows.len(), (end - start) * self.d);
                rows
            }
        }
    }

    /// Dtype-agnostic view of KV head `head`'s block `b` keys — the
    /// per-block view the decode kernels read through. On an f32 store
    /// this is a zero-cost slice wrapper ([`KvView::F32`]), so the f32
    /// path stays bit-transparent to the pre-dtype kernels; quantized
    /// rows dequantize element-wise inside the fused kernels, never
    /// into a materialized f32 copy.
    pub fn block_keys_view(&self, head: usize, b: usize) -> KvView<'_> {
        let (start, end) = self.block_span(head, b);
        match &self.heads[head].storage {
            HeadStorage::Contig { k, .. } => k.view_rows(start, end, self.d),
            HeadStorage::Paged { pages } => {
                let view = pages[b].data().k_view();
                debug_assert_eq!(view.rows(self.d), end - start);
                view
            }
        }
    }

    /// Dtype-agnostic view of KV head `head`'s block `b` values — see
    /// [`KvCache::block_keys_view`].
    pub fn block_values_view(&self, head: usize, b: usize) -> KvView<'_> {
        let (start, end) = self.block_span(head, b);
        match &self.heads[head].storage {
            HeadStorage::Contig { v, .. } => v.view_rows(start, end, self.d),
            HeadStorage::Paged { pages } => {
                let view = pages[b].data().v_view();
                debug_assert_eq!(view.rows(self.d), end - start);
                view
            }
        }
    }

    /// Token span `[start, end)` of KV head `head`'s block `b`.
    fn block_span(&self, head: usize, b: usize) -> (usize, usize) {
        assert!(b < self.num_blocks_of(head), "block {b} out of range");
        let block = self.blocks[head];
        (b * block, ((b + 1) * block).min(self.len))
    }

    /// Single-KV-head convenience accessor (`h_kv == 1`).
    pub fn keys(&self) -> &[f32] {
        assert_eq!(self.h_kv, 1, "use keys_of(head) on a multi-head cache");
        self.keys_of(0)
    }

    /// Single-KV-head convenience accessor (`h_kv == 1`).
    pub fn values(&self) -> &[f32] {
        assert_eq!(self.h_kv, 1, "use values_of(head) on a multi-head cache");
        self.values_of(0)
    }

    /// Append one token's packed (k_t, v_t), each `(h_kv, d)` row-major.
    /// Amortized O(h_kv · d): per head one ring-buffer kconv step
    /// (O(width · d)) when enabled, one add into the current block's
    /// running sum, two row copies — no per-token allocation on the
    /// plain path.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        assert_eq!(k_t.len(), self.h_kv * self.d, "key row has wrong width");
        assert_eq!(v_t.len(), self.h_kv * self.d, "value row has wrong width");
        let t = self.len;
        let d = self.d;
        let KvCache { heads, blocks, pool, dtype, .. } = self;
        for (head, store) in heads.iter_mut().enumerate() {
            let block = blocks[head];
            let kh = &k_t[head * d..(head + 1) * d];
            let vh = &v_t[head * d..(head + 1) * d];
            let HeadStore { storage, kconv } = store;
            match kconv {
                Some(stream) => {
                    let stored = stream.push(kh);
                    store_row(storage, pool.as_ref(), block, t, d, *dtype, &stored, vh);
                }
                None => store_row(storage, pool.as_ref(), block, t, d, *dtype, kh, vh),
            }
        }
        self.len = t + 1;
    }

    /// Share this cache's pages with a new cache — CoW prefix sharing
    /// for a common prompt. Paged caches share every page (refcount
    /// bumps, zero copies — the fork's table size is reported to the
    /// pool as `prefix_shared`); divergent appends split only the
    /// partial tail page, on first write. Contiguous caches deep-copy.
    /// Either way the fork decodes bit-identically to an independent
    /// cache fed the same history.
    pub fn fork(&self) -> KvCache {
        if let Some(pool) = &self.pool {
            pool.note_share(self.total_pages() as u64);
        }
        self.clone()
    }

    /// Drop all cached tokens, returning the storage to its empty state
    /// (pages go back to the pool once no sibling table shares them;
    /// kconv streams reset). Returns the page-table entries released.
    /// Replaying the same appends afterwards rebuilds the cache bit for
    /// bit — eviction + re-prefill, the preemption path.
    pub fn evict(&mut self) -> usize {
        let mut released = 0;
        for store in &mut self.heads {
            match &mut store.storage {
                HeadStorage::Contig { k, v, sums } => {
                    k.clear();
                    v.clear();
                    sums.clear();
                }
                HeadStorage::Paged { pages } => {
                    released += pages.len();
                    pages.clear();
                }
            }
            if let Some(stream) = &mut store.kconv {
                stream.reset();
            }
        }
        self.len = 0;
        released
    }

    /// Write KV head `head`'s block `b` centroid (mean of its stored
    /// keys) into `out`. For complete blocks this is bit-identical to
    /// the batch [`centroids`](super::centroid::centroids): the sum
    /// accumulates in arrival order and is scaled by `1 / block` once.
    pub fn centroid_into(&self, head: usize, b: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.d);
        let inv = 1.0 / self.block_len_of(head, b) as f32;
        let sum = match &self.heads[head].storage {
            HeadStorage::Contig { sums, .. } => &sums[b * self.d..(b + 1) * self.d],
            HeadStorage::Paged { pages } => pages[b].data().sum(),
        };
        for (c, o) in out.iter_mut().enumerate() {
            *o = sum[c] * inv;
        }
    }

    /// KV head `head`'s block `b` centroid as an owned row.
    pub fn centroid(&self, head: usize, b: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.centroid_into(head, b, &mut out);
        out
    }

    /// Route one query head's row (at the current position, i.e. the
    /// last appended token) against KV head `head`'s centroids:
    /// top-`topk` complete strictly-past blocks by q·centroid, plus the
    /// always-attended current block. Returns block indices sorted
    /// ascending, deduplicated, all causal (`<= own`), with the own
    /// block always last.
    ///
    /// Selection uses the same streaming insertion (and therefore the
    /// same tie-breaking: earliest block wins) as
    /// [`tiled_topk`](super::topk::tiled_topk), over centroids computed
    /// with the same arithmetic — so it reproduces prefill routing
    /// exactly.
    pub fn route(&self, q: &[f32], head: usize, topk: usize) -> Vec<usize> {
        let mut blocks = Vec::new();
        let (mut best_s, mut best_i, mut cbuf) = (Vec::new(), Vec::new(), Vec::new());
        self.route_into(q, head, topk, &mut blocks, &mut best_s, &mut best_i, &mut cbuf);
        blocks
    }

    /// [`KvCache::route`] into caller-provided (reused) buffers — the
    /// per-token zero-allocation path. `blocks` receives the selection;
    /// `best_s`/`best_i`/`cbuf` are the running top-k state and the
    /// centroid row, reused across calls.
    ///
    /// Returns this row's routing score margin: worst admitted score
    /// minus best rejected (non-NaN) score — the decode analogue of the
    /// prefill [`routing_margin`](super::topk::routing_margin) probe,
    /// at zero extra dot products. `+inf` when nothing was rejectable
    /// (no candidates, `topk == 0`, or fewer candidates than `topk`).
    #[allow(clippy::too_many_arguments)]
    pub fn route_into(
        &self,
        q: &[f32],
        head: usize,
        topk: usize,
        blocks: &mut Vec<usize>,
        best_s: &mut Vec<f32>,
        best_i: &mut Vec<i32>,
        cbuf: &mut Vec<f32>,
    ) -> f32 {
        assert!(!self.is_empty(), "route called on an empty cache");
        assert_eq!(q.len(), self.d);
        let own = (self.len() - 1) / self.blocks[head];
        blocks.clear();
        let mut max_rej = f32::NEG_INFINITY;
        if topk > 0 && own > 0 {
            // candidates: blocks [0, own) — all complete by construction
            best_s.clear();
            best_s.resize(topk, f32::NEG_INFINITY);
            best_i.clear();
            best_i.resize(topk, -1);
            cbuf.clear();
            cbuf.resize(self.d, 0.0);
            for j in 0..own {
                self.centroid_into(head, j, cbuf);
                let s = dot(q, cbuf);
                // margin accounting: topk_insert admits iff s beats the
                // current worst slot (strict, never NaN); on admission
                // the displaced slot joins the rejected pool
                let worst = best_s[topk - 1];
                if s.is_nan() || s <= worst {
                    if s > max_rej {
                        max_rej = s;
                    }
                } else if worst > max_rej {
                    max_rej = worst;
                }
                topk_insert(best_s, best_i, s, j as i32);
            }
            blocks.extend(best_i.iter().filter(|&&j| j >= 0).map(|&j| j as usize));
            blocks.sort_unstable();
        }
        blocks.push(own);
        if max_rej > f32::NEG_INFINITY {
            best_s[topk - 1] - max_rej
        } else {
            f32::INFINITY
        }
    }

    /// Single-row softmax attention of one query head's row `q` over
    /// the given blocks of KV head `head` (ascending; the last may be
    /// the partial current block). Exact per-row softmax: gather
    /// scores, subtract the max, combine values — the decode analogue
    /// of one `naive_attention` row.
    pub fn attend(&self, q: &[f32], head: usize, blocks: &[usize]) -> Vec<f32> {
        let mut scores = Vec::new();
        let mut out = vec![0.0f32; self.d];
        self.attend_into(q, head, blocks, &mut scores, &mut out);
        out
    }

    /// [`KvCache::attend`] into a caller-provided output row, with the
    /// score buffer reused across calls — the per-token
    /// zero-allocation path. Scores run on the register-blocked gemv
    /// per block (cache rows are contiguous) and the value combine on
    /// the fused [`accum_rows_view`]; on an f32 store both delegate to
    /// the pre-dtype f32 kernels and preserve the per-element f32
    /// operation order of the dot/axpy formulation, so the output is
    /// bit-identical to it (pinned by the single-head legacy decode
    /// regression). Quantized stores dequantize element-wise inside
    /// the fused kernels — no materialized f32 copy, no allocation.
    pub fn attend_into(
        &self,
        q: &[f32],
        head: usize,
        blocks: &[usize],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        assert!(!self.is_empty(), "attend called on an empty cache");
        assert_eq!(q.len(), self.d);
        assert_eq!(out.len(), self.d);
        let d = self.d;
        let scale = 1.0 / (d as f32).sqrt();
        // per-block reads through block_keys/block_values: a block's
        // rows are contiguous on both layouts (slab slice or page), so
        // the gemv tiles see identical memory and produce identical bits
        scores.clear();
        for &b in blocks {
            let rows = self.block_len_of(head, b);
            let seg = scores.len();
            scores.resize(seg + rows, 0.0);
            qk_row_view(q, &self.block_keys_view(head, b), d, rows, scale, &mut scores[seg..]);
        }
        let mut m = NEG_INF;
        for &x in scores.iter() {
            if x > m {
                m = x;
            }
        }
        let mut z = 0.0f32;
        for x in scores.iter_mut() {
            *x = (*x - m).exp();
            z += *x;
        }
        out.fill(0.0);
        let mut seg = 0usize;
        for &b in blocks {
            let rows = self.block_len_of(head, b);
            accum_rows_view(out, &scores[seg..seg + rows], &self.block_values_view(head, b));
            seg += rows;
        }
        for o in out.iter_mut() {
            *o /= z;
        }
    }

    /// K and V bytes one query head reads from KV head `head`'s store
    /// for `blocks` — dtype-aware, so an f16 cache reports half the
    /// traffic of f32 for the same block set (i8 scale rows are noise
    /// and are not counted).
    pub fn gather_bytes(&self, head: usize, blocks: &[usize]) -> u64 {
        let toks: usize = blocks.iter().map(|&b| self.block_len_of(head, b)).sum();
        (2 * toks * self.d * self.dtype.elem_bytes()) as u64
    }
}

/// The per-session reusable buffers one decode step works in: routing
/// state, the selected block list, the score row and the centroid row.
/// Persisted across steps so a steady-state decode step performs
/// **zero heap allocations** (pinned by
/// `rust/tests/alloc_regression.rs`) — these were eight fresh `Vec`s
/// per token before the workspace-reuse pass.
#[derive(Debug, Clone, Default)]
struct DecodeScratch {
    blocks: Vec<usize>,
    best_s: Vec<f32>,
    best_i: Vec<i32>,
    cbuf: Vec<f32>,
    scores: Vec<f32>,
}

/// One autoregressive decode session: a [`KvCache`] plus the head
/// layout, routing geometry, reusable step workspace and per-step
/// accounting. One
/// [`AttentionBackend::forward_decode`](super::backend::AttentionBackend::forward_decode)
/// call per token covers all `h` query heads.
#[derive(Debug, Clone)]
pub struct DecodeSession {
    cache: KvCache,
    /// query heads served per step (GQA group = h / cache.h_kv())
    h: usize,
    /// per-KV-head routing geometry; [`DecodeSession::new`] builds the
    /// uniform plan, which reproduces the pre-plan session bit for bit
    plan: RoutePlan,
    /// reusable per-step working buffers
    scratch: DecodeScratch,
    /// decode steps served so far
    steps: u64,
    /// K/V bytes gathered from the cache by the last decode step,
    /// summed over all query heads
    last_gathered_bytes: u64,
    /// blocks attended by the last decode step, summed over all query
    /// heads (each incl. its own block)
    last_routed_blocks: usize,
    /// query-head decode steps that degraded to dense via the runtime
    /// margin fallback (planned-`Dense` heads don't count)
    fallback_steps: u64,
}

impl DecodeSession {
    /// A session routing every KV head uniformly at `(block, topk)`
    /// over a contiguous cache.
    pub fn new(h: usize, h_kv: usize, d: usize, block: usize, topk: usize) -> Self {
        Self::with_plan(h, h_kv, d, RoutePlan::uniform(h_kv, block, topk))
    }

    /// A session whose KV heads follow a per-head [`RoutePlan`]: each
    /// KV head's cache store is block-partitioned at its plan's
    /// `block`, routed heads select their plan's `topk`, and
    /// [`HeadMode::Dense`](super::plan::HeadMode::Dense) heads attend
    /// the whole cache. A uniform plan is bit-identical to
    /// [`DecodeSession::new`].
    pub fn with_plan(h: usize, h_kv: usize, d: usize, plan: RoutePlan) -> Self {
        assert!(h >= 1 && h_kv >= 1 && h % h_kv == 0, "h={h} must be a multiple of h_kv={h_kv}");
        assert_eq!(
            plan.h_kv(),
            h_kv,
            "route plan covers {} KV heads, session has {h_kv}",
            plan.h_kv()
        );
        let blocks: Vec<usize> = plan.heads.iter().map(|hp| hp.block).collect();
        Self {
            cache: KvCache::with_blocks(h_kv, d, &blocks),
            h,
            plan,
            scratch: DecodeScratch::default(),
            steps: 0,
            last_gathered_bytes: 0,
            last_routed_blocks: 0,
            fallback_steps: 0,
        }
    }

    /// A session whose cache applies the streaming key convolution
    /// (shared taps, one stream per KV head).
    pub fn with_kconv(
        h: usize,
        h_kv: usize,
        d: usize,
        block: usize,
        topk: usize,
        w: &[f32],
        width: usize,
    ) -> Self {
        let mut s = Self::new(h, h_kv, d, block, topk);
        s.cache = KvCache::with_kconv(h_kv, d, block, w, width);
        s
    }

    /// The paged twin of [`DecodeSession::new`]: cache blocks live in
    /// pages from the shared `pool`. Decodes bit-identically to the
    /// contiguous session (pinned by `rust/tests/paged_parity.rs`).
    pub fn new_paged(
        h: usize,
        h_kv: usize,
        d: usize,
        block: usize,
        topk: usize,
        pool: &PagePool,
    ) -> Self {
        Self::with_plan_paged(h, h_kv, d, RoutePlan::uniform(h_kv, block, topk), pool)
    }

    /// The paged twin of [`DecodeSession::with_plan`].
    pub fn with_plan_paged(h: usize, h_kv: usize, d: usize, plan: RoutePlan, pool: &PagePool) -> Self {
        let mut s = Self::with_plan(h, h_kv, d, plan);
        let blocks: Vec<usize> = s.plan.heads.iter().map(|hp| hp.block).collect();
        s.cache = KvCache::paged_with_blocks(h_kv, d, &blocks, pool);
        s
    }

    /// The paged twin of [`DecodeSession::with_kconv`]: key convolution
    /// streams over page-backed storage.
    #[allow(clippy::too_many_arguments)]
    pub fn with_kconv_paged(
        h: usize,
        h_kv: usize,
        d: usize,
        block: usize,
        topk: usize,
        w: &[f32],
        width: usize,
        pool: &PagePool,
    ) -> Self {
        let mut s = Self::new(h, h_kv, d, block, topk);
        s.cache = KvCache::paged_with_kconv(h_kv, d, block, w, width, pool);
        s
    }

    /// Switch the cache's storage dtype (builder-style, before any
    /// append): K/V rows quantize to `dtype` at store time, routing
    /// stays f32 ([`KvCache::with_dtype`]). The session's routed block
    /// sets are bit-identical across dtypes; only the attention
    /// arithmetic reads quantized rows (through the fused dequant
    /// kernels). Panics if tokens are already cached.
    pub fn with_dtype(mut self, dtype: KvDtype) -> Self {
        self.cache = self.cache.with_dtype(dtype);
        self
    }

    /// Storage dtype of this session's KV cache.
    pub fn dtype(&self) -> KvDtype {
        self.cache.dtype()
    }

    /// Fork a new session sharing this session's cached prefix via CoW
    /// pages ([`KvCache::fork`]) — the paged prefix-sharing path for a
    /// common system prompt. The fork keeps the plan and head layout
    /// but starts its own step counters and workspace; it decodes
    /// bit-identically to an independent session fed the same history.
    pub fn fork(&self) -> DecodeSession {
        DecodeSession {
            cache: self.cache.fork(),
            h: self.h,
            plan: self.plan.clone(),
            scratch: DecodeScratch::default(),
            steps: 0,
            last_gathered_bytes: 0,
            last_routed_blocks: 0,
            fallback_steps: 0,
        }
    }

    /// Evict this session's cached tokens ([`KvCache::evict`]) —
    /// preemption under page-budget pressure. The session stays open
    /// (plan, layout and served-step counters intact); replaying the
    /// original appends restores its decode outputs bit for bit.
    /// Returns the page-table entries released.
    pub fn evict(&mut self) -> usize {
        self.cache.evict()
    }

    /// Page-table entries this session's cache holds
    /// ([`KvCache::total_pages`]).
    pub fn total_pages(&self) -> usize {
        self.cache.total_pages()
    }

    /// The session's KV cache (read-only).
    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// Query heads per step.
    pub fn h(&self) -> usize {
        self.h
    }

    /// KV heads in the cache.
    pub fn h_kv(&self) -> usize {
        self.cache.h_kv()
    }

    /// Head dimension.
    pub fn d(&self) -> usize {
        self.cache.d()
    }

    /// Head 0's routed top-k — the session-wide top-k of a uniform
    /// plan. Mixed plans should ask per head via [`DecodeSession::plan`].
    pub fn topk(&self) -> usize {
        self.plan.head(0).topk
    }

    /// The per-KV-head routing plan this session decodes under.
    pub fn plan(&self) -> &RoutePlan {
        &self.plan
    }

    /// Query-head decode steps that degraded to dense via the runtime
    /// margin fallback so far.
    pub fn fallback_steps(&self) -> u64 {
        self.fallback_steps
    }

    /// The KV head query head `qh` routes and attends against.
    pub fn kv_head_of(&self, qh: usize) -> usize {
        debug_assert!(qh < self.h);
        qh / (self.h / self.cache.h_kv())
    }

    /// Tokens cached so far.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    /// Whether the cache is still empty.
    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    /// Decode steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// K/V bytes gathered by the most recent step (all query heads).
    pub fn last_gathered_bytes(&self) -> u64 {
        self.last_gathered_bytes
    }

    /// Blocks attended by the most recent step (all query heads).
    pub fn last_routed_blocks(&self) -> usize {
        self.last_routed_blocks
    }

    /// Append one token's packed `(h_kv, d)` (k_t, v_t) to the cache.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        self.cache.append(k_t, v_t);
    }

    /// The block sets the current packed `(h, d)` query would attend
    /// (routing only), one per query head. Planned-dense heads report
    /// every block of their KV head's store.
    pub fn route_current(&self, q: &[f32]) -> Vec<Vec<usize>> {
        assert_eq!(q.len(), self.h * self.d());
        let d = self.d();
        (0..self.h)
            .map(|qh| {
                let kvh = self.kv_head_of(qh);
                let hp = self.plan.head(kvh);
                if hp.is_dense() {
                    (0..self.cache.num_blocks_of(kvh)).collect()
                } else {
                    self.cache.route(&q[qh * d..(qh + 1) * d], kvh, hp.topk)
                }
            })
            .collect()
    }

    /// Routed decode of a packed `(h, d)` query: per query head, its KV
    /// head's planned top-k blocks + own block (the MoBA decode path);
    /// planned-dense heads attend the whole cache. When the plan's
    /// margin fallback is enabled, a routed head whose per-row score
    /// margin collapses below the threshold degrades to dense for that
    /// step (counted in [`DecodeSession::fallback_steps`]). Returns the
    /// packed `(h, d)` output row.
    pub fn decode_routed(&mut self, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_routed_into(q, &mut out);
        out
    }

    /// [`DecodeSession::decode_routed`] into a caller-provided (reused)
    /// output row — with the session's persistent step workspace, a
    /// steady-state call performs zero heap allocations.
    pub fn decode_routed_into(&mut self, q: &[f32], out: &mut Vec<f32>) {
        // resize only: decode_routed_slice fully rewrites every row
        out.resize(self.h * self.d(), 0.0);
        self.decode_routed_slice(q, out);
    }

    /// [`DecodeSession::decode_routed_into`] against a pre-sized output
    /// window (`out.len() == h * d`) — the batched decode entry point:
    /// [`AttentionBackend::forward_decode_batch`](super::backend::AttentionBackend::forward_decode_batch)
    /// hands each session a disjoint window of the packed batch output,
    /// so B sessions can step concurrently without touching each
    /// other's rows. Bit-identical to `decode_routed_into`.
    pub fn decode_routed_slice(&mut self, q: &[f32], out: &mut [f32]) {
        assert_eq!(q.len(), self.h * self.d());
        assert_eq!(out.len(), self.h * self.d());
        let d = self.d();
        let h = self.h;
        let group = h / self.cache.h_kv();
        let mut gathered = 0u64;
        let mut routed = 0usize;
        let mut degraded = 0u64;
        {
            let DecodeSession { cache, scratch, plan, .. } = self;
            for qh in 0..h {
                let kvh = qh / group;
                let hp = plan.head(kvh);
                let qrow = &q[qh * d..(qh + 1) * d];
                if hp.is_dense() {
                    scratch.blocks.clear();
                    scratch.blocks.extend(0..cache.num_blocks_of(kvh));
                } else {
                    let margin = cache.route_into(
                        qrow,
                        kvh,
                        hp.topk,
                        &mut scratch.blocks,
                        &mut scratch.best_s,
                        &mut scratch.best_i,
                        &mut scratch.cbuf,
                    );
                    if margin < plan.fallback_margin {
                        // collapsed margin: distractor blocks score as
                        // well as the selected ones — attend everything
                        degraded += 1;
                        scratch.blocks.clear();
                        scratch.blocks.extend(0..cache.num_blocks_of(kvh));
                    }
                }
                gathered += cache.gather_bytes(kvh, &scratch.blocks);
                routed += scratch.blocks.len();
                let orow = &mut out[qh * d..(qh + 1) * d];
                cache.attend_into(qrow, kvh, &scratch.blocks, &mut scratch.scores, orow);
            }
        }
        self.fallback_steps += degraded;
        self.note_step(gathered, routed);
    }

    /// Exact dense decode of a packed `(h, d)` query over the whole
    /// cache (the fallback path and the oracle for routed decode at
    /// full routing). Returns the packed `(h, d)` output row.
    pub fn decode_dense(&mut self, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_dense_into(q, &mut out);
        out
    }

    /// [`DecodeSession::decode_dense`] into a caller-provided (reused)
    /// output row — the zero-allocation twin.
    pub fn decode_dense_into(&mut self, q: &[f32], out: &mut Vec<f32>) {
        // resize only: decode_dense_slice fully rewrites every row
        out.resize(self.h * self.d(), 0.0);
        self.decode_dense_slice(q, out);
    }

    /// [`DecodeSession::decode_dense_into`] against a pre-sized output
    /// window (`out.len() == h * d`) — the dense twin of
    /// [`DecodeSession::decode_routed_slice`] for the batched decode
    /// path. Bit-identical to `decode_dense_into`.
    pub fn decode_dense_slice(&mut self, q: &[f32], out: &mut [f32]) {
        assert_eq!(q.len(), self.h * self.d());
        assert_eq!(out.len(), self.h * self.d());
        let d = self.d();
        let h = self.h;
        let group = h / self.cache.h_kv();
        let mut gathered = 0u64;
        let mut routed = 0usize;
        {
            let DecodeSession { cache, scratch, .. } = self;
            for qh in 0..h {
                let kvh = qh / group;
                // per-head block list: mixed plans partition each KV
                // head's store at its own block size
                scratch.blocks.clear();
                scratch.blocks.extend(0..cache.num_blocks_of(kvh));
                gathered += cache.gather_bytes(kvh, &scratch.blocks);
                routed += scratch.blocks.len();
                let qrow = &q[qh * d..(qh + 1) * d];
                let orow = &mut out[qh * d..(qh + 1) * d];
                cache.attend_into(qrow, kvh, &scratch.blocks, &mut scratch.scores, orow);
            }
        }
        self.note_step(gathered, routed);
    }

    fn note_step(&mut self, gathered: u64, routed: usize) {
        self.last_gathered_bytes = gathered;
        self.last_routed_blocks = routed;
        self.steps += 1;
    }
}

/// Slow single-head oracle for the decode semantics, ragged-n capable:
/// row `t` attends its own (possibly partial) block causally plus the
/// top-k complete strictly-past blocks by q·centroid, with f64 softmax.
/// Routing reuses [`tiled_topk`] over the complete-prefix centroids, so
/// selection ties break exactly as in prefill and decode. Multi-head
/// callers run it once per query head with the GQA-mapped K/V slices.
pub fn decode_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let cb = n / block; // complete blocks
    let c = centroids(&k[..cb * block * d], cb * block, d, block);
    let (idx, _) = tiled_topk(q, &c, n, d, block, topk, 64);
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; n * d];
    for t in 0..n {
        let own = t / block;
        let routed = &idx[t * topk..(t + 1) * topk];
        let qt = &q[t * d..(t + 1) * d];
        let mut m = f64::NEG_INFINITY;
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            let ub = u / block;
            if ub != own && !routed.contains(&(ub as i32)) {
                continue;
            }
            let ku = &k[u * d..(u + 1) * d];
            let mut acc = 0.0f64;
            for cc in 0..d {
                acc += qt[cc] as f64 * ku[cc] as f64;
            }
            *su = acc * scale;
            if *su > m {
                m = *su;
            }
        }
        let mut z = 0.0f64;
        let mut acc = vec![0.0f64; d];
        for (u, &su) in s.iter().enumerate() {
            if su == f64::NEG_INFINITY {
                continue;
            }
            let p = (su - m).exp();
            z += p;
            let vu = &v[u * d..(u + 1) * d];
            for cc in 0..d {
                acc[cc] += p * vu[cc] as f64;
            }
        }
        let ot = &mut o[t * d..(t + 1) * d];
        for cc in 0..d {
            ot[cc] = (acc[cc] / z) as f32;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::kconv::kconv;
    use crate::attention::plan::HeadPlan;
    use crate::attention::testutil::{max_abs_diff, qkv, qkv_packed, Rng};
    use crate::attention::packed_rows;

    #[test]
    fn append_tracks_blocks_and_centroids() {
        let (d, block) = (4, 8);
        let mut cache = KvCache::new(1, d, block);
        let mut rng = Rng::new(1);
        for t in 0..20 {
            cache.append(&rng.normal_vec(d), &rng.normal_vec(d));
            assert_eq!(cache.len(), t + 1);
            assert_eq!(cache.num_blocks(), (t + 1).div_ceil(block));
            assert_eq!(cache.complete_blocks(), (t + 1) / block);
        }
        assert_eq!(cache.block_len(0), 8);
        assert_eq!(cache.block_len(2), 4); // 20 = 2*8 + 4
        // centroid of block 1 == mean of its stored keys
        let cen = cache.centroid(0, 1);
        for c in 0..d {
            let mean: f32 =
                (8..16).map(|t| cache.keys()[t * d + c]).sum::<f32>() / 8.0;
            assert!((cen[c] - mean).abs() < 1e-5);
        }
    }

    /// Multi-head appends keep every KV head's store independent: each
    /// head's keys/values/centroids equal a single-head cache fed that
    /// head's rows.
    #[test]
    fn multi_head_stores_are_per_head_caches() {
        let (h_kv, n, d, block) = (3, 26, 4, 8);
        let (_, k, v) = qkv_packed(2, 1, h_kv, n, d);
        let mut cache = KvCache::new(h_kv, d, block);
        for t in 0..n {
            cache.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
        }
        for head in 0..h_kv {
            let mut single = KvCache::new(1, d, block);
            for t in 0..n {
                single.append(
                    &k[(head * n + t) * d..(head * n + t + 1) * d],
                    &v[(head * n + t) * d..(head * n + t + 1) * d],
                );
            }
            assert_eq!(cache.keys_of(head), single.keys(), "head {head} keys");
            assert_eq!(cache.values_of(head), single.values(), "head {head} values");
            for b in 0..cache.num_blocks() {
                assert_eq!(cache.centroid(head, b), single.centroid(0, b), "head {head} b {b}");
            }
        }
    }

    /// Complete-block centroids are bit-identical to the batch kernel.
    #[test]
    fn complete_block_centroids_match_batch_exactly() {
        let (n, d, block) = (64, 8, 16);
        let (_, k, v) = qkv(2, n, d);
        let mut cache = KvCache::new(1, d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let batch = crate::attention::centroid::centroids(&k, n, d, block);
        for b in 0..n / block {
            assert_eq!(&cache.centroid(0, b)[..], &batch[b * d..(b + 1) * d], "block {b}");
        }
    }

    #[test]
    fn route_is_sorted_causal_and_includes_own_block() {
        let (n, d, block, topk) = (100, 8, 16, 3);
        let (q, k, v) = qkv(3, n, d);
        let mut cache = KvCache::new(1, d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let blocks = cache.route(&q[t * d..(t + 1) * d], 0, topk);
            let own = t / block;
            assert!(blocks.windows(2).all(|w| w[0] < w[1]), "t={t} {blocks:?}");
            assert_eq!(*blocks.last().unwrap(), own);
            assert!(blocks.len() <= topk + 1);
            // routed (non-own) blocks are complete and strictly past
            for &b in &blocks[..blocks.len() - 1] {
                assert!(b < own);
            }
        }
    }

    #[test]
    fn full_routing_decode_equals_dense_rows() {
        let (n, d, block) = (96, 8, 16);
        let (q, k, v) = qkv(4, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(1, 1, d, block, n / block); // topk >= all blocks
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
            assert!(
                max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                "row {t}"
            );
        }
        assert_eq!(sess.steps(), n as u64);
        assert!(sess.last_gathered_bytes() > 0);
    }

    /// One GQA decode step covers every query head: the packed output
    /// equals per-head single-head sessions over the mapped KV heads.
    #[test]
    fn gqa_step_equals_per_head_single_head_sessions() {
        let (h, h_kv, n, d, block, topk) = (4, 2, 60, 8, 16, 2);
        let (q, k, v) = qkv_packed(5, h, h_kv, n, d);
        let mut sess = DecodeSession::new(h, h_kv, d, block, topk);
        let mut singles: Vec<DecodeSession> =
            (0..h).map(|_| DecodeSession::new(1, 1, d, block, topk)).collect();
        let group = h / h_kv;
        for t in 0..n {
            sess.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
            let o = sess.decode_routed(&packed_rows(&q, h, n, d, t));
            assert_eq!(o.len(), h * d);
            for (qh, single) in singles.iter_mut().enumerate() {
                let kvh = qh / group;
                single.append(
                    &k[(kvh * n + t) * d..(kvh * n + t + 1) * d],
                    &v[(kvh * n + t) * d..(kvh * n + t + 1) * d],
                );
                let oh = single.decode_routed(&q[(qh * n + t) * d..(qh * n + t + 1) * d]);
                assert_eq!(&o[qh * d..(qh + 1) * d], &oh[..], "t={t} head {qh}");
            }
        }
        // accounting sums over query heads
        assert_eq!(
            sess.last_routed_blocks(),
            singles.iter().map(|s| s.last_routed_blocks()).sum::<usize>()
        );
        assert_eq!(
            sess.last_gathered_bytes(),
            singles.iter().map(|s| s.last_gathered_bytes()).sum::<u64>()
        );
    }

    #[test]
    fn dense_decode_equals_naive_rows_ragged() {
        let (n, d, block) = (70, 4, 16); // n not divisible by block
        let (q, k, v) = qkv(5, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(1, 1, d, block, 0);
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_dense(&q[t * d..(t + 1) * d]);
            assert!(max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4, "row {t}");
        }
    }

    #[test]
    fn routed_decode_matches_reference_ragged_and_topk0() {
        for (n, d, block, topk) in [(100, 8, 16, 2), (64, 4, 16, 0), (50, 4, 8, 3)] {
            let (q, k, v) = qkv(6 + n as u64, n, d);
            let oracle = decode_reference(&q, &k, &v, n, d, block, topk);
            let mut sess = DecodeSession::new(1, 1, d, block, topk);
            for t in 0..n {
                sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
                assert!(
                    max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                    "n={n} block={block} topk={topk} row {t}"
                );
            }
        }
    }

    /// Streaming kconv inside the cache == batch kconv of the same
    /// keys, independently per KV head.
    #[test]
    fn cached_keys_match_batch_kconv() {
        let (h_kv, n, d, block, width) = (2, 48, 8, 16, 4);
        let (_, k, v) = qkv_packed(7, 1, h_kv, n, d);
        let mut rng = Rng::new(8);
        let w = rng.normal_vec(width * d);
        let mut cache = KvCache::with_kconv(h_kv, d, block, &w, width);
        for t in 0..n {
            cache.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
        }
        for head in 0..h_kv {
            let batch = kconv(&k[head * n * d..(head + 1) * n * d], &w, n, d, width);
            assert_eq!(cache.keys_of(head), &batch[..], "head {head}");
            // values are stored untouched
            assert_eq!(cache.values_of(head), &v[head * n * d..(head + 1) * n * d]);
        }
    }

    /// A uniform plan is the identity: `with_plan` reproduces `new`
    /// bit for bit, step for step, including the accounting counters.
    #[test]
    fn uniform_plan_session_is_bitwise_identical_to_new() {
        let (h, h_kv, n, d, block, topk) = (4, 2, 52, 8, 16, 2);
        let (q, k, v) = qkv_packed(11, h, h_kv, n, d);
        let mut legacy = DecodeSession::new(h, h_kv, d, block, topk);
        let plan = RoutePlan::uniform(h_kv, block, topk);
        let mut planned = DecodeSession::with_plan(h, h_kv, d, plan);
        assert_eq!(planned.topk(), topk);
        assert_eq!(planned.cache().block(), block);
        for t in 0..n {
            let (kt, vt) = (packed_rows(&k, h_kv, n, d, t), packed_rows(&v, h_kv, n, d, t));
            legacy.append(&kt, &vt);
            planned.append(&kt, &vt);
            let qt = packed_rows(&q, h, n, d, t);
            let (a, b) = (legacy.decode_routed(&qt), planned.decode_routed(&qt));
            assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "t={t}");
        }
        assert_eq!(legacy.last_gathered_bytes(), planned.last_gathered_bytes());
        assert_eq!(legacy.last_routed_blocks(), planned.last_routed_blocks());
        assert_eq!(planned.fallback_steps(), 0);
    }

    /// A mixed plan composes per head: each KV head's slab equals a
    /// single-head session at that head's own geometry, and dense-mode
    /// heads equal dense decode.
    #[test]
    fn mixed_plan_decode_composes_per_head_geometries() {
        let (h, h_kv, n, d) = (4, 2, 57, 8);
        let plan = RoutePlan {
            heads: vec![HeadPlan::routed(8, 3), HeadPlan::dense(16)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        };
        let (q, k, v) = qkv_packed(12, h, h_kv, n, d);
        let mut sess = DecodeSession::with_plan(h, h_kv, d, plan.clone());
        let mut routed0 = DecodeSession::new(1, 1, d, 8, 3);
        let mut dense1 = DecodeSession::new(1, 1, d, 16, 0);
        let group = h / h_kv;
        for t in 0..n {
            sess.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
            routed0.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            dense1.append(
                &k[(n + t) * d..(n + t + 1) * d],
                &v[(n + t) * d..(n + t + 1) * d],
            );
            let o = sess.decode_routed(&packed_rows(&q, h, n, d, t));
            for qh in 0..h {
                let qrow = &q[(qh * n + t) * d..(qh * n + t + 1) * d];
                let expect = if qh / group == 0 {
                    routed0.decode_routed(qrow)
                } else {
                    dense1.decode_dense(qrow)
                };
                let got = &o[qh * d..(qh + 1) * d];
                assert!(
                    got.iter().zip(&expect).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "t={t} head {qh}"
                );
            }
        }
        // per-head stores carry per-head block geometry
        assert_eq!(sess.cache().block_of(0), 8);
        assert_eq!(sess.cache().block_of(1), 16);
        assert_eq!(sess.cache().num_blocks_of(0), n.div_ceil(8));
        assert_eq!(sess.cache().num_blocks_of(1), n.div_ceil(16));
        // dense heads report all their blocks from route_current
        let routes = sess.route_current(&packed_rows(&q, h, n, d, n - 1));
        assert_eq!(routes[h - 1], (0..n.div_ceil(16)).collect::<Vec<_>>());
        assert_eq!(sess.fallback_steps(), 0); // planned dense is not a fallback
    }

    /// The runtime margin fallback: an impossible threshold degrades
    /// every routed step to dense (output == dense decode), a disabled
    /// threshold never fires.
    #[test]
    fn margin_fallback_degrades_routed_steps_to_dense() {
        let (n, d, block, topk) = (64, 8, 8, 1);
        let (q, k, v) = qkv(13, n, d);
        let mut plan = RoutePlan::uniform(1, block, topk);
        plan.fallback_margin = f32::INFINITY; // every finite margin collapses
        let mut degraded = DecodeSession::with_plan(1, 1, d, plan);
        let mut dense = DecodeSession::new(1, 1, d, block, topk);
        for t in 0..n {
            degraded.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            dense.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = degraded.decode_routed(&q[t * d..(t + 1) * d]);
            let od = dense.decode_dense(&q[t * d..(t + 1) * d]);
            assert!(o.iter().zip(&od).all(|(x, y)| x.to_bits() == y.to_bits()), "t={t}");
        }
        // rows with at least one rejected candidate have finite margin:
        // own > topk, i.e. from t = (topk + 1) * block onward
        let finite_rows = (n - (topk + 1) * block) as u64;
        assert_eq!(degraded.fallback_steps(), finite_rows);
    }

    /// `route_into` reports the selection margin: +inf while nothing is
    /// rejectable, positive once distractor blocks are scored, and the
    /// selection itself is untouched by the accounting.
    #[test]
    fn route_margin_tracks_worst_admitted_vs_best_rejected() {
        let (d, block, topk) = (4, 4, 2);
        let mut cache = KvCache::new(1, d, block);
        let mut scratch = DecodeScratch::default();
        let q = [1.0f32, 0.0, 0.0, 0.0];
        let margin_at = |cache: &KvCache, s: &mut DecodeScratch| {
            cache.route_into(
                &q,
                0,
                topk,
                &mut s.blocks,
                &mut s.best_s,
                &mut s.best_i,
                &mut s.cbuf,
            )
        };
        // three blocks of constant keys scoring 3.0, 2.0, 1.0 — then a
        // current block the row lives in
        for val in [3.0f32, 2.0, 1.0] {
            for _ in 0..block {
                cache.append(&[val, 0.0, 0.0, 0.0], &[0.0; 4]);
            }
        }
        cache.append(&[0.0; 4], &[0.0; 4]);
        // candidates {3, 2, 1}: admitted worst 2.0, best rejected 1.0
        let m = margin_at(&cache, &mut scratch);
        assert!((m - 1.0).abs() < 1e-5, "margin {m}");
        assert_eq!(scratch.blocks, vec![0, 1, 3]);
        // fewer candidates than topk: nothing rejected, margin = +inf
        let mut small = KvCache::new(1, d, block);
        for _ in 0..block + 1 {
            small.append(&[1.0, 0.0, 0.0, 0.0], &[0.0; 4]);
        }
        assert_eq!(margin_at(&small, &mut scratch), f32::INFINITY);
    }

    /// The tentpole contract in miniature: a paged session's outputs
    /// and per-step counters are bit-identical to the contiguous
    /// session's, mixed plans and ragged tails included. (The full
    /// sweep over shapes, thread counts and the batched entry points
    /// lives in `rust/tests/paged_parity.rs`.)
    #[test]
    fn paged_session_is_bitwise_identical_to_contiguous() {
        let (h, h_kv, n, d) = (4, 2, 57, 8);
        let plan = RoutePlan {
            heads: vec![HeadPlan::routed(8, 3), HeadPlan::dense(16)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        };
        let pool = PagePool::new(16, None);
        let (q, k, v) = qkv_packed(21, h, h_kv, n, d);
        let mut contig = DecodeSession::with_plan(h, h_kv, d, plan.clone());
        let mut paged = DecodeSession::with_plan_paged(h, h_kv, d, plan, &pool);
        assert!(paged.cache().is_paged() && !contig.cache().is_paged());
        for t in 0..n {
            let (kt, vt) = (packed_rows(&k, h_kv, n, d, t), packed_rows(&v, h_kv, n, d, t));
            contig.append(&kt, &vt);
            paged.append(&kt, &vt);
            let qt = packed_rows(&q, h, n, d, t);
            let (a, b) = (contig.decode_routed(&qt), paged.decode_routed(&qt));
            assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()), "t={t}");
            assert_eq!(contig.last_gathered_bytes(), paged.last_gathered_bytes(), "t={t}");
            assert_eq!(contig.last_routed_blocks(), paged.last_routed_blocks(), "t={t}");
        }
        // one page per logical block per head, live in the pool
        let expect = n.div_ceil(8) + n.div_ceil(16);
        assert_eq!(paged.total_pages(), expect);
        assert_eq!(pool.live_pages(), expect);
        // per-block views agree across layouts
        for head in 0..h_kv {
            for b in 0..contig.cache().num_blocks_of(head) {
                assert_eq!(
                    contig.cache().block_keys(head, b),
                    paged.cache().block_keys(head, b),
                    "head {head} block {b}"
                );
            }
        }
        drop(paged);
        assert_eq!(pool.live_pages(), 0);
    }

    /// Fork shares every page, then the first divergent append splits
    /// only the partial tail page — and both sessions decode exactly
    /// like independent sessions fed the same histories.
    #[test]
    fn fork_shares_prefix_and_splits_on_divergence() {
        let (h, n_prefix, d, block, topk) = (1, 20, 8, 8, 2);
        let pool = PagePool::new(block, None);
        let (q, k, v) = qkv_packed(22, h, 1, n_prefix + 8, d);
        let mut parent = DecodeSession::new_paged(h, 1, d, block, topk, &pool);
        let mut indep_parent = DecodeSession::new(h, 1, d, block, topk);
        for t in 0..n_prefix {
            let (kt, vt) = (packed_rows(&k, 1, n_prefix + 8, d, t), packed_rows(&v, 1, n_prefix + 8, d, t));
            parent.append(&kt, &vt);
            indep_parent.append(&kt, &vt);
        }
        let pages_before = pool.live_pages();
        assert_eq!(pages_before, n_prefix.div_ceil(block)); // 3 pages, last partial

        let mut child = parent.fork();
        let mut indep_child = indep_parent.clone();
        // zero new pages: the whole prefix is shared
        assert_eq!(pool.live_pages(), pages_before);
        assert_eq!(pool.prefix_shared(), pages_before as u64);

        // diverge: parent and child append different continuations
        for (i, t) in (n_prefix..n_prefix + 4).enumerate() {
            let (kt, vt) =
                (packed_rows(&k, 1, n_prefix + 8, d, t), packed_rows(&v, 1, n_prefix + 8, d, t));
            let (kt2, vt2) = (
                packed_rows(&k, 1, n_prefix + 8, d, t + 4),
                packed_rows(&v, 1, n_prefix + 8, d, t + 4),
            );
            parent.append(&kt, &vt);
            indep_parent.append(&kt, &vt);
            child.append(&kt2, &vt2);
            indep_child.append(&kt2, &vt2);
            let qt = packed_rows(&q, h, n_prefix + 8, d, t);
            for (sess, indep) in
                [(&mut parent, &mut indep_parent), (&mut child, &mut indep_child)]
            {
                let (a, b) = (sess.decode_routed(&qt), indep.decode_routed(&qt));
                assert!(
                    a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "divergent step {i}"
                );
            }
        }
        // exactly one CoW split: parent's first divergent append found
        // the partial tail shared; once parent moved to its copy the
        // child's tail was unique again and wrote in place
        assert_eq!(pool.cow_splits(), 1);
        // complete prefix pages stayed shared; only the tails forked
        // (24 tokens = 3 blocks/table): 2 shared + 2 private tails
        assert_eq!(pool.live_pages(), 2 + 2);
    }

    /// Evict, replay the same appends, and every output bit comes back
    /// — the preemption/re-prefill path, kconv streams included.
    #[test]
    fn evict_then_replay_restores_outputs_bitwise() {
        let (h, n, d, block, topk, width) = (2, 26, 8, 8, 2, 3);
        let pool = PagePool::new(block, None);
        let mut rng = Rng::new(23);
        let w = rng.normal_vec(width * d);
        let (q, k, v) = qkv_packed(24, h, 1, n, d);
        let mut sess = DecodeSession::new_paged(h, 1, d, block, topk, &pool);
        sess.cache = KvCache::paged_with_kconv(1, d, block, &w, width, &pool);
        let mut outputs = Vec::new();
        for t in 0..n {
            sess.append(&packed_rows(&k, 1, n, d, t), &packed_rows(&v, 1, n, d, t));
            outputs.push(sess.decode_routed(&packed_rows(&q, h, n, d, t)));
        }
        let released = sess.evict();
        assert_eq!(released, n.div_ceil(block));
        assert_eq!(pool.live_pages(), 0);
        assert!(sess.is_empty());
        // replay: identical appends rebuild identical pages and streams
        for t in 0..n {
            sess.append(&packed_rows(&k, 1, n, d, t), &packed_rows(&v, 1, n, d, t));
            let o = sess.decode_routed(&packed_rows(&q, h, n, d, t));
            assert!(
                o.iter().zip(&outputs[t]).all(|(x, y)| x.to_bits() == y.to_bits()),
                "replayed step {t} diverged"
            );
        }
    }

    #[test]
    #[should_panic(expected = "no contiguous view")]
    fn paged_cache_rejects_contiguous_accessors() {
        let pool = PagePool::new(8, None);
        let mut cache = KvCache::paged_with_blocks(1, 4, &[8], &pool);
        cache.append(&[0.0; 4], &[0.0; 4]);
        let _ = cache.keys_of(0);
    }

    #[test]
    #[should_panic(expected = "exceeds the pool's page_tokens")]
    fn block_larger_than_page_rejected() {
        let pool = PagePool::new(8, None);
        KvCache::paged_with_blocks(1, 4, &[16], &pool);
    }

    /// Admission-cost estimates: fresh blocks plus a CoW split for a
    /// shared partial tail.
    #[test]
    fn append_page_cost_counts_new_blocks_and_tail_splits() {
        let pool = PagePool::new(8, None);
        let mut cache = KvCache::paged_with_blocks(2, 4, &[8, 4], &pool);
        for _ in 0..6 {
            cache.append(&[0.0; 8], &[0.0; 8]);
        }
        // head 0 (block 8): 6 + 10 tokens = 2 blocks (1 new); head 1
        // (block 4): 6 + 10 = 4 blocks (2 new)
        assert_eq!(cache.append_page_cost(10), 3);
        assert_eq!(cache.append_page_cost(0), 0);
        // a fork makes both partial tails shared: +1 split each
        let _fork = cache.fork();
        assert_eq!(cache.append_page_cost(10), 5);
        // replay estimate is layout-independent
        assert_eq!(cache.pages_for(16), 16usize.div_ceil(8) + 16usize.div_ceil(4));
    }

    #[test]
    #[should_panic]
    fn route_on_empty_cache_panics() {
        KvCache::new(1, 4, 8).route(&[0.0; 4], 0, 2);
    }

    /// Routing is dtype-invariant: centroid sums accumulate the
    /// pre-quantization f32 rows, so every dtype's session selects
    /// bitwise-identical block sets — the tentpole's
    /// routing-stays-full-precision rule at the session level.
    #[test]
    fn routed_block_sets_are_identical_across_dtypes() {
        let (h, h_kv, n, d, block, topk) = (4, 2, 57, 8, 8, 2);
        let mut rng = Rng::new(23);
        let mut sessions: Vec<DecodeSession> = KvDtype::ALL
            .iter()
            .map(|&dt| DecodeSession::new(h, h_kv, d, block, topk).with_dtype(dt))
            .collect();
        for _ in 0..n {
            let (kt, vt) = (rng.normal_vec(h_kv * d), rng.normal_vec(h_kv * d));
            let q = rng.normal_vec(h * d);
            for sess in sessions.iter_mut() {
                sess.append(&kt, &vt);
            }
            let base = sessions[0].route_current(&q);
            for sess in sessions.iter().skip(1) {
                assert_eq!(
                    sess.route_current(&q),
                    base,
                    "dtype {} routed differently from f32",
                    sess.dtype().as_str()
                );
            }
        }
    }

    /// An f16 session's outputs track the f32 session's within a small
    /// relative error (f16 has 11 significand bits; the softmax keeps
    /// intermediate arithmetic f32) — and the quantized paged session
    /// is bitwise identical to the quantized contiguous one.
    #[test]
    fn f16_session_tracks_f32_and_paged_matches_contig_bitwise() {
        let (h, h_kv, n, d, block, topk) = (4, 2, 41, 8, 8, 2);
        let pool = PagePool::new(block, None);
        let mut rng = Rng::new(71);
        let mut f32s = DecodeSession::new(h, h_kv, d, block, topk);
        let mut f16c = DecodeSession::new(h, h_kv, d, block, topk).with_dtype(KvDtype::F16);
        let mut f16p =
            DecodeSession::new_paged(h, h_kv, d, block, topk, &pool).with_dtype(KvDtype::F16);
        for _ in 0..n {
            let (kt, vt) = (rng.normal_vec(h_kv * d), rng.normal_vec(h_kv * d));
            let q = rng.normal_vec(h * d);
            f32s.append(&kt, &vt);
            f16c.append(&kt, &vt);
            f16p.append(&kt, &vt);
            let exact = f32s.decode_routed(&q);
            let quant = f16c.decode_routed(&q);
            let paged = f16p.decode_routed(&q);
            assert_eq!(
                quant.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                paged.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "paged f16 diverged from contiguous f16"
            );
            let scale = exact.iter().fold(1.0f32, |m, &x| m.max(x.abs()));
            for (o, e) in quant.iter().zip(exact.iter()) {
                assert!(
                    (o - e).abs() <= 2e-2 * scale,
                    "f16 output {o} too far from f32 output {e}"
                );
            }
        }
        // byte-true accounting: same blocks gathered, half the bytes
        assert_eq!(f16c.last_gathered_bytes() * 2, f32s.last_gathered_bytes());
    }

    #[test]
    #[should_panic(expected = "with_dtype must be called before any append")]
    fn with_dtype_after_append_panics() {
        let mut cache = KvCache::new(1, 4, 8);
        cache.append(&[1.0; 4], &[2.0; 4]);
        let _ = cache.with_dtype(KvDtype::F16);
    }

    #[test]
    #[should_panic]
    fn plan_head_count_mismatch_panics() {
        DecodeSession::with_plan(4, 2, 8, RoutePlan::uniform(3, 16, 2));
    }

    #[test]
    #[should_panic]
    fn ragged_head_groups_panic() {
        DecodeSession::new(3, 2, 4, 8, 1);
    }
}
