//! Incremental (autoregressive) decode over a block KV cache with
//! streaming MoBA routing — the serving-side twin of the prefill
//! kernels.
//!
//! The paper's routing model (§3; the tiled top-k of Algorithm 1)
//! extends to decode by maintaining block statistics *incrementally* as
//! keys arrive:
//!
//! * [`KvCache`] — per-session K/V storage, one block-partitioned store
//!   *per KV head*, each with a running per-block key sum so the
//!   centroid of any block is one O(d) multiply away. Appending a token
//!   is amortized O(h_kv · d); with key convolution enabled, a
//!   per-head ring buffer of the last `width` raw keys
//!   ([`KconvStream`]) makes the streaming kconv bit-identical to the
//!   batch [`kconv`](super::kconv::kconv).
//! * [`DecodeSession`] — one decode step covers *all* query heads:
//!   each query head routes against its GQA group's KV-head centroids
//!   (top-k over complete, strictly-past blocks, plus the
//!   always-attended current block — the paper's causal own-block
//!   rule) and computes single-row softmax attention over the gathered
//!   blocks. `h = h_kv = 1` reproduces the single-head decode path
//!   bit-for-bit.
//!
//! Parity contract: feeding tokens one at a time through a session
//! reproduces the prefill `forward` of the matching backend
//! row-for-row (see `rust/tests/decode_parity.rs`). The load-bearing
//! detail is that the running block sums are accumulated in arrival
//! order and divided once at read time — exactly the arithmetic of the
//! batch [`centroids`](super::centroid::centroids) — so the routing
//! scores, and therefore the selected block sets, are bit-identical to
//! prefill's.

use super::centroid::centroids;
use super::dense::NEG_INF;
use super::gemm::{accum_rows, qk_row};
use super::kconv::KconvStream;
use super::simd::dot;
use super::topk::{tiled_topk, topk_insert};

/// One KV head's storage: cached (possibly kconv'd) keys and values,
/// (len, d) row-major, plus the running per-block key sums.
#[derive(Debug, Clone)]
struct HeadStore {
    k: Vec<f32>,
    v: Vec<f32>,
    /// running per-block key sums, (num_blocks, d); divided by the
    /// block's token count at read time to form the centroid
    sums: Vec<f32>,
    kconv: Option<KconvStream>,
}

/// Per-session K/V block storage with running centroids, one store per
/// KV head.
///
/// Keys stored here are post-kconv when a [`KconvStream`] is attached
/// (one independent stream per head, shared taps); values are stored as
/// given. `len` tokens occupy `ceil(len / block)` logical blocks per
/// head, of which the last may be partial.
#[derive(Debug, Clone)]
pub struct KvCache {
    h_kv: usize,
    d: usize,
    block: usize,
    heads: Vec<HeadStore>,
}

impl KvCache {
    pub fn new(h_kv: usize, d: usize, block: usize) -> Self {
        assert!(
            h_kv >= 1 && d >= 1 && block >= 1,
            "KvCache needs h_kv >= 1, d >= 1 and block >= 1"
        );
        let heads = (0..h_kv)
            .map(|_| HeadStore { k: Vec::new(), v: Vec::new(), sums: Vec::new(), kconv: None })
            .collect();
        Self { h_kv, d, block, heads }
    }

    /// A cache that applies the depthwise causal key convolution
    /// (paper Appendix B) to every appended key before storing it —
    /// one independent stream per KV head, sharing the (width, d) tap
    /// tensor `w`.
    pub fn with_kconv(h_kv: usize, d: usize, block: usize, w: &[f32], width: usize) -> Self {
        let mut c = Self::new(h_kv, d, block);
        for store in &mut c.heads {
            store.kconv = Some(KconvStream::new(w, width, d));
        }
        c
    }

    pub fn h_kv(&self) -> usize {
        self.h_kv
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Tokens cached (identical across heads).
    pub fn len(&self) -> usize {
        self.heads[0].k.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.heads[0].k.is_empty()
    }

    /// Logical blocks currently occupied, `ceil(len / block)`.
    pub fn num_blocks(&self) -> usize {
        self.len().div_ceil(self.block)
    }

    /// Blocks holding exactly `block` tokens, `len / block`.
    pub fn complete_blocks(&self) -> usize {
        self.len() / self.block
    }

    /// Tokens stored in block `b`.
    pub fn block_len(&self, b: usize) -> usize {
        assert!(b < self.num_blocks());
        (self.len() - b * self.block).min(self.block)
    }

    /// KV head `head`'s cached (post-kconv) keys, (len, d) row-major.
    pub fn keys_of(&self, head: usize) -> &[f32] {
        &self.heads[head].k
    }

    /// KV head `head`'s cached values, (len, d) row-major.
    pub fn values_of(&self, head: usize) -> &[f32] {
        &self.heads[head].v
    }

    /// Single-KV-head convenience accessor (`h_kv == 1`).
    pub fn keys(&self) -> &[f32] {
        assert_eq!(self.h_kv, 1, "use keys_of(head) on a multi-head cache");
        self.keys_of(0)
    }

    /// Single-KV-head convenience accessor (`h_kv == 1`).
    pub fn values(&self) -> &[f32] {
        assert_eq!(self.h_kv, 1, "use values_of(head) on a multi-head cache");
        self.values_of(0)
    }

    /// Append one token's packed (k_t, v_t), each `(h_kv, d)` row-major.
    /// Amortized O(h_kv · d): per head one ring-buffer kconv step
    /// (O(width · d)) when enabled, one add into the current block's
    /// running sum, two row copies — no per-token allocation on the
    /// plain path.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        assert_eq!(k_t.len(), self.h_kv * self.d, "key row has wrong width");
        assert_eq!(v_t.len(), self.h_kv * self.d, "value row has wrong width");
        let t = self.len();
        let b = t / self.block;
        let d = self.d;
        for (head, store) in self.heads.iter_mut().enumerate() {
            if t % self.block == 0 {
                // first token of a fresh block: open its running sum
                let len = store.sums.len();
                store.sums.resize(len + d, 0.0);
            }
            let kh = &k_t[head * d..(head + 1) * d];
            match &mut store.kconv {
                Some(stream) => {
                    let stored = stream.push(kh);
                    let sum = &mut store.sums[b * d..(b + 1) * d];
                    for (c, s) in sum.iter_mut().enumerate() {
                        *s += stored[c];
                    }
                    store.k.extend_from_slice(&stored);
                }
                None => {
                    let sum = &mut store.sums[b * d..(b + 1) * d];
                    for (c, s) in sum.iter_mut().enumerate() {
                        *s += kh[c];
                    }
                    store.k.extend_from_slice(kh);
                }
            }
            store.v.extend_from_slice(&v_t[head * d..(head + 1) * d]);
        }
    }

    /// Write KV head `head`'s block `b` centroid (mean of its stored
    /// keys) into `out`. For complete blocks this is bit-identical to
    /// the batch [`centroids`](super::centroid::centroids): the sum
    /// accumulates in arrival order and is scaled by `1 / block` once.
    pub fn centroid_into(&self, head: usize, b: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.d);
        let inv = 1.0 / self.block_len(b) as f32;
        let sum = &self.heads[head].sums[b * self.d..(b + 1) * self.d];
        for (c, o) in out.iter_mut().enumerate() {
            *o = sum[c] * inv;
        }
    }

    /// KV head `head`'s block `b` centroid as an owned row.
    pub fn centroid(&self, head: usize, b: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.centroid_into(head, b, &mut out);
        out
    }

    /// Route one query head's row (at the current position, i.e. the
    /// last appended token) against KV head `head`'s centroids:
    /// top-`topk` complete strictly-past blocks by q·centroid, plus the
    /// always-attended current block. Returns block indices sorted
    /// ascending, deduplicated, all causal (`<= own`), with the own
    /// block always last.
    ///
    /// Selection uses the same streaming insertion (and therefore the
    /// same tie-breaking: earliest block wins) as
    /// [`tiled_topk`](super::topk::tiled_topk), over centroids computed
    /// with the same arithmetic — so it reproduces prefill routing
    /// exactly.
    pub fn route(&self, q: &[f32], head: usize, topk: usize) -> Vec<usize> {
        let mut blocks = Vec::new();
        let (mut best_s, mut best_i, mut cbuf) = (Vec::new(), Vec::new(), Vec::new());
        self.route_into(q, head, topk, &mut blocks, &mut best_s, &mut best_i, &mut cbuf);
        blocks
    }

    /// [`KvCache::route`] into caller-provided (reused) buffers — the
    /// per-token zero-allocation path. `blocks` receives the selection;
    /// `best_s`/`best_i`/`cbuf` are the running top-k state and the
    /// centroid row, reused across calls.
    #[allow(clippy::too_many_arguments)]
    pub fn route_into(
        &self,
        q: &[f32],
        head: usize,
        topk: usize,
        blocks: &mut Vec<usize>,
        best_s: &mut Vec<f32>,
        best_i: &mut Vec<i32>,
        cbuf: &mut Vec<f32>,
    ) {
        assert!(!self.is_empty(), "route called on an empty cache");
        assert_eq!(q.len(), self.d);
        let own = (self.len() - 1) / self.block;
        blocks.clear();
        if topk > 0 && own > 0 {
            // candidates: blocks [0, own) — all complete by construction
            best_s.clear();
            best_s.resize(topk, f32::NEG_INFINITY);
            best_i.clear();
            best_i.resize(topk, -1);
            cbuf.clear();
            cbuf.resize(self.d, 0.0);
            for j in 0..own {
                self.centroid_into(head, j, cbuf);
                topk_insert(best_s, best_i, dot(q, cbuf), j as i32);
            }
            blocks.extend(best_i.iter().filter(|&&j| j >= 0).map(|&j| j as usize));
            blocks.sort_unstable();
        }
        blocks.push(own);
    }

    /// Single-row softmax attention of one query head's row `q` over
    /// the given blocks of KV head `head` (ascending; the last may be
    /// the partial current block). Exact per-row softmax: gather
    /// scores, subtract the max, combine values — the decode analogue
    /// of one `naive_attention` row.
    pub fn attend(&self, q: &[f32], head: usize, blocks: &[usize]) -> Vec<f32> {
        let mut scores = Vec::new();
        let mut out = vec![0.0f32; self.d];
        self.attend_into(q, head, blocks, &mut scores, &mut out);
        out
    }

    /// [`KvCache::attend`] into a caller-provided output row, with the
    /// score buffer reused across calls — the per-token
    /// zero-allocation path. Scores run on the register-blocked gemv
    /// per block (cache rows are contiguous) and the value combine on
    /// the fused [`accum_rows`]; both preserve the per-element f32
    /// operation order of the dot/axpy formulation, so the output is
    /// bit-identical to it (pinned by the single-head legacy decode
    /// regression).
    pub fn attend_into(
        &self,
        q: &[f32],
        head: usize,
        blocks: &[usize],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        assert!(!self.is_empty(), "attend called on an empty cache");
        assert_eq!(q.len(), self.d);
        assert_eq!(out.len(), self.d);
        let d = self.d;
        let len = self.len();
        let store = &self.heads[head];
        let scale = 1.0 / (d as f32).sqrt();
        scores.clear();
        for &b in blocks {
            let start = b * self.block;
            let end = ((b + 1) * self.block).min(len);
            let seg = scores.len();
            scores.resize(seg + (end - start), 0.0);
            qk_row(q, &store.k[start * d..end * d], d, end - start, scale, &mut scores[seg..]);
        }
        let mut m = NEG_INF;
        for &x in scores.iter() {
            if x > m {
                m = x;
            }
        }
        let mut z = 0.0f32;
        for x in scores.iter_mut() {
            *x = (*x - m).exp();
            z += *x;
        }
        out.fill(0.0);
        let mut seg = 0usize;
        for &b in blocks {
            let start = b * self.block;
            let end = ((b + 1) * self.block).min(len);
            accum_rows(out, &scores[seg..seg + (end - start)], &store.v[start * d..end * d]);
            seg += end - start;
        }
        for o in out.iter_mut() {
            *o /= z;
        }
    }

    /// K and V bytes one query head reads from the cache for `blocks`.
    pub fn gather_bytes(&self, blocks: &[usize]) -> u64 {
        let toks: usize = blocks.iter().map(|&b| self.block_len(b)).sum();
        (2 * toks * self.d * 4) as u64
    }
}

/// The per-session reusable buffers one decode step works in: routing
/// state, the selected block list, the score row and the centroid row.
/// Persisted across steps so a steady-state decode step performs
/// **zero heap allocations** (pinned by
/// `rust/tests/alloc_regression.rs`) — these were eight fresh `Vec`s
/// per token before the workspace-reuse pass.
#[derive(Debug, Clone, Default)]
struct DecodeScratch {
    blocks: Vec<usize>,
    best_s: Vec<f32>,
    best_i: Vec<i32>,
    cbuf: Vec<f32>,
    scores: Vec<f32>,
}

/// One autoregressive decode session: a [`KvCache`] plus the head
/// layout, routing geometry, reusable step workspace and per-step
/// accounting. One
/// [`AttentionBackend::forward_decode`](super::backend::AttentionBackend::forward_decode)
/// call per token covers all `h` query heads.
#[derive(Debug, Clone)]
pub struct DecodeSession {
    cache: KvCache,
    /// query heads served per step (GQA group = h / cache.h_kv())
    h: usize,
    topk: usize,
    /// reusable per-step working buffers
    scratch: DecodeScratch,
    /// decode steps served so far
    steps: u64,
    /// K/V bytes gathered from the cache by the last decode step,
    /// summed over all query heads
    last_gathered_bytes: u64,
    /// blocks attended by the last decode step, summed over all query
    /// heads (each incl. its own block)
    last_routed_blocks: usize,
}

impl DecodeSession {
    pub fn new(h: usize, h_kv: usize, d: usize, block: usize, topk: usize) -> Self {
        assert!(h >= 1 && h_kv >= 1 && h % h_kv == 0, "h={h} must be a multiple of h_kv={h_kv}");
        Self {
            cache: KvCache::new(h_kv, d, block),
            h,
            topk,
            scratch: DecodeScratch::default(),
            steps: 0,
            last_gathered_bytes: 0,
            last_routed_blocks: 0,
        }
    }

    /// A session whose cache applies the streaming key convolution
    /// (shared taps, one stream per KV head).
    pub fn with_kconv(
        h: usize,
        h_kv: usize,
        d: usize,
        block: usize,
        topk: usize,
        w: &[f32],
        width: usize,
    ) -> Self {
        let mut s = Self::new(h, h_kv, d, block, topk);
        s.cache = KvCache::with_kconv(h_kv, d, block, w, width);
        s
    }

    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    /// Query heads per step.
    pub fn h(&self) -> usize {
        self.h
    }

    /// KV heads in the cache.
    pub fn h_kv(&self) -> usize {
        self.cache.h_kv()
    }

    pub fn d(&self) -> usize {
        self.cache.d()
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    /// The KV head query head `qh` routes and attends against.
    pub fn kv_head_of(&self, qh: usize) -> usize {
        debug_assert!(qh < self.h);
        qh / (self.h / self.cache.h_kv())
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn last_gathered_bytes(&self) -> u64 {
        self.last_gathered_bytes
    }

    pub fn last_routed_blocks(&self) -> usize {
        self.last_routed_blocks
    }

    /// Append one token's packed `(h_kv, d)` (k_t, v_t) to the cache.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        self.cache.append(k_t, v_t);
    }

    /// The block sets the current packed `(h, d)` query would attend
    /// (routing only), one per query head.
    pub fn route_current(&self, q: &[f32]) -> Vec<Vec<usize>> {
        assert_eq!(q.len(), self.h * self.d());
        let d = self.d();
        (0..self.h)
            .map(|qh| self.cache.route(&q[qh * d..(qh + 1) * d], self.kv_head_of(qh), self.topk))
            .collect()
    }

    /// Routed decode of a packed `(h, d)` query: per query head, top-k
    /// blocks + own block (the MoBA decode path). Returns the packed
    /// `(h, d)` output row.
    pub fn decode_routed(&mut self, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_routed_into(q, &mut out);
        out
    }

    /// [`DecodeSession::decode_routed`] into a caller-provided (reused)
    /// output row — with the session's persistent step workspace, a
    /// steady-state call performs zero heap allocations.
    pub fn decode_routed_into(&mut self, q: &[f32], out: &mut Vec<f32>) {
        assert_eq!(q.len(), self.h * self.d());
        let d = self.d();
        let h = self.h;
        let topk = self.topk;
        let group = h / self.cache.h_kv();
        // resize only: attend_into fully rewrites every head's row
        out.resize(h * d, 0.0);
        let mut gathered = 0u64;
        let mut routed = 0usize;
        {
            let DecodeSession { cache, scratch, .. } = self;
            for qh in 0..h {
                let kvh = qh / group;
                let qrow = &q[qh * d..(qh + 1) * d];
                cache.route_into(
                    qrow,
                    kvh,
                    topk,
                    &mut scratch.blocks,
                    &mut scratch.best_s,
                    &mut scratch.best_i,
                    &mut scratch.cbuf,
                );
                gathered += cache.gather_bytes(&scratch.blocks);
                routed += scratch.blocks.len();
                let orow = &mut out[qh * d..(qh + 1) * d];
                cache.attend_into(qrow, kvh, &scratch.blocks, &mut scratch.scores, orow);
            }
        }
        self.note_step(gathered, routed);
    }

    /// Exact dense decode of a packed `(h, d)` query over the whole
    /// cache (the fallback path and the oracle for routed decode at
    /// full routing). Returns the packed `(h, d)` output row.
    pub fn decode_dense(&mut self, q: &[f32]) -> Vec<f32> {
        let mut out = Vec::new();
        self.decode_dense_into(q, &mut out);
        out
    }

    /// [`DecodeSession::decode_dense`] into a caller-provided (reused)
    /// output row — the zero-allocation twin.
    pub fn decode_dense_into(&mut self, q: &[f32], out: &mut Vec<f32>) {
        assert_eq!(q.len(), self.h * self.d());
        let d = self.d();
        let h = self.h;
        let group = h / self.cache.h_kv();
        // resize only: attend_into fully rewrites every head's row
        out.resize(h * d, 0.0);
        let mut gathered = 0u64;
        let mut routed = 0usize;
        {
            let DecodeSession { cache, scratch, .. } = self;
            scratch.blocks.clear();
            scratch.blocks.extend(0..cache.num_blocks());
            for qh in 0..h {
                let kvh = qh / group;
                gathered += cache.gather_bytes(&scratch.blocks);
                routed += scratch.blocks.len();
                let qrow = &q[qh * d..(qh + 1) * d];
                let orow = &mut out[qh * d..(qh + 1) * d];
                cache.attend_into(qrow, kvh, &scratch.blocks, &mut scratch.scores, orow);
            }
        }
        self.note_step(gathered, routed);
    }

    fn note_step(&mut self, gathered: u64, routed: usize) {
        self.last_gathered_bytes = gathered;
        self.last_routed_blocks = routed;
        self.steps += 1;
    }
}

/// Slow single-head oracle for the decode semantics, ragged-n capable:
/// row `t` attends its own (possibly partial) block causally plus the
/// top-k complete strictly-past blocks by q·centroid, with f64 softmax.
/// Routing reuses [`tiled_topk`] over the complete-prefix centroids, so
/// selection ties break exactly as in prefill and decode. Multi-head
/// callers run it once per query head with the GQA-mapped K/V slices.
pub fn decode_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let cb = n / block; // complete blocks
    let c = centroids(&k[..cb * block * d], cb * block, d, block);
    let (idx, _) = tiled_topk(q, &c, n, d, block, topk, 64);
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; n * d];
    for t in 0..n {
        let own = t / block;
        let routed = &idx[t * topk..(t + 1) * topk];
        let qt = &q[t * d..(t + 1) * d];
        let mut m = f64::NEG_INFINITY;
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            let ub = u / block;
            if ub != own && !routed.contains(&(ub as i32)) {
                continue;
            }
            let ku = &k[u * d..(u + 1) * d];
            let mut acc = 0.0f64;
            for cc in 0..d {
                acc += qt[cc] as f64 * ku[cc] as f64;
            }
            *su = acc * scale;
            if *su > m {
                m = *su;
            }
        }
        let mut z = 0.0f64;
        let mut acc = vec![0.0f64; d];
        for (u, &su) in s.iter().enumerate() {
            if su == f64::NEG_INFINITY {
                continue;
            }
            let p = (su - m).exp();
            z += p;
            let vu = &v[u * d..(u + 1) * d];
            for cc in 0..d {
                acc[cc] += p * vu[cc] as f64;
            }
        }
        let ot = &mut o[t * d..(t + 1) * d];
        for cc in 0..d {
            ot[cc] = (acc[cc] / z) as f32;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::kconv::kconv;
    use crate::attention::testutil::{max_abs_diff, qkv, qkv_packed, Rng};
    use crate::attention::packed_rows;

    #[test]
    fn append_tracks_blocks_and_centroids() {
        let (d, block) = (4, 8);
        let mut cache = KvCache::new(1, d, block);
        let mut rng = Rng::new(1);
        for t in 0..20 {
            cache.append(&rng.normal_vec(d), &rng.normal_vec(d));
            assert_eq!(cache.len(), t + 1);
            assert_eq!(cache.num_blocks(), (t + 1).div_ceil(block));
            assert_eq!(cache.complete_blocks(), (t + 1) / block);
        }
        assert_eq!(cache.block_len(0), 8);
        assert_eq!(cache.block_len(2), 4); // 20 = 2*8 + 4
        // centroid of block 1 == mean of its stored keys
        let cen = cache.centroid(0, 1);
        for c in 0..d {
            let mean: f32 =
                (8..16).map(|t| cache.keys()[t * d + c]).sum::<f32>() / 8.0;
            assert!((cen[c] - mean).abs() < 1e-5);
        }
    }

    /// Multi-head appends keep every KV head's store independent: each
    /// head's keys/values/centroids equal a single-head cache fed that
    /// head's rows.
    #[test]
    fn multi_head_stores_are_per_head_caches() {
        let (h_kv, n, d, block) = (3, 26, 4, 8);
        let (_, k, v) = qkv_packed(2, 1, h_kv, n, d);
        let mut cache = KvCache::new(h_kv, d, block);
        for t in 0..n {
            cache.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
        }
        for head in 0..h_kv {
            let mut single = KvCache::new(1, d, block);
            for t in 0..n {
                single.append(
                    &k[(head * n + t) * d..(head * n + t + 1) * d],
                    &v[(head * n + t) * d..(head * n + t + 1) * d],
                );
            }
            assert_eq!(cache.keys_of(head), single.keys(), "head {head} keys");
            assert_eq!(cache.values_of(head), single.values(), "head {head} values");
            for b in 0..cache.num_blocks() {
                assert_eq!(cache.centroid(head, b), single.centroid(0, b), "head {head} b {b}");
            }
        }
    }

    /// Complete-block centroids are bit-identical to the batch kernel.
    #[test]
    fn complete_block_centroids_match_batch_exactly() {
        let (n, d, block) = (64, 8, 16);
        let (_, k, v) = qkv(2, n, d);
        let mut cache = KvCache::new(1, d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let batch = crate::attention::centroid::centroids(&k, n, d, block);
        for b in 0..n / block {
            assert_eq!(&cache.centroid(0, b)[..], &batch[b * d..(b + 1) * d], "block {b}");
        }
    }

    #[test]
    fn route_is_sorted_causal_and_includes_own_block() {
        let (n, d, block, topk) = (100, 8, 16, 3);
        let (q, k, v) = qkv(3, n, d);
        let mut cache = KvCache::new(1, d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let blocks = cache.route(&q[t * d..(t + 1) * d], 0, topk);
            let own = t / block;
            assert!(blocks.windows(2).all(|w| w[0] < w[1]), "t={t} {blocks:?}");
            assert_eq!(*blocks.last().unwrap(), own);
            assert!(blocks.len() <= topk + 1);
            // routed (non-own) blocks are complete and strictly past
            for &b in &blocks[..blocks.len() - 1] {
                assert!(b < own);
            }
        }
    }

    #[test]
    fn full_routing_decode_equals_dense_rows() {
        let (n, d, block) = (96, 8, 16);
        let (q, k, v) = qkv(4, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(1, 1, d, block, n / block); // topk >= all blocks
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
            assert!(
                max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                "row {t}"
            );
        }
        assert_eq!(sess.steps(), n as u64);
        assert!(sess.last_gathered_bytes() > 0);
    }

    /// One GQA decode step covers every query head: the packed output
    /// equals per-head single-head sessions over the mapped KV heads.
    #[test]
    fn gqa_step_equals_per_head_single_head_sessions() {
        let (h, h_kv, n, d, block, topk) = (4, 2, 60, 8, 16, 2);
        let (q, k, v) = qkv_packed(5, h, h_kv, n, d);
        let mut sess = DecodeSession::new(h, h_kv, d, block, topk);
        let mut singles: Vec<DecodeSession> =
            (0..h).map(|_| DecodeSession::new(1, 1, d, block, topk)).collect();
        let group = h / h_kv;
        for t in 0..n {
            sess.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
            let o = sess.decode_routed(&packed_rows(&q, h, n, d, t));
            assert_eq!(o.len(), h * d);
            for (qh, single) in singles.iter_mut().enumerate() {
                let kvh = qh / group;
                single.append(
                    &k[(kvh * n + t) * d..(kvh * n + t + 1) * d],
                    &v[(kvh * n + t) * d..(kvh * n + t + 1) * d],
                );
                let oh = single.decode_routed(&q[(qh * n + t) * d..(qh * n + t + 1) * d]);
                assert_eq!(&o[qh * d..(qh + 1) * d], &oh[..], "t={t} head {qh}");
            }
        }
        // accounting sums over query heads
        assert_eq!(
            sess.last_routed_blocks(),
            singles.iter().map(|s| s.last_routed_blocks()).sum::<usize>()
        );
        assert_eq!(
            sess.last_gathered_bytes(),
            singles.iter().map(|s| s.last_gathered_bytes()).sum::<u64>()
        );
    }

    #[test]
    fn dense_decode_equals_naive_rows_ragged() {
        let (n, d, block) = (70, 4, 16); // n not divisible by block
        let (q, k, v) = qkv(5, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(1, 1, d, block, 0);
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_dense(&q[t * d..(t + 1) * d]);
            assert!(max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4, "row {t}");
        }
    }

    #[test]
    fn routed_decode_matches_reference_ragged_and_topk0() {
        for (n, d, block, topk) in [(100, 8, 16, 2), (64, 4, 16, 0), (50, 4, 8, 3)] {
            let (q, k, v) = qkv(6 + n as u64, n, d);
            let oracle = decode_reference(&q, &k, &v, n, d, block, topk);
            let mut sess = DecodeSession::new(1, 1, d, block, topk);
            for t in 0..n {
                sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
                assert!(
                    max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                    "n={n} block={block} topk={topk} row {t}"
                );
            }
        }
    }

    /// Streaming kconv inside the cache == batch kconv of the same
    /// keys, independently per KV head.
    #[test]
    fn cached_keys_match_batch_kconv() {
        let (h_kv, n, d, block, width) = (2, 48, 8, 16, 4);
        let (_, k, v) = qkv_packed(7, 1, h_kv, n, d);
        let mut rng = Rng::new(8);
        let w = rng.normal_vec(width * d);
        let mut cache = KvCache::with_kconv(h_kv, d, block, &w, width);
        for t in 0..n {
            cache.append(&packed_rows(&k, h_kv, n, d, t), &packed_rows(&v, h_kv, n, d, t));
        }
        for head in 0..h_kv {
            let batch = kconv(&k[head * n * d..(head + 1) * n * d], &w, n, d, width);
            assert_eq!(cache.keys_of(head), &batch[..], "head {head}");
            // values are stored untouched
            assert_eq!(cache.values_of(head), &v[head * n * d..(head + 1) * n * d]);
        }
    }

    #[test]
    #[should_panic]
    fn route_on_empty_cache_panics() {
        KvCache::new(1, 4, 8).route(&[0.0; 4], 0, 2);
    }

    #[test]
    #[should_panic]
    fn ragged_head_groups_panic() {
        DecodeSession::new(3, 2, 4, 8, 1);
    }
}
