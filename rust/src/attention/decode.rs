//! Incremental (autoregressive) decode over a block KV cache with
//! streaming MoBA routing — the serving-side twin of the prefill
//! kernels.
//!
//! The paper's routing model (§3; the tiled top-k of Algorithm 1)
//! extends to decode by maintaining block statistics *incrementally* as
//! keys arrive:
//!
//! * [`KvCache`] — per-session K/V storage partitioned into logical
//!   MoBA blocks, with a running per-block key sum so the centroid of
//!   any block is one O(d) multiply away. Appending a token is
//!   amortized O(d); with key convolution enabled, a ring buffer of the
//!   last `width` raw keys ([`KconvStream`]) makes the streaming kconv
//!   bit-identical to the batch [`kconv`](super::kconv::kconv).
//! * [`DecodeSession`] — routes each new query against the cached
//!   centroids (top-k over *complete, strictly-past* blocks, plus the
//!   always-attended current block — the paper's causal own-block
//!   rule) and computes single-row softmax attention over the gathered
//!   blocks.
//!
//! Parity contract: feeding tokens one at a time through a session
//! reproduces the prefill `forward` of the matching backend
//! row-for-row (see `rust/tests/decode_parity.rs`). The load-bearing
//! detail is that the running block sums are accumulated in arrival
//! order and divided once at read time — exactly the arithmetic of the
//! batch [`centroids`](super::centroid::centroids) — so the routing
//! scores, and therefore the selected block sets, are bit-identical to
//! prefill's.

use super::centroid::centroids;
use super::dense::NEG_INF;
use super::kconv::KconvStream;
use super::simd::{axpy, dot};
use super::topk::{tiled_topk, topk_insert};

/// Per-session K/V block storage with running centroids.
///
/// Keys stored here are post-kconv when a [`KconvStream`] is attached;
/// values are stored as given. `len` tokens occupy `ceil(len / block)`
/// logical blocks, of which the last may be partial.
#[derive(Debug, Clone)]
pub struct KvCache {
    d: usize,
    block: usize,
    /// cached (possibly kconv'd) keys, (len, d) row-major
    k: Vec<f32>,
    /// cached values, (len, d) row-major
    v: Vec<f32>,
    /// running per-block key sums, (num_blocks, d); divided by the
    /// block's token count at read time to form the centroid
    sums: Vec<f32>,
    kconv: Option<KconvStream>,
}

impl KvCache {
    pub fn new(d: usize, block: usize) -> Self {
        assert!(d >= 1 && block >= 1, "KvCache needs d >= 1 and block >= 1");
        Self { d, block, k: Vec::new(), v: Vec::new(), sums: Vec::new(), kconv: None }
    }

    /// A cache that applies the depthwise causal key convolution
    /// (paper Appendix B) to every appended key before storing it.
    /// `w` is the (width, d) tap tensor.
    pub fn with_kconv(d: usize, block: usize, w: &[f32], width: usize) -> Self {
        let mut c = Self::new(d, block);
        c.kconv = Some(KconvStream::new(w, width, d));
        c
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn block(&self) -> usize {
        self.block
    }

    /// Tokens cached.
    pub fn len(&self) -> usize {
        self.k.len() / self.d
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    /// Logical blocks currently occupied, `ceil(len / block)`.
    pub fn num_blocks(&self) -> usize {
        self.len().div_ceil(self.block)
    }

    /// Blocks holding exactly `block` tokens, `len / block`.
    pub fn complete_blocks(&self) -> usize {
        self.len() / self.block
    }

    /// Tokens stored in block `b`.
    pub fn block_len(&self, b: usize) -> usize {
        assert!(b < self.num_blocks());
        (self.len() - b * self.block).min(self.block)
    }

    /// Cached (post-kconv) keys, (len, d) row-major.
    pub fn keys(&self) -> &[f32] {
        &self.k
    }

    /// Cached values, (len, d) row-major.
    pub fn values(&self) -> &[f32] {
        &self.v
    }

    /// Append one token's (k_t, v_t). Amortized O(d): one ring-buffer
    /// kconv step (O(width · d)) when enabled, one add into the current
    /// block's running sum, two row copies — no per-token allocation on
    /// the plain path.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        assert_eq!(k_t.len(), self.d, "key row has wrong width");
        assert_eq!(v_t.len(), self.d, "value row has wrong width");
        let t = self.len();
        if t % self.block == 0 {
            // first token of a fresh block: open its running sum
            self.sums.extend(std::iter::repeat(0.0f32).take(self.d));
        }
        let b = t / self.block;
        match &mut self.kconv {
            Some(stream) => {
                let stored = stream.push(k_t);
                let sum = &mut self.sums[b * self.d..(b + 1) * self.d];
                for (c, s) in sum.iter_mut().enumerate() {
                    *s += stored[c];
                }
                self.k.extend_from_slice(&stored);
            }
            None => {
                let sum = &mut self.sums[b * self.d..(b + 1) * self.d];
                for (c, s) in sum.iter_mut().enumerate() {
                    *s += k_t[c];
                }
                self.k.extend_from_slice(k_t);
            }
        }
        self.v.extend_from_slice(v_t);
    }

    /// Write block `b`'s centroid (mean of its stored keys) into `out`.
    /// For complete blocks this is bit-identical to the batch
    /// [`centroids`](super::centroid::centroids): the sum accumulates
    /// in arrival order and is scaled by `1 / block` once.
    pub fn centroid_into(&self, b: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.d);
        let inv = 1.0 / self.block_len(b) as f32;
        let sum = &self.sums[b * self.d..(b + 1) * self.d];
        for (c, o) in out.iter_mut().enumerate() {
            *o = sum[c] * inv;
        }
    }

    /// Block `b`'s centroid as an owned row.
    pub fn centroid(&self, b: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.centroid_into(b, &mut out);
        out
    }

    /// Route the query at the current position (the last appended
    /// token): top-`topk` complete strictly-past blocks by q·centroid,
    /// plus the always-attended current block. Returns block indices
    /// sorted ascending, deduplicated, all causal (`<= own`), with the
    /// own block always last.
    ///
    /// Selection uses the same streaming insertion (and therefore the
    /// same tie-breaking: earliest block wins) as
    /// [`tiled_topk`](super::topk::tiled_topk), over centroids computed
    /// with the same arithmetic — so it reproduces prefill routing
    /// exactly.
    pub fn route(&self, q: &[f32], topk: usize) -> Vec<usize> {
        assert!(!self.is_empty(), "route called on an empty cache");
        assert_eq!(q.len(), self.d);
        let own = (self.len() - 1) / self.block;
        let mut blocks: Vec<usize> = Vec::with_capacity(topk + 1);
        if topk > 0 && own > 0 {
            // candidates: blocks [0, own) — all complete by construction
            let mut best_s = vec![f32::NEG_INFINITY; topk];
            let mut best_i = vec![-1i32; topk];
            let mut cbuf = vec![0.0f32; self.d];
            for j in 0..own {
                self.centroid_into(j, &mut cbuf);
                topk_insert(&mut best_s, &mut best_i, dot(q, &cbuf), j as i32);
            }
            blocks.extend(best_i.iter().filter(|&&j| j >= 0).map(|&j| j as usize));
            blocks.sort_unstable();
        }
        blocks.push(own);
        blocks
    }

    /// Single-row softmax attention of `q` over the given blocks
    /// (ascending; the last may be the partial current block). Exact
    /// per-row softmax: gather scores, subtract the max, combine
    /// values — the decode analogue of one `naive_attention` row.
    pub fn attend(&self, q: &[f32], blocks: &[usize]) -> Vec<f32> {
        assert!(!self.is_empty(), "attend called on an empty cache");
        assert_eq!(q.len(), self.d);
        let d = self.d;
        let len = self.len();
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores: Vec<f32> = Vec::with_capacity(blocks.len() * self.block);
        let mut rows: Vec<usize> = Vec::with_capacity(blocks.len() * self.block);
        let mut m = NEG_INF;
        for &b in blocks {
            let start = b * self.block;
            let end = ((b + 1) * self.block).min(len);
            for u in start..end {
                let s = dot(q, &self.k[u * d..(u + 1) * d]) * scale;
                if s > m {
                    m = s;
                }
                scores.push(s);
                rows.push(u);
            }
        }
        let mut z = 0.0f32;
        let mut out = vec![0.0f32; d];
        for (&s, &u) in scores.iter().zip(rows.iter()) {
            let p = (s - m).exp();
            z += p;
            axpy(&mut out, p, &self.v[u * d..(u + 1) * d]);
        }
        for o in out.iter_mut() {
            *o /= z;
        }
        out
    }
}

/// One autoregressive decode session: a [`KvCache`] plus the routing
/// geometry and per-step accounting. Backends drive it through
/// [`AttentionBackend::forward_decode`](super::backend::AttentionBackend::forward_decode).
#[derive(Debug, Clone)]
pub struct DecodeSession {
    cache: KvCache,
    topk: usize,
    /// decode steps served so far
    steps: u64,
    /// K/V bytes gathered from the cache by the last decode step
    last_gathered_bytes: u64,
    /// blocks attended by the last decode step (incl. the own block)
    last_routed_blocks: usize,
}

impl DecodeSession {
    pub fn new(d: usize, block: usize, topk: usize) -> Self {
        Self {
            cache: KvCache::new(d, block),
            topk,
            steps: 0,
            last_gathered_bytes: 0,
            last_routed_blocks: 0,
        }
    }

    /// A session whose cache applies the streaming key convolution.
    pub fn with_kconv(d: usize, block: usize, topk: usize, w: &[f32], width: usize) -> Self {
        let mut s = Self::new(d, block, topk);
        s.cache = KvCache::with_kconv(d, block, w, width);
        s
    }

    pub fn cache(&self) -> &KvCache {
        &self.cache
    }

    pub fn d(&self) -> usize {
        self.cache.d()
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn last_gathered_bytes(&self) -> u64 {
        self.last_gathered_bytes
    }

    pub fn last_routed_blocks(&self) -> usize {
        self.last_routed_blocks
    }

    /// Append one token's (k_t, v_t) to the cache.
    pub fn append(&mut self, k_t: &[f32], v_t: &[f32]) {
        self.cache.append(k_t, v_t);
    }

    /// The block set the current query would attend (routing only).
    pub fn route_current(&self, q: &[f32]) -> Vec<usize> {
        self.cache.route(q, self.topk)
    }

    /// Routed decode: top-k blocks + own block (the MoBA decode path).
    pub fn decode_routed(&mut self, q: &[f32]) -> Vec<f32> {
        let blocks = self.cache.route(q, self.topk);
        self.note_gather(&blocks);
        self.cache.attend(q, &blocks)
    }

    /// Exact dense decode over the whole cache (the fallback path and
    /// the oracle for routed decode at full routing).
    pub fn decode_dense(&mut self, q: &[f32]) -> Vec<f32> {
        let blocks: Vec<usize> = (0..self.cache.num_blocks()).collect();
        self.note_gather(&blocks);
        self.cache.attend(q, &blocks)
    }

    fn note_gather(&mut self, blocks: &[usize]) {
        let toks: usize = blocks.iter().map(|&b| self.cache.block_len(b)).sum();
        // K and V rows read from the cache for this step
        self.last_gathered_bytes = (2 * toks * self.cache.d() * 4) as u64;
        self.last_routed_blocks = blocks.len();
        self.steps += 1;
    }
}

/// Slow oracle for the decode semantics, ragged-n capable: row `t`
/// attends its own (possibly partial) block causally plus the top-k
/// complete strictly-past blocks by q·centroid, with f64 softmax.
/// Routing reuses [`tiled_topk`] over the complete-prefix centroids, so
/// selection ties break exactly as in prefill and decode.
pub fn decode_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    block: usize,
    topk: usize,
) -> Vec<f32> {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * d);
    let cb = n / block; // complete blocks
    let c = centroids(&k[..cb * block * d], cb * block, d, block);
    let (idx, _) = tiled_topk(q, &c, n, d, block, topk, 64);
    let scale = 1.0 / (d as f64).sqrt();
    let mut o = vec![0.0f32; n * d];
    for t in 0..n {
        let own = t / block;
        let routed = &idx[t * topk..(t + 1) * topk];
        let qt = &q[t * d..(t + 1) * d];
        let mut m = f64::NEG_INFINITY;
        let mut s = vec![f64::NEG_INFINITY; t + 1];
        for (u, su) in s.iter_mut().enumerate() {
            let ub = u / block;
            if ub != own && !routed.contains(&(ub as i32)) {
                continue;
            }
            let ku = &k[u * d..(u + 1) * d];
            let mut acc = 0.0f64;
            for cc in 0..d {
                acc += qt[cc] as f64 * ku[cc] as f64;
            }
            *su = acc * scale;
            if *su > m {
                m = *su;
            }
        }
        let mut z = 0.0f64;
        let mut acc = vec![0.0f64; d];
        for (u, &su) in s.iter().enumerate() {
            if su == f64::NEG_INFINITY {
                continue;
            }
            let p = (su - m).exp();
            z += p;
            let vu = &v[u * d..(u + 1) * d];
            for cc in 0..d {
                acc[cc] += p * vu[cc] as f64;
            }
        }
        let ot = &mut o[t * d..(t + 1) * d];
        for cc in 0..d {
            ot[cc] = (acc[cc] / z) as f32;
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::naive_attention;
    use crate::attention::kconv::kconv;
    use crate::attention::testutil::{max_abs_diff, qkv, Rng};

    #[test]
    fn append_tracks_blocks_and_centroids() {
        let (d, block) = (4, 8);
        let mut cache = KvCache::new(d, block);
        let mut rng = Rng::new(1);
        for t in 0..20 {
            cache.append(&rng.normal_vec(d), &rng.normal_vec(d));
            assert_eq!(cache.len(), t + 1);
            assert_eq!(cache.num_blocks(), (t + 1).div_ceil(block));
            assert_eq!(cache.complete_blocks(), (t + 1) / block);
        }
        assert_eq!(cache.block_len(0), 8);
        assert_eq!(cache.block_len(2), 4); // 20 = 2*8 + 4
        // centroid of block 1 == mean of its stored keys
        let cen = cache.centroid(1);
        for c in 0..d {
            let mean: f32 =
                (8..16).map(|t| cache.keys()[t * d + c]).sum::<f32>() / 8.0;
            assert!((cen[c] - mean).abs() < 1e-5);
        }
    }

    /// Complete-block centroids are bit-identical to the batch kernel.
    #[test]
    fn complete_block_centroids_match_batch_exactly() {
        let (n, d, block) = (64, 8, 16);
        let (_, k, v) = qkv(2, n, d);
        let mut cache = KvCache::new(d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let batch = crate::attention::centroid::centroids(&k, n, d, block);
        for b in 0..n / block {
            assert_eq!(&cache.centroid(b)[..], &batch[b * d..(b + 1) * d], "block {b}");
        }
    }

    #[test]
    fn route_is_sorted_causal_and_includes_own_block() {
        let (n, d, block, topk) = (100, 8, 16, 3);
        let (q, k, v) = qkv(3, n, d);
        let mut cache = KvCache::new(d, block);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let blocks = cache.route(&q[t * d..(t + 1) * d], topk);
            let own = t / block;
            assert!(blocks.windows(2).all(|w| w[0] < w[1]), "t={t} {blocks:?}");
            assert_eq!(*blocks.last().unwrap(), own);
            assert!(blocks.len() <= topk + 1);
            // routed (non-own) blocks are complete and strictly past
            for &b in &blocks[..blocks.len() - 1] {
                assert!(b < own);
            }
        }
    }

    #[test]
    fn full_routing_decode_equals_dense_rows() {
        let (n, d, block) = (96, 8, 16);
        let (q, k, v) = qkv(4, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(d, block, n / block); // topk >= all blocks
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
            assert!(
                max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                "row {t}"
            );
        }
        assert_eq!(sess.steps(), n as u64);
        assert!(sess.last_gathered_bytes() > 0);
    }

    #[test]
    fn dense_decode_equals_naive_rows_ragged() {
        let (n, d, block) = (70, 4, 16); // n not divisible by block
        let (q, k, v) = qkv(5, n, d);
        let (oracle, _) = naive_attention(&q, &k, &v, n, d);
        let mut sess = DecodeSession::new(d, block, 0);
        for t in 0..n {
            sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
            let o = sess.decode_dense(&q[t * d..(t + 1) * d]);
            assert!(max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4, "row {t}");
        }
    }

    #[test]
    fn routed_decode_matches_reference_ragged_and_topk0() {
        for (n, d, block, topk) in [(100, 8, 16, 2), (64, 4, 16, 0), (50, 4, 8, 3)] {
            let (q, k, v) = qkv(6 + n as u64, n, d);
            let oracle = decode_reference(&q, &k, &v, n, d, block, topk);
            let mut sess = DecodeSession::new(d, block, topk);
            for t in 0..n {
                sess.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
                let o = sess.decode_routed(&q[t * d..(t + 1) * d]);
                assert!(
                    max_abs_diff(&o, &oracle[t * d..(t + 1) * d]) < 1e-4,
                    "n={n} block={block} topk={topk} row {t}"
                );
            }
        }
    }

    /// Streaming kconv inside the cache == batch kconv of the same keys.
    #[test]
    fn cached_keys_match_batch_kconv() {
        let (n, d, block, width) = (48, 8, 16, 4);
        let (_, k, v) = qkv(7, n, d);
        let mut rng = Rng::new(8);
        let w = rng.normal_vec(width * d);
        let mut cache = KvCache::with_kconv(d, block, &w, width);
        for t in 0..n {
            cache.append(&k[t * d..(t + 1) * d], &v[t * d..(t + 1) * d]);
        }
        let batch = kconv(&k, &w, n, d, width);
        assert_eq!(cache.keys(), &batch[..]);
        // values are stored untouched
        assert_eq!(cache.values(), &v[..]);
    }

    #[test]
    #[should_panic]
    fn route_on_empty_cache_panics() {
        KvCache::new(4, 8).route(&[0.0; 4], 2);
    }
}
