//! The training loop. Owns the (params, m, v) state tensors and advances
//! them through the `train_step` executable.

use std::path::Path;
use std::time::Instant;

use anyhow::anyhow;

use super::schedule::cosine_lr;
use crate::config::TrainParams;
use crate::data::corpus::Corpus;
use crate::runtime::{ParamStore, Runtime, Tensor, VariantSpec};
use crate::Result;

/// Per-step record for the loss curve.
#[derive(Debug, Clone, Copy)]
pub struct TrainLog {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    pub step_time_s: f64,
}

pub struct Trainer<'rt> {
    runtime: &'rt Runtime,
    spec: VariantSpec,
    exe: std::sync::Arc<crate::runtime::Executable>,
    params: Vec<Tensor>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    step: usize,
    pub history: Vec<TrainLog>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, variant: &str) -> Result<Self> {
        let spec = runtime.manifest().variant(variant)?.clone();
        let ts_name = spec
            .train_step
            .clone()
            .ok_or_else(|| anyhow!("variant {variant} has no train_step artifact"))?;
        let exe = runtime.get(&ts_name)?;
        let init = runtime.load_init_params(variant)?;
        let zeros_m = init.zeros_like().into_tensors();
        let zeros_v = init.zeros_like().into_tensors();
        Ok(Self {
            runtime,
            spec,
            exe,
            params: init.into_tensors(),
            m: zeros_m,
            v: zeros_v,
            step: 0,
            history: Vec::new(),
        })
    }

    pub fn spec(&self) -> &VariantSpec {
        &self.spec
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Current parameters as a [`ParamStore`] (for eval / checkpointing).
    pub fn params(&self) -> Result<ParamStore> {
        ParamStore::from_tensors(&self.spec, self.params.clone())
    }

    /// Restore parameters (e.g. from a checkpoint); optimizer state resets.
    pub fn set_params(&mut self, store: ParamStore) -> Result<()> {
        let zeros_m = store.zeros_like().into_tensors();
        let zeros_v = store.zeros_like().into_tensors();
        self.params = store.into_tensors();
        self.m = zeros_m;
        self.v = zeros_v;
        Ok(())
    }

    /// One optimizer step on a (tokens, targets) batch; returns the loss.
    pub fn step_batch(&mut self, tokens: &[i32], targets: &[i32], lr: f64) -> Result<f64> {
        let t0 = Instant::now();
        let b = self.spec.train_batch;
        let n = self.spec.seq_len;
        let np = self.params.len();
        let mut inputs: Vec<Tensor> = Vec::with_capacity(4 + 3 * np);
        inputs.push(Tensor::i32(tokens.to_vec(), &[b, n])?);
        inputs.push(Tensor::i32(targets.to_vec(), &[b, n])?);
        inputs.push(Tensor::scalar_f32(lr as f32));
        inputs.push(Tensor::scalar_f32((self.step + 1) as f32));
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());

        let mut out = self.exe.run(&inputs)?;
        // outputs: loss, p'..., m'..., v'...
        if out.len() != 1 + 3 * np {
            return Err(anyhow!("train_step returned {} outputs, expected {}", out.len(), 1 + 3 * np));
        }
        let rest = out.split_off(1);
        let loss = out[0].scalar()? as f64;
        let (p_new, mv) = rest.split_at(np);
        let (m_new, v_new) = mv.split_at(np);
        self.params = p_new.to_vec();
        self.m = m_new.to_vec();
        self.v = v_new.to_vec();
        self.step += 1;
        self.history.push(TrainLog {
            step: self.step,
            loss,
            lr,
            step_time_s: t0.elapsed().as_secs_f64(),
        });
        Ok(loss)
    }

    /// Run `cfg.steps` steps over the corpus with the cosine schedule.
    /// `on_log` fires every `cfg.log_every` steps with the latest record.
    pub fn run(
        &mut self,
        corpus: &Corpus,
        cfg: &TrainParams,
        mut on_log: impl FnMut(&TrainLog),
    ) -> Result<f64> {
        let b = self.spec.train_batch;
        let n = self.spec.seq_len;
        let mut last = f64::NAN;
        for s in 0..cfg.steps {
            let (tokens, targets) = corpus.train_batch(b, n, cfg.seed.wrapping_add(s as u64));
            let lr = cosine_lr(s, cfg.steps, cfg.peak_lr, cfg.warmup, cfg.floor_frac);
            last = self.step_batch(&tokens, &targets, lr)?;
            if (s + 1) % cfg.log_every == 0 || s + 1 == cfg.steps {
                on_log(self.history.last().unwrap());
            }
        }
        let _ = self.runtime; // (kept for future device-resident state)
        Ok(last)
    }

    /// Save params in init.bin format + the loss curve as CSV.
    pub fn checkpoint(&self, dir: &Path, tag: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let ps = self.params()?;
        std::fs::write(dir.join(format!("{}_{tag}.bin", self.spec.name)), ps.to_bytes()?)?;
        let mut csv = String::from("step,loss,lr,step_time_s\n");
        for l in &self.history {
            csv.push_str(&format!("{},{},{},{}\n", l.step, l.loss, l.lr, l.step_time_s));
        }
        std::fs::write(dir.join(format!("{}_{tag}_loss.csv", self.spec.name)), csv)?;
        Ok(())
    }

    /// Load a params checkpoint saved by [`Self::checkpoint`].
    pub fn load_checkpoint(runtime: &Runtime, variant: &str, path: &Path) -> Result<ParamStore> {
        let spec = runtime.manifest().variant(variant)?.clone();
        ParamStore::from_init_bin(&spec, path)
    }
}
