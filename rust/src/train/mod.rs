//! Training driver: the rust loop around the AOT `train_step` artifact.
//!
//! Each step feeds (tokens, targets, lr, step, params, m, v) and reads
//! back (loss, params', m', v'); state stays in manifest order the whole
//! time. The cosine schedule mirrors `python/compile/train.py`.

mod driver;
mod schedule;

pub use driver::{TrainLog, Trainer};
pub use schedule::cosine_lr;
