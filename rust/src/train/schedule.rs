//! Cosine LR schedule with linear warmup (paper §5.1). Mirror of
//! `python/compile/train.py::cosine_lr` — kept in lockstep by tests on
//! a shared set of probe points.

/// LR at 0-based `step` of a `total`-step run.
pub fn cosine_lr(step: usize, total: usize, peak: f64, warmup: usize, floor_frac: f64) -> f64 {
    if step < warmup {
        return peak * (step + 1) as f64 / warmup as f64;
    }
    let t = (step - warmup) as f64 / (total.saturating_sub(warmup)).max(1) as f64;
    let t = t.min(1.0);
    peak * (floor_frac + (1.0 - floor_frac) * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let peak = 6e-4;
        assert!((cosine_lr(0, 100, peak, 20, 0.1) - peak / 20.0).abs() < 1e-12);
        assert!((cosine_lr(19, 100, peak, 20, 0.1) - peak).abs() < 1e-12);
    }

    #[test]
    fn peak_then_decays_to_floor() {
        let peak = 1e-3;
        let at_peak = cosine_lr(20, 120, peak, 20, 0.1);
        assert!((at_peak - peak).abs() < 1e-9);
        let end = cosine_lr(119, 120, peak, 20, 0.1);
        assert!(end < peak * 0.12 && end >= peak * 0.1 - 1e-12);
        // monotone decreasing after warmup
        let mut prev = at_peak;
        for s in 21..120 {
            let lr = cosine_lr(s, 120, peak, 20, 0.1);
            assert!(lr <= prev + 1e-15);
            prev = lr;
        }
    }

    #[test]
    fn beyond_total_clamps_at_floor() {
        let peak = 1e-3;
        assert!((cosine_lr(500, 100, peak, 10, 0.1) - peak * 0.1).abs() < 1e-12);
    }
}
