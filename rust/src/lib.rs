//! # flash-moba
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"Optimizing Mixture
//! of Block Attention"* (Xiao et al., 2025).
//!
//! * **L1** — Pallas kernels (build-time python, `python/compile/kernels/`):
//!   block centroids, Flash TopK selection, MoBA attention, key conv.
//! * **L2** — JAX model (build-time python, `python/compile/model.py`):
//!   the paper's hybrid SWA/MoBA transformer, AOT-lowered to HLO text.
//! * **L3** — this crate: loads the artifacts over PJRT ([`runtime`]),
//!   drives training ([`train`]) and serving ([`coordinator`]), and hosts
//!   every substrate the paper's evaluation needs: a CPU attention
//!   performance testbed ([`attention`]), the SNR statistical model
//!   ([`snr`]), synthetic datasets ([`data`]), evaluators ([`eval`]) and
//!   the table/figure regeneration harness ([`bench_harness`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `flash-moba` binary is self-contained.

// The numeric kernels intentionally mirror the paper's index-based
// pseudocode (Algorithms 1–5); rewriting the index loops as iterator
// chains would hurt the side-by-side readability the reproduction is
// for. CI runs clippy with `-D warnings` under this posture.
#![allow(clippy::needless_range_loop)]

pub mod attention;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod runtime;
pub mod snr;
pub mod train;
pub mod util;
// PJRT binding surface. This build ships the in-tree stub (execution is
// gated, see the module docs); to link the real vendored bindings,
// replace this declaration with `pub use real_xla_crate as xla;`.
pub mod xla;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
