//! Synthetic training corpus (the FineWeb-Edu stand-in).
//!
//! Two interleaved processes:
//!
//! 1. **Background language** — a deterministic bigram Markov chain over
//!    the language-token region with a Zipf-like successor distribution,
//!    giving the LM compressible local structure (drives the perplexity
//!    differences between attention variants).
//! 2. **Episodic facts** — `[ASSIGN key value]` statements with bindings
//!    drawn fresh *per sequence*, later probed by `[QUERY key] value`.
//!    Predicting the queried value requires long-range in-context
//!    retrieval — exactly the router capability the SNR model analyzes.

use super::vocabulary::{Vocab, ASSIGN, QUERY};
use crate::attention::testutil::Rng;

#[derive(Debug, Clone, Copy)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// distinct keys bound per sequence
    pub facts_per_seq: usize,
    /// probability of starting a fact/query clause at a position
    pub fact_rate: f64,
    /// Zipf skew of the successor distribution
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self { vocab: 512, facts_per_seq: 8, fact_rate: 0.04, zipf_s: 1.2, seed: 0xC0FFEE }
    }
}

pub struct Corpus {
    cfg: CorpusConfig,
    vocab: Vocab,
    /// per-token successor permutation bases for the Markov chain
    succ: Vec<u32>,
    /// precomputed Zipf CDF over rank
    zipf_cdf: Vec<f64>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let vocab = Vocab::new(cfg.vocab);
        let lang = vocab.lang_count();
        let mut rng = Rng::new(cfg.seed);
        let succ: Vec<u32> = (0..lang).map(|_| rng.next_u64() as u32).collect();
        // zipf over ranks 1..=R
        let r = 32usize.min(lang);
        let mut w: Vec<f64> = (1..=r).map(|i| 1.0 / (i as f64).powf(cfg.zipf_s)).collect();
        let z: f64 = w.iter().sum();
        let mut acc = 0.0;
        for x in w.iter_mut() {
            acc += *x / z;
            *x = acc;
        }
        Self { cfg, vocab, succ, zipf_cdf: w }
    }

    pub fn vocab(&self) -> Vocab {
        self.vocab
    }

    fn zipf_rank(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.zipf_cdf.iter().position(|&c| u <= c).unwrap_or(self.zipf_cdf.len() - 1)
    }

    fn next_lang(&self, cur: i32, rng: &mut Rng) -> i32 {
        let lang = self.vocab.lang_count() as u32;
        let cur_ix = (cur - self.vocab.lang_base()) as u32 % lang;
        let rank = self.zipf_rank(rng) as u32;
        // deterministic successor ladder: mix current token with rank
        let next = (self.succ[cur_ix as usize]
            .wrapping_mul(2654435761)
            .wrapping_add(rank.wrapping_mul(40503)))
            % lang;
        self.vocab.lang_base() + next as i32
    }

    /// One sequence of `len` tokens. Facts are bound per sequence from
    /// `seq_seed`; queries always refer to an already-assigned key.
    pub fn sequence(&self, len: usize, seq_seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(self.cfg.seed ^ seq_seed.wrapping_mul(0x9E3779B97F4A7C15));
        let mut out = Vec::with_capacity(len);
        // per-sequence episodic binding
        let nf = self.cfg.facts_per_seq;
        let keys: Vec<usize> = (0..nf).map(|_| rng.below(128)).collect();
        let vals: Vec<usize> = (0..nf).map(|_| rng.below(128)).collect();
        let mut assigned = vec![false; nf];

        let mut cur = self.vocab.lang_base() + rng.below(self.vocab.lang_count()) as i32;
        out.push(cur);
        while out.len() < len {
            if rng.uniform() < self.cfg.fact_rate && len - out.len() >= 3 {
                let f = rng.below(nf);
                if !assigned[f] || rng.uniform() < 0.4 {
                    // (re)state the fact
                    out.push(ASSIGN);
                    out.push(self.vocab.key(keys[f]));
                    out.push(self.vocab.value(vals[f]));
                    assigned[f] = true;
                } else {
                    // probe it
                    out.push(QUERY);
                    out.push(self.vocab.key(keys[f]));
                    out.push(self.vocab.value(vals[f]));
                }
                cur = self.vocab.lang_base()
                    + (self.succ[rng.below(self.succ.len())] % self.vocab.lang_count() as u32) as i32;
                continue;
            }
            cur = self.next_lang(cur, &mut rng);
            out.push(cur);
        }
        out.truncate(len);
        out
    }

    /// Training batch: (tokens, targets), each `batch * seq` i32,
    /// targets = tokens shifted left (next-token prediction).
    pub fn train_batch(&self, batch: usize, seq: usize, step: u64) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let row = self.sequence(seq + 1, step * 1000 + b as u64);
            tokens.extend_from_slice(&row[..seq]);
            targets.extend_from_slice(&row[1..seq + 1]);
        }
        (tokens, targets)
    }

    /// Held-out sequence ids start far away from any training step.
    pub fn heldout_batch(&self, batch: usize, seq: usize, idx: u64) -> (Vec<i32>, Vec<i32>) {
        self.train_batch(batch, seq, 0xDEAD_0000 + idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let c = Corpus::new(CorpusConfig::default());
        let a = c.sequence(256, 7);
        let b = c.sequence(256, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < 512));
        let other = c.sequence(256, 8);
        assert_ne!(a, other);
    }

    #[test]
    fn queries_only_after_assignment() {
        let c = Corpus::new(CorpusConfig { fact_rate: 0.2, ..Default::default() });
        for s in 0..20 {
            let seq = c.sequence(512, s);
            let mut seen: Vec<(i32, i32)> = Vec::new();
            let mut i = 0;
            while i < seq.len() {
                if seq[i] == ASSIGN && i + 2 < seq.len() {
                    seen.push((seq[i + 1], seq[i + 2]));
                    i += 3;
                } else if seq[i] == QUERY && i + 2 < seq.len() {
                    assert!(
                        seen.contains(&(seq[i + 1], seq[i + 2])),
                        "query before assignment at {i} in seq {s}"
                    );
                    i += 3;
                } else {
                    i += 1;
                }
            }
        }
    }

    #[test]
    fn train_batch_shifts_targets() {
        let c = Corpus::new(CorpusConfig::default());
        let (tok, tgt) = c.train_batch(2, 128, 3);
        assert_eq!(tok.len(), 256);
        assert_eq!(tgt.len(), 256);
        // within each row, target[i] == token[i+1]
        for b in 0..2 {
            for i in 0..127 {
                assert_eq!(tgt[b * 128 + i], tok[b * 128 + i + 1]);
            }
        }
    }

    #[test]
    fn language_tokens_have_zipfy_bigrams() {
        // successor distribution should be concentrated (compressible)
        let c = Corpus::new(CorpusConfig { fact_rate: 0.0, ..Default::default() });
        let seq = c.sequence(4096, 1);
        use std::collections::HashMap;
        let mut pair_counts: HashMap<(i32, i32), usize> = HashMap::new();
        for w in seq.windows(2) {
            *pair_counts.entry((w[0], w[1])).or_default() += 1;
        }
        // repeated bigrams must exist (a uniform random stream over ~240
        // tokens would almost never repeat pairs 5+ times in 4k tokens)
        let max_pair = pair_counts.values().max().copied().unwrap_or(0);
        assert!(max_pair >= 4, "max bigram count {max_pair}");
    }

    #[test]
    fn heldout_differs_from_train() {
        let c = Corpus::new(CorpusConfig::default());
        let (a, _) = c.train_batch(1, 64, 5);
        let (b, _) = c.heldout_batch(1, 64, 5);
        assert_ne!(a, b);
    }
}
