//! Synthetic datasets — the CPU-testbed stand-ins for FineWeb-Edu,
//! RULER S-NIAH and LongBench (see README.md §Architecture for the
//! substitution rationale).
//!
//! Everything is deterministic given a seed and expressed over a small
//! shared token vocabulary ([`vocabulary`]):
//!
//! * [`corpus`] — training corpus: a Zipfian bigram background language
//!   with *episodic* key→value facts planted per sequence (bindings are
//!   random per sequence, so predicting a queried value requires
//!   in-context retrieval, not memorization — the ability MoBA's router
//!   must learn).
//! * [`niah`] — S-NIAH-1/2/3 analogues (single needle in filler; value
//!   lengths 1/4/8 tokens) scored by teacher-forced next-token argmax.
//! * [`longbench`] — 12 proxy tasks mirroring LongBench's grouping:
//!   single-doc QA, multi-doc QA (2–3 hop), summarization (salience
//!   copy), few-shot pattern induction, and code (assignment chasing).

pub mod corpus;
pub mod longbench;
pub mod niah;
pub mod vocabulary;

pub use corpus::{Corpus, CorpusConfig};
pub use niah::{NiahSample, NiahVariant};
pub use vocabulary::Vocab;

/// One scored evaluation sample: feed `tokens`, and for each i require
/// `argmax logits[answer_pos[i]] == answer[i]` (next-token prediction).
#[derive(Debug, Clone)]
pub struct TaskSample {
    pub tokens: Vec<i32>,
    pub answer_pos: Vec<usize>,
    pub answer: Vec<i32>,
}

impl TaskSample {
    /// Internal consistency: answer positions in range, one answer each,
    /// and the ground-truth token actually present after each position.
    pub fn validate(&self) -> bool {
        self.answer_pos.len() == self.answer.len()
            && self
                .answer_pos
                .iter()
                .zip(&self.answer)
                .all(|(&p, &a)| p + 1 < self.tokens.len() && self.tokens[p + 1] == a)
    }
}
