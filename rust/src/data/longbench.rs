//! LongBench proxy suite (paper Tables 5–6): 12 synthetic tasks keeping
//! LongBench's grouping and relative difficulty structure, expressed
//! over the shared vocabulary. Every task emits a [`TaskSample`] scored
//! by teacher-forced next-token accuracy on the answer span.
//!
//! | group          | paper tasks            | proxy mechanics                      |
//! |----------------|------------------------|--------------------------------------|
//! | single-doc QA  | Qasper, MField         | 1-hop fact lookup; entity+field keys |
//! | multi-doc QA   | HotpotQA, 2Wiki, MuSiQue | 2–3-hop chained lookups across docs |
//! | summarization  | GovReport, QMSum, MNews | copy the IMPORTANT-tagged span       |
//! | few-shot       | TriviaQA, SAMSum       | in-context pattern induction          |
//! | code           | LCC, RepoBench-P       | assignment chasing, cross-"file"     |

use super::vocabulary::{Vocab, ASSIGN, CALL, DEF, DOC, ENT, FIELD, IMPORTANT, QUERY, SAYS, SUMMARIZE};
use super::TaskSample;
use crate::attention::testutil::Rng;

/// Task identifiers in paper column order (Tables 5–6).
pub const TASKS: [&str; 12] = [
    "qasper", "mfield", "hotpotqa", "2wikimqa", "musique", "gov_report", "qmsum",
    "multi_news", "triviaqa", "samsum", "lcc", "repobench",
];

/// Group label for a task (report formatting).
pub fn group_of(task: &str) -> &'static str {
    match task {
        "qasper" | "mfield" => "Single-Doc QA",
        "hotpotqa" | "2wikimqa" | "musique" => "Multi-Doc QA",
        "gov_report" | "qmsum" | "multi_news" => "Summarization",
        "triviaqa" | "samsum" => "Few-shot",
        "lcc" | "repobench" => "Code",
        _ => "Unknown",
    }
}

/// Generate one sample of `len` tokens for `task`.
pub fn generate(vocab: Vocab, task: &str, len: usize, seed: u64) -> TaskSample {
    let mut rng = Rng::new(seed.wrapping_mul(0x2545_F491).wrapping_add(7));
    match task {
        "qasper" => single_doc_qa(vocab, len, &mut rng, false),
        "mfield" => single_doc_qa(vocab, len, &mut rng, true),
        "hotpotqa" => multi_doc_qa(vocab, len, &mut rng, 2, false),
        "2wikimqa" => multi_doc_qa(vocab, len, &mut rng, 2, true),
        "musique" => multi_doc_qa(vocab, len, &mut rng, 3, false),
        "gov_report" => summarize(vocab, len, &mut rng, 1, false),
        "qmsum" => summarize(vocab, len, &mut rng, 2, true),
        "multi_news" => summarize(vocab, len, &mut rng, 2, false),
        "triviaqa" => few_shot(vocab, len, &mut rng, 4),
        "samsum" => dialogue(vocab, len, &mut rng),
        "lcc" => code(vocab, len, &mut rng, false),
        "repobench" => code(vocab, len, &mut rng, true),
        other => panic!("unknown task {other}"),
    }
}

fn filler(vocab: Vocab, rng: &mut Rng, out: &mut Vec<i32>, count: usize) {
    for _ in 0..count {
        out.push(vocab.lang_base() + rng.below(vocab.lang_count()) as i32);
    }
}

fn pad_to(vocab: Vocab, rng: &mut Rng, tokens: &mut Vec<i32>, head: usize) {
    // pad *in front* so the probe stays at the end
    let missing = head;
    let mut pre = Vec::with_capacity(missing);
    filler(vocab, rng, &mut pre, missing);
    pre.append(tokens);
    *tokens = pre;
}

/// 1-hop lookup. `fielded`: key = (entity, field) pair (MField flavour).
fn single_doc_qa(vocab: Vocab, len: usize, rng: &mut Rng, fielded: bool) -> TaskSample {
    let n_facts = 6;
    let ents: Vec<i32> = (0..n_facts).map(|_| vocab.key(rng.below(128))).collect();
    let fields: Vec<i32> = (0..n_facts).map(|_| vocab.key(rng.below(128))).collect();
    let vals: Vec<i32> = (0..n_facts).map(|_| vocab.value(rng.below(128))).collect();
    let mut body = Vec::new();
    for i in 0..n_facts {
        filler(vocab, rng, &mut body, 6);
        if fielded {
            body.extend_from_slice(&[ENT, ents[i], FIELD, fields[i], ASSIGN, vals[i]]);
        } else {
            body.extend_from_slice(&[ASSIGN, ents[i], vals[i]]);
        }
    }
    let pick = rng.below(n_facts);
    let probe = if fielded {
        vec![QUERY, ENT, ents[pick], FIELD, fields[pick], vals[pick]]
    } else {
        vec![QUERY, ents[pick], vals[pick]]
    };
    finish(vocab, rng, len, body, probe, 1)
}

/// 2/3-hop chain across DOC-separated contexts.
fn multi_doc_qa(vocab: Vocab, len: usize, rng: &mut Rng, hops: usize, shuffled: bool) -> TaskSample {
    // chain k0 -> k1 -> ... -> value
    let keys: Vec<i32> = (0..hops).map(|_| vocab.key(rng.below(128))).collect();
    let val = vocab.value(rng.below(128));
    let mut docs: Vec<Vec<i32>> = Vec::new();
    for h in 0..hops {
        let mut doc = vec![DOC];
        filler(vocab, rng, &mut doc, 8);
        let rhs = if h + 1 < hops { keys[h + 1] } else { val };
        doc.extend_from_slice(&[ASSIGN, keys[h], rhs]);
        filler(vocab, rng, &mut doc, 8);
        docs.push(doc);
    }
    if shuffled && docs.len() >= 2 {
        let last = docs.len() - 1;
        docs.swap(0, last);
    }
    let body: Vec<i32> = docs.into_iter().flatten().collect();
    let probe = vec![QUERY, keys[0], val];
    finish(vocab, rng, len, body, probe, 1)
}

/// Copy the IMPORTANT-tagged span. `spans`: how many tagged candidates;
/// `queried`: QMSum flavour — the probe names which span (by key).
fn summarize(vocab: Vocab, len: usize, rng: &mut Rng, spans: usize, queried: bool) -> TaskSample {
    let span_len = 3;
    let keys: Vec<i32> = (0..spans).map(|_| vocab.key(rng.below(128))).collect();
    let content: Vec<Vec<i32>> = (0..spans)
        .map(|_| (0..span_len).map(|_| vocab.value(rng.below(128))).collect())
        .collect();
    let mut body = Vec::new();
    for i in 0..spans {
        filler(vocab, rng, &mut body, 10);
        body.push(IMPORTANT);
        body.push(keys[i]);
        body.extend_from_slice(&content[i]);
    }
    let pick = if queried { rng.below(spans) } else { 0 };
    let mut probe = vec![SUMMARIZE];
    if queried {
        probe.push(keys[pick]);
    } else {
        probe.push(keys[0]);
    }
    probe.extend_from_slice(&content[pick]);
    finish(vocab, rng, len, body, probe, span_len)
}

/// In-context pattern induction: shots of `[QUERY k v]` with a fixed
/// per-sample mapping; the final shot's value is scored.
fn few_shot(vocab: Vocab, len: usize, rng: &mut Rng, shots: usize) -> TaskSample {
    let k = vocab.key(rng.below(128));
    let v = vocab.value(rng.below(128));
    let mut body = Vec::new();
    for _ in 0..shots {
        filler(vocab, rng, &mut body, 6);
        body.extend_from_slice(&[ASSIGN, k, v]);
    }
    let probe = vec![QUERY, k, v];
    finish(vocab, rng, len, body, probe, 1)
}

/// Dialogue "summary": speakers tagged SAYS; answer = first speaker id.
fn dialogue(vocab: Vocab, len: usize, rng: &mut Rng) -> TaskSample {
    let speakers: Vec<i32> = (0..3).map(|_| vocab.key(rng.below(128))).collect();
    let mut body = Vec::new();
    for turn in 0..6 {
        body.push(SAYS);
        body.push(speakers[turn % speakers.len()]);
        filler(vocab, rng, &mut body, 8);
    }
    let probe = vec![SUMMARIZE, SAYS, speakers[0]];
    finish(vocab, rng, len, body, probe, 1)
}

/// Assignment chasing: `DEF f v` … `CALL f -> v`. `multi_file`:
/// definition lives in an earlier DOC-separated "file".
fn code(vocab: Vocab, len: usize, rng: &mut Rng, multi_file: bool) -> TaskSample {
    let n_defs = 5;
    let fns: Vec<i32> = (0..n_defs).map(|_| vocab.key(rng.below(128))).collect();
    let vals: Vec<i32> = (0..n_defs).map(|_| vocab.value(rng.below(128))).collect();
    let mut body = Vec::new();
    for i in 0..n_defs {
        if multi_file && i == 0 {
            body.push(DOC);
        }
        filler(vocab, rng, &mut body, 5);
        body.extend_from_slice(&[DEF, fns[i], vals[i]]);
    }
    if multi_file {
        body.push(DOC);
        filler(vocab, rng, &mut body, 12);
    }
    let pick = rng.below(n_defs);
    let probe = vec![CALL, fns[pick], vals[pick]];
    finish(vocab, rng, len, body, probe, 1)
}

/// Assemble body + probe into an exactly-`len` sample; last
/// `answer_len` probe tokens are the scored span.
fn finish(
    vocab: Vocab,
    rng: &mut Rng,
    len: usize,
    mut body: Vec<i32>,
    probe: Vec<i32>,
    answer_len: usize,
) -> TaskSample {
    let need = len as i64 - (body.len() + probe.len()) as i64;
    if need > 0 {
        pad_to(vocab, rng, &mut body, need as usize);
    } else if need < 0 {
        // truncate the *front* of the body (keep facts near the end intact
        // only if they fit; generators keep body short so this is rare)
        let cut = (-need) as usize;
        body.drain(..cut.min(body.len()));
    }
    let mut tokens = body;
    tokens.extend_from_slice(&probe);
    debug_assert_eq!(tokens.len(), len);
    let answer: Vec<i32> = probe[probe.len() - answer_len..].to_vec();
    let start = len - answer_len;
    let answer_pos: Vec<usize> = (0..answer_len).map(|i| start + i - 1).collect();
    TaskSample { tokens, answer_pos, answer }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_samples() {
        let v = Vocab::new(512);
        for task in TASKS {
            for seed in 0..5 {
                let s = generate(v, task, 1024, seed);
                assert_eq!(s.tokens.len(), 1024, "{task}");
                assert!(s.validate(), "{task} seed {seed}");
            }
        }
    }

    #[test]
    fn groups_cover_all_tasks() {
        for task in TASKS {
            assert_ne!(group_of(task), "Unknown", "{task}");
        }
    }

    #[test]
    fn multi_hop_requires_chain() {
        // answer value must appear in the body exactly once (in the chain
        // terminus), and the probe key differs from the terminus key
        let v = Vocab::new(512);
        let s = generate(v, "musique", 512, 11);
        let ans = s.answer[0];
        let count = s.tokens[..s.tokens.len() - 1].iter().filter(|&&t| t == ans).count();
        assert!(count >= 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let v = Vocab::new(512);
        let a = generate(v, "lcc", 768, 3);
        let b = generate(v, "lcc", 768, 3);
        assert_eq!(a.tokens, b.tokens);
        let c = generate(v, "lcc", 768, 4);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn answers_live_in_value_or_key_region() {
        let v = Vocab::new(512);
        for task in TASKS {
            let s = generate(v, task, 512, 2);
            for &a in &s.answer {
                assert!(v.is_value(a) || v.is_key(a), "{task} answer {a}");
            }
        }
    }
}
