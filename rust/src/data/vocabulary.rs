//! Shared synthetic vocabulary layout.
//!
//! The smallest model vocab is 512, so every region fits within
//! [0, 512); larger-vocab variants simply leave the tail for extra
//! language tokens.

/// Vocabulary regions. All generators draw from these ranges.
#[derive(Debug, Clone, Copy)]
pub struct Vocab {
    pub size: usize,
}

// marker tokens
pub const PAD: i32 = 0;
pub const ASSIGN: i32 = 1; // "X is Y" statements
pub const QUERY: i32 = 2; // "what is X?"
pub const ENT: i32 = 3; // entity marker (multi-field QA)
pub const FIELD: i32 = 4; // field marker
pub const SUMMARIZE: i32 = 5;
pub const IMPORTANT: i32 = 6; // salient-sentence tag
pub const DOC: i32 = 7; // document separator
pub const SAYS: i32 = 8; // dialogue marker
pub const DEF: i32 = 9; // code: definition
pub const CALL: i32 = 10; // code: reference
pub const EOS: i32 = 11;
pub const N_MARKERS: i32 = 16;

pub const N_KEYS: i32 = 128;
pub const N_VALUES: i32 = 128;

impl Vocab {
    pub fn new(size: usize) -> Self {
        assert!(size >= 512, "vocab must be >= 512");
        Self { size }
    }

    pub fn key(&self, i: usize) -> i32 {
        N_MARKERS + (i as i32 % N_KEYS)
    }

    pub fn value(&self, i: usize) -> i32 {
        N_MARKERS + N_KEYS + (i as i32 % N_VALUES)
    }

    /// First language (filler) token id.
    pub fn lang_base(&self) -> i32 {
        N_MARKERS + N_KEYS + N_VALUES
    }

    /// Number of language tokens.
    pub fn lang_count(&self) -> usize {
        self.size - self.lang_base() as usize
    }

    pub fn is_value(&self, t: i32) -> bool {
        t >= N_MARKERS + N_KEYS && t < N_MARKERS + N_KEYS + N_VALUES
    }

    pub fn is_key(&self, t: i32) -> bool {
        t >= N_MARKERS && t < N_MARKERS + N_KEYS
    }

    pub fn is_lang(&self, t: i32) -> bool {
        t >= self.lang_base() && (t as usize) < self.size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions_disjoint_and_in_range() {
        let v = Vocab::new(512);
        assert!(v.is_key(v.key(0)) && v.is_key(v.key(127)));
        assert!(v.is_value(v.value(0)) && v.is_value(v.value(127)));
        assert!(!v.is_key(v.value(0)));
        assert!(!v.is_value(v.key(5)));
        assert!(v.lang_count() >= 200);
        assert!(v.is_lang(v.lang_base()));
        assert!((v.lang_base() as usize) + v.lang_count() == 512);
    }

    #[test]
    fn wraps_indices() {
        let v = Vocab::new(1024);
        assert_eq!(v.key(0), v.key(128));
        assert_eq!(v.value(5), v.value(133));
        assert_eq!(v.lang_count(), 1024 - 272);
    }
}
