//! S-NIAH analogues (RULER single-needle tasks, paper Tables 3–4).
//!
//! A haystack of repetitive filler sentences hides one needle
//! `[ASSIGN key v_1 .. v_L]` at a seeded depth; the sequence ends with
//! the probe `[QUERY key] v_1 .. v_L`. The model is scored teacher-forced:
//! every value token must be the argmax prediction of its predecessor
//! position (mirrors RULER's exact-match string scoring).
//!
//! Variants mirror RULER's difficulty ladder by value length:
//!   S-NIAH-1 → 1 value token  ("word" needle)
//!   S-NIAH-2 → 4 value tokens ("number" needle)
//!   S-NIAH-3 → 8 value tokens ("uuid" needle)

use super::vocabulary::{Vocab, ASSIGN, QUERY};
use super::TaskSample;
use crate::attention::testutil::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NiahVariant {
    S1,
    S2,
    S3,
}

impl NiahVariant {
    pub fn value_len(self) -> usize {
        match self {
            NiahVariant::S1 => 1,
            NiahVariant::S2 => 4,
            NiahVariant::S3 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            NiahVariant::S1 => "S-NIAH-1",
            NiahVariant::S2 => "S-NIAH-2",
            NiahVariant::S3 => "S-NIAH-3",
        }
    }

    pub fn all() -> [NiahVariant; 3] {
        [NiahVariant::S1, NiahVariant::S2, NiahVariant::S3]
    }
}

pub type NiahSample = TaskSample;

/// Build one sample of exactly `len` tokens.
pub fn generate(vocab: Vocab, variant: NiahVariant, len: usize, seed: u64) -> NiahSample {
    let vl = variant.value_len();
    assert!(len >= 2 * (vl + 2) + 16, "context too short");
    let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));

    let key = vocab.key(rng.below(128));
    let values: Vec<i32> = (0..vl).map(|_| vocab.value(rng.below(128))).collect();

    // filler: cycle of 8-token "sentences" from the language region
    let mut filler_sentence: Vec<i32> = Vec::new();
    for _ in 0..8 {
        filler_sentence.push(vocab.lang_base() + rng.below(vocab.lang_count()) as i32);
    }

    let needle_len = 2 + vl; // ASSIGN key values
    let probe_len = 2 + vl; // QUERY key values
    let hay_len = len - needle_len - probe_len;
    // needle depth uniform in the haystack
    let depth = rng.below(hay_len.max(1));

    let mut tokens = Vec::with_capacity(len);
    let fill = |tokens: &mut Vec<i32>, count: usize| {
        for i in 0..count {
            tokens.push(filler_sentence[i % filler_sentence.len()]);
        }
    };
    fill(&mut tokens, depth);
    tokens.push(ASSIGN);
    tokens.push(key);
    tokens.extend_from_slice(&values);
    fill(&mut tokens, hay_len - depth);
    tokens.push(QUERY);
    tokens.push(key);
    let probe_start = tokens.len(); // first value goes here
    tokens.extend_from_slice(&values);
    assert_eq!(tokens.len(), len);

    let answer_pos: Vec<usize> = (0..vl).map(|i| probe_start + i - 1).collect();
    NiahSample { tokens, answer_pos, answer: values }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_validate() {
        let v = Vocab::new(512);
        for variant in NiahVariant::all() {
            for seed in 0..10 {
                let s = generate(v, variant, 1024, seed);
                assert_eq!(s.tokens.len(), 1024);
                assert!(s.validate(), "{variant:?} seed {seed}");
                assert_eq!(s.answer.len(), variant.value_len());
            }
        }
    }

    #[test]
    fn needle_appears_before_probe() {
        let v = Vocab::new(512);
        let s = generate(v, NiahVariant::S2, 512, 3);
        let assign_pos = s.tokens.iter().position(|&t| t == ASSIGN).unwrap();
        let query_pos = s.tokens.iter().position(|&t| t == QUERY).unwrap();
        assert!(assign_pos < query_pos);
        // needle values equal probe answer
        assert_eq!(&s.tokens[assign_pos + 2..assign_pos + 6], s.answer.as_slice());
    }

    #[test]
    fn depth_varies_with_seed() {
        let v = Vocab::new(512);
        let p1 = generate(v, NiahVariant::S1, 1024, 1)
            .tokens.iter().position(|&t| t == ASSIGN).unwrap();
        let p2 = generate(v, NiahVariant::S1, 1024, 2)
            .tokens.iter().position(|&t| t == ASSIGN).unwrap();
        assert_ne!(p1, p2);
    }

    #[test]
    fn exact_length_for_all_contexts() {
        let v = Vocab::new(512);
        for len in [512, 1024, 2048, 4096] {
            let s = generate(v, NiahVariant::S3, len, 9);
            assert_eq!(s.tokens.len(), len);
            assert!(s.validate());
        }
    }
}
