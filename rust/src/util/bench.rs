//! Criterion-style micro-benchmark harness for `cargo bench`
//! (`harness = false` benches): warmup, repeated timing, median/min/mean
//! reporting, `--filter substring` support.

use std::time::{Duration, Instant};

pub struct Bench {
    filter: Option<String>,
    results: Vec<(String, Stats)>,
    samples: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // cargo bench passes "--bench"; a positional arg filters by name
        let filter = std::env::args().skip(1).find(|a| !a.starts_with("--"));
        Self { filter, results: Vec::new(), samples: 10 }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(3);
        self
    }

    /// Time `f`, auto-calibrating iterations so each sample runs >= 10ms.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(100));
        let iters = (Duration::from_millis(10).as_secs_f64() / once.as_secs_f64())
            .ceil()
            .clamp(1.0, 1e6) as usize;

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.total_cmp(b));
        let stats = Stats {
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            median_s: times[times.len() / 2],
            min_s: times[0],
        };
        println!(
            "{name:<48} {:>12}/iter  (median {:>12}, min {:>12}, {} samples x {} iters)",
            fmt_t(stats.mean_s),
            fmt_t(stats.median_s),
            fmt_t(stats.min_s),
            self.samples,
            iters
        );
        self.results.push((name.to_string(), stats));
    }

    pub fn results(&self) -> &[(String, Stats)] {
        &self.results
    }

    /// Ratio of two benched entries (e.g. speedup reporting).
    pub fn ratio(&self, num: &str, den: &str) -> Option<f64> {
        let get = |n: &str| {
            self.results.iter().find(|(name, _)| name == n).map(|(_, s)| s.median_s)
        };
        Some(get(num)? / get(den)?)
    }
}

pub fn fmt_t(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_something() {
        let mut b = Bench { filter: None, results: Vec::new(), samples: 3 };
        let mut x = 0u64;
        b.bench("spin", || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].1.min_s > 0.0);
        std::hint::black_box(x);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench { filter: Some("yes".into()), results: Vec::new(), samples: 3 };
        b.bench("no_match", || {});
        assert!(b.results().is_empty());
        b.bench("yes_match", || {});
        assert_eq!(b.results().len(), 1);
        assert!(b.ratio("yes_match", "yes_match").unwrap() == 1.0);
        assert!(b.ratio("nope", "yes_match").is_none());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_t(2.0).ends_with('s'));
        assert!(fmt_t(2e-3).ends_with("ms"));
        assert!(fmt_t(2e-6).ends_with("µs"));
        assert!(fmt_t(2e-9).ends_with("ns"));
    }
}
