//! Reusable buffer arena for the zero-allocation kernel runtime.
//!
//! Every hot kernel in the attention substrate needs a handful of
//! per-call working buffers (online-softmax accumulators, gathered
//! query tiles, score tiles, routing state). Before this existed they
//! were `vec![...]`'d fresh on every `forward` call and every decode
//! token — the allocator churn dominated exactly the small-block
//! regime the paper optimizes for. A [`Scratch`] keeps freed buffers
//! on typed freelists and hands them back on the next request, so a
//! steady-state repeat of the same shape performs **zero heap
//! allocations** after the first (warmup) call — pinned by
//! `rust/tests/alloc_regression.rs`.
//!
//! Protocol: `take_*` pops the first freelist entry whose capacity
//! fits (growing one only when nothing fits — counted by the
//! [`Scratch::grown_bytes`] hook the allocation-regression tests
//! assert on), clears it and resizes it to `len` filled with `fill`.
//! `give_*` returns the buffer for reuse. Buffers are plain owned
//! `Vec`s while out, so there is no borrow entanglement with the
//! arena: take several, use them together, give them back in any
//! order.
//!
//! Threading: one `Scratch` is single-owner (`&mut`). The per-worker
//! story lives in [`crate::util::pool::ExecCtx`], which holds one
//! mutex-guarded arena per worker slot; deterministic kernels lock the
//! slot matching their partition index, so repeated same-shape calls
//! replay the identical take/give sequence per slot.

/// Typed freelists of reusable buffers, plus growth accounting.
#[derive(Debug, Default)]
pub struct Scratch {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
    i32s: Vec<Vec<i32>>,
    /// bytes of fresh capacity the arena had to allocate (0 in steady
    /// state — the allocation-regression hook)
    grown_bytes: u64,
    /// take_* calls served
    takes: u64,
}

/// Pop the *smallest* freelist buffer whose capacity fits `len`
/// (best-fit: a small request must not consume the big buffer a later
/// request in the same take/give sequence needs, or the sequence would
/// keep growing buffers instead of converging). When nothing fits,
/// grow the largest buffer — the one closest to fitting. Returns the
/// buffer cleared and resized to `len` filled with `fill`, plus the
/// bytes of capacity growth.
fn take_from<T: Clone>(free: &mut Vec<Vec<T>>, len: usize, fill: T) -> (Vec<T>, u64) {
    let mut fit: Option<(usize, usize)> = None; // (index, capacity)
    let mut largest: Option<(usize, usize)> = None;
    for (i, b) in free.iter().enumerate() {
        let c = b.capacity();
        let tighter = match fit {
            Some((_, fc)) => c < fc,
            None => true,
        };
        if c >= len && tighter {
            fit = Some((i, c));
        }
        let larger = match largest {
            Some((_, lc)) => c > lc,
            None => true,
        };
        if larger {
            largest = Some((i, c));
        }
    }
    let mut v = match fit.or(largest) {
        Some((i, _)) => free.swap_remove(i),
        None => Vec::new(),
    };
    let grown = len.saturating_sub(v.capacity()) * std::mem::size_of::<T>();
    v.clear();
    v.resize(len, fill);
    (v, grown as u64)
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// An f32 buffer of exactly `len` elements, every element `fill`.
    pub fn take_f32(&mut self, len: usize, fill: f32) -> Vec<f32> {
        let (v, grown) = take_from(&mut self.f32s, len, fill);
        self.grown_bytes += grown;
        self.takes += 1;
        v
    }

    /// A u32 buffer of exactly `len` elements, every element `fill`.
    pub fn take_u32(&mut self, len: usize, fill: u32) -> Vec<u32> {
        let (v, grown) = take_from(&mut self.u32s, len, fill);
        self.grown_bytes += grown;
        self.takes += 1;
        v
    }

    /// An i32 buffer of exactly `len` elements, every element `fill`.
    pub fn take_i32(&mut self, len: usize, fill: i32) -> Vec<i32> {
        let (v, grown) = take_from(&mut self.i32s, len, fill);
        self.grown_bytes += grown;
        self.takes += 1;
        v
    }

    pub fn give_f32(&mut self, v: Vec<f32>) {
        self.f32s.push(v);
    }

    pub fn give_u32(&mut self, v: Vec<u32>) {
        self.u32s.push(v);
    }

    pub fn give_i32(&mut self, v: Vec<i32>) {
        self.i32s.push(v);
    }

    /// Bytes of fresh buffer capacity allocated so far. Stops growing
    /// once every shape the arena serves has warmed up — the invariant
    /// the allocation-regression tests pin.
    pub fn grown_bytes(&self) -> u64 {
        self.grown_bytes
    }

    /// take_* calls served (reuse diagnostics).
    pub fn takes(&self) -> u64 {
        self.takes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_sized_filled_and_reused() {
        let mut s = Scratch::new();
        let a = s.take_f32(8, 1.5);
        assert_eq!(a, vec![1.5; 8]);
        let first_growth = s.grown_bytes();
        assert_eq!(first_growth, 8 * 4);
        s.give_f32(a);
        // same size again: reused, no growth
        let b = s.take_f32(8, 0.0);
        assert_eq!(b, vec![0.0; 8]);
        assert_eq!(s.grown_bytes(), first_growth);
        s.give_f32(b);
        // smaller: still reused
        let c = s.take_f32(3, 2.0);
        assert_eq!(c, vec![2.0; 3]);
        assert_eq!(s.grown_bytes(), first_growth);
        s.give_f32(c);
        assert_eq!(s.takes(), 3);
    }

    #[test]
    fn best_fit_prefers_a_buffer_that_already_fits() {
        let mut s = Scratch::new();
        let small = s.take_u32(4, 0);
        let large = s.take_u32(64, 0);
        s.give_u32(small);
        s.give_u32(large);
        let grown = s.grown_bytes();
        // a 16-element request must pick the 64-cap buffer, not grow
        // the 4-cap one
        let v = s.take_u32(16, 7);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|&x| x == 7));
        assert_eq!(s.grown_bytes(), grown);
    }

    #[test]
    fn steady_state_sequence_stops_growing() {
        let mut s = Scratch::new();
        let mut after_warmup = 0;
        for round in 0..4 {
            let a = s.take_f32(100, 0.0);
            let b = s.take_i32(10, -1);
            let c = s.take_u32(33, 0);
            s.give_u32(c);
            s.give_i32(b);
            s.give_f32(a);
            if round == 0 {
                after_warmup = s.grown_bytes();
                assert!(after_warmup > 0);
            } else {
                assert_eq!(s.grown_bytes(), after_warmup, "round {round} grew");
            }
        }
    }

    #[test]
    fn zero_len_takes_work() {
        let mut s = Scratch::new();
        let v = s.take_f32(0, 0.0);
        assert!(v.is_empty());
        assert_eq!(s.grown_bytes(), 0);
        s.give_f32(v);
    }
}
