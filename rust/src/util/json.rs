//! Minimal JSON: a recursive-descent parser and a writer with correct
//! string escaping. Covers everything `aot.py`'s manifest and our result
//! blobs need (objects, arrays, strings, f64 numbers, bools, null).
//!
//! Object key order is preserved (Vec of pairs, not a map) so written
//! results stay diff-stable.

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ------------------------------------------------------------ access
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ------------------------------------------------------ construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    // ------------------------------------------------------------ parse
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // ------------------------------------------------------------ write
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl From<Option<f64>> for Json {
    fn from(x: Option<f64>) -> Self {
        x.map(Json::Num).unwrap_or(Json::Null)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected char {:?} at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // (surrogate pairs unsupported; manifest is ASCII)
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the full char
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let chunk = std::str::from_utf8(&self.b[start..start + len])?;
                    s.push_str(chunk);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_structure() {
        let text = r#"{
            "version": 1,
            "variants": {"tiny": {"params": [{"name": "embed", "shape": [512, 128]}]}},
            "flags": [true, false, null],
            "pi": 3.25e0
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("version").unwrap().as_usize(), Some(1));
        let shape = j
            .req("variants").unwrap()
            .req("tiny").unwrap()
            .req("params").unwrap()
            .as_arr().unwrap()[0]
            .req("shape").unwrap();
        let dims: Vec<usize> = shape.as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![512, 128]);
        assert_eq!(j.req("pi").unwrap().as_f64(), Some(3.25));
        assert!(j.req("flags").unwrap().as_arr().unwrap()[2].is_null());
    }

    #[test]
    fn roundtrips() {
        let j = Json::obj(vec![
            ("a", Json::from(1usize)),
            ("b", Json::arr(vec![Json::from("x\"y\n"), Json::Null, Json::from(true)])),
            ("c", Json::from(-1.5)),
        ]);
        let text = j.to_string();
        let j2 = Json::parse(&text).unwrap();
        assert_eq!(j, j2);
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ↑""#).unwrap();
        assert_eq!(j.as_str(), Some("café ↑"));
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::from(42usize).to_string(), "42");
        assert_eq!(Json::from(1.5).to_string(), "1.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(Json::parse("[ ]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::Obj(vec![]).to_string_pretty(), "{}");
    }
}
