//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] decides, per injection point, whether a fault fires
//! for a given *logical key* (a session id, request id, or admission
//! attempt ordinal). Decisions are a pure function of
//! `(seed, point, key)` — thread identity, wall time, and iteration
//! order never enter — so an injected fault lands on the same logical
//! work at `MOBA_THREADS=1` and `MOBA_THREADS=64`, and a chaos run is
//! replayable bit-for-bit. This is the same stance the rest of the
//! repo takes on scheduling (logical LRU clocks, fixed reduction
//! orders; see `docs/ARCHITECTURE.md`).
//!
//! The plan is disabled by default ([`FaultPlan::disabled`]): every
//! predicate is a branch on an empty trigger table, no allocation, no
//! syscalls — the zero-alloc and bit-determinism contracts of the
//! serving stack are unchanged when no plan is armed. A plan is armed
//! via the `MOBA_FAULTS=seed:spec` environment variable or
//! `ServeParams.fault_plan`; the env var wins when both are set.
//!
//! # Spec grammar
//!
//! ```text
//! MOBA_FAULTS=<seed>:<entry>[,<entry>...]
//! entry := <point>=<rate>        probabilistic: fires when
//!                                hash(seed, point, key) < rate
//!        | <point>@<k1>|<k2>...  exact: fires only for the listed keys
//! point := kernel_panic | alloc_deny | wave_stall | corrupt_input
//! ```
//!
//! Examples: `MOBA_FAULTS=42:kernel_panic=0.05,alloc_deny=0.25`,
//! `MOBA_FAULTS=7:kernel_panic@2|9` (panic the launches keyed 2 and 9).
//!
//! # Injection points
//!
//! * `kernel_panic` — the coordinator panics immediately before a
//!   kernel launch whose key (request id for prefill, session id for
//!   decode) fires. Exercises the `catch_unwind` isolation and session
//!   quarantine paths.
//! * `alloc_deny` — page-pool admission is denied even though the
//!   budget would fit, keyed by `(session, attempt)`. Denials are
//!   bounded: attempts at or beyond [`MAX_DENY_ATTEMPTS`] never fire,
//!   so injected denial delays work (park + deterministic retry) but
//!   can never wedge it.
//! * `wave_stall` — a short artificial sleep before a decode wave
//!   launch. Perturbs timing without touching arithmetic, so outputs
//!   must stay bitwise identical (the chaos-parity contract).
//! * `corrupt_input` — a decode step's K row has its first element
//!   replaced with NaN before validation. Exercises the non-finite
//!   input rejection path end to end.

use anyhow::anyhow;

use crate::Result;

/// Injected alloc denials stop firing at this attempt ordinal, so a
/// denied admission always clears after a bounded number of
/// deterministic retries (liveness under any plan).
pub const MAX_DENY_ATTEMPTS: u32 = 8;

/// The places a [`FaultPlan`] can inject a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Panic immediately before a kernel launch.
    KernelPanic,
    /// Deny a page-pool admission that would otherwise fit.
    AllocDeny,
    /// Sleep briefly before a decode wave launch.
    WaveStall,
    /// Poison a decode step's K row with NaN before validation.
    CorruptInput,
}

impl FaultPoint {
    /// Every injection point, for exhaustive sweeps in tests.
    pub const ALL: [FaultPoint; 4] = [
        FaultPoint::KernelPanic,
        FaultPoint::AllocDeny,
        FaultPoint::WaveStall,
        FaultPoint::CorruptInput,
    ];

    fn name(self) -> &'static str {
        match self {
            FaultPoint::KernelPanic => "kernel_panic",
            FaultPoint::AllocDeny => "alloc_deny",
            FaultPoint::WaveStall => "wave_stall",
            FaultPoint::CorruptInput => "corrupt_input",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultPoint::KernelPanic => 0,
            FaultPoint::AllocDeny => 1,
            FaultPoint::WaveStall => 2,
            FaultPoint::CorruptInput => 3,
        }
    }
}

/// How one injection point decides whether to fire.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
enum Trigger {
    /// Never fires (the disabled state).
    #[default]
    Never,
    /// Fires when the keyed hash lands under this threshold (a rate in
    /// [0, 1] mapped onto `[0, 2^53)` so the comparison is integral
    /// and platform-independent).
    Rate(u64),
    /// Fires only for these exact keys.
    Keys(Vec<u64>),
}

/// A seeded, thread-deterministic fault plan. `Default`/[`disabled`]
/// is the armed-off state: every predicate returns `false` without
/// allocating. See the module docs for the spec grammar.
///
/// [`disabled`]: FaultPlan::disabled
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    seed: u64,
    triggers: [Trigger; 4],
}

/// splitmix64 finalizer: the repo's standard cheap bit mixer.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `2^53`: the rate-threshold scale. A rate of 1.0 maps to exactly
/// `2^53`, which every 53-bit hash value is strictly below.
const RATE_ONE: u64 = 1 << 53;

impl FaultPlan {
    /// The armed-off plan: no point ever fires.
    pub fn disabled() -> Self {
        Self::default()
    }

    /// Parse a `seed:spec` string (see the module docs for the
    /// grammar). An empty spec after the seed is an error — arming a
    /// plan that can never fire is always a typo.
    pub fn parse(s: &str) -> Result<Self> {
        let (seed_s, spec) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("fault plan {s:?}: expected seed:spec"))?;
        let seed: u64 = seed_s
            .trim()
            .parse()
            .map_err(|_| anyhow!("fault plan {s:?}: seed {seed_s:?} is not a u64"))?;
        let mut plan = FaultPlan { seed, triggers: Default::default() };
        let mut any = false;
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (point, trigger) = if let Some((name, rate)) = entry.split_once('=') {
                let rate: f64 = rate
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("fault plan entry {entry:?}: bad rate"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(anyhow!("fault plan entry {entry:?}: rate must be in [0, 1]"));
                }
                (name.trim(), Trigger::Rate((rate * RATE_ONE as f64) as u64))
            } else if let Some((name, keys)) = entry.split_once('@') {
                let keys = keys
                    .split('|')
                    .map(|k| {
                        k.trim()
                            .parse::<u64>()
                            .map_err(|_| anyhow!("fault plan entry {entry:?}: bad key {k:?}"))
                    })
                    .collect::<Result<Vec<u64>>>()?;
                (name.trim(), Trigger::Keys(keys))
            } else {
                return Err(anyhow!(
                    "fault plan entry {entry:?}: expected point=rate or point@k1|k2"
                ));
            };
            let point = FaultPoint::ALL
                .into_iter()
                .find(|p| p.name() == point)
                .ok_or_else(|| {
                    anyhow!(
                        "fault plan entry {entry:?}: unknown point {point:?} \
                         (kernel_panic | alloc_deny | wave_stall | corrupt_input)"
                    )
                })?;
            plan.triggers[point.index()] = trigger;
            any = true;
        }
        if !any {
            return Err(anyhow!("fault plan {s:?}: no injection points"));
        }
        Ok(plan)
    }

    /// The plan named by `MOBA_FAULTS`, if set. A set-but-unparseable
    /// value is a loud startup error, never a silent no-op.
    pub fn from_env() -> Result<Option<Self>> {
        match std::env::var("MOBA_FAULTS") {
            Ok(s) if !s.trim().is_empty() => Ok(Some(Self::parse(&s)?)),
            _ => Ok(None),
        }
    }

    /// Resolve the active plan for a coordinator: `MOBA_FAULTS` wins,
    /// then `ServeParams.fault_plan`, then disabled.
    pub fn resolve(config_spec: Option<&str>) -> Result<Self> {
        if let Some(p) = Self::from_env()? {
            return Ok(p);
        }
        match config_spec {
            Some(s) => Self::parse(s),
            None => Ok(Self::disabled()),
        }
    }

    /// Whether any injection point can fire.
    pub fn is_enabled(&self) -> bool {
        self.triggers.iter().any(|t| *t != Trigger::Never)
    }

    /// Whether `point` has a trigger armed at all (for any key). The
    /// coordinator uses this to switch its idle wait from "block for
    /// the next envelope" to a short poll: injected allocation denials
    /// clear on loop *turns* (the attempt ordinal), not on envelopes,
    /// so blocking forever would strand the parked work they pace.
    pub fn armed(&self, point: FaultPoint) -> bool {
        self.triggers[point.index()] != Trigger::Never
    }

    /// Pure injection predicate: does `point` fire for `key`?
    /// Deterministic across threads, processes, and platforms.
    pub fn fires(&self, point: FaultPoint, key: u64) -> bool {
        match &self.triggers[point.index()] {
            Trigger::Never => false,
            Trigger::Rate(threshold) => {
                let h = mix(self.seed ^ mix((point.index() as u64 + 1) ^ mix(key)));
                (h >> 11) < *threshold
            }
            Trigger::Keys(keys) => keys.contains(&key),
        }
    }

    /// Attempt-aware variant for `alloc_deny`: the same key stops
    /// firing at [`MAX_DENY_ATTEMPTS`], bounding how long an injected
    /// denial can hold work parked. Exact-key triggers deny every
    /// attempt below the bound; rate triggers rehash per attempt.
    pub fn fires_attempt(&self, point: FaultPoint, key: u64, attempt: u32) -> bool {
        if attempt >= MAX_DENY_ATTEMPTS {
            return false;
        }
        match &self.triggers[point.index()] {
            Trigger::Never => false,
            Trigger::Rate(threshold) => {
                let k = mix(key ^ ((attempt as u64 + 1) << 48));
                let h = mix(self.seed ^ mix((point.index() as u64 + 1) ^ k));
                (h >> 11) < *threshold
            }
            Trigger::Keys(keys) => keys.contains(&key),
        }
    }

    /// Panic (to be caught by the launch's `catch_unwind` barrier) if
    /// `point` fires for `key`. The message carries a recognizable
    /// prefix so caught panics are attributable in logs and tests.
    pub fn maybe_panic(&self, point: FaultPoint, key: u64, what: &str) {
        if self.fires(point, key) {
            panic!("injected fault [{}] in {what} (key {key})", point.name());
        }
    }

    /// Sleep briefly if a `wave_stall` fires for `key`. Timing-only:
    /// never touches data, so outputs must stay bitwise identical.
    pub fn maybe_stall(&self, key: u64) {
        if self.fires(FaultPoint::WaveStall, key) {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_plan_never_fires() {
        let p = FaultPlan::disabled();
        assert!(!p.is_enabled());
        for point in FaultPoint::ALL {
            for key in 0..64 {
                assert!(!p.fires(point, key));
                assert!(!p.fires_attempt(point, key, 0));
            }
        }
    }

    #[test]
    fn parse_rate_and_key_entries() {
        let p = FaultPlan::parse("42:kernel_panic=0.5,alloc_deny@3|7").unwrap();
        assert!(p.is_enabled());
        // exact keys fire exactly
        assert!(p.fires(FaultPoint::AllocDeny, 3));
        assert!(p.fires(FaultPoint::AllocDeny, 7));
        assert!(!p.fires(FaultPoint::AllocDeny, 4));
        // unlisted points never fire
        assert!(!p.fires(FaultPoint::WaveStall, 3));
        // rate 0.5 fires for roughly half the keys
        let hits = (0..1000).filter(|&k| p.fires(FaultPoint::KernelPanic, k)).count();
        assert!((350..650).contains(&hits), "rate 0.5 hit {hits}/1000");
    }

    #[test]
    fn rate_zero_never_rate_one_always() {
        let never = FaultPlan::parse("1:kernel_panic=0.0").unwrap();
        let always = FaultPlan::parse("1:kernel_panic=1.0").unwrap();
        for k in 0..256 {
            assert!(!never.fires(FaultPoint::KernelPanic, k));
            assert!(always.fires(FaultPoint::KernelPanic, k));
        }
    }

    #[test]
    fn decisions_depend_on_seed_not_call_order() {
        let a = FaultPlan::parse("1:kernel_panic=0.3").unwrap();
        let b = FaultPlan::parse("2:kernel_panic=0.3").unwrap();
        let fwd: Vec<bool> = (0..512).map(|k| a.fires(FaultPoint::KernelPanic, k)).collect();
        let rev: Vec<bool> =
            (0..512).rev().map(|k| a.fires(FaultPoint::KernelPanic, k)).collect();
        assert_eq!(fwd, rev.into_iter().rev().collect::<Vec<_>>());
        let other: Vec<bool> = (0..512).map(|k| b.fires(FaultPoint::KernelPanic, k)).collect();
        assert_ne!(fwd, other, "seed must matter");
    }

    #[test]
    fn alloc_denials_are_bounded() {
        let p = FaultPlan::parse("9:alloc_deny@5").unwrap();
        for attempt in 0..MAX_DENY_ATTEMPTS {
            assert!(p.fires_attempt(FaultPoint::AllocDeny, 5, attempt));
        }
        assert!(!p.fires_attempt(FaultPoint::AllocDeny, 5, MAX_DENY_ATTEMPTS));
        assert!(!p.fires_attempt(FaultPoint::AllocDeny, 6, 0));
    }

    #[test]
    fn bad_specs_are_loud() {
        for bad in [
            "no-seed-sep",
            "x:kernel_panic=0.5",     // non-numeric seed
            "1:kernel_panic=1.5",     // rate out of range
            "1:kernel_panic=abc",     // non-numeric rate
            "1:warp_drive=0.5",       // unknown point
            "1:kernel_panic@x",       // non-numeric key
            "1:kernel_panic",         // entry with no trigger
            "1:",                     // armed but empty
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn maybe_panic_fires_only_for_cursed_keys() {
        let p = FaultPlan::parse("3:kernel_panic@2").unwrap();
        p.maybe_panic(FaultPoint::KernelPanic, 1, "launch"); // no-op
        let err = std::panic::catch_unwind(|| {
            p.maybe_panic(FaultPoint::KernelPanic, 2, "launch");
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("injected fault [kernel_panic]"), "{msg}");
    }

    #[test]
    fn resolve_prefers_env_then_config() {
        // the test environment does not set MOBA_FAULTS, so the config
        // spec (or disabled) is the expected resolution
        if std::env::var("MOBA_FAULTS").is_err() {
            assert!(!FaultPlan::resolve(None).unwrap().is_enabled());
            let p = FaultPlan::resolve(Some("4:wave_stall=1.0")).unwrap();
            assert!(p.is_enabled());
            assert!(FaultPlan::resolve(Some("garbage")).is_err());
        } else {
            // under a CI chaos leg the env plan must win and parse
            assert!(FaultPlan::resolve(Some("4:wave_stall=1.0")).unwrap().is_enabled());
        }
    }
}
