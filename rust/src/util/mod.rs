//! In-tree utilities replacing external crates (the crate's only
//! external dependency is `anyhow` — see Cargo.toml; even the PJRT
//! binding surface is an in-tree stub, [`crate::xla`]).
//!
//! * [`json`] — minimal JSON parser/writer (manifest.json, configs,
//!   results persistence).
//! * [`bench`] — tiny criterion-style timing harness for `cargo bench`.
//! * [`cli`] — flag/positional argument parsing for the binary.
//! * [`pool`] — scoped thread pool + [`pool::ExecCtx`]: the
//!   deterministic multi-core execution layer under every attention
//!   backend (`MOBA_THREADS` workers, bit-identical to serial).
//! * [`scratch`] — reusable buffer arena (one per `ExecCtx` worker
//!   slot): the zero-allocation kernel runtime's freelists.
//! * [`faults`] — seeded, thread-deterministic fault injection
//!   ([`faults::FaultPlan`], armed via `MOBA_FAULTS=seed:spec`): the
//!   chaos layer the serving stack's crash isolation is tested with.

pub mod bench;
pub mod cli;
pub mod faults;
pub mod json;
pub mod pool;
pub mod scratch;
