//! In-tree utilities replacing external crates (the testbed vendors only
//! the xla closure — see Cargo.toml).
//!
//! * [`json`] — minimal JSON parser/writer (manifest.json, configs,
//!   results persistence).
//! * [`bench`] — tiny criterion-style timing harness for `cargo bench`.
//! * [`cli`] — flag/positional argument parsing for the binary.

pub mod bench;
pub mod cli;
pub mod json;
