//! Tiny argv parser: positionals + `--flag [value]` options.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positionals: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse argv (excluding the program name). `switch_names` are
    /// boolean flags that take no value.
    pub fn parse(argv: impl Iterator<Item = String>, switch_names: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if switch_names.contains(&name) {
                    out.switches.push(name.to_string());
                } else if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        out.switches.push(name.to_string());
                    } else {
                        out.flags.insert(name.to_string(), it.next().unwrap());
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|x| x.to_string())
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(argv("bench fig3 --steps 50 --quick --artifacts /tmp/a"), &["quick"]);
        assert_eq!(a.pos(0), Some("bench"));
        assert_eq!(a.pos(1), Some("fig3"));
        assert_eq!(a.get_usize("steps"), Some(50));
        assert!(a.has("quick"));
        assert_eq!(a.get("artifacts"), Some("/tmp/a"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn eq_form_and_trailing_switch() {
        let a = Args::parse(argv("train --variant=tiny-moba32 --quick"), &["quick"]);
        assert_eq!(a.get("variant"), Some("tiny-moba32"));
        assert!(a.has("quick"));
    }

    #[test]
    fn unknown_trailing_flag_becomes_switch() {
        let a = Args::parse(argv("x --dangling"), &[]);
        assert!(a.has("dangling"));
        assert_eq!(a.get("dangling"), None);
    }
}
