//! Zero-dependency scoped thread pool + the [`ExecCtx`] execution
//! context threaded through every attention backend.
//!
//! Design constraints (see README.md §Performance):
//!
//! * **Determinism.** Every parallel kernel in the substrate partitions
//!   *independent* work units (query rows, query tiles, key blocks)
//!   into contiguous ranges and runs the unchanged serial arithmetic on
//!   each unit. There are no cross-thread reductions, so the f32
//!   results are bit-identical to the serial path at any worker count —
//!   the property suite and the CI `MOBA_THREADS={1,4}` matrix both
//!   pin this.
//! * **No dependencies.** Built on [`std::thread::scope`]: each
//!   parallel region spawns at most `workers` scoped threads and joins
//!   them before returning. Worker threads never outlive a call, so
//!   there is no shared mutable pool state to poison — a panicking task
//!   propagates to the caller (after all siblings are joined) and the
//!   pool remains usable.
//! * **Serial fast path.** With one worker (or one task) everything
//!   runs inline on the caller's thread; `MOBA_THREADS=1` spawns
//!   nothing.
//!
//! Known trade-off: spawning scoped threads per region costs tens of
//! microseconds, which the tiniest shapes (the parity-grid tests, a
//! short serving prefill) don't amortize. That overhead was accepted
//! over persistent workers because persistence needs either unsafe
//! lifetime erasure or 'static channels — the wrong risk profile for a
//! correctness-first substrate; callers that care run `MOBA_THREADS=1`
//! or an [`ExecCtx::serial`] context.

use std::ops::{Deref, DerefMut, Range};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, TryLockError};

use super::scratch::Scratch;

/// Scoped fork-join pool: a worker-count budget plus the spawn/join
/// helpers every parallel kernel uses.
#[derive(Debug)]
pub struct ThreadPool {
    workers: usize,
}

/// Parse a `MOBA_THREADS`-style override; `None` means "use the
/// hardware default". Zero and garbage are rejected rather than
/// clamped so a typo cannot silently serialize the substrate.
fn parse_workers(var: Option<&str>) -> Option<usize> {
    var.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&w| w >= 1)
}

impl ThreadPool {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }

    /// Worker count from the `MOBA_THREADS` env var (default: all
    /// available cores).
    pub fn from_env() -> Self {
        let workers = parse_workers(std::env::var("MOBA_THREADS").ok().as_deref())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        Self::new(workers)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run the tasks concurrently and return the results in task order.
    /// Callers hand over at most [`ThreadPool::workers`] tasks (use
    /// [`partition`] to chunk larger work lists). The first task runs
    /// inline on the calling thread — it would otherwise idle in the
    /// join — so a region of W tasks spawns only W-1 threads. An empty
    /// task list is a no-op; a single task runs entirely inline. If a
    /// task panics, the panic propagates to the caller after every
    /// sibling has been joined — the pool itself holds no state and
    /// stays usable.
    #[allow(clippy::type_complexity)]
    pub fn run_tasks<'env, T: Send>(
        &self,
        mut tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
    ) -> Vec<T> {
        if tasks.is_empty() {
            return Vec::new();
        }
        if tasks.len() == 1 {
            let task = tasks.pop().unwrap();
            return vec![task()];
        }
        std::thread::scope(|s| {
            let mut rest = tasks.into_iter();
            let first = rest.next().expect("tasks is non-empty");
            let handles: Vec<_> = rest.map(|t| s.spawn(t)).collect();
            let mut out = Vec::with_capacity(handles.len() + 1);
            out.push(first());
            for h in handles {
                out.push(h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)));
            }
            out
        })
    }

    /// Partition `0..n` into at most `workers` contiguous ranges, run
    /// `f` on each range concurrently (via [`ThreadPool::run_tasks`]),
    /// and return the results in range order (so concatenating them
    /// reassembles `0..n`). `n == 0` is a no-op returning an empty vec.
    pub fn map_ranges<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> T + Sync,
    {
        let ranges = partition(n, self.workers);
        if ranges.len() <= 1 {
            return ranges.into_iter().map(f).collect();
        }
        let fr = &f;
        let tasks: Vec<Box<dyn FnOnce() -> T + Send + '_>> = ranges
            .into_iter()
            .map(|r| Box::new(move || fr(r)) as Box<dyn FnOnce() -> T + Send + '_>)
            .collect();
        self.run_tasks(tasks)
    }

    /// [`ThreadPool::map_ranges`] writing **in place**: partition
    /// `0..n` into at most `workers` contiguous unit-ranges and hand
    /// each task disjoint mutable windows of two output buffers —
    /// no per-range result vectors, no concat copy. `bound(u)` maps a
    /// unit boundary `u` (0..=n) to element offsets in `a` and `b`
    /// (must be monotone; `bound(0) == (0, 0)`). `f` receives
    /// `(range_index, unit_range, a_window, b_window)` where the
    /// windows cover `bound(range.start)..bound(range.end)`.
    ///
    /// Range `i` is always the i-th partition of `0..n`, so a kernel
    /// that keys per-worker scratch off `range_index` replays the
    /// identical buffer sequence on every same-shape call. The serial
    /// path (one worker or one unit) runs `f` inline with **zero heap
    /// allocations** — the property the allocation-regression suite
    /// pins through the kernels built on this.
    pub fn for_ranges_split<A, B, FB, F>(&self, n: usize, a: &mut [A], b: &mut [B], bound: FB, f: F)
    where
        A: Send,
        B: Send,
        FB: Fn(usize) -> (usize, usize),
        F: Fn(usize, Range<usize>, &mut [A], &mut [B]) + Sync,
    {
        if n == 0 {
            return;
        }
        debug_assert_eq!(bound(0), (0, 0), "bound must start at the buffer origin");
        let (a_end, b_end) = bound(n);
        if self.workers.min(n) <= 1 {
            f(0, 0..n, &mut a[..a_end], &mut b[..b_end]);
            return;
        }
        let ranges = partition(n, self.workers);
        let fr = &f;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
        let mut a_rest = &mut a[..a_end];
        let mut b_rest = &mut b[..b_end];
        let (mut a_base, mut b_base) = (0usize, 0usize);
        for (i, r) in ranges.into_iter().enumerate() {
            let (a_next, b_next) = bound(r.end);
            debug_assert!(a_next >= a_base && b_next >= b_base, "bound must be monotone");
            let (a_chunk, a_tail) = std::mem::take(&mut a_rest).split_at_mut(a_next - a_base);
            let (b_chunk, b_tail) = std::mem::take(&mut b_rest).split_at_mut(b_next - b_base);
            a_rest = a_tail;
            b_rest = b_tail;
            a_base = a_next;
            b_base = b_next;
            tasks.push(Box::new(move || fr(i, r, a_chunk, b_chunk)));
        }
        self.run_tasks(tasks);
    }
}

/// Split `0..n` into at most `parts` contiguous, near-equal, non-empty
/// ranges (the first `n % parts` ranges get one extra element).
/// Deterministic in (n, parts); empty for n == 0.
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Concatenate per-range result chunks back into one buffer (the
/// companion of [`ThreadPool::map_ranges`]).
pub fn concat<T: Clone>(parts: Vec<Vec<T>>) -> Vec<T> {
    let total = parts.iter().map(|p| p.len()).sum();
    let mut out = Vec::with_capacity(total);
    for p in &parts {
        out.extend_from_slice(p);
    }
    out
}

/// Execution context handed to every [`AttentionBackend`]
/// (`crate::attention::backend::AttentionBackend`) call: the shared
/// thread pool the kernels partition their work over, plus one
/// [`Scratch`] buffer arena per worker slot (the zero-allocation
/// kernel runtime's workspace). Cheap to clone (two [`Arc`]s; clones
/// share both the worker budget and the arenas); `threads() == 1`
/// selects the pure serial path.
#[derive(Debug, Clone)]
pub struct ExecCtx {
    pool: Arc<ThreadPool>,
    scratch: Arc<Vec<Mutex<Scratch>>>,
}

/// A locked (or, under contention, private fallback) scratch arena —
/// see [`ExecCtx::scratch`].
pub enum ScratchHandle<'a> {
    /// the worker slot's pooled arena (the steady-state path)
    Pooled(MutexGuard<'a, Scratch>),
    /// a throwaway arena: the slot was held by a concurrent call on
    /// the same context, so this call pays allocations rather than
    /// blocking behind it
    Local(Box<Scratch>),
}

impl ScratchHandle<'_> {
    /// Did this handle reach the worker slot's pooled arena? Callers
    /// that give buffers back in a *separate* later acquisition (e.g.
    /// `forward_into` taking before a parallel region and giving
    /// after) must check this: pooled-taken buffers go back through
    /// [`ExecCtx::scratch_wait`], Local-taken ones are throwaway and
    /// must be dropped — returning them would grow the pooled
    /// freelists without bound under repeated contention.
    pub fn is_pooled(&self) -> bool {
        matches!(self, ScratchHandle::Pooled(_))
    }
}

impl Deref for ScratchHandle<'_> {
    type Target = Scratch;
    fn deref(&self) -> &Scratch {
        match self {
            ScratchHandle::Pooled(g) => g,
            ScratchHandle::Local(s) => s,
        }
    }
}

impl DerefMut for ScratchHandle<'_> {
    fn deref_mut(&mut self) -> &mut Scratch {
        match self {
            ScratchHandle::Pooled(g) => g,
            ScratchHandle::Local(s) => s,
        }
    }
}

impl ExecCtx {
    pub fn new(pool: ThreadPool) -> Self {
        let slots = (0..pool.workers()).map(|_| Mutex::new(Scratch::new())).collect();
        Self { pool: Arc::new(pool), scratch: Arc::new(slots) }
    }

    /// A context with exactly `n` workers (tests pin 1 vs N to assert
    /// bit-identical outputs).
    pub fn with_threads(n: usize) -> Self {
        Self::new(ThreadPool::new(n))
    }

    /// The single-threaded context (identical results, no spawning).
    pub fn serial() -> Self {
        Self::with_threads(1)
    }

    /// A fresh context from `MOBA_THREADS` / available cores.
    pub fn from_env() -> Self {
        Self::new(ThreadPool::from_env())
    }

    /// The process-wide shared context (env read once). Entry points
    /// that take no explicit context — the compat kernel wrappers, the
    /// bench harness — run on this pool, so the whole process shares
    /// one worker budget.
    pub fn global() -> &'static ExecCtx {
        static GLOBAL: OnceLock<ExecCtx> = OnceLock::new();
        GLOBAL.get_or_init(ExecCtx::from_env)
    }

    pub fn threads(&self) -> usize {
        self.pool.workers()
    }

    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Lock worker slot `slot`'s scratch arena (slots wrap modulo the
    /// worker count, so a partition index is always a valid slot).
    /// Deterministic kernels key the slot off their
    /// [`ThreadPool::for_ranges_split`] range index: repeated
    /// same-shape calls then replay the identical take/give sequence
    /// per slot and stay allocation-free after warmup. If the slot is
    /// held by a *concurrent* call on the same context, a private
    /// throwaway arena is returned instead of blocking — correctness
    /// is unaffected, that call just pays its allocations.
    ///
    /// A slot poisoned by a panicking worker (a kernel that died while
    /// holding the guard may have taken buffers it never gave back) is
    /// **rebuilt fresh and unpoisoned**, not propagated: the next
    /// caller gets an empty arena that re-warms, never a half-mutated
    /// freelist or an eternally-poisoned lock.
    pub fn scratch(&self, slot: usize) -> ScratchHandle<'_> {
        let m = &self.scratch[slot % self.scratch.len()];
        match m.try_lock() {
            Ok(g) => ScratchHandle::Pooled(g),
            Err(TryLockError::Poisoned(p)) => {
                let mut g = p.into_inner();
                *g = Scratch::new();
                m.clear_poison();
                ScratchHandle::Pooled(g)
            }
            Err(TryLockError::WouldBlock) => ScratchHandle::Local(Box::default()),
        }
    }

    /// Lock slot `slot`'s arena, *waiting* if a concurrent call holds
    /// it. Used on give-back paths that took buffers in an earlier,
    /// separate acquisition: a buffer taken from the pooled arena must
    /// never be lost to a throwaway fallback just because the slot was
    /// momentarily contended (that would silently re-grow the pool on
    /// every later call). Callers must not already hold this slot's
    /// handle on the same thread (the in-tree kernels never do — give
    /// sites run after every kernel handle is dropped).
    ///
    /// Poisoned slots are rebuilt fresh and unpoisoned, same as
    /// [`ExecCtx::scratch`] — a give-back into an arena a panic left
    /// inconsistent would preserve the corruption forever.
    pub fn scratch_wait(&self, slot: usize) -> MutexGuard<'_, Scratch> {
        let m = &self.scratch[slot % self.scratch.len()];
        match m.lock() {
            Ok(g) => g,
            Err(p) => {
                let mut g = p.into_inner();
                *g = Scratch::new();
                m.clear_poison();
                g
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_covers_contiguously() {
        for (n, parts) in [(0, 4), (1, 4), (7, 3), (8, 3), (9, 3), (100, 7), (3, 10)] {
            let ranges = partition(n, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next, "n={n} parts={parts}");
                assert!(!r.is_empty(), "n={n} parts={parts}");
                next = r.end;
            }
            assert_eq!(next, n, "n={n} parts={parts}");
            // near-equal: sizes differ by at most one
            if let (Some(max), Some(min)) = (
                ranges.iter().map(|r| r.len()).max(),
                ranges.iter().map(|r| r.len()).min(),
            ) {
                assert!(max - min <= 1);
            }
        }
    }

    #[test]
    fn zero_items_is_a_noop() {
        let pool = ThreadPool::new(4);
        assert!(pool.map_ranges(0, |r| r.len()).is_empty());
        assert!(pool.run_tasks::<usize>(Vec::new()).is_empty());
    }

    #[test]
    fn map_ranges_preserves_order_and_runs_everything() {
        for workers in [1, 2, 3, 8] {
            let pool = ThreadPool::new(workers);
            let counter = AtomicUsize::new(0);
            let parts = pool.map_ranges(23, |r| {
                counter.fetch_add(r.len(), Ordering::Relaxed);
                r.collect::<Vec<usize>>()
            });
            assert_eq!(counter.load(Ordering::Relaxed), 23);
            let flat: Vec<usize> = parts.into_iter().flatten().collect();
            assert_eq!(flat, (0..23).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn run_tasks_returns_in_task_order() {
        let pool = ThreadPool::new(4);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    // later tasks finish first; order must still hold
                    std::thread::sleep(std::time::Duration::from_millis(4 - i as u64));
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(pool.run_tasks(tasks), vec![0, 1, 2, 3]);
    }

    /// A panicking task propagates to the caller but does not poison
    /// the pool: subsequent parallel regions run normally.
    #[test]
    fn panic_propagates_without_poisoning_the_pool() {
        let pool = ThreadPool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send>> = vec![
                Box::new(|| panic!("task panic")),
                Box::new(|| ()),
                Box::new(|| ()),
            ];
            pool.run_tasks(tasks);
        }));
        assert!(result.is_err(), "panic must reach the caller");
        // the pool is stateless: the next region works
        let sums = pool.map_ranges(16, |r| r.sum::<usize>());
        assert_eq!(sums.iter().sum::<usize>(), (0..16).sum::<usize>());
    }

    #[test]
    fn inline_single_task_panic_also_propagates() {
        let pool = ThreadPool::new(1);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks::<()>(vec![Box::new(|| panic!("inline"))]);
        }));
        assert!(result.is_err());
        assert_eq!(pool.map_ranges(4, |r| r.len()).iter().sum::<usize>(), 4);
    }

    #[test]
    fn worker_parsing_rules() {
        assert_eq!(parse_workers(None), None);
        assert_eq!(parse_workers(Some("4")), Some(4));
        assert_eq!(parse_workers(Some(" 2 ")), Some(2));
        assert_eq!(parse_workers(Some("0")), None, "0 is rejected, not clamped");
        assert_eq!(parse_workers(Some("lots")), None);
        assert!(ThreadPool::new(0).workers() >= 1);
    }

    #[test]
    fn ctx_constructors() {
        assert_eq!(ExecCtx::serial().threads(), 1);
        assert_eq!(ExecCtx::with_threads(3).threads(), 3);
        assert!(ExecCtx::global().threads() >= 1);
        // clones share the same pool budget
        let ctx = ExecCtx::with_threads(2);
        assert_eq!(ctx.clone().threads(), 2);
    }

    #[test]
    fn concat_reassembles() {
        assert_eq!(concat(vec![vec![1, 2], vec![], vec![3]]), vec![1, 2, 3]);
        assert!(concat::<f32>(Vec::new()).is_empty());
    }

    /// In-place range splitting covers both buffers exactly once, at
    /// any worker count, with non-uniform unit spans.
    #[test]
    fn for_ranges_split_covers_disjoint_windows() {
        // unit u owns u+1 elements of `a` and 1 element of `b`
        let n = 7;
        let bound = |u: usize| (u * (u + 1) / 2, u);
        for workers in [1, 2, 3, 8] {
            let pool = ThreadPool::new(workers);
            let mut a = vec![0u32; bound(n).0];
            let mut b = vec![0u32; n];
            pool.for_ranges_split(n, &mut a, &mut b, bound, |idx, range, aw, bw| {
                assert_eq!(aw.len(), bound(range.end).0 - bound(range.start).0);
                assert_eq!(bw.len(), range.len());
                for x in aw.iter_mut() {
                    *x += 1 + idx as u32;
                }
                for (off, u) in range.enumerate() {
                    bw[off] = u as u32;
                }
            });
            // every element written exactly once
            assert!(a.iter().all(|&x| x >= 1), "workers={workers}");
            assert_eq!(b, (0..n as u32).collect::<Vec<_>>(), "workers={workers}");
        }
    }

    #[test]
    fn for_ranges_split_zero_units_is_noop() {
        let pool = ThreadPool::new(4);
        let mut a: Vec<f32> = Vec::new();
        let mut b: Vec<f32> = Vec::new();
        pool.for_ranges_split(0, &mut a, &mut b, |_| (0, 0), |_, _, _, _| panic!("no units"));
    }

    /// Scratch slots: same slot reuses buffers across calls; a held
    /// slot falls back to a private arena instead of deadlocking.
    #[test]
    fn ctx_scratch_slots_reuse_and_fall_back() {
        let ctx = ExecCtx::with_threads(2);
        {
            let mut s = ctx.scratch(0);
            let v = s.take_f32(32, 0.0);
            s.give_f32(v);
            assert!(s.grown_bytes() > 0);
        }
        let grown = {
            let s = ctx.scratch(0);
            s.grown_bytes()
        };
        {
            // steady state: same request, no further growth
            let mut s = ctx.scratch(0);
            let v = s.take_f32(32, 1.0);
            assert_eq!(v.len(), 32);
            s.give_f32(v);
            assert_eq!(s.grown_bytes(), grown);
        }
        // slots wrap modulo worker count
        let _ = ctx.scratch(5);
        // holding slot 1 while asking for it again must not block
        let _held = ctx.scratch(1);
        let mut fallback = ctx.scratch(1);
        assert!(matches!(fallback, ScratchHandle::Local(_)));
        let v = fallback.take_f32(4, 0.0);
        assert_eq!(v.len(), 4);
    }

    /// scratch_wait reaches the pooled arena (so give-backs are never
    /// lost): a buffer given through it is reused by the next take.
    #[test]
    fn scratch_wait_gives_back_to_the_pool() {
        let ctx = ExecCtx::with_threads(1);
        let buf = {
            let mut s = ctx.scratch(0);
            s.take_f32(16, 0.0)
        };
        ctx.scratch_wait(0).give_f32(buf);
        let mut s = ctx.scratch(0);
        let grown = s.grown_bytes();
        let again = s.take_f32(16, 1.0);
        assert_eq!(again.len(), 16);
        assert_eq!(s.grown_bytes(), grown, "pooled buffer was lost");
        s.give_f32(again);
    }

    /// The crash-isolation contract for arenas: a panic while holding
    /// a scratch guard poisons the slot's mutex, and the next caller
    /// must get a fresh, working, *pooled* arena — not a propagated
    /// poison, not a permanently-degraded Local fallback, and not the
    /// half-mutated freelist the panicking kernel left behind (here: a
    /// taken buffer that was never given back).
    #[test]
    fn poisoned_scratch_slot_is_rebuilt_fresh() {
        let ctx = ExecCtx::with_threads(1);
        let boom = catch_unwind(AssertUnwindSafe(|| {
            let mut s = ctx.scratch_wait(0);
            let _leaked = s.take_f32(64, 0.0); // never given back
            panic!("kernel died mid-forward");
        }));
        assert!(boom.is_err());
        // scratch(): pooled handle, rebuilt (no leaked growth visible)
        {
            let mut s = ctx.scratch(0);
            assert!(s.is_pooled(), "poison degraded the slot to Local");
            if let ScratchHandle::Pooled(g) = &s {
                assert_eq!(g.grown_bytes(), 0, "arena was not rebuilt fresh");
            }
            let v = s.take_f32(8, 1.0);
            assert_eq!(v.len(), 8);
            s.give_f32(v);
        }
        // the slot is unpoisoned for every later acquisition, and the
        // pool serves steady-state again (give-backs are retained)
        let grown = {
            let mut s = ctx.scratch_wait(0);
            let v = s.take_f32(8, 2.0);
            s.give_f32(v);
            s.grown_bytes()
        };
        let mut s = ctx.scratch_wait(0);
        let v = s.take_f32(8, 3.0);
        assert_eq!(s.grown_bytes(), grown, "steady state lost after poison recovery");
        s.give_f32(v);
    }

    /// Same recovery through `scratch_wait` when the *waiting* path
    /// meets the poison first.
    #[test]
    fn poisoned_slot_recovery_via_scratch_wait() {
        let ctx = ExecCtx::with_threads(2);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = ctx.scratch_wait(1);
            panic!("boom");
        }));
        let mut g = ctx.scratch_wait(1);
        assert_eq!(g.grown_bytes(), 0);
        let v = g.take_f32(4, 0.0);
        g.give_f32(v);
    }
}
