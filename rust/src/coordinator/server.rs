//! The coordinator event loop: route → batch → execute → respond.
//!
//! Plain threads + channels (the testbed vendors no async runtime): one
//! worker thread owns the batcher and the PJRT executables; clients get
//! a per-request response channel ([`Pending`] ticket) and either block
//! on it ([`Coordinator::submit`]) or collect tickets first and join
//! later ([`Coordinator::submit_async`]) for concurrent load.
//!
//! Correctness of padding: requests shorter than the kernel's sequence
//! capacity are zero-padded *at the tail*. Because MoBA routing only
//! scores strictly-past blocks and the own block is causally masked,
//! tail padding can never influence rows `< n` — the served output is
//! exactly the n-length computation (asserted by integration tests).

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{Batch, Batcher};
use super::metrics::Metrics;
use super::request::{AttnRequest, AttnResponse, QueueStamp};
use super::router::Router;
use crate::config::ServeParams;
use crate::runtime::{Runtime, Tensor};
use crate::Result;

enum Envelope {
    Req(AttnRequest, SyncSender<Result<AttnResponse>>),
    Shutdown,
}

/// A pending response ticket.
pub struct Ticket(Receiver<Result<AttnResponse>>);

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<AttnResponse> {
        self.0.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }
}

/// In-process serving handle.
pub struct Coordinator {
    tx: SyncSender<Envelope>,
    metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker thread. The PJRT client is not `Send` (the xla
    /// crate uses `Rc` internally), so the worker *constructs its own*
    /// [`Runtime`] from the artifacts directory and owns all PJRT state
    /// for its lifetime; startup errors are reported synchronously.
    pub fn start(artifacts_dir: impl Into<PathBuf>, params: ServeParams) -> Result<Self> {
        let dir = artifacts_dir.into();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Envelope>(params.queue_capacity.max(16));
        let (boot_tx, boot_rx) = sync_channel::<Result<()>>(1);
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("flash-moba-coordinator".into())
            .spawn(move || {
                let runtime = match Runtime::load(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let router = match Router::from_manifest(runtime.manifest()) {
                    Ok(r) => r,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let _ = boot_tx.send(Ok(()));
                worker_loop(runtime, router, params, rx, m2)
            })
            .expect("spawn coordinator");
        boot_rx
            .recv()
            .map_err(|_| anyhow!("coordinator worker died during startup"))??;
        Ok(Self { tx, metrics, worker: Some(worker) })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Submit without blocking; returns a ticket to wait on.
    pub fn submit_async(&self, req: AttnRequest) -> Result<Ticket> {
        if !req.validate() {
            return Err(anyhow!("invalid request {}: shape mismatch", req.id));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::Req(req, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(Ticket(orx))
    }

    /// Submit and block for the response.
    pub fn submit(&self, req: AttnRequest) -> Result<AttnResponse> {
        self.submit_async(req)?.wait()
    }

    /// Graceful shutdown: drains queued work.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.try_send(Envelope::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

type Pending = Vec<(u64, SyncSender<Result<AttnResponse>>)>;

fn worker_loop(
    runtime: Runtime,
    router: Router,
    params: ServeParams,
    rx: Receiver<Envelope>,
    metrics: Arc<Metrics>,
) {
    let max_wait = Duration::from_millis(params.max_wait_ms);
    let mut batcher =
        Batcher::new(params.max_batch.min(router.heads), max_wait, params.queue_capacity);
    let mut pending: Pending = Vec::new();

    loop {
        // wait for work or the earliest batch deadline
        let msg = match batcher.next_deadline() {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break, // all senders gone
            },
            Some(dl) => {
                let now = Instant::now();
                if dl <= now {
                    None // deadline passed: flush first
                } else {
                    match rx.recv_timeout(dl - now) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
        };

        let mut shutdown = false;
        match msg {
            Some(Envelope::Req(req, otx)) => match router.route(req.kind, req.n) {
                Ok((cap, artifact)) => {
                    let artifact = artifact.to_string();
                    pending.push((req.id, otx));
                    if let Err(rej) = batcher.push(req, &artifact, cap, Instant::now()) {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        respond(&mut pending, rej.id, Err(anyhow!("queue full")));
                    }
                }
                Err(e) => {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(e));
                }
            },
            Some(Envelope::Shutdown) => shutdown = true,
            None => {} // deadline wake-up
        }

        // execute everything ready (all lanes on shutdown)
        let now = Instant::now();
        let batches: Vec<Batch> = if shutdown {
            batcher.flush_all()
        } else {
            std::iter::from_fn(|| batcher.poll(now)).collect()
        };
        for batch in batches {
            run_batch(&runtime, &router, batch, &mut pending, &metrics);
        }
        if shutdown {
            for (_, otx) in pending.drain(..) {
                let _ = otx.send(Err(anyhow!("coordinator shut down")));
            }
            break;
        }
    }
}

fn respond(pending: &mut Pending, id: u64, result: Result<AttnResponse>) {
    if let Some(pos) = pending.iter().position(|(pid, _)| *pid == id) {
        let (_, otx) = pending.swap_remove(pos);
        let _ = otx.send(result);
    }
}

/// Pack requests into the (H, N, d) kernel, execute, unpack, respond.
fn run_batch(
    runtime: &Runtime,
    router: &Router,
    batch: Batch,
    pending: &mut Pending,
    metrics: &Metrics,
) {
    let h = router.heads;
    let d = router.head_dim;
    let n = batch.kernel_n;
    let occupancy = batch.items.len();
    debug_assert!(occupancy <= h);

    let exec = || -> Result<Vec<Tensor>> {
        let exe = runtime.get(&batch.artifact)?;
        let mut q = vec![0.0f32; h * n * d];
        let mut k = vec![0.0f32; h * n * d];
        let mut v = vec![0.0f32; h * n * d];
        for (slot, (req, _)) in batch.items.iter().enumerate() {
            let e = req.n * d;
            q[slot * n * d..slot * n * d + e].copy_from_slice(&req.q);
            k[slot * n * d..slot * n * d + e].copy_from_slice(&req.k);
            v[slot * n * d..slot * n * d + e].copy_from_slice(&req.v);
        }
        let shape = [h, n, d];
        exe.run(&[
            Tensor::f32(q, &shape)?,
            Tensor::f32(k, &shape)?,
            Tensor::f32(v, &shape)?,
        ])
    };

    match exec() {
        Ok(outs) => {
            let executed = Instant::now();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
            let o = outs.into_iter().next().and_then(|t| t.into_f32().ok());
            match o {
                Some(o) => {
                    for (slot, (req, enq)) in batch.items.iter().enumerate() {
                        let e = req.n * d;
                        let out = o[slot * n * d..slot * n * d + e].to_vec();
                        let stamp = QueueStamp { enqueued: *enq, executed };
                        metrics.record_latency(stamp.queue_latency_s());
                        metrics.responses.fetch_add(1, Ordering::Relaxed);
                        respond(
                            pending,
                            req.id,
                            Ok(AttnResponse {
                                id: req.id,
                                o: out,
                                served_n: n,
                                batch_occupancy: occupancy,
                                queued_at: Some(stamp),
                            }),
                        );
                    }
                }
                None => {
                    for (req, _) in &batch.items {
                        respond(pending, req.id, Err(anyhow!("bad kernel output")));
                    }
                }
            }
        }
        Err(e) => {
            for (req, _) in &batch.items {
                respond(pending, req.id, Err(anyhow!("execution failed: {e}")));
            }
        }
    }
}
