//! The coordinator event loop: route → batch → execute → respond.
//!
//! Plain threads + channels (the testbed vendors no async runtime): one
//! worker thread owns the batcher, the execution backend and the decode
//! session table; clients get a per-request response channel
//! ([`Pending`] ticket) and either block on it ([`Coordinator::submit`])
//! or collect tickets first and join later ([`Coordinator::submit_async`])
//! for concurrent load.
//!
//! Two request families share the loop:
//!
//! * **Prefill** — one-shot attention over a full packed `(h, n, d)` /
//!   `(h_kv, n, d)` problem. One request is ONE kernel launch covering
//!   every head — the kernels iterate heads internally, so the server
//!   has no head loop.
//! * **Decode** — autoregressive sessions: `session_create` opens a
//!   *paged* block KV cache (one page table per KV head, pages owned by
//!   the worker's shared [`PagePool`]) in the worker
//!   ([`crate::attention::decode::DecodeSession`]), each
//!   [`Coordinator::decode`] step ships only the new token's packed
//!   `(h, d)` / `(h_kv, d)` rows through a dedicated batcher lane (the
//!   cached context never travels through the queue), and `session_free`
//!   drops the cache. Steps for one session execute in submission order
//!   (FIFO within the lane). [`Coordinator::session_fork`] opens a new
//!   session sharing the parent's cache pages copy-on-write (common
//!   prompt prefixes cost no new pages until they diverge), and
//!   [`Coordinator::session_prefill`] bulk-appends a prompt's packed
//!   `(h_kv, n, d)` K/V through the same admission path.
//!
//! **Continuous batching**: when `ServeParams::max_pages` bounds the
//! pool, cache growth goes through an admission rule instead of
//! allocating unchecked. Work whose page cost fits the remaining budget
//! is admitted into the running decode waves; otherwise the scheduler
//! preempts the coldest sessions (LRU, deterministic tie-break) that
//! have no steps in flight — their caches are evicted, pages
//! returned — and if no victim can make room the work is *parked* FIFO
//! and retried head-only after every loop turn (strict arrival order;
//! the head never loses its place to a smaller request). Every executed
//! append is recorded in a per-session swap log, so a preempted session
//! restores on next touch by replaying its log — bit-identical to never
//! having been evicted (the paging parity suite pins this). With an
//! unbounded pool (the default) the admission rule, swap logging and
//! preemption are all inert.
//!
//! Two execution paths behind one loop:
//!
//! * **PJRT** — compiled `attn_*` artifacts; the kernels compute a
//!   fixed (H, N, d) problem, so up to H *single-head* requests are
//!   packed per launch (multi-head requests are rejected on this path —
//!   the compiled head dimension is the packing axis). Requests shorter
//!   than the kernel's capacity are zero-padded *at the tail*. Because
//!   MoBA routing only scores strictly-past blocks and the own block is
//!   causally masked, tail padding can never influence rows `< n` — the
//!   served output is exactly the n-length computation (asserted by
//!   integration tests). The compiled kernels are prefill-only, so
//!   `session_create` is rejected on this path.
//! * **CPU substrate** — when no artifacts (or no PJRT bindings) are
//!   available, requests dispatch through the
//!   [`crate::attention::backend::AttentionBackend`] registry: MoBA
//!   requests run FlashMoBA, anything the sparse backend's
//!   supported-config predicate rejects falls back to the exact dense
//!   backend. No padding; `served_n == n`; any head layout with
//!   `h % h_kv == 0` is served, ragged lengths included (the tail block
//!   is always-attended, never routed). Decode sessions live here: MoBA
//!   sessions route each query head over its KV head's cached block
//!   centroids under the serving [`RoutePlan`] — per-KV-head
//!   `(block, topk)` from a loaded plan file, or the uniform
//!   `ServeParams.moba_block` / `moba_topk` geometry — dense sessions
//!   use the exact fallback over the whole cache. MoBA prefills run
//!   the same plan (a request may carry its own override), and heads
//!   whose observed routing margin collapses below the configured
//!   threshold degrade to dense per request/step (counted by
//!   `Metrics::fallback_heads`).
//!
//! **Failure handling** (see `docs/ARCHITECTURE.md` for the full state
//! machine): every kernel launch runs under a `catch_unwind` barrier,
//! so a panicking kernel fails its own request with a typed
//! [`ServeError::KernelPanic`] instead of killing the worker. A decode
//! wave that panics is re-run one session at a time to attribute blame
//! — innocent wave-mates get exactly the bits they would have gotten
//! alone (the batching contract), while the faulty session is
//! *quarantined*: its cache is dropped (pages returned) but its id
//! keeps answering with [`ServeError::SessionPoisoned`] until freed.
//! Requests and decode steps may carry a deadline; expired work is shed
//! loudly ([`ServeError::DeadlineExceeded`]) at arrival, in the queue,
//! and at the execution gate. Transient admission denials retry with a
//! bounded deterministic backoff before parking, and a saturated pool
//! with nothing evictable either admits new sessions degraded to an i8
//! cache (`serve.degrade_under_pressure`) or rejects them with
//! [`ServeError::PoolSaturated`] — never a panic, never a silent hang.
//! Deterministic fault injection ([`crate::util::faults::FaultPlan`],
//! armed via `MOBA_FAULTS` or `ServeParams.fault_plan`) exercises all
//! of these paths; the `chaos-soak` bench pins that non-faulted
//! traffic stays bitwise identical under an armed plan.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{Batch, Batcher};
use super::error::ServeError;
use super::metrics::Metrics;
use super::request::{
    AttnKind, AttnRequest, AttnResponse, DecodeStep, QueueStamp, WorkItem,
};
use super::router::{effective_dtype, effective_plan, load_route_plan, Router};
use super::scheduler::PageScheduler;
#[allow(unused_imports)]
use crate::attention::backend::AttentionBackend;
use crate::attention::backend::BackendRegistry;
use crate::attention::decode::DecodeSession;
use crate::attention::paged::PagePool;
use crate::attention::plan::RoutePlan;
use crate::attention::{packed_rows, AttnShape, KvDtype};
use crate::config::ServeParams;
use crate::runtime::{Runtime, Tensor};
use crate::util::faults::{FaultPlan, FaultPoint};
use crate::util::pool::{partition, ExecCtx};
use crate::Result;

/// What the worker thread executes batches on.
enum Exec {
    /// Compiled PJRT artifacts (owned by the worker; not `Send`).
    Pjrt(Runtime),
    /// The pure-rust attention substrate behind the backend trait.
    Cpu(BackendRegistry),
}

/// Decode-session parameters fixed at creation time.
struct SessionSpec {
    kind: AttnKind,
    h: usize,
    h_kv: usize,
    d: usize,
}

enum Envelope {
    Req(AttnRequest, SyncSender<Result<AttnResponse>>),
    Decode(DecodeStep, SyncSender<Result<AttnResponse>>),
    SessionCreate(SessionSpec, SyncSender<Result<u64>>),
    /// open a copy-on-write fork of an existing session's cache
    SessionFork(u64, SyncSender<Result<u64>>),
    /// bulk-append a prompt's packed `(h_kv, n, d)` K/V to a session
    SessionPrefill {
        session: u64,
        n: usize,
        k: Vec<f32>,
        v: Vec<f32>,
        tx: SyncSender<Result<usize>>,
    },
    SessionFree(u64, SyncSender<Result<()>>),
    Shutdown,
}

/// Ids at and above this are allocated by the coordinator for decode
/// tickets; caller-chosen prefill request ids must stay below it so the
/// shared pending-response table can never route a decode row to a
/// prefill waiter (or vice versa).
pub const DECODE_ID_BASE: u64 = 1 << 62;

/// A pending response ticket.
pub struct Ticket(Receiver<Result<AttnResponse>>);

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<AttnResponse> {
        self.0.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }
}

/// A pending session-prefill ticket; resolves to the session's context
/// length after the append. Prefills go through the page-budget
/// admission path and may be parked behind preemptions, so callers
/// driving several sessions should collect tickets and join later
/// rather than block one at a time.
pub struct PrefillTicket(Receiver<Result<usize>>);

impl PrefillTicket {
    /// Block until the prefill has been admitted and executed.
    pub fn wait(self) -> Result<usize> {
        self.0.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }
}

/// In-process serving handle.
pub struct Coordinator {
    tx: SyncSender<Envelope>,
    metrics: Arc<Metrics>,
    /// ids for decode-step tickets; high range so they never collide
    /// with caller-chosen prefill request ids
    next_decode_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker thread. The PJRT client is not `Send` (the xla
    /// crate uses `Rc` internally), so the worker *constructs its own*
    /// [`Runtime`] from the artifacts directory and owns all PJRT state
    /// for its lifetime; startup errors are reported synchronously.
    ///
    /// When the runtime cannot load (no artifacts, or a build without
    /// PJRT bindings) the coordinator serves on the CPU attention
    /// substrate instead of failing. On that path the router's
    /// advertised head layout comes from `params.n_heads` /
    /// `params.n_kv_heads` — callers serving a specific manifest
    /// variant should build `params` with
    /// [`ServeParams::with_variant`](crate::config::ServeParams::with_variant)
    /// so the variant's head layout and MoBA geometry travel with it
    /// (the coordinator cannot do this itself: the substrate path is
    /// taken exactly when no manifest could be loaded).
    pub fn start(artifacts_dir: impl Into<PathBuf>, params: ServeParams) -> Result<Self> {
        let dir = artifacts_dir.into();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<Envelope>(params.queue_capacity.max(16));
        let (boot_tx, boot_rx) = sync_channel::<Result<()>>(1);
        let m2 = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("flash-moba-coordinator".into())
            .spawn(move || {
                // resolve the serving route plan (if configured) before
                // acking boot, so a bad plan file is a startup error
                let serve_plan = match load_route_plan(&params) {
                    Ok(p) => p,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                // resolve the fault plan (MOBA_FAULTS wins over the
                // config spec) before acking boot: an unparseable plan
                // is a loud startup error, never a silently-disarmed
                // chaos run
                let faults = match FaultPlan::resolve(params.fault_plan.as_deref()) {
                    Ok(f) => f,
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                let (exec, router) = match Runtime::load(&dir) {
                    Ok(rt) => match Router::from_manifest(rt.manifest()) {
                        Ok(r) => (Exec::Pjrt(rt), r),
                        Err(e) => {
                            let _ = boot_tx.send(Err(e));
                            return;
                        }
                    },
                    Err(e) => {
                        eprintln!(
                            "[coordinator] PJRT runtime unavailable ({e:#}); \
                             serving on the CPU attention substrate"
                        );
                        let registry = BackendRegistry::with_defaults();
                        match Router::from_backends(&registry, &params) {
                            Ok(r) => (Exec::Cpu(registry), r),
                            Err(e) => {
                                let _ = boot_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                let _ = boot_tx.send(Ok(()));
                worker_loop(exec, router, serve_plan, faults, params, rx, m2)
            })
            .map_err(|e| anyhow!("failed to spawn the coordinator worker thread: {e}"))?;
        boot_rx
            .recv()
            .map_err(|_| anyhow!("coordinator worker died during startup"))??;
        Ok(Self {
            tx,
            metrics,
            next_decode_id: AtomicU64::new(DECODE_ID_BASE),
            worker: Some(worker),
        })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Submit without blocking; returns a ticket to wait on. A request
    /// may carry an optional `deadline` ([`AttnRequest::deadline`]);
    /// work still queued past it is shed with a typed
    /// [`ServeError::DeadlineExceeded`] instead of executing late.
    pub fn submit_async(&self, req: AttnRequest) -> Result<Ticket> {
        if !req.payloads_finite() {
            return Err(ServeError::InvalidInput {
                id: req.id,
                what: "q/k/v contain non-finite (NaN/Inf) values".into(),
            }
            .into());
        }
        if !req.validate() {
            return Err(anyhow!("invalid request {}: shape mismatch", req.id));
        }
        if req.id >= DECODE_ID_BASE {
            return Err(anyhow!(
                "invalid request id {}: ids >= 2^62 are reserved for decode tickets",
                req.id
            ));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::Req(req, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(Ticket(orx))
    }

    /// Submit and block for the response.
    pub fn submit(&self, req: AttnRequest) -> Result<AttnResponse> {
        self.submit_async(req)?.wait()
    }

    /// Open a decode session with `h` query heads, `h_kv` KV heads and
    /// head dim `d`. MoBA sessions route under the serving plan — the
    /// loaded route-plan file when one is configured, otherwise the
    /// uniform `ServeParams` geometry (`moba_block` / `moba_topk`) —
    /// with the runtime margin fallback active when
    /// `ServeParams::fallback_margin` (or the plan) enables it; dense
    /// sessions decode exactly over the whole cache. Returns the
    /// session handle for [`Coordinator::decode`] / `session_free`.
    pub fn session_create(&self, kind: AttnKind, h: usize, h_kv: usize, d: usize) -> Result<u64> {
        if d == 0 {
            return Err(anyhow!("decode session needs d > 0"));
        }
        if h == 0 || h_kv == 0 || h % h_kv != 0 {
            return Err(anyhow!(
                "decode session needs h a positive multiple of h_kv (got h={h}, h_kv={h_kv})"
            ));
        }
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::SessionCreate(SessionSpec { kind, h, h_kv, d }, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        orx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }

    /// Submit one decode step without blocking: append the packed
    /// `(h_kv, d)` (k, v) rows to the session's cache, attend the
    /// packed `(h, d)` q over it — every head in one step. Steps for
    /// one session execute in submission order; the response's `o` is
    /// the packed `(h, d)` output row and `served_n` the session's
    /// context length after the append. Row widths are validated
    /// against the session's head layout in the worker.
    pub fn decode_async(
        &self,
        session: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<Ticket> {
        self.decode_deadline_async(session, q, k, v, None)
    }

    /// [`Coordinator::decode_async`] with an optional deadline: a step
    /// still queued (or parked behind admission) when `deadline`
    /// passes is shed with a typed [`ServeError::DeadlineExceeded`]
    /// *before* it appends to the session's cache — a shed step leaves
    /// the session exactly as if it was never submitted.
    pub fn decode_deadline_async(
        &self,
        session: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<Ticket> {
        let id = self.next_decode_id.fetch_add(1, Ordering::Relaxed);
        // table_pages and kv_dtype are stamped by the worker at enqueue
        // time — only it knows the session's current page-table size and
        // cache dtype
        let step =
            DecodeStep { id, session, q, k, v, table_pages: 0, kv_dtype: KvDtype::F32, deadline };
        if step.q.is_empty() || step.k.is_empty() || step.k.len() != step.v.len() {
            return Err(anyhow!(
                "decode step {id}: q and k must be non-empty and k/v equal-length"
            ));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::Decode(step, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(Ticket(orx))
    }

    /// Submit one decode step and block for the packed output row.
    pub fn decode(
        &self,
        session: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<AttnResponse> {
        self.decode_async(session, q, k, v)?.wait()
    }

    /// Open a new decode session sharing `session`'s cache pages
    /// copy-on-write: the fork costs zero new pages until one side
    /// appends past the shared prefix, at which point only the divergent
    /// tail page is copied. Forking a currently-preempted session is
    /// fine — the child inherits the swap log and restores independently
    /// on first touch. Returns the child's session handle; both sessions
    /// decode bit-identically to independent sessions fed the same
    /// histories.
    pub fn session_fork(&self, session: u64) -> Result<u64> {
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::SessionFork(session, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        orx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }

    /// Bulk-append a prompt's K/V to a session's cache without blocking:
    /// `k`/`v` are packed `(h_kv, n, d)` (the [`AttnRequest`] layout).
    /// Goes through the page-budget admission path — under page pressure
    /// the prefill may preempt colder sessions or be parked FIFO until
    /// pages free up. The ticket resolves to the session's context
    /// length after the append.
    pub fn session_prefill_async(
        &self,
        session: u64,
        n: usize,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<PrefillTicket> {
        if n == 0 || k.is_empty() || k.len() != v.len() {
            return Err(anyhow!(
                "session_prefill: n must be > 0 and k/v non-empty equal-length"
            ));
        }
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::SessionPrefill { session, n, k, v, tx: otx })
            .map_err(|_| anyhow!("coordinator is down"))?;
        Ok(PrefillTicket(orx))
    }

    /// [`Coordinator::session_prefill_async`], blocking for the result.
    pub fn session_prefill(
        &self,
        session: u64,
        n: usize,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<usize> {
        self.session_prefill_async(session, n, k, v)?.wait()
    }

    /// Drop a session's KV cache. Steps already queued for it will be
    /// answered with an error; wait for outstanding tickets first.
    pub fn session_free(&self, session: u64) -> Result<()> {
        let (otx, orx) = sync_channel(1);
        self.tx
            .send(Envelope::SessionFree(session, otx))
            .map_err(|_| anyhow!("coordinator is down"))?;
        orx.recv().map_err(|_| anyhow!("coordinator dropped the request"))?
    }

    /// Graceful shutdown: drains queued work.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.try_send(Envelope::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

type Pending = Vec<(u64, SyncSender<Result<AttnResponse>>)>;

/// Open decode sessions: handle -> (backend target, session state).
type Sessions = HashMap<u64, (String, DecodeSession)>;

/// Work waiting for page-budget admission, parked in arrival order.
enum SessionWork {
    Step(DecodeStep),
    Prefill {
        n: usize,
        k: Vec<f32>,
        v: Vec<f32>,
        tx: SyncSender<Result<usize>>,
    },
}

impl SessionWork {
    /// Tokens this work would append (the admission cost driver).
    fn tokens(&self) -> usize {
        match self {
            SessionWork::Step(_) => 1,
            SessionWork::Prefill { n, .. } => *n,
        }
    }
}

/// Per-session continuous-batching state, parallel to [`Sessions`] (kept
/// separate so decode waves can pull `DecodeSession`s out of the table
/// while this bookkeeping stays put).
#[derive(Default)]
struct SessState {
    /// decode steps currently in the batcher (the session is protected
    /// from preemption while > 0 — queued steps execute against the
    /// live cache)
    queued_steps: usize,
    /// preempted: cache evicted, pages returned, swap log pending replay
    evicted: bool,
    /// swap log — every executed append's packed `(h_kv, d)` rows in
    /// order (kept only under a finite page budget); replaying it
    /// rebuilds the cache bit for bit
    log_k: Vec<f32>,
    log_v: Vec<f32>,
    /// work parked behind admission, drained strictly in order
    parked: VecDeque<SessionWork>,
    /// injected-denial attempt ordinal for the admission FIFO head:
    /// each loop turn the head is denied bumps this, so
    /// [`FaultPlan::fires_attempt`]'s bound guarantees the park always
    /// clears; reset on successful admission
    deny_attempts: u32,
}

/// The worker's continuous-batching machinery: the shared page pool, the
/// LRU residency scheduler, per-session scheduling state, and the FIFO
/// of sessions with parked work awaiting admission.
struct PagingCtl {
    pool: PagePool,
    scheduler: PageScheduler,
    state: HashMap<u64, SessState>,
    admit_fifo: VecDeque<u64>,
    /// record swap logs (exactly when the budget is finite — an
    /// unbounded pool never evicts, so logging would be pure overhead)
    log_swaps: bool,
}

impl PagingCtl {
    fn new(params: &ServeParams, serve_plan: &Option<RoutePlan>) -> Self {
        // the page must hold the largest block any serving plan can ask
        // for; the configured page_tokens is a floor request on top
        let mut page_tokens = params.moba_block.max(1);
        if let Some(p) = serve_plan {
            for hp in &p.heads {
                page_tokens = page_tokens.max(hp.block);
            }
        }
        page_tokens = page_tokens.max(params.page_tokens);
        let budget = (params.max_pages > 0).then_some(params.max_pages);
        Self {
            pool: PagePool::new(page_tokens, budget),
            scheduler: PageScheduler::new(),
            state: HashMap::new(),
            admit_fifo: VecDeque::new(),
            log_swaps: budget.is_some(),
        }
    }

    /// Copy the pool counters into the served metrics (gauges).
    fn sync_metrics(&self, metrics: &Metrics) {
        let st = self.pool.stats();
        metrics.pages_allocated.store(st.allocated, Ordering::Relaxed);
        metrics.pages_live.store(st.live as u64, Ordering::Relaxed);
        metrics.cow_splits.store(st.cow_splits, Ordering::Relaxed);
        metrics.prefix_hits.store(st.prefix_shared, Ordering::Relaxed);
    }
}

/// Make room for `cost` budget units (1 unit = one byte per page
/// element; an f32 page costs 4, f16 2, i8 1 — see
/// [`PagePool::would_fit_units`]): preempt coldest-first victims until
/// the budget fits. Protected (never evicted): the session being admitted
/// and sessions with steps in the batcher (those steps execute against
/// the live cache). A session with *parked* work is fair game — its
/// restore cost is recomputed when its FIFO turn comes, so evicting it
/// is safe, and protecting it would deadlock two parked sessions
/// against each other. Returns false when every resident session is
/// protected and the budget still doesn't fit — the caller parks the
/// work instead of spinning. Terminates because each round removes one
/// scheduler entry.
fn try_admit(
    cost: usize,
    admitting: u64,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    metrics: &Metrics,
) -> bool {
    while !ctl.pool.would_fit_units(cost) {
        let victim = ctl.scheduler.victim(|vid| {
            vid == admitting
                || ctl.state.get(&vid).map_or(true, |st| st.queued_steps > 0)
        });
        let Some((vid, _)) = victim else {
            return false;
        };
        ctl.scheduler.remove(vid);
        if let Some((_, sess)) = sessions.get_mut(&vid) {
            sess.evict();
        }
        if let Some(st) = ctl.state.get_mut(&vid) {
            st.evicted = true;
        }
        metrics.preemptions.fetch_add(1, Ordering::Relaxed);
    }
    true
}

/// [`try_admit`] behind a bounded, deterministic retry loop. Injected
/// allocation denials ([`FaultPoint::AllocDeny`] — the transient
/// failure class) cost a retry with a short deterministic backoff
/// (the schedule depends only on the attempt index) before the work
/// parks; genuine budget exhaustion parks immediately — within one
/// loop turn nothing can free pages, so spinning on a real denial is
/// pure waste. Every retry is counted in `Metrics::retries`.
fn try_admit_with_retry(
    cost: usize,
    admitting: u64,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    metrics: &Metrics,
    faults: &FaultPlan,
    retries: usize,
) -> bool {
    for attempt in 0..=(retries as u32) {
        if attempt > 0 {
            metrics.retries.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_micros(20u64 << attempt.min(8)));
        }
        if faults.fires_attempt(FaultPoint::AllocDeny, admitting, attempt) {
            continue; // injected transient denial: costs one retry
        }
        return try_admit(cost, admitting, sessions, ctl, metrics);
    }
    false
}

/// Quarantine a session after a caught kernel panic: its cache is
/// already gone (dropped by the caller, pages returned to the pool),
/// its scheduler/admission bookkeeping is cleared, its parked work is
/// answered with typed errors, and its id is remembered so later
/// steps, forks and prefills get [`ServeError::SessionPoisoned`]
/// instead of a silent "unknown session". `session_free` clears the
/// quarantine record.
fn quarantine_session(
    sid: u64,
    detail: String,
    ctl: &mut PagingCtl,
    pending: &mut Pending,
    poisoned: &mut HashMap<u64, String>,
    metrics: &Metrics,
) {
    ctl.scheduler.remove(sid);
    ctl.admit_fifo.retain(|&s| s != sid);
    if let Some(mut st) = ctl.state.remove(&sid) {
        for work in st.parked.drain(..) {
            metrics.rejected.fetch_add(1, Ordering::Relaxed);
            match work {
                SessionWork::Step(s) => respond(
                    pending,
                    s.id,
                    Err(ServeError::SessionPoisoned { session: sid }.into()),
                ),
                SessionWork::Prefill { tx, .. } => {
                    let _ = tx.send(Err(ServeError::SessionPoisoned { session: sid }.into()));
                }
            }
        }
    }
    poisoned.insert(sid, detail);
    metrics.sessions_poisoned.fetch_add(1, Ordering::Relaxed);
}

/// The graceful-degradation gate for `session_create`: when the pool
/// is saturated and preemption has nothing left to take, a session
/// created at `want` could never append — park-forever disguised as
/// success. Admit it with an i8-degraded cache (1/4 the budget units
/// of f32) when `serve.degrade_under_pressure` allows and that
/// actually helps, otherwise reject with a typed
/// [`ServeError::PoolSaturated`]. With an unbounded pool, or whenever
/// a first append could be admitted normally, `want` passes through
/// untouched.
fn admit_dtype_under_pressure(
    want: KvDtype,
    would_be: u64,
    ctl: &PagingCtl,
    params: &ServeParams,
    metrics: &Metrics,
) -> Result<KvDtype> {
    if ctl.pool.max_pages().is_none() || ctl.pool.would_fit_units(PagePool::units_for(1, want)) {
        return Ok(want);
    }
    let evictable = ctl
        .scheduler
        .has_evictable(|vid| ctl.state.get(&vid).map_or(true, |st| st.queued_steps > 0));
    if evictable {
        return Ok(want); // admission can preempt its way to pages
    }
    let degraded = KvDtype::I8;
    if params.degrade_under_pressure
        && ctl.pool.would_fit_units(PagePool::units_for(1, degraded))
    {
        Ok(degraded)
    } else {
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        Err(ServeError::PoolSaturated { session: would_be }.into())
    }
}

/// Shed expired parked *steps* loudly (parked prefills carry no
/// deadline). The batcher's own queues are shed by
/// [`Batcher::shed_expired`]; this is its mirror for work waiting on
/// page-budget admission. A shed step never appended, so the session
/// is exactly as if the step was never submitted.
fn shed_expired_parked(
    ctl: &mut PagingCtl,
    pending: &mut Pending,
    metrics: &Metrics,
    now: Instant,
) {
    for st in ctl.state.values_mut() {
        let expired =
            |w: &SessionWork| matches!(w, SessionWork::Step(s) if s.deadline.is_some_and(|dl| now >= dl));
        if !st.parked.iter().any(expired) {
            continue;
        }
        let kept = std::mem::take(&mut st.parked);
        for work in kept {
            if let SessionWork::Step(s) = &work {
                if s.deadline.is_some_and(|dl| now >= dl) {
                    metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                    respond(pending, s.id, Err(ServeError::DeadlineExceeded { id: s.id }.into()));
                    continue;
                }
            }
            st.parked.push_back(work);
        }
    }
}

/// The earliest deadline across parked decode steps, if any. Parked
/// work is shed by [`shed_expired_parked`] on loop turns, so the
/// worker's idle wait must not outlive the nearest parked deadline —
/// with no traffic in flight there is no envelope to wake it.
fn earliest_parked_deadline(ctl: &PagingCtl) -> Option<Instant> {
    ctl.state
        .values()
        .flat_map(|st| st.parked.iter())
        .filter_map(|w| match w {
            SessionWork::Step(s) => s.deadline,
            SessionWork::Prefill { .. } => None,
        })
        .min()
}

/// Park work for `sid` behind admission, keeping strict arrival order.
fn park_work(ctl: &mut PagingCtl, sid: u64, work: SessionWork, metrics: &Metrics) {
    ctl.state.entry(sid).or_default().parked.push_back(work);
    if !ctl.admit_fifo.contains(&sid) {
        ctl.admit_fifo.push_back(sid);
        metrics.admits_deferred.fetch_add(1, Ordering::Relaxed);
    }
}

/// Stamp an admitted step's page-table size and cache dtype, then hand
/// it to the batcher's decode lane. The stamps are what make queue
/// payload accounting layout- and dtype-aware
/// ([`DecodeStep::payload_bytes`]).
fn enqueue_step(
    mut step: DecodeStep,
    sessions: &Sessions,
    ctl: &mut PagingCtl,
    batcher: &mut Batcher,
    pending: &mut Pending,
    metrics: &Metrics,
) {
    let sid = step.session;
    let id = step.id;
    let Some((target, sess)) = sessions.get(&sid) else {
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        respond(pending, id, Err(anyhow!("decode session {sid} was freed")));
        return;
    };
    step.table_pages = sess.total_pages();
    step.kv_dtype = sess.dtype();
    let lane = format!("decode:{target}");
    if batcher.push(step, &lane, 1, Instant::now()).is_err() {
        metrics.rejected.fetch_add(1, Ordering::Relaxed);
        respond(pending, id, Err(ServeError::QueueFull { id }.into()));
        return;
    }
    ctl.state.entry(sid).or_default().queued_steps += 1;
    ctl.scheduler.touch(sid);
}

/// Route a validated decode step through admission: park it if the
/// session is preempted or already has parked work (order!), otherwise
/// make room for its append (retrying transient denials with a
/// bounded deterministic backoff) and enqueue it.
#[allow(clippy::too_many_arguments)]
fn admit_step(
    step: DecodeStep,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    batcher: &mut Batcher,
    pending: &mut Pending,
    metrics: &Metrics,
    faults: &FaultPlan,
    retries: usize,
) {
    let sid = step.session;
    let blocked = ctl
        .state
        .get(&sid)
        .is_some_and(|st| st.evicted || !st.parked.is_empty());
    let cost = sessions
        .get(&sid)
        .map_or(0, |(_, sess)| sess.cache().append_page_cost_units(1));
    if blocked || !try_admit_with_retry(cost, sid, sessions, ctl, metrics, faults, retries) {
        park_work(ctl, sid, SessionWork::Step(step), metrics);
        return;
    }
    enqueue_step(step, sessions, ctl, batcher, pending, metrics);
}

/// Append a prompt's packed `(h_kv, n, d)` K/V to an admitted session,
/// token by token (identical arithmetic to decoding the same tokens one
/// step at a time), recording the swap log when enabled. Returns the
/// context length after the append.
fn execute_prefill(
    sess: &mut DecodeSession,
    st: &mut SessState,
    log: bool,
    n: usize,
    k: &[f32],
    v: &[f32],
) -> usize {
    let (h_kv, d) = (sess.h_kv(), sess.d());
    for t in 0..n {
        let kt = packed_rows(k, h_kv, n, d, t);
        let vt = packed_rows(v, h_kv, n, d, t);
        sess.append(&kt, &vt);
        if log {
            st.log_k.extend_from_slice(&kt);
            st.log_v.extend_from_slice(&vt);
        }
    }
    sess.len()
}

/// Replay an evicted session's swap log, rebuilding its cache bit for
/// bit (pages re-allocated, kconv streams re-driven).
fn restore_session(sess: &mut DecodeSession, st: &mut SessState, metrics: &Metrics) {
    let roww = sess.h_kv() * sess.d();
    let tokens = st.log_k.len() / roww.max(1);
    for t in 0..tokens {
        sess.append(&st.log_k[t * roww..(t + 1) * roww], &st.log_v[t * roww..(t + 1) * roww]);
    }
    st.evicted = false;
    metrics.restores.fetch_add(1, Ordering::Relaxed);
}

/// Retry the parked-work FIFO, strictly head-only: the head session is
/// restored (swap-log replay) and its parked work released in order; if
/// its cost still doesn't fit after preempting every evictable victim,
/// the whole queue waits (no smaller request ever jumps the line).
/// Called after every loop turn — any state change that could unblock
/// admission (an executed batch, a freed session, an arriving message)
/// happens within a turn, so no wake-up is ever missed.
fn drain_admissions(
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    batcher: &mut Batcher,
    pending: &mut Pending,
    metrics: &Metrics,
    faults: &FaultPlan,
) {
    while let Some(&sid) = ctl.admit_fifo.front() {
        if !sessions.contains_key(&sid) {
            ctl.admit_fifo.pop_front(); // freed while parked
            continue;
        }
        // cost of everything the session needs: the swap-log replay (if
        // preempted) plus every parked append. `footprint` is the
        // session's total page need — resident pages included — the
        // can-this-ever-fit bound even with every other session evicted
        // costs are in budget *units* (pages × the session's per-element
        // byte width), so an f16 session's replay charges half an f32's
        let (cost, footprint, evicted) = {
            let (_, sess) = sessions.get(&sid).expect("checked above");
            let st = ctl.state.entry(sid).or_default();
            let parked_tokens: usize = st.parked.iter().map(|w| w.tokens()).sum();
            let roww = (sess.h_kv() * sess.d()).max(1);
            let dtype = sess.dtype();
            if st.evicted {
                let log_tokens = st.log_k.len() / roww;
                let need = sess.cache().pages_for(log_tokens + parked_tokens);
                let units = PagePool::units_for(need, dtype);
                (units, units, true)
            } else {
                let need = sess.cache().append_page_cost_units(parked_tokens);
                (need, PagePool::units_for(sess.total_pages(), dtype) + need, false)
            }
        };
        if let Some(m) = ctl.pool.max_pages() {
            let budget = PagePool::units_for(m, KvDtype::F32);
            if footprint > budget {
                // can never fit, not even with every other session
                // evicted: fail the parked work loudly instead of
                // livelocking the queue (a live session holding the
                // whole budget is its own unevictable blocker)
                let st = ctl.state.entry(sid).or_default();
                for work in st.parked.drain(..) {
                    let err = || -> anyhow::Error {
                        ServeError::AdmissionImpossible {
                            session: sid,
                            needed: footprint,
                            budget,
                        }
                        .into()
                    };
                    match work {
                        SessionWork::Step(s) => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            respond(pending, s.id, Err(err()));
                        }
                        SessionWork::Prefill { tx, .. } => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            let _ = tx.send(Err(err()));
                        }
                    }
                }
                ctl.admit_fifo.pop_front();
                continue;
            }
        }
        // injected allocation denial against the FIFO head: count a
        // retry and leave the head parked — the next loop turn retries
        // with a bumped attempt ordinal (a backoff paced by the loop
        // itself), and fires_attempt's bound guarantees it clears
        {
            let st = ctl.state.entry(sid).or_default();
            if faults.fires_attempt(FaultPoint::AllocDeny, sid, st.deny_attempts) {
                st.deny_attempts += 1;
                metrics.retries.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        if !try_admit(cost, sid, sessions, ctl, metrics) {
            break; // strict FIFO: the head blocks until pages free up
        }
        ctl.state.entry(sid).or_default().deny_attempts = 0;
        if evicted {
            let (_, sess) = sessions.get_mut(&sid).expect("checked above");
            let st = ctl.state.get_mut(&sid).expect("entry ensured above");
            restore_session(sess, st, metrics);
        }
        // release parked work in arrival order; a prefill queued behind
        // steps waits for those steps to execute first (they append to
        // the cache ahead of it)
        loop {
            enum Next {
                Step,
                PrefillReady,
                Blocked,
                Empty,
            }
            let next = {
                let st = ctl.state.get(&sid).expect("entry ensured above");
                match st.parked.front() {
                    None => Next::Empty,
                    Some(SessionWork::Step(_)) => Next::Step,
                    Some(SessionWork::Prefill { .. }) if st.queued_steps == 0 => {
                        Next::PrefillReady
                    }
                    Some(SessionWork::Prefill { .. }) => Next::Blocked,
                }
            };
            match next {
                Next::Empty | Next::Blocked => break,
                Next::Step => {
                    let Some(SessionWork::Step(step)) =
                        ctl.state.get_mut(&sid).expect("entry ensured above").parked.pop_front()
                    else {
                        unreachable!("peeked a step")
                    };
                    enqueue_step(step, sessions, ctl, batcher, pending, metrics);
                }
                Next::PrefillReady => {
                    let Some(SessionWork::Prefill { n, k, v, tx }) =
                        ctl.state.get_mut(&sid).expect("entry ensured above").parked.pop_front()
                    else {
                        unreachable!("peeked a prefill")
                    };
                    let log = ctl.log_swaps;
                    let (_, sess) = sessions.get_mut(&sid).expect("checked above");
                    let st = ctl.state.get_mut(&sid).expect("entry ensured above");
                    let len = execute_prefill(sess, st, log, n, &k, &v);
                    let _ = tx.send(Ok(len));
                }
            }
        }
        let (_, sess) = sessions.get(&sid).expect("checked above");
        ctl.scheduler.note_resident(sid, sess.total_pages());
        let st = ctl.state.get(&sid).expect("entry ensured above");
        if st.parked.is_empty() {
            ctl.admit_fifo.pop_front();
        } else {
            break; // prefill still blocked behind queued steps
        }
    }
}

fn worker_loop(
    exec: Exec,
    router: Router,
    serve_plan: Option<RoutePlan>,
    faults: FaultPlan,
    params: ServeParams,
    rx: Receiver<Envelope>,
    metrics: Arc<Metrics>,
) {
    let max_wait = Duration::from_millis(params.max_wait_ms);
    // batching: bounded by max_batch, and on the PJRT path additionally
    // by the compiled kernels' head-packing capacity
    let mut batcher =
        Batcher::new(params.max_batch.min(router.pack_limit()).max(1), max_wait, params.queue_capacity);
    let mut pending: Pending = Vec::new();
    let mut sessions: Sessions = HashMap::new();
    // quarantined sessions: id -> the caught panic detail. A poisoned
    // session's cache is gone (pages returned) but its id answers
    // every subsequent step/fork/prefill with a typed
    // `SessionPoisoned` until `session_free` clears the record — a
    // crashed session must fail loudly, never vanish
    let mut poisoned: HashMap<u64, String> = HashMap::new();
    let mut next_session: u64 = 1;
    // the paged-KV machinery: shared pool, LRU residency, parked work
    let mut ctl = PagingCtl::new(&params, &serve_plan);
    // one worker pool for the whole serving path (MOBA_THREADS budget):
    // single-item batches parallelize inside the kernel, multi-item
    // batches fan items across it — bit-identical either way
    let ctx = ExecCtx::from_env();
    // one long-lived serial context per fan-out lane: a fanned-out
    // prefill item runs the serial kernel path against its lane's
    // scratch arenas, which persist across batches — so the fan-out
    // path reaches the same steady-state allocation-free behavior as
    // the single-item path (fresh per-batch contexts would re-warm
    // every buffer every batch and contend on one slot)
    let serial_lanes: Vec<ExecCtx> = (0..ctx.threads()).map(|_| ExecCtx::serial()).collect();

    loop {
        // wait for work or the earliest wake-up: a batch flush
        // deadline, an expired parked-step deadline (sheds happen on
        // loop turns), or — with an alloc_deny fault armed — the paced
        // retry of an injected-denied admission head. The last two
        // clear on loop *turns*, never on envelopes, so blocking
        // forever on `recv` would strand parked work (and deadlock a
        // client waiting on its ticket).
        let mut wake = batcher.next_deadline();
        if let Some(dl) = earliest_parked_deadline(&ctl) {
            wake = Some(wake.map_or(dl, |w| w.min(dl)));
        }
        if !ctl.admit_fifo.is_empty() && faults.armed(FaultPoint::AllocDeny) {
            let pace = Instant::now() + Duration::from_millis(1);
            wake = Some(wake.map_or(pace, |w| w.min(pace)));
        }
        let msg = match wake {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break, // all senders gone
            },
            Some(dl) => {
                let now = Instant::now();
                if dl <= now {
                    None // deadline passed: flush first
                } else {
                    match rx.recv_timeout(dl - now) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
            }
        };

        let mut shutdown = false;
        match msg {
            Some(Envelope::Req(req, otx)) => {
                // dead on arrival: shed rather than burn a launch on
                // an answer nobody is waiting for
                if req.deadline.is_some_and(|dl| Instant::now() >= dl) {
                    metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(ServeError::DeadlineExceeded { id: req.id }.into()));
                }
                // PJRT kernels compute a fixed (H, N, d): the head
                // dimension is the request-packing axis, so only
                // single-head requests with the kernel head dim are
                // accepted there. (The CPU substrate serves any layout.)
                else if !router.cpu_substrate && (req.h != 1 || req.h_kv != 1) {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(anyhow!(
                        "request {} has h={} h_kv={}: the compiled kernels pack \
                         single-head requests along their head dimension",
                        req.id,
                        req.h,
                        req.h_kv
                    )));
                } else if !router.cpu_substrate && req.d != router.head_dim {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(anyhow!(
                        "request {} has d={}, serving kernels compute d={}",
                        req.id,
                        req.d,
                        router.head_dim
                    )));
                } else {
                    match router.route(req.kind, req.n) {
                        Ok((cap, artifact)) => {
                            let artifact = artifact.to_string();
                            pending.push((req.id, otx));
                            if let Err(rej) = batcher.push(req, &artifact, cap, Instant::now()) {
                                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                let id = rej.id();
                                respond(&mut pending, id, Err(ServeError::QueueFull { id }.into()));
                            }
                        }
                        Err(e) => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            let _ = otx.send(Err(e));
                        }
                    }
                }
            }
            Some(Envelope::Decode(mut step, otx)) => {
                let sid = step.session;
                // deterministic corrupted-input injection: poison one
                // K element *before* validation, so the corruption is
                // caught by the same finite check that guards real
                // traffic (never by the kernel)
                if faults.fires(FaultPoint::CorruptInput, sid) {
                    if let Some(x) = step.k.first_mut() {
                        *x = f32::NAN;
                    }
                }
                if step.deadline.is_some_and(|dl| Instant::now() >= dl) {
                    metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(ServeError::DeadlineExceeded { id: step.id }.into()));
                } else if poisoned.contains_key(&sid) {
                    metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = otx.send(Err(ServeError::SessionPoisoned { session: sid }.into()));
                } else {
                    match sessions.get(&sid) {
                        None => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            let _ =
                                otx.send(Err(ServeError::SessionUnknown { session: sid }.into()));
                        }
                        Some(_) if !step.payloads_finite() => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            let _ = otx.send(Err(ServeError::InvalidInput {
                                id: step.id,
                                what: "decode step q/k/v contain non-finite (NaN/Inf) values"
                                    .into(),
                            }
                            .into()));
                        }
                        Some((_, sess)) if !step.validate(sess.h(), sess.h_kv(), sess.d()) => {
                            metrics.rejected.fetch_add(1, Ordering::Relaxed);
                            let _ = otx.send(Err(anyhow!(
                                "decode step {}: rows must match the session head layout \
                                 h={} h_kv={} d={}",
                                step.id,
                                sess.h(),
                                sess.h_kv(),
                                sess.d()
                            )));
                        }
                        Some(_) => {
                            // through the page-budget admission path:
                            // the step lands in its target's decode
                            // lane (one lane per backend: steps batch
                            // with each other, never with prefill)
                            // unless admission parks it first
                            pending.push((step.id, otx));
                            admit_step(
                                step,
                                &mut sessions,
                                &mut ctl,
                                &mut batcher,
                                &mut pending,
                                &metrics,
                                &faults,
                                params.admit_retries,
                            );
                        }
                    }
                }
            }
            Some(Envelope::SessionCreate(spec, otx)) => {
                let result = match &exec {
                    Exec::Pjrt(_) => Err(anyhow!(
                        "decode sessions need the CPU substrate: the compiled \
                         PJRT kernels are prefill-only"
                    )),
                    Exec::Cpu(_) => match router.route(spec.kind, 1) {
                        Err(e) => Err(e),
                        Ok((_, target)) => {
                            let sess = match spec.kind {
                                // MoBA sessions decode under the serving
                                // route plan: per-KV-head (block, topk),
                                // planned-dense heads, and the runtime
                                // margin fallback all apply per step
                                AttnKind::Moba => {
                                    let plan = effective_plan(&serve_plan, &params, spec.h_kv);
                                    // the session starts empty — n = 0
                                    // means "length unknown", so only
                                    // structurally degenerate plans are
                                    // rejected here (block = 0, routed
                                    // topk = 0, no heads)
                                    if let Err(e) = plan.validate(0) {
                                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                        Err(anyhow!(
                                            "session_create: serving route plan is invalid: {e}"
                                        ))
                                    } else {
                                        // page_tokens was derived to
                                        // cover every serving block, so
                                        // this can never trip the
                                        // pool's block-size assert.
                                        // dtype precedence: plan file >
                                        // MOBA_KV_DTYPE env > serve
                                        // config > f32, then through
                                        // the saturation gate (degrade
                                        // to i8 or reject typed)
                                        admit_dtype_under_pressure(
                                            effective_dtype(plan.kv_dtype, &params),
                                            next_session,
                                            &ctl,
                                            &params,
                                            &metrics,
                                        )
                                        .map(|dtype| {
                                            DecodeSession::with_plan_paged(
                                                spec.h, spec.h_kv, spec.d, plan, &ctl.pool,
                                            )
                                            .with_dtype(dtype)
                                        })
                                    }
                                }
                                // dense decode ignores routing; the block
                                // size only shapes cache bookkeeping
                                AttnKind::Dense => admit_dtype_under_pressure(
                                    effective_dtype(None, &params),
                                    next_session,
                                    &ctl,
                                    &params,
                                    &metrics,
                                )
                                .map(|dtype| {
                                    DecodeSession::new_paged(
                                        spec.h,
                                        spec.h_kv,
                                        spec.d,
                                        params.moba_block.max(1),
                                        0,
                                        &ctl.pool,
                                    )
                                    .with_dtype(dtype)
                                }),
                            };
                            sess.map(|sess| {
                                let id = next_session;
                                next_session += 1;
                                sessions.insert(id, (target.to_string(), sess));
                                ctl.state.insert(id, SessState::default());
                                ctl.scheduler.note_resident(id, 0);
                                metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
                                id
                            })
                        }
                    },
                };
                let _ = otx.send(result);
            }
            Some(Envelope::SessionFork(parent, otx)) => {
                let result = match sessions.get(&parent) {
                    None if poisoned.contains_key(&parent) => {
                        // a quarantined cache is gone: forking it
                        // would silently resurrect lost state
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        Err(ServeError::SessionPoisoned { session: parent }.into())
                    }
                    None => {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        Err(ServeError::SessionUnknown { session: parent }.into())
                    }
                    Some((target, sess)) => {
                        // the child is a point-in-time CoW share of the
                        // parent's *executed* state (steps still queued
                        // for the parent are not part of the prefix);
                        // it inherits the swap log so a preempted
                        // lineage restores independently
                        let child = sess.fork();
                        let target = target.clone();
                        let pages = child.total_pages();
                        let (log_k, log_v, evicted) = match ctl.state.get(&parent) {
                            Some(st) => (st.log_k.clone(), st.log_v.clone(), st.evicted),
                            None => (Vec::new(), Vec::new(), false),
                        };
                        let id = next_session;
                        next_session += 1;
                        sessions.insert(id, (target, child));
                        ctl.state.insert(
                            id,
                            SessState { evicted, log_k, log_v, ..Default::default() },
                        );
                        if !evicted {
                            ctl.scheduler.note_resident(id, pages);
                        }
                        metrics.sessions_created.fetch_add(1, Ordering::Relaxed);
                        Ok(id)
                    }
                };
                let _ = otx.send(result);
            }
            Some(Envelope::SessionPrefill { session, n, k, v, tx }) => {
                // phase 1 — validate and cost under a shared borrow
                let decision = match sessions.get(&session) {
                    None if poisoned.contains_key(&session) => {
                        Err(ServeError::SessionPoisoned { session }.into())
                    }
                    None => Err(ServeError::SessionUnknown { session }.into()),
                    Some((_, sess)) => {
                        let roww = sess.h_kv() * sess.d();
                        if k.len() != n * roww {
                            Err(anyhow!(
                                "session_prefill: k/v must be packed (h_kv={}, n={n}, d={}) \
                                 = {} floats, got {}",
                                sess.h_kv(),
                                sess.d(),
                                n * roww,
                                k.len()
                            ))
                        } else if !(k.iter().all(|x| x.is_finite())
                            && v.iter().all(|x| x.is_finite()))
                        {
                            // reject before any token lands: a NaN/Inf
                            // row would poison the quantization scale
                            // and every subsequent attend
                            Err(ServeError::InvalidInput {
                                id: session,
                                what: "session_prefill k/v contain non-finite (NaN/Inf) values"
                                    .into(),
                            }
                            .into())
                        } else {
                            Ok(sess.cache().append_page_cost_units(n))
                        }
                    }
                };
                // phase 2 — admit, park, or reject
                match decision {
                    Err(e) => {
                        metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = tx.send(Err(e));
                    }
                    Ok(cost) => {
                        // parked when the session is preempted, already
                        // has parked work, or has steps in the batcher
                        // (the prefill must append *after* them)
                        let blocked = ctl.state.get(&session).is_some_and(|st| {
                            st.evicted || !st.parked.is_empty() || st.queued_steps > 0
                        });
                        if blocked
                            || !try_admit_with_retry(
                                cost,
                                session,
                                &mut sessions,
                                &mut ctl,
                                &metrics,
                                &faults,
                                params.admit_retries,
                            )
                        {
                            park_work(
                                &mut ctl,
                                session,
                                SessionWork::Prefill { n, k, v, tx },
                                &metrics,
                            );
                        } else {
                            let log = ctl.log_swaps;
                            let (_, sess) = sessions.get_mut(&session).expect("checked above");
                            let st = ctl.state.entry(session).or_default();
                            let len = execute_prefill(sess, st, log, n, &k, &v);
                            ctl.scheduler.note_resident(session, sess.total_pages());
                            let _ = tx.send(Ok(len));
                        }
                    }
                }
            }
            Some(Envelope::SessionFree(id, otx)) => {
                let result = match sessions.remove(&id) {
                    Some(_) => {
                        // pages return to the pool when the removed
                        // cache drops (unless a fork still shares them);
                        // parked work is answered with an error, queued
                        // steps fail at execution ("freed mid-queue")
                        ctl.scheduler.remove(id);
                        ctl.admit_fifo.retain(|&s| s != id);
                        if let Some(mut st) = ctl.state.remove(&id) {
                            for work in st.parked.drain(..) {
                                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                match work {
                                    SessionWork::Step(s) => respond(
                                        &mut pending,
                                        s.id,
                                        Err(anyhow!("decode session {id} was freed")),
                                    ),
                                    SessionWork::Prefill { tx, .. } => {
                                        let _ = tx
                                            .send(Err(anyhow!("decode session {id} was freed")));
                                    }
                                }
                            }
                        }
                        metrics.sessions_freed.fetch_add(1, Ordering::Relaxed);
                        Ok(())
                    }
                    // freeing a quarantined session clears the record:
                    // the id stops answering (it is truly gone now,
                    // by explicit client request)
                    None if poisoned.remove(&id).is_some() => {
                        metrics.sessions_freed.fetch_add(1, Ordering::Relaxed);
                        Ok(())
                    }
                    None => Err(ServeError::SessionUnknown { session: id }.into()),
                };
                let _ = otx.send(result);
            }
            Some(Envelope::Shutdown) => shutdown = true,
            None => {} // deadline wake-up
        }

        // deadline shedding, every loop turn: expired queued work
        // leaves loudly before batch assembly, expired parked steps
        // before their admission retry (work already inside a flushed
        // batch is shed at the execution gate instead)
        let now = Instant::now();
        for (item, _) in batcher.shed_expired(now) {
            metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
            if let WorkItem::Decode(step) = &item {
                // the shed step never executes, so its preemption
                // protection ends here
                if let Some(st) = ctl.state.get_mut(&step.session) {
                    st.queued_steps = st.queued_steps.saturating_sub(1);
                }
            }
            let id = item.id();
            respond(&mut pending, id, Err(ServeError::DeadlineExceeded { id }.into()));
        }
        shed_expired_parked(&mut ctl, &mut pending, &metrics, now);

        // execute everything ready (all lanes on shutdown)
        let batches: Vec<Batch> = if shutdown {
            batcher.flush_all()
        } else {
            std::iter::from_fn(|| batcher.poll(now)).collect()
        };
        for batch in batches {
            run_batch(
                &exec,
                &router,
                &serve_plan,
                &params,
                &ctx,
                &serial_lanes,
                batch,
                &mut pending,
                &mut sessions,
                &mut ctl,
                &mut poisoned,
                &faults,
                &metrics,
            );
        }
        // retry parked admissions (executed batches may have freed
        // pages or drained queued steps) and publish the pool gauges —
        // every state change that can unblock admission happens inside
        // a loop turn, so running this here can never miss a wake-up
        drain_admissions(&mut sessions, &mut ctl, &mut batcher, &mut pending, &metrics, &faults);
        ctl.sync_metrics(&metrics);
        if shutdown {
            // parked prefills carry their own reply channel; parked
            // steps have tickets in `pending` and fail with it below
            for st in ctl.state.values_mut() {
                for work in st.parked.drain(..) {
                    if let SessionWork::Prefill { tx, .. } = work {
                        let _ = tx.send(Err(ServeError::Shutdown.into()));
                    }
                }
            }
            for (_, otx) in pending.drain(..) {
                let _ = otx.send(Err(ServeError::Shutdown.into()));
            }
            break;
        }
    }
}

fn respond(pending: &mut Pending, id: u64, result: Result<AttnResponse>) {
    if let Some(pos) = pending.iter().position(|(pid, _)| *pid == id) {
        let (_, otx) = pending.swap_remove(pos);
        let _ = otx.send(result);
    }
}

/// Dispatch a ready batch to the active execution path.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    exec: &Exec,
    router: &Router,
    serve_plan: &Option<RoutePlan>,
    params: &ServeParams,
    ctx: &ExecCtx,
    serial_lanes: &[ExecCtx],
    batch: Batch,
    pending: &mut Pending,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    poisoned: &mut HashMap<u64, String>,
    faults: &FaultPlan,
    metrics: &Metrics,
) {
    match exec {
        Exec::Pjrt(runtime) => run_batch_pjrt(runtime, router, batch, pending, metrics),
        Exec::Cpu(registry) => run_batch_cpu(
            registry, serve_plan, params, ctx, serial_lanes, batch, pending, sessions, ctl,
            poisoned, faults, metrics,
        ),
    }
}

/// Execute a batch on the CPU attention substrate: prefill requests run
/// at their native length and head layout through the
/// [`BackendRegistry`] (no padding, no head loop — one launch per
/// request covers all heads), decode steps append to their session's
/// cache and attend over it — so batching amortizes queueing rather
/// than kernel launches.
///
/// Prefill items fan out across the worker pool (each item on one
/// worker, running the serial kernel path against that fan-out lane's
/// *persistent* serial context — its scratch arenas outlive the batch,
/// so steady traffic reuses every kernel buffer) instead of queueing
/// behind one another; a batch of one parallelizes *inside* the
/// kernel. Both paths produce bit-identical outputs (the pool's
/// determinism contract), so batching never changes what a request
/// computes. Decode steps execute as batched cross-session launches
/// ([`run_cpu_decode_batch`]): a flushed decode lane becomes one
/// `forward_decode_batch` call per wave of distinct sessions instead
/// of B sequential steps — bit-identical to the sequential loop, FIFO
/// preserved within a session.
#[allow(clippy::too_many_arguments)]
fn run_batch_cpu(
    registry: &BackendRegistry,
    serve_plan: &Option<RoutePlan>,
    params: &ServeParams,
    ctx: &ExecCtx,
    serial_lanes: &[ExecCtx],
    batch: Batch,
    pending: &mut Pending,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    poisoned: &mut HashMap<u64, String>,
    faults: &FaultPlan,
    metrics: &Metrics,
) {
    let occupancy = batch.items.len();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);

    // phase 1: compute all prefill outputs (item-level fan-out when the
    // batch has several; intra-kernel parallelism when it has one)
    let prefills: Vec<&AttnRequest> = batch
        .items
        .iter()
        .filter_map(|(item, _)| match item {
            WorkItem::Prefill(req) => Some(req),
            WorkItem::Decode(_) => None,
        })
        .collect();
    let use_fanout = prefills.len() > 1 && ctx.threads() > 1 && !serial_lanes.is_empty();
    type PrefillOut = Result<(Vec<f32>, u32)>;
    let prefill_results: Vec<PrefillOut> = if use_fanout {
        // range i always runs on lane i: each lane is owned by at most
        // one task at a time, so its arena slot is never contended
        let prefills_ref = &prefills;
        let artifact = &batch.artifact;
        let tasks: Vec<Box<dyn FnOnce() -> Vec<PrefillOut> + Send + '_>> =
            partition(prefills.len(), serial_lanes.len().min(ctx.threads()))
                .into_iter()
                .enumerate()
                .map(|(i, range)| {
                    let lane = &serial_lanes[i];
                    Box::new(move || {
                        range
                            .map(|j| {
                                run_cpu_request_isolated(
                                    registry,
                                    serve_plan,
                                    params,
                                    lane,
                                    artifact,
                                    prefills_ref[j],
                                    faults,
                                    metrics,
                                )
                            })
                            .collect::<Vec<_>>()
                    }) as Box<dyn FnOnce() -> Vec<PrefillOut> + Send + '_>
                })
                .collect();
        ctx.pool().run_tasks(tasks).into_iter().flatten().collect()
    } else {
        prefills
            .iter()
            .map(|&req| {
                run_cpu_request_isolated(
                    registry,
                    serve_plan,
                    params,
                    ctx,
                    &batch.artifact,
                    req,
                    faults,
                    metrics,
                )
            })
            .collect()
    };

    // phase 1.5: decode steps run as batched cross-session launches
    // against the worker-owned session table (one kernel call per wave
    // of distinct sessions, not one per step)
    let decode_steps: Vec<&DecodeStep> = batch
        .items
        .iter()
        .filter_map(|(item, _)| match item {
            WorkItem::Decode(step) => Some(step),
            WorkItem::Prefill(_) => None,
        })
        .collect();
    let decode_results = run_cpu_decode_batch(
        registry,
        ctx,
        sessions,
        ctl,
        poisoned,
        pending,
        &decode_steps,
        faults,
        metrics,
    );

    // phase 2: respond in item order
    let mut prefill_iter = prefill_results.into_iter();
    let mut decode_iter = decode_results.into_iter();
    for (item, enq) in &batch.items {
        match item {
            WorkItem::Prefill(req) => {
                let result = prefill_iter.next().expect("one result per prefill item");
                let executed = Instant::now();
                match result {
                    Ok((o, fallback_heads)) => {
                        let stamp = QueueStamp { enqueued: *enq, executed };
                        metrics.record_latency(stamp.queue_latency_s());
                        metrics.responses.fetch_add(1, Ordering::Relaxed);
                        metrics
                            .fallback_heads
                            .fetch_add(fallback_heads as u64, Ordering::Relaxed);
                        respond(
                            pending,
                            req.id,
                            Ok(AttnResponse {
                                id: req.id,
                                o,
                                served_n: req.n,
                                batch_occupancy: occupancy,
                                queued_at: Some(stamp),
                            }),
                        );
                    }
                    Err(e) => respond(pending, req.id, Err(e)),
                }
            }
            WorkItem::Decode(step) => {
                let result = decode_iter.next().expect("one result per decode item");
                let executed = Instant::now();
                match result {
                    Ok((o, served_n)) => {
                        let stamp = QueueStamp { enqueued: *enq, executed };
                        metrics.record_latency(stamp.queue_latency_s());
                        metrics.responses.fetch_add(1, Ordering::Relaxed);
                        respond(
                            pending,
                            step.id,
                            Ok(AttnResponse {
                                id: step.id,
                                o,
                                served_n,
                                batch_occupancy: occupancy,
                                queued_at: Some(stamp),
                            }),
                        );
                    }
                    Err(e) => respond(pending, step.id, Err(e)),
                }
            }
        }
    }
}

/// Execute a flushed decode lane's steps as batched cross-session
/// launches: the steps are split into *waves* — maximal consecutive
/// runs with pairwise-distinct sessions and one backend target — and
/// each wave appends its token rows, packs its query rows, and runs as
/// ONE [`AttentionBackend::forward_decode_batch_into`] call over all
/// its sessions (fanned across the worker pool, outputs through
/// disjoint per-session windows). A session with several steps queued
/// lands in consecutive waves, preserving its FIFO append→attend
/// order; sessions are temporarily removed from the table for the
/// launch (B disjoint `&mut` sessions out of one map) and reinserted
/// after. Per-session arithmetic is untouched, so results are
/// bit-identical to the old one-step-at-a-time loop. Returns one
/// `(packed (h, d) output row, context length after the append)`
/// result per step, in step order.
///
/// **Crash isolation**: the wave launch runs under a `catch_unwind`
/// barrier. On a caught panic the wave is re-run one session at a
/// time, each under its own barrier — the appends already landed
/// before the first launch and the attend is a pure read of them, so
/// innocent wave-mates compute exactly the bits a solo launch gives
/// (which the batching contract pins equal to the batched bits), while
/// the panicking session is quarantined via [`quarantine_session`].
#[allow(clippy::too_many_arguments)]
fn run_cpu_decode_batch(
    registry: &BackendRegistry,
    ctx: &ExecCtx,
    sessions: &mut Sessions,
    ctl: &mut PagingCtl,
    poisoned: &mut HashMap<u64, String>,
    pending: &mut Pending,
    steps: &[&DecodeStep],
    faults: &FaultPlan,
    metrics: &Metrics,
) -> Vec<Result<(Vec<f32>, usize)>> {
    let now = Instant::now();
    let mut results: Vec<Option<Result<(Vec<f32>, usize)>>> =
        steps.iter().map(|_| None).collect();
    // sessions this call quarantined: answered typed, never reinserted
    let mut to_poison: Vec<(u64, String)> = Vec::new();
    // wave workspace, reused across the batch's waves
    let mut wave: Vec<usize> = Vec::new();
    let mut meta: Vec<(u64, String)> = Vec::new();
    let mut wave_sessions: Vec<DecodeSession> = Vec::new();
    let mut q: Vec<f32> = Vec::new();
    let mut o: Vec<f32> = Vec::new();
    let mut i = 0;
    while i < steps.len() {
        wave.clear();
        meta.clear();
        wave_sessions.clear();
        q.clear();
        while i < steps.len() {
            let step = steps[i];
            // a session already riding this wave ends it — its next step
            // belongs to the following wave (the session is out of the
            // table right now, so this check must precede the lookup or
            // a pipelined second step reads as "freed")
            if meta.iter().any(|(id, _)| *id == step.session) {
                break;
            }
            // the execution gate's deadline check: a step can expire
            // between flush and launch; shed it before it appends
            if step.deadline.is_some_and(|dl| now >= dl) {
                metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                results[i] = Some(Err(ServeError::DeadlineExceeded { id: step.id }.into()));
                i += 1;
                continue;
            }
            // quarantined earlier in this very batch (or a prior one):
            // answer typed, never "was freed"
            if poisoned.contains_key(&step.session) || to_poison.iter().any(|(p, _)| *p == step.session) {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                results[i] =
                    Some(Err(ServeError::SessionPoisoned { session: step.session }.into()));
                i += 1;
                continue;
            }
            let Some((target, _)) = sessions.get(&step.session) else {
                // freed mid-queue: answer inline (nothing to mutate)
                results[i] =
                    Some(Err(anyhow!("decode session {} was freed", step.session)));
                i += 1;
                continue;
            };
            if !wave.is_empty() && meta[0].1 != *target {
                break; // wave boundary: new backend target
            }
            // pull the session out of the table for the launch (B
            // disjoint &mut sessions out of one map); the step's token
            // rows are appended only once the wave's backend resolves,
            // so a failed wave leaves every cache untouched
            let (target, sess) = sessions.remove(&step.session).expect("checked above");
            meta.push((step.session, target));
            wave_sessions.push(sess);
            wave.push(i);
            i += 1;
        }
        if wave.is_empty() {
            continue;
        }
        let target = meta[0].1.clone();
        match registry.get(&target).or_else(|| registry.get("dense")) {
            Some(backend) => {
                for (sess, &slot) in wave_sessions.iter_mut().zip(&wave) {
                    sess.append(&steps[slot].k, &steps[slot].v);
                    // swap log, recorded at EXECUTION (not enqueue):
                    // only appends that actually landed in the cache
                    // are replayed after an eviction
                    if ctl.log_swaps {
                        if let Some(st) = ctl.state.get_mut(&steps[slot].session) {
                            st.log_k.extend_from_slice(&steps[slot].k);
                            st.log_v.extend_from_slice(&steps[slot].v);
                        }
                    }
                    q.extend_from_slice(&steps[slot].q);
                }
                // injected wave stall: latency-only chaos, exercises
                // deadline shedding without touching any arithmetic
                faults.maybe_stall(meta[0].0);
                // the crash barrier: a panicking launch (real or
                // injected) is caught at the wave boundary; the
                // worker thread survives. AssertUnwindSafe is sound
                // here because the appends above are the only durable
                // state change (already complete), the attend only
                // reads the caches, and a scratch slot poisoned by
                // the unwind is rebuilt fresh on next acquire
                // (`ExecCtx::scratch`).
                let launch = catch_unwind(AssertUnwindSafe(|| {
                    for (sid, _) in &meta {
                        faults.maybe_panic(FaultPoint::KernelPanic, *sid, "batched decode launch");
                    }
                    backend.forward_decode_batch_into(ctx, &mut wave_sessions, &q, &mut o);
                }));
                match launch {
                    Ok(()) => {
                        metrics.decode_batches.fetch_add(1, Ordering::Relaxed);
                        let mut off = 0;
                        for (sess, &slot) in wave_sessions.iter().zip(&wave) {
                            let e = sess.h() * sess.d();
                            // the response row is handed to the client, so it is
                            // a fresh Vec; the launch's working buffers are the
                            // sessions' persistent scratch
                            results[slot] = Some(Ok((o[off..off + e].to_vec(), sess.len())));
                            off += e;
                            metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
                            metrics
                                .decode_payload_bytes
                                .fetch_add(steps[slot].payload_bytes(), Ordering::Relaxed);
                        }
                    }
                    Err(_) => {
                        metrics.panics_caught.fetch_add(1, Ordering::Relaxed);
                        // blame attribution: re-run each wave slot as
                        // its own single-session launch under its own
                        // barrier. The appends already landed above
                        // and a batched attend is bit-identical to
                        // the same attends one session at a time (the
                        // batching contract), so innocent sessions
                        // get exactly the bits they would have gotten
                        // had the faulty session never shared their
                        // wave — and the panicker is identified, not
                        // guessed.
                        for (idx, &slot) in wave.iter().enumerate() {
                            let sid = meta[idx].0;
                            let mut solo_o = Vec::new();
                            let sess = &mut wave_sessions[idx];
                            let solo = catch_unwind(AssertUnwindSafe(|| {
                                faults.maybe_panic(
                                    FaultPoint::KernelPanic,
                                    sid,
                                    "isolated decode launch",
                                );
                                backend.forward_decode_batch_into(
                                    ctx,
                                    std::slice::from_mut(sess),
                                    &steps[slot].q,
                                    &mut solo_o,
                                );
                            }));
                            match solo {
                                Ok(()) => {
                                    results[slot] =
                                        Some(Ok((solo_o, wave_sessions[idx].len())));
                                    metrics.decode_steps.fetch_add(1, Ordering::Relaxed);
                                    metrics.decode_payload_bytes.fetch_add(
                                        steps[slot].payload_bytes(),
                                        Ordering::Relaxed,
                                    );
                                }
                                Err(payload) => {
                                    metrics.panics_caught.fetch_add(1, Ordering::Relaxed);
                                    let detail = ServeError::panic_detail(payload.as_ref());
                                    results[slot] = Some(Err(ServeError::KernelPanic {
                                        session: Some(sid),
                                        detail: detail.clone(),
                                    }
                                    .into()));
                                    to_poison.push((sid, detail));
                                }
                            }
                        }
                    }
                }
            }
            None => {
                for &slot in &wave {
                    results[slot] = Some(Err(anyhow!(
                        "no backend available for decode target {target}"
                    )));
                }
            }
        }
        // return the stepped sessions to the table under their ids,
        // refreshing their LRU residency (they just grew and were
        // touched; a session with queued steps is preemption-protected,
        // so every wave session is guaranteed resident). A session
        // this wave quarantined is NOT reinserted — dropping its cache
        // here returns its pages to the pool
        for ((id, target), sess) in meta.drain(..).zip(wave_sessions.drain(..)) {
            if to_poison.iter().any(|(p, _)| *p == id) {
                drop(sess);
                continue;
            }
            ctl.scheduler.note_resident(id, sess.total_pages());
            sessions.insert(id, (target, sess));
        }
    }
    // quarantine bookkeeping for every session that panicked above:
    // parked work answered typed, id remembered as poisoned
    for (sid, detail) in to_poison {
        quarantine_session(sid, detail, ctl, pending, poisoned, metrics);
    }
    // every step handed to this function leaves the batcher here —
    // executed, failed, or freed-mid-queue — so its queued_steps
    // protection ends now (freed sessions have no state entry: no-op)
    for step in steps {
        if let Some(st) = ctl.state.get_mut(&step.session) {
            st.queued_steps = st.queued_steps.saturating_sub(1);
        }
    }
    results.into_iter().map(|r| r.expect("every decode step resolved")).collect()
}

/// [`run_cpu_request`] behind the crash barrier: the launch runs under
/// `catch_unwind`, so a panicking kernel (or an injected
/// `kernel_panic` fault keyed by the request id) fails this one
/// request with a typed [`ServeError::KernelPanic`] instead of
/// killing the worker — and, on the fan-out path, the whole wave. A
/// panic can poison the lane's scratch-slot mutex; `ExecCtx::scratch`
/// rebuilds a poisoned slot fresh, so the next request on the lane
/// starts from a clean (if cold) arena. Expired deadlines are shed
/// here too — the last gate before compute.
#[allow(clippy::too_many_arguments)]
fn run_cpu_request_isolated(
    registry: &BackendRegistry,
    serve_plan: &Option<RoutePlan>,
    params: &ServeParams,
    ctx: &ExecCtx,
    routed: &str,
    req: &AttnRequest,
    faults: &FaultPlan,
    metrics: &Metrics,
) -> Result<(Vec<f32>, u32)> {
    if req.deadline.is_some_and(|dl| Instant::now() >= dl) {
        metrics.deadline_sheds.fetch_add(1, Ordering::Relaxed);
        return Err(ServeError::DeadlineExceeded { id: req.id }.into());
    }
    match catch_unwind(AssertUnwindSafe(|| {
        faults.maybe_panic(FaultPoint::KernelPanic, req.id, "prefill kernel launch");
        run_cpu_request(registry, serve_plan, params, ctx, routed, req)
    })) {
        Ok(r) => r,
        Err(payload) => {
            metrics.panics_caught.fetch_add(1, Ordering::Relaxed);
            Err(ServeError::KernelPanic {
                session: None,
                detail: ServeError::panic_detail(payload.as_ref()),
            }
            .into())
        }
    }
}

/// Pick the backend for one request and execute it under its routing
/// plan: per-request plan if the request carries one, the server's
/// configured plan otherwise (uniform `ServeParams` geometry when no
/// plan file is loaded). The router's chosen target (`routed`, the
/// batch's lane name) serves when its supported-config predicate
/// accepts the geometry; the exact dense backend otherwise. Returns
/// the packed output plus the number of heads the runtime margin probe
/// degraded to dense.
fn run_cpu_request(
    registry: &BackendRegistry,
    serve_plan: &Option<RoutePlan>,
    params: &ServeParams,
    ctx: &ExecCtx,
    routed: &str,
    req: &AttnRequest,
) -> Result<(Vec<f32>, u32)> {
    let dense = registry
        .get("dense")
        .ok_or_else(|| anyhow!("no dense backend registered"))?;
    let mut o = Vec::new();
    if req.kind == AttnKind::Moba {
        let mut plan = match &req.plan {
            Some(p) => p.clone(),
            None => effective_plan(serve_plan, params, req.h_kv),
        };
        // a per-request plan without its own probe threshold inherits
        // the server's (effective_plan already did this for the rest)
        if !plan.fallback_enabled() && params.fallback_margin > f64::NEG_INFINITY {
            plan.fallback_margin = params.fallback_margin as f32;
        }
        // a client-supplied plan that doesn't fit the request is a
        // client error: reject it loudly. Through the coordinator queue
        // `AttnRequest::validate` already rejects this at enqueue, so
        // here it is defense-in-depth for direct callers of this
        // function. A *serve-time* plan that doesn't cover this
        // request's layout still takes the dense fallback below — that
        // mismatch is server configuration, not a bad request.
        if let Some(p) = &req.plan {
            if p.h_kv() != req.h_kv {
                return Err(anyhow!(
                    "request {}: per-request route plan covers {} KV heads, \
                     request has {}",
                    req.id,
                    p.h_kv(),
                    req.h_kv
                ));
            }
            if let Err(e) = p.validate(req.n) {
                return Err(anyhow!(
                    "request {}: invalid per-request route plan: {e}",
                    req.id
                ));
            }
        }
        let plan_ok = plan.h_kv() == req.h_kv && plan.validate(req.n).is_ok();
        // the representative shape (the supported-config probe and the
        // stats stamp): the uniform geometry when the plan is uniform,
        // head 0's otherwise — per-head sub-launches use their own
        // head's geometry regardless
        let (block, topk) = match plan.is_uniform() {
            Some(bt) => bt,
            None => {
                let hp = plan.head(0);
                (hp.block, hp.topk.max(1))
            }
        };
        if plan_ok {
            if let Some(shape) = AttnShape::try_new(req.h, req.h_kv, req.n, req.d, block, topk) {
                let b = registry.get(routed).unwrap_or(dense);
                if b.supports(&shape) {
                    // the output Vec becomes the response payload
                    // (ownership moves to the client); kernel
                    // intermediates come from ctx's scratch arenas via
                    // the steady-state forward_plan_into path
                    let st =
                        b.forward_plan_into(ctx, &shape, &plan, &req.q, &req.k, &req.v, &mut o);
                    return Ok((o, st.fallback_heads));
                }
            }
        }
    }
    // dense requests, unroutable geometries, and plans that don't cover
    // this request's layout all take the exact dense path
    dense.forward_into(ctx, &dense_shape(req), &req.q, &req.k, &req.v, &mut o);
    Ok((o, 0))
}

/// A single-block geometry valid for any n; exact backends ignore the
/// routing fields.
fn dense_shape(req: &AttnRequest) -> AttnShape {
    AttnShape { h: req.h, h_kv: req.h_kv, n: req.n, d: req.d, block: req.n, topk: 0 }
}

/// Pack single-head requests into the (H, N, d) kernel, execute,
/// unpack, respond. Decode steps cannot reach this path (sessions are
/// rejected at creation on PJRT), but are answered with an error
/// defensively.
fn run_batch_pjrt(
    runtime: &Runtime,
    router: &Router,
    batch: Batch,
    pending: &mut Pending,
    metrics: &Metrics,
) {
    let h = router.heads;
    let d = router.head_dim;
    let n = batch.kernel_n;
    let mut reqs: Vec<(&AttnRequest, Instant)> = Vec::with_capacity(batch.items.len());
    for (item, enq) in &batch.items {
        match item {
            WorkItem::Prefill(r) => reqs.push((r, *enq)),
            WorkItem::Decode(s) => respond(
                pending,
                s.id,
                Err(anyhow!("decode is not served by the PJRT path")),
            ),
        }
    }
    let occupancy = reqs.len();
    if occupancy == 0 {
        return;
    }
    debug_assert!(occupancy <= h);

    let exec = || -> Result<Vec<Tensor>> {
        let exe = runtime.get(&batch.artifact)?;
        let mut q = vec![0.0f32; h * n * d];
        let mut k = vec![0.0f32; h * n * d];
        let mut v = vec![0.0f32; h * n * d];
        for (slot, (req, _)) in reqs.iter().enumerate() {
            let e = req.n * d;
            q[slot * n * d..slot * n * d + e].copy_from_slice(&req.q);
            k[slot * n * d..slot * n * d + e].copy_from_slice(&req.k);
            v[slot * n * d..slot * n * d + e].copy_from_slice(&req.v);
        }
        let shape = [h, n, d];
        exe.run(&[
            Tensor::f32(q, &shape)?,
            Tensor::f32(k, &shape)?,
            Tensor::f32(v, &shape)?,
        ])
    };

    match exec() {
        Ok(outs) => {
            let executed = Instant::now();
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics.batched_requests.fetch_add(occupancy as u64, Ordering::Relaxed);
            let o = outs.into_iter().next().and_then(|t| t.into_f32().ok());
            match o {
                Some(o) => {
                    for (slot, (req, enq)) in reqs.iter().enumerate() {
                        let e = req.n * d;
                        let out = o[slot * n * d..slot * n * d + e].to_vec();
                        let stamp = QueueStamp { enqueued: *enq, executed };
                        metrics.record_latency(stamp.queue_latency_s());
                        metrics.responses.fetch_add(1, Ordering::Relaxed);
                        respond(
                            pending,
                            req.id,
                            Ok(AttnResponse {
                                id: req.id,
                                o: out,
                                served_n: n,
                                batch_occupancy: occupancy,
                                queued_at: Some(stamp),
                            }),
                        );
                    }
                }
                None => {
                    for (req, _) in &reqs {
                        respond(pending, req.id, Err(anyhow!("bad kernel output")));
                    }
                }
            }
        }
        Err(e) => {
            for (req, _) in &reqs {
                respond(pending, req.id, Err(anyhow!("execution failed: {e}")));
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // test assertions on known-Some/Ok values
mod tests {
    use super::*;
    use crate::attention::plan::HeadPlan;
    use crate::attention::testutil::qkv_packed;

    fn moba_req(
        id: u64,
        h: usize,
        h_kv: usize,
        n: usize,
        d: usize,
        plan: Option<RoutePlan>,
    ) -> AttnRequest {
        let (q, k, v) = qkv_packed(0xC0FFEE ^ id, h, h_kv, n, d);
        AttnRequest { id, kind: AttnKind::Moba, h, h_kv, n, d, q, k, v, plan, deadline: None }
    }

    /// An injected kernel panic is caught at the launch barrier: the
    /// faulted request gets a typed `KernelPanic`, the thread (and its
    /// scratch arenas) survive, and the next request on the SAME
    /// context serves bits identical to a context that never saw a
    /// panic — the chaos-parity contract in miniature.
    #[test]
    fn isolated_prefill_catches_injected_panics_and_recovers() {
        let registry = BackendRegistry::with_defaults();
        let params = ServeParams::default();
        let ctx = ExecCtx::serial();
        let metrics = Metrics::new();
        let faults = FaultPlan::parse("7:kernel_panic@5").unwrap();

        let req = moba_req(5, 2, 2, 64, 8, None);
        let err = run_cpu_request_isolated(
            &registry, &None, &params, &ctx, "flash_moba", &req, &faults, &metrics,
        )
        .expect_err("injected panic must surface as an error");
        match ServeError::of(&err) {
            Some(ServeError::KernelPanic { session: None, detail }) => {
                assert!(detail.contains("injected fault"), "{detail}");
            }
            other => panic!("wrong error class: {other:?}"),
        }
        assert_eq!(metrics.panics_caught.load(Ordering::Relaxed), 1);

        // a non-targeted request on the same ctx still serves (any
        // scratch slot poisoned by the unwind was rebuilt fresh) ...
        let req = moba_req(6, 2, 2, 64, 8, None);
        let (o, _) = run_cpu_request_isolated(
            &registry, &None, &params, &ctx, "flash_moba", &req, &faults, &metrics,
        )
        .expect("sibling request serves after the caught panic");
        assert_eq!(o.len(), 2 * 64 * 8);
        // ... bit-identical to a context that never saw the panic
        let ctx2 = ExecCtx::serial();
        let (o2, _) =
            run_cpu_request(&registry, &None, &params, &ctx2, "flash_moba", &req).unwrap();
        assert!(
            o.iter().zip(&o2).all(|(a, b)| a.to_bits() == b.to_bits()),
            "post-panic output diverged from the fault-free run"
        );
    }

    /// Expired deadlines are shed at the execution gate with a typed
    /// error, before any compute.
    #[test]
    fn expired_prefill_is_shed_at_the_execution_gate() {
        let registry = BackendRegistry::with_defaults();
        let params = ServeParams::default();
        let ctx = ExecCtx::serial();
        let metrics = Metrics::new();
        let faults = FaultPlan::disabled();
        let mut req = moba_req(9, 2, 2, 64, 8, None);
        req.deadline = Some(Instant::now() - Duration::from_millis(1));
        let err = run_cpu_request_isolated(
            &registry, &None, &params, &ctx, "flash_moba", &req, &faults, &metrics,
        )
        .expect_err("expired work must shed");
        assert!(matches!(
            ServeError::of(&err),
            Some(ServeError::DeadlineExceeded { id: 9 })
        ));
        assert_eq!(metrics.deadline_sheds.load(Ordering::Relaxed), 1);
    }

    /// A client-supplied plan that doesn't fit its request is a loud
    /// error, not a silent dense serve (the old fall-through); a
    /// serve-time plan mismatch still degrades to dense silently —
    /// that's server configuration, not a bad request.
    #[test]
    fn per_request_plan_rejection_vs_serve_plan_fallback() {
        let registry = BackendRegistry::with_defaults();
        let params = ServeParams::default();
        let ctx = ExecCtx::serial();

        // wrong KV-head coverage: plan spans 3 heads, request has 2
        let req = moba_req(1, 2, 2, 64, 8, Some(RoutePlan::uniform(3, 16, 2)));
        let err = run_cpu_request(&registry, &None, &params, &ctx, "flash_moba", &req)
            .expect_err("mismatched plan coverage must error");
        assert!(
            err.to_string().contains("per-request route plan covers"),
            "unexpected error text: {err}"
        );

        // a plan block larger than the request's context is degenerate
        let req = moba_req(2, 2, 2, 64, 8, Some(RoutePlan::uniform(2, 128, 2)));
        let err = run_cpu_request(&registry, &None, &params, &ctx, "flash_moba", &req)
            .expect_err("oversized plan block must error");
        assert!(
            err.to_string().contains("invalid per-request route plan"),
            "unexpected error text: {err}"
        );

        // a valid per-request plan serves, bit-identical to the same
        // plan installed server-side
        let plan = RoutePlan {
            heads: vec![HeadPlan::routed(16, 2), HeadPlan::dense(32)],
            fallback_margin: f32::NEG_INFINITY,
            kv_dtype: None,
        };
        let req = moba_req(3, 2, 2, 64, 8, Some(plan.clone()));
        let (o, _) = run_cpu_request(&registry, &None, &params, &ctx, "flash_moba", &req)
            .expect("valid per-request plan serves");
        assert_eq!(o.len(), 2 * 64 * 8);
        let bare = AttnRequest { plan: None, ..req };
        let (o_serve, _) =
            run_cpu_request(&registry, &Some(plan), &params, &ctx, "flash_moba", &bare)
                .expect("serve-time plan serves");
        assert!(
            o.iter().zip(&o_serve).all(|(a, b)| a.to_bits() == b.to_bits()),
            "per-request plan diverged from the same plan served server-side"
        );

        // serve-time plan covering the wrong layout: silent exact-dense
        let bare = moba_req(4, 2, 2, 64, 8, None);
        let serve_plan = Some(RoutePlan::uniform(3, 16, 2));
        let (o, fallback) =
            run_cpu_request(&registry, &serve_plan, &params, &ctx, "flash_moba", &bare)
                .expect("serve-plan mismatch still serves densely");
        assert_eq!(fallback, 0);
        let mut dense_o = Vec::new();
        registry.get("dense").unwrap().forward_into(
            &ctx,
            &dense_shape(&bare),
            &bare.q,
            &bare.k,
            &bare.v,
            &mut dense_o,
        );
        assert!(
            o.iter().zip(&dense_o).all(|(a, b)| a.to_bits() == b.to_bits()),
            "serve-plan mismatch did not take the exact dense path"
        );
    }
}
